//! Integration of the GAE service fabric: shard failover under live
//! multi-threaded load (every submitted request completes, rerouted,
//! bit-identical to the scalar reference), client-pool seq-space
//! isolation over real sockets, a mixed in-process/remote fleet
//! surviving a remote endpoint death, and the multi-replica coordinator
//! mode feeding one fabric.

use heppo::coordinator::pipeline::{run_stage_fleet, run_stages, PipelineMode};
use heppo::coordinator::GaeBackend;
use heppo::fabric::{
    ClientPool, FabricConfig, GaeFabric, PoolConfig, ShardBackend,
};
use heppo::gae::reference::gae_trajectory;
use heppo::gae::{GaeParams, Trajectory};
use heppo::net::{NetServer, NetServerConfig, PlaneCodec};
use heppo::quant::CodecKind;
use heppo::service::{BatcherConfig, GaeService, ServiceConfig};
use heppo::testing::{digest_f32, Gen};
use heppo::util::Rng;
use std::sync::Arc;
use std::time::Duration;

fn scalar_service(workers: usize) -> Arc<GaeService> {
    Arc::new(
        GaeService::start(ServiceConfig {
            workers,
            backend: GaeBackend::Scalar,
            queue_capacity: 1024,
            batcher: BatcherConfig {
                max_batch_lanes: 64,
                tile_lanes: 16,
                max_wait: Duration::from_micros(100),
            },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    )
}

fn in_process_fabric(shards: usize) -> (GaeFabric, Vec<Arc<GaeService>>) {
    let services: Vec<Arc<GaeService>> = (0..shards).map(|_| scalar_service(1)).collect();
    let slots = services
        .iter()
        .enumerate()
        .map(|(i, s)| (format!("shard-{i}"), ShardBackend::in_process(Arc::clone(s))))
        .collect();
    (GaeFabric::new(slots, FabricConfig::default()).unwrap(), services)
}

/// Deterministic planes for `(stream, index)` — distinct across
/// streams, reproducible for the reference computation.
fn planes_for(
    stream: u64,
    index: u64,
    t_len: usize,
    batch: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xfab0 + stream * 7919 + index);
    let mut rewards = vec![0.0f32; t_len * batch];
    let mut values = vec![0.0f32; (t_len + 1) * batch];
    rng.fill_normal_f32(&mut rewards);
    rng.fill_normal_f32(&mut values);
    let done_mask = (0..t_len * batch)
        .map(|_| if rng.uniform() < 0.05 { 1.0 } else { 0.0 })
        .collect();
    (rewards, values, done_mask)
}

/// The scalar reference, column by column, timestep-major planes out.
fn reference(
    t_len: usize,
    batch: usize,
    rewards: &[f32],
    values: &[f32],
    done_mask: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut adv = vec![0.0f32; t_len * batch];
    let mut rtg = vec![0.0f32; t_len * batch];
    for col in 0..batch {
        let traj = Trajectory::new(
            (0..t_len).map(|t| rewards[t * batch + col]).collect(),
            (0..=t_len).map(|t| values[t * batch + col]).collect(),
            (0..t_len).map(|t| done_mask[t * batch + col] == 1.0).collect(),
        );
        let want = gae_trajectory(&GaeParams::default(), &traj);
        for t in 0..t_len {
            adv[t * batch + col] = want.advantages[t];
            rtg[t * batch + col] = want.rewards_to_go[t];
        }
    }
    (adv, rtg)
}

fn assert_planes_eq(got_adv: &[f32], got_rtg: &[f32], want: &(Vec<f32>, Vec<f32>), what: &str) {
    assert_eq!(got_adv.len(), want.0.len(), "{what}: shape");
    for (i, (a, b)) in got_adv.iter().zip(&want.0).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: adv[{i}]");
    }
    for (i, (a, b)) in got_rtg.iter().zip(&want.1).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: rtg[{i}]");
    }
}

#[test]
fn killing_a_shard_mid_load_loses_nothing_and_stays_bit_identical() {
    let (fabric, services) = in_process_fabric(3);
    let (t_len, batch) = (24, 4);
    let threads = 6u64;
    let per_thread = 15u64;

    // Concurrent load; one shard dies while all streams are in flight.
    std::thread::scope(|s| {
        for stream in 0..threads {
            let fabric = fabric.clone();
            s.spawn(move || {
                let mut window = std::collections::VecDeque::new();
                let check = |(index, pending): (u64, heppo::fabric::FabricPending)| {
                    let gae = pending.wait().unwrap_or_else(|e| {
                        panic!("stream {stream} req {index} lost: {e}")
                    });
                    let (rewards, values, done_mask) =
                        planes_for(stream, index, t_len, batch);
                    let want = reference(t_len, batch, &rewards, &values, &done_mask);
                    assert_planes_eq(
                        &gae.advantages,
                        &gae.rewards_to_go,
                        &want,
                        &format!("stream {stream} req {index}"),
                    );
                };
                for index in 0..per_thread {
                    let (rewards, values, done_mask) =
                        planes_for(stream, index, t_len, batch);
                    let key = (stream << 32) | index;
                    let pending = fabric
                        .submit("load", key, t_len, batch, rewards, values, done_mask)
                        .unwrap_or_else(|e| {
                            panic!("stream {stream} submit {index}: {e}")
                        });
                    window.push_back((index, pending));
                    while window.len() >= 4 {
                        check(window.pop_front().unwrap());
                    }
                }
                while let Some(pair) = window.pop_front() {
                    check(pair);
                }
            });
        }
        // Kill one shard while the six streams run. Even if the timing
        // lands late, the deterministic spill below still forces a
        // failover through the dead shard.
        std::thread::sleep(Duration::from_millis(2));
        services[1].begin_shutdown();
    });

    // Deterministic forced spill: a key whose primary is the dead shard
    // must complete on a survivor, bit-identically.
    let key = (0..1024u64)
        .find(|&k| fabric.rank("load", k)[0] == 1)
        .expect("some key must rank shard 1 first");
    let (rewards, values, done_mask) = planes_for(99, 0, t_len, batch);
    let want = reference(t_len, batch, &rewards, &values, &done_mask);
    let gae = fabric
        .call("load", key, t_len, batch, rewards, values, done_mask)
        .expect("forced spill must complete");
    assert_ne!(gae.shard, 1, "dead shard cannot serve");
    assert!(gae.failovers >= 1 || !fabric.is_healthy(1));
    assert_planes_eq(&gae.advantages, &gae.rewards_to_go, &want, "forced spill");

    let fleet = fabric.fleet();
    assert_eq!(
        fleet.completed,
        threads * per_thread + 1,
        "every submitted request must complete: {fleet}"
    );
    assert!(!fabric.is_healthy(1));
    assert!(fleet.healthy_shards >= 2);
    // The tenant breakdown made it through the in-process shards.
    let load = fleet.tenants.iter().find(|t| t.tenant == "load").unwrap();
    assert_eq!(load.requests, threads * per_thread + 1);
}

#[test]
fn pool_submitters_share_sockets_without_crossing_seq_spaces() {
    let svc = scalar_service(2);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let pool = ClientPool::connect(
        &server.local_addr().to_string(),
        // f32 both ways so results are bit-exact against the reference.
        PoolConfig {
            sockets: 2,
            codec: PlaneCodec::F32,
            resp: PlaneCodec::F32,
            auth: None,
        },
    )
    .unwrap();

    let submitters = 6u64;
    let frames = 10u64;
    let (t_len, batch) = (12, 2);
    std::thread::scope(|s| {
        for sub in 0..submitters {
            let submitter = pool.submitter(&format!("sub-{sub}"));
            s.spawn(move || {
                // Pipeline 5 in flight, complete out of order; every
                // completion must carry *this* submitter's payload
                // result — a crossed seq space would mismatch.
                let mut window = std::collections::VecDeque::new();
                let check = |(index, pending): (u64, heppo::fabric::PoolPending)| {
                    let gae = pending.wait().unwrap_or_else(|e| {
                        panic!("submitter {sub} frame {index}: {e}")
                    });
                    let (rewards, values, done_mask) =
                        planes_for(1000 + sub, index, t_len, batch);
                    let want = reference(t_len, batch, &rewards, &values, &done_mask);
                    assert_planes_eq(
                        &gae.advantages,
                        &gae.rewards_to_go,
                        &want,
                        &format!("submitter {sub} frame {index}"),
                    );
                };
                for index in 0..frames {
                    let (rewards, values, done_mask) =
                        planes_for(1000 + sub, index, t_len, batch);
                    let pending = submitter
                        .submit_planes(t_len, batch, &rewards, &values, &done_mask)
                        .unwrap();
                    // The wire seq must sit inside this submitter's space.
                    assert_eq!(
                        heppo::fabric::submitter_of(pending.seq()),
                        Some(submitter.id()),
                    );
                    window.push_back((index, pending));
                    while window.len() >= 5 {
                        check(window.pop_front().unwrap());
                    }
                }
                while let Some(pair) = window.pop_front() {
                    check(pair);
                }
            });
        }
    });
    assert_eq!(pool.wire_stats().frames, submitters * frames);
    // Every frame becomes one service request per env column.
    assert_eq!(svc.metrics().completed, submitters * frames * batch as u64);
    server.shutdown();
}

#[test]
fn pool_reports_dead_endpoint_promptly_instead_of_hanging() {
    let svc = scalar_service(1);
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", NetServerConfig::default())
            .unwrap();
    let pool = ClientPool::connect(
        &server.local_addr().to_string(),
        PoolConfig { sockets: 1, ..PoolConfig::default() },
    )
    .unwrap();
    let submitter = pool.submitter("t");
    let (rewards, values, done_mask) = planes_for(0, 0, 8, 2);
    submitter.call_planes(8, 2, &rewards, &values, &done_mask).unwrap();
    server.shutdown();
    // Every subsequent attempt fails promptly — at the write, at the
    // re-dial, or as a dead in-flight frame — never hangs.
    for _ in 0..3 {
        if let Ok(pending) = submitter.submit_planes(8, 2, &rewards, &values, &done_mask)
        {
            assert!(pending.wait().is_err());
        }
    }
}

#[test]
fn mixed_fleet_survives_a_remote_endpoint_death_with_frames_in_flight() {
    let remote_svc = scalar_service(1);
    let server = NetServer::start(
        Arc::clone(&remote_svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let local_svc = scalar_service(1);
    let fabric = GaeFabric::new(
        vec![
            (
                "remote-0".to_string(),
                ShardBackend::remote(
                    &server.local_addr().to_string(),
                    PoolConfig {
                        sockets: 1,
                        codec: PlaneCodec::F32,
                        resp: PlaneCodec::F32,
                        auth: None,
                    },
                )
                .unwrap(),
            ),
            ("local-0".to_string(), ShardBackend::in_process(Arc::clone(&local_svc))),
        ],
        FabricConfig {
            cooldown: Duration::from_millis(50),
            max_attempts: 8,
            request_timeout: None,
        },
    )
    .unwrap();
    let (t_len, batch) = (16, 3);

    // Phase A: both shards healthy; everything bit-identical.
    for index in 0..10u64 {
        let (rewards, values, done_mask) = planes_for(7, index, t_len, batch);
        let want = reference(t_len, batch, &rewards, &values, &done_mask);
        let gae = fabric
            .call("mixed", index, t_len, batch, rewards, values, done_mask)
            .unwrap();
        assert_planes_eq(&gae.advantages, &gae.rewards_to_go, &want, "phase A");
    }

    // Phase B: submit a window, then kill the remote endpoint with
    // frames potentially in flight on it. Every request must still
    // complete (retried onto the in-process shard) bit-identically.
    let mut pending = Vec::new();
    for index in 100..108u64 {
        let (rewards, values, done_mask) = planes_for(7, index, t_len, batch);
        pending.push((
            index,
            fabric
                .submit("mixed", index, t_len, batch, rewards, values, done_mask)
                .unwrap(),
        ));
    }
    server.shutdown();
    for (index, p) in pending {
        let gae = p.wait().unwrap_or_else(|e| panic!("req {index} lost: {e}"));
        let (rewards, values, done_mask) = planes_for(7, index, t_len, batch);
        let want = reference(t_len, batch, &rewards, &values, &done_mask);
        assert_planes_eq(
            &gae.advantages,
            &gae.rewards_to_go,
            &want,
            &format!("phase B req {index}"),
        );
    }

    // Phase C: with the endpoint gone, new load still completes on the
    // surviving shard.
    for index in 200..206u64 {
        let (rewards, values, done_mask) = planes_for(7, index, t_len, batch);
        let want = reference(t_len, batch, &rewards, &values, &done_mask);
        let gae = fabric
            .call("mixed", index, t_len, batch, rewards, values, done_mask)
            .unwrap_or_else(|e| panic!("phase C req {index}: {e}"));
        assert_eq!(gae.shard, 1, "only the in-process shard survives");
        assert_planes_eq(&gae.advantages, &gae.rewards_to_go, &want, "phase C");
    }
    let fleet = fabric.fleet();
    assert_eq!(fleet.completed, 24, "{fleet}");
}

#[test]
fn coordinator_replicas_feed_one_fabric_with_solo_identical_streams() {
    let (fabric, _services) = in_process_fabric(2);
    let (t_len, batch) = (10, 3);
    let iters = 4;

    // Each replica runs the PR-2 stage driver; its GAE stage submits
    // the rollout planes through the shared fabric.
    let run_replica = |replica: usize| {
        let fabric = fabric.clone();
        run_stages(
            PipelineMode::Sequential,
            iters,
            move |i, buf: &mut heppo::coordinator::rollout::Rollout| {
                let (rewards, values, done_mask) =
                    planes_for(replica as u64, i as u64, t_len, batch);
                buf.t_len = t_len;
                buf.batch = batch;
                buf.rewards = rewards;
                buf.values = values;
                buf.done_mask = done_mask;
                Ok(())
            },
            move |i, buf| {
                let key = ((replica as u64) << 32) | i as u64;
                let gae = fabric
                    .call(
                        &format!("replica-{replica}"),
                        key,
                        buf.t_len,
                        buf.batch,
                        buf.rewards.clone(),
                        buf.values.clone(),
                        buf.done_mask.clone(),
                    )
                    .map_err(|e| anyhow::anyhow!("fabric gae: {e}"))?;
                Ok(heppo::coordinator::gae_stage::GaeResult {
                    advantages: gae.advantages,
                    rewards_to_go: gae.rewards_to_go,
                    hw_cycles: gae.hw_cycles,
                })
            },
            |_i, _buf, g| Ok(digest_f32(&g.advantages) ^ digest_f32(&g.rewards_to_go)),
        )
    };

    let fleet_run = run_stage_fleet(3, run_replica).unwrap();
    assert_eq!(fleet_run.replicas.len(), 3);
    assert_eq!(fleet_run.total_iters(), 3 * iters);

    // Every replica's stream equals the scalar-reference digest stream:
    // the fabric changed where GAE ran, not what it computed.
    for (replica, run) in fleet_run.replicas.iter().enumerate() {
        let want: Vec<u64> = (0..iters)
            .map(|i| {
                let (rewards, values, done_mask) =
                    planes_for(replica as u64, i as u64, t_len, batch);
                let (adv, rtg) = reference(t_len, batch, &rewards, &values, &done_mask);
                digest_f32(&adv) ^ digest_f32(&rtg)
            })
            .collect();
        assert_eq!(run.stats, want, "replica {replica}");
    }

    let fleet = fabric.fleet();
    assert_eq!(fleet.completed, 3 * iters as u64);
    assert_eq!(fleet.tenants.len(), 3, "one tenant per replica: {fleet}");
}

#[test]
fn quantized_replies_roundtrip_through_pool_with_bounded_error() {
    // The resp-codec satellite, end to end through the pool: quantized
    // replies come back lossy-but-close; the same planes through the
    // f32 default stay bit-exact.
    let svc = scalar_service(2);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let q_pool = ClientPool::connect(
        &addr,
        PoolConfig {
            sockets: 1,
            codec: PlaneCodec::F32,
            resp: PlaneCodec { kind: CodecKind::Exp5DynamicBlock, bits: 8 },
            auth: None,
        },
    )
    .unwrap();
    let f_pool = ClientPool::connect(
        &addr,
        PoolConfig { sockets: 1, codec: PlaneCodec::F32, resp: PlaneCodec::F32, auth: None },
    )
    .unwrap();

    let mut g = Gen::new(41);
    let (t_len, batch) = (30, 4);
    let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
    let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
    let done_mask: Vec<f32> = (0..t_len * batch)
        .map(|_| if g.bool_p(0.05) { 1.0 } else { 0.0 })
        .collect();
    let want = reference(t_len, batch, &rewards, &values, &done_mask);

    let exact = f_pool
        .submitter("exact")
        .call_planes(t_len, batch, &rewards, &values, &done_mask)
        .unwrap();
    assert!(!exact.quantized);
    assert_planes_eq(&exact.advantages, &exact.rewards_to_go, &want, "f32 replies");

    let lossy = q_pool
        .submitter("lossy")
        .call_planes(t_len, batch, &rewards, &values, &done_mask)
        .unwrap();
    assert!(lossy.quantized, "server must honor the requested reply codec");
    // 8-bit quantization: bounded by the quantizer's in-range step over
    // each plane's own (μ, σ) — the same bound the wire tests use.
    let q = heppo::quant::UniformQuantizer::new(8);
    for (plane, exact_plane) in
        [(&lossy.advantages, &want.0), (&lossy.rewards_to_go, &want.1)]
    {
        let stats = heppo::quant::BlockStats::of(exact_plane);
        let tol = q.max_in_range_error() * stats.std.abs().max(1e-3) + 1e-4;
        for (a, b) in plane.iter().zip(exact_plane.iter()) {
            assert!((a - b).abs() <= tol, "quantized {a} vs {b} (tol {tol})");
        }
    }
    server.shutdown();
}

//! Numerics-plane integration: forced quantizer saturation over real
//! sockets, under both server front-ends.
//!
//! The acceptance scenario (per mode):
//!
//! - **Baseline** — clean quantized traffic trains the lifetime (μ,σ)
//!   baseline; the shard's `NumericsHealth` verdict is `Ok`.
//! - **Forced saturation** — traced requests whose planes hide rare
//!   ±100 spikes among unit-scale noise: the per-plane block σ (~17)
//!   leaves the spikes at z ≈ ±5.7, past the quantizer's ±5σ range, so
//!   ~3% of elements land on end codes. That breaches
//!   [`SATURATION_CRITICAL`] and the verdict flips `Critical` within
//!   one 1s window, visible on the exposition page — with the
//!   offending trace id attached to the windowed saturation rows as an
//!   OpenMetrics exemplar (`reason="saturated"`) that greps straight
//!   into the `GET /traces` Chrome-trace export.
//! - **Recovery** — clean traffic one window later walks the verdict
//!   back to `Ok` without a restart; lifetime clip counters persist.
//!
//! [`SATURATION_CRITICAL`]: heppo::obs::numerics::SATURATION_CRITICAL

#![cfg(target_os = "linux")]

use heppo::coordinator::GaeBackend;
use heppo::net::{wire, NetServer, NetServerConfig, PlaneCodec, ServerMode};
use heppo::obs::numerics::SATURATION_CRITICAL;
use heppo::obs::telemetry::trace_hex;
use heppo::service::{GaeService, ServiceConfig};
use heppo::testing::Gen;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

const TENANT: &str = "numerics";
const T_LEN: usize = 64;
const BATCH: usize = 2;

/// One-shot plaintext scrape over the binary port: `(status_line,
/// body)`. The server answers and closes, so read-to-EOF terminates.
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: heppo\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("response has a blank line");
    let status = head.lines().next().unwrap_or("").to_string();
    (status, body.to_string())
}

/// Value of the first sample whose name matches and whose label set
/// contains every `labels` fragment. Exemplar suffixes (` # {...}`)
/// are stripped before the value parse.
fn metric_value(body: &str, name: &str, labels: &[&str]) -> f64 {
    for line in body.lines() {
        if !line.starts_with(name) || !line[name.len()..].starts_with('{') {
            continue;
        }
        if !labels.iter().all(|l| line.contains(l)) {
            continue;
        }
        let sample = line.split(" # ").next().unwrap();
        let value = sample.rsplit(' ').next().unwrap();
        return value.parse().unwrap_or_else(|_| panic!("unparsable sample: {line}"));
    }
    panic!("no sample {name}{labels:?} in exposition page:\n{body}");
}

/// A well-behaved quantized request: ≈N(0,1) planes standardize to
/// z well inside ±5σ — nothing clips.
fn clean_frame(g: &mut Gen, seq: u64) -> Vec<u8> {
    let rewards = g.vec_normal_f32(T_LEN * BATCH, 0.0, 1.0);
    let values = g.vec_normal_f32((T_LEN + 1) * BATCH, 0.0, 1.0);
    let done_mask = vec![0.0f32; T_LEN * BATCH];
    wire::encode_request(
        seq,
        TENANT,
        PlaneCodec::Q8,
        PlaneCodec::Q8,
        0,
        T_LEN,
        BATCH,
        &rewards,
        &values,
        &done_mask,
    )
    .unwrap()
    .bytes
}

/// The poison pill: every 36th element is a ±100 spike amid unit-scale
/// noise. The plane's own block σ ≈ 17, so the spikes standardize to
/// z ≈ ±5.7 — clipped — at a ~3% rate, past the 2% Critical bar, while
/// the noise elements quantize normally.
fn saturated_frame(seq: u64, trace: u64, seed: u64) -> Vec<u8> {
    let plane = |n: usize| -> Vec<f32> {
        (0..n)
            .map(|i| {
                if i % 36 == 0 {
                    if (i / 36) % 2 == 0 { 100.0 } else { -100.0 }
                } else {
                    (((i as u64 + seed) as f32) * 0.37).sin()
                }
            })
            .collect()
    };
    let rewards = plane(T_LEN * BATCH);
    let values = plane((T_LEN + 1) * BATCH);
    let done_mask = vec![0.0f32; T_LEN * BATCH];
    wire::encode_request(
        seq,
        TENANT,
        PlaneCodec::Q8,
        PlaneCodec::Q8,
        trace,
        T_LEN,
        BATCH,
        &rewards,
        &values,
        &done_mask,
    )
    .unwrap()
    .bytes
}

fn forced_saturation_pages_then_recovers(mode: ServerMode) {
    heppo::obs::set_enabled(true);
    let svc = Arc::new(
        GaeService::start(ServiceConfig {
            backend: GaeBackend::Scalar,
            ..ServiceConfig::default()
        })
        .unwrap(),
    );
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { mode, cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(stream);
    let mut g = Gen::new(41);
    let mut seq = 0u64;
    let send_and_wait = |writer: &mut TcpStream,
                             reader: &mut std::io::BufReader<TcpStream>,
                             frame: Vec<u8>,
                             want_seq: u64| {
        writer.write_all(&frame).unwrap();
        let frame = wire::read_frame(reader).unwrap().expect("response frame");
        match wire::decode_frame(&frame).unwrap() {
            wire::Frame::Response(r) => assert_eq!(r.seq, want_seq),
            other => panic!("expected response, got {other:?}"),
        }
    };

    // Baseline: clean quantized traffic trains the lifetime σ stream
    // (past MIN_BASELINE_PLANES) and the verdict holds Ok.
    for _ in 0..10 {
        seq += 1;
        let f = clean_frame(&mut g, seq);
        send_and_wait(&mut writer, &mut reader, f, seq);
    }
    let (status, page0) = http_get(addr, "/metrics");
    assert!(status.contains("200"), "baseline scrape status: {status}");
    // 10 requests x 2 request planes, plus quantized response planes.
    assert!(metric_value(&page0, "heppo_quant_planes_total", &[]) >= 20.0);
    assert_eq!(
        metric_value(&page0, "heppo_numerics_health", &[]),
        0.0,
        "clean quantized traffic must verdict Ok:\n{page0}"
    );

    // Forced saturation: a burst of traced poison requests, aligned to
    // the server's metrics second (via the uptime gauge) so burst and
    // scrape share one 1s window; a boundary race retries.
    let mut traces: Vec<u64> = Vec::new();
    let mut paged = String::new();
    for attempt in 0..4u64 {
        let (_, probe) = http_get(addr, "/metrics");
        let up = metric_value(&probe, "heppo_uptime_seconds", &[]);
        let frac = up - up.floor();
        if frac > 0.4 {
            std::thread::sleep(Duration::from_secs_f64(1.02 - frac));
        }
        for k in 0..4u64 {
            seq += 1;
            let trace = 0x5a70_0000_0000_0010 + attempt * 16 + k;
            traces.push(trace);
            let f = saturated_frame(seq, trace, attempt * 1000 + k);
            send_and_wait(&mut writer, &mut reader, f, seq);
        }
        let (_, page) = http_get(addr, "/metrics");
        if metric_value(&page, "heppo_numerics_health", &[]) >= 2.0 {
            paged = page;
            break;
        }
    }
    assert!(!paged.is_empty(), "saturated burst never flipped the verdict Critical");

    // The Critical verdict is on the page, shard-wide and for the
    // offending tenant, with the 1s-window saturation past the bar.
    assert!(
        paged.contains("state=\"critical\"} 2"),
        "no critical numerics row:\n{paged}"
    );
    assert!(
        paged.contains(&format!(
            "heppo_tenant_numerics_health{{shard=\"{addr}\",tenant=\"{TENANT}\",state=\"critical\"}} 2"
        )),
        "tenant verdict missing:\n{paged}"
    );
    let win_sat =
        metric_value(&paged, "heppo_quant_window_saturation_rate", &["window=\"1s\""]);
    assert!(
        win_sat >= SATURATION_CRITICAL,
        "1s saturation rate {win_sat} under the Critical bar"
    );

    // Exemplar retention: a poison trace id rides the windowed
    // saturation rows as an OpenMetrics exemplar…
    assert!(paged.contains("reason=\"saturated\""), "no saturation exemplar:\n{paged}");
    assert!(metric_value(&paged, "heppo_quant_saturated_exemplars_total", &[]) >= 1.0);
    let on_page: Vec<String> = traces
        .iter()
        .map(|t| trace_hex(*t))
        .filter(|h| paged.contains(&format!("trace_id=\"{h}\"")))
        .collect();
    assert!(!on_page.is_empty(), "no poison trace id exposed as exemplar:\n{paged}");

    // …and the same hex ids stitch into the Chrome-trace export.
    let (status, chrome) = http_get(addr, "/traces");
    assert!(status.contains("200"), "traces status: {status}");
    assert!(chrome.contains("traceEvents"));
    for hex in &on_page {
        assert!(
            chrome.contains(hex.as_str()),
            "saturation exemplar {hex} missing from the Chrome-trace export"
        );
    }

    // Recovery: clean traffic one window later walks the verdict back
    // to Ok — no restart, and the lifetime clip counters persist.
    let deadline = Instant::now() + Duration::from_secs(30);
    let final_page = loop {
        for _ in 0..8 {
            seq += 1;
            let f = clean_frame(&mut g, seq);
            send_and_wait(&mut writer, &mut reader, f, seq);
        }
        let (_, page) = http_get(addr, "/metrics");
        if metric_value(&page, "heppo_numerics_health", &[]) == 0.0 {
            break page;
        }
        assert!(
            Instant::now() < deadline,
            "verdict never recovered to Ok:\n{page}"
        );
        std::thread::sleep(Duration::from_millis(200));
    };
    assert!(
        metric_value(&final_page, "heppo_quant_clipped_total", &[]) > 0.0,
        "lifetime clip counter must survive recovery"
    );

    server.shutdown();
    svc.begin_shutdown();
}

#[test]
fn threads_mode_saturation_pages_then_recovers() {
    forced_saturation_pages_then_recovers(ServerMode::Threads);
}

#[test]
fn reactor_mode_saturation_pages_then_recovers() {
    forced_saturation_pages_then_recovers(ServerMode::Reactor);
}

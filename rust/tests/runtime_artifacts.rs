//! Integration: the PJRT runtime loads and executes every HLO artifact,
//! and the GAE kernel artifact agrees with the rust reference — the
//! cross-language correctness loop (`make artifacts` must have run).

use heppo::gae::batched::{gae_batched, GaeBatch};
use heppo::gae::reference::gae_trajectory;
use heppo::gae::{GaeParams, Trajectory};
use heppo::runtime::{Runtime, Tensor};
use heppo::util::Rng;

/// Build the runtime, or `None` (skip) when the artifacts or the PJRT
/// native library are absent — this offline build compiles against the
/// xla stub, so these tests only run on a machine with `make artifacts`
/// output and a real `xla_extension`.
fn runtime() -> Option<Runtime> {
    heppo::testing::try_runtime(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    for name in [
        "cartpole_policy_fwd",
        "cartpole_train_step",
        "cartpole_init_params",
        "pendulum_policy_fwd",
        "pendulum_train_step",
        "humanoid_lite_policy_fwd",
        "gae_T128_B16",
        "gae_T1024_B64",
        "quant_block_N2048",
    ] {
        assert!(rt.manifest.get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn policy_fwd_executes_with_correct_shapes() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let spec = rt.manifest.get("cartpole_policy_fwd").unwrap().clone();
    let p = spec.meta_usize("param_count").unwrap();
    let b = spec.meta_usize("batch").unwrap();
    let params = rt.manifest.load_blob_f32("cartpole_init_params").unwrap();
    assert_eq!(params.len(), p);

    let mut rng = Rng::new(0);
    let mut obs = vec![0.0f32; b * 4];
    rng.fill_normal_f32(&mut obs);
    let out = rt
        .call(
            "cartpole_policy_fwd",
            &[Tensor::vec1(params), Tensor::new(obs, vec![b, 4])],
        )
        .unwrap();
    assert_eq!(out[0].shape, vec![b, 2]); // logits
    assert_eq!(out[1].shape, vec![b]); // values
    assert!(out[0].data.iter().all(|x| x.is_finite()));
}

#[test]
fn gae_kernel_artifact_matches_rust_reference() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let (t_len, b) = (128usize, 16usize);
    let mut rng = Rng::new(42);
    let mut rewards = vec![0.0f32; t_len * b];
    let mut values = vec![0.0f32; (t_len + 1) * b];
    rng.fill_normal_f32(&mut rewards);
    rng.fill_normal_f32(&mut values);
    let done_mask: Vec<f32> = (0..t_len * b)
        .map(|_| if rng.uniform() < 0.05 { 1.0 } else { 0.0 })
        .collect();

    let out = rt
        .call(
            "gae_T128_B16",
            &[
                Tensor::new(rewards.clone(), vec![t_len, b]),
                Tensor::new(values.clone(), vec![t_len + 1, b]),
                Tensor::new(done_mask.clone(), vec![t_len, b]),
            ],
        )
        .unwrap();

    let batch = GaeBatch { t_len, batch: b, rewards, values, done_mask };
    let want = gae_batched(&GaeParams::new(0.99, 0.95), &batch);
    assert_eq!(out[0].data.len(), want.advantages.len());
    for (i, (got, want)) in out[0].data.iter().zip(&want.advantages).enumerate() {
        assert!(
            (got - want).abs() < 1e-3,
            "adv[{i}]: kernel {got} vs rust {want}"
        );
    }
    for (got, want) in out[1].data.iter().zip(&want.rewards_to_go) {
        assert!((got - want).abs() < 1e-3, "rtg: {got} vs {want}");
    }
}

#[test]
fn gae_kernel_paper_shape_1024x64() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let (t_len, b) = (1024usize, 64usize);
    let mut rng = Rng::new(7);
    let mut rewards = vec![0.0f32; t_len * b];
    let mut values = vec![0.0f32; (t_len + 1) * b];
    rng.fill_normal_f32(&mut rewards);
    rng.fill_normal_f32(&mut values);
    let done_mask = vec![0.0f32; t_len * b];
    let out = rt
        .call(
            "gae_T1024_B64",
            &[
                Tensor::new(rewards.clone(), vec![t_len, b]),
                Tensor::new(values.clone(), vec![t_len + 1, b]),
                Tensor::new(done_mask, vec![t_len, b]),
            ],
        )
        .unwrap();
    // Spot-check one column against the scalar reference.
    let col = 13;
    let r: Vec<f32> = (0..t_len).map(|t| rewards[t * b + col]).collect();
    let v: Vec<f32> = (0..=t_len).map(|t| values[t * b + col]).collect();
    let want = gae_trajectory(&GaeParams::new(0.99, 0.95), &Trajectory::without_dones(r, v));
    for t in (0..t_len).step_by(97) {
        assert!(
            (out[0].data[t * b + col] - want.advantages[t]).abs() < 1e-2,
            "t={t}"
        );
    }
}

#[test]
fn train_step_executes_and_decreases_value_loss() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let spec = rt.manifest.get("cartpole_train_step").unwrap().clone();
    let p = spec.meta_usize("param_count").unwrap();
    let m = spec.meta_usize("minibatch").unwrap();
    let mut params = rt.manifest.load_blob_f32("cartpole_init_params").unwrap();
    let mut adam_m = vec![0.0f32; p];
    let mut adam_v = vec![0.0f32; p];
    let mut step = 0.0f32;

    let mut rng = Rng::new(3);
    let mut obs = vec![0.0f32; m * 4];
    rng.fill_normal_f32(&mut obs);
    let actions: Vec<f32> = (0..m).map(|_| (rng.below(2)) as f32).collect();
    let old_logp = vec![(0.5f32).ln(); m];
    let adv = vec![0.0f32; m]; // isolate the value head
    let ret: Vec<f32> = (0..m).map(|_| rng.uniform_f32(0.0, 1.0)).collect();

    let mut first_v_loss = None;
    let mut last_v_loss = 0.0;
    for _ in 0..30 {
        let out = rt
            .call(
                "cartpole_train_step",
                &[
                    Tensor::vec1(params.clone()),
                    Tensor::vec1(adam_m.clone()),
                    Tensor::vec1(adam_v.clone()),
                    Tensor::scalar(step),
                    Tensor::new(obs.clone(), vec![m, 4]),
                    Tensor::vec1(actions.clone()),
                    Tensor::vec1(old_logp.clone()),
                    Tensor::vec1(adv.clone()),
                    Tensor::vec1(ret.clone()),
                    Tensor::scalar(3e-3),
                    Tensor::scalar(0.2),
                    Tensor::scalar(0.0),
                ],
            )
            .unwrap();
        params = out[0].data.clone();
        adam_m = out[1].data.clone();
        adam_v = out[2].data.clone();
        step = out[3].data[0];
        last_v_loss = out[4].data[1];
        first_v_loss.get_or_insert(last_v_loss);
    }
    let first = first_v_loss.unwrap();
    assert!(step == 30.0);
    assert!(
        last_v_loss < first * 0.8,
        "v_loss must descend: {first} -> {last_v_loss}"
    );
}

#[test]
fn quant_block_artifact_roundtrips() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let spec = rt.manifest.get("quant_block_N2048").unwrap().clone();
    let n = spec.meta_usize("n").unwrap();
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..n).map(|_| rng.normal_with(3.0, 2.0) as f32).collect();
    let out = rt.call("quant_block_N2048", &[Tensor::vec1(x.clone())]).unwrap();
    // 8-bit block round trip: |err| <= sigma * step/2.
    let sigma = {
        let mean = x.iter().sum::<f32>() / n as f32;
        (x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n as f32).sqrt()
    };
    let tol = sigma * (10.0 / 255.0) / 2.0 + 1e-4;
    for (a, b) in out[0].data.iter().zip(&x) {
        assert!((a - b).abs() <= tol, "{a} vs {b} (tol {tol})");
    }
}

#[test]
fn wrong_arity_is_rejected() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let err = rt
        .call("cartpole_policy_fwd", &[Tensor::scalar(0.0)])
        .unwrap_err()
        .to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn wrong_shape_is_rejected() {
    let rt = match runtime() {
        Some(rt) => rt,
        None => return,
    };
    let err = rt
        .call(
            "cartpole_policy_fwd",
            &[Tensor::vec1(vec![0.0; 3]), Tensor::zeros(&[16, 4])],
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("shape"), "{err}");
}

//! Loopback integration of the network front-end: a real TCP socket
//! between [`NetClient`] and [`NetServer`] over a live `GaeService` —
//! f32 bit-identity against in-process submission, pipelined
//! out-of-order completion, response-cache hits, per-tenant quota
//! refusals, admission-control sheds, malformed-frame handling, HMAC
//! tenant authentication (accept / typed reject / strike-limit close),
//! fuzz seed-corpus replay, and the client-side request deadline.
//!
//! Every scenario runs under **both** server modes (`threads` and, on
//! Linux, `reactor`): the `*_threads` / `*_reactor` test pairs call one
//! shared body, so the two front-ends are pinned to byte-identical
//! client-observable behavior by construction.

use heppo::coordinator::GaeBackend;
use heppo::gae::{GaeParams, Trajectory};
use heppo::net::{
    AuthKey, AuthToken, ErrorKind, NetClient, NetClientConfig, NetError, NetServer,
    NetServerConfig, PlaneCodec, QuotaConfig, ServerMode,
};
use heppo::quant::CodecKind;
use heppo::service::{BatcherConfig, GaeService, ServiceConfig};
use heppo::testing::Gen;
use std::sync::Arc;
use std::time::Duration;

fn service(workers: usize, backend: GaeBackend, queue_capacity: usize) -> Arc<GaeService> {
    Arc::new(
        GaeService::start(ServiceConfig {
            workers,
            backend,
            queue_capacity,
            batcher: BatcherConfig {
                max_batch_lanes: 64,
                tile_lanes: 16,
                max_wait: Duration::from_micros(100),
            },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    )
}

fn cfg(mode: ServerMode) -> NetServerConfig {
    NetServerConfig { mode, ..NetServerConfig::default() }
}

fn planes(g: &mut Gen, t_len: usize, batch: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
    let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
    let done_mask = (0..t_len * batch)
        .map(|_| if g.bool_p(0.05) { 1.0 } else { 0.0 })
        .collect();
    (rewards, values, done_mask)
}

fn f32_client(addr: &str) -> NetClient {
    NetClient::connect(
        addr,
        NetClientConfig {
            tenant: "test".to_string(),
            codec: CodecKind::Exp1Baseline,
            bits: 8,
            resp: PlaneCodec::F32,
            auth: None,
        },
    )
    .unwrap()
}

/// Declare a `<name>_threads` / `<name>_reactor` test pair over one
/// mode-parameterized body.
macro_rules! both_modes {
    ($name:ident, $body:ident) => {
        mod $name {
            use super::*;

            #[test]
            fn threads() {
                $body(ServerMode::Threads);
            }

            #[cfg(target_os = "linux")]
            #[test]
            fn reactor() {
                $body(ServerMode::Reactor);
            }
        }
    };
}

both_modes!(f32_codec_is_bit_identical_to_in_process_submission, bit_identical_body);
fn bit_identical_body(mode: ServerMode) {
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..cfg(mode) },
    )
    .unwrap();
    let client = f32_client(&server.local_addr().to_string());

    let mut g = Gen::new(1);
    for case in 0..4 {
        let (t_len, batch) = (g.usize_in(1, 40), g.usize_in(1, 6));
        let (rewards, values, done_mask) = planes(&mut g, t_len, batch);
        let local = svc
            .submit_planes(t_len, batch, &rewards, &values, &done_mask)
            .unwrap()
            .wait()
            .unwrap();
        let remote = client
            .call_planes(t_len, batch, &rewards, &values, &done_mask)
            .unwrap();
        assert!(!remote.cache_hit);
        assert_eq!(remote.advantages.len(), t_len * batch);
        for (i, (a, b)) in remote.advantages.iter().zip(&local.advantages).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} adv {i}");
        }
        for (i, (a, b)) in
            remote.rewards_to_go.iter().zip(&local.rewards_to_go).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} rtg {i}");
        }
    }
    assert_eq!(client.wire_stats().frames, 4);
    server.shutdown();
}

both_modes!(pipelined_frames_complete_out_of_order_safely, pipelined_body);
fn pipelined_body(mode: ServerMode) {
    let svc = service(4, GaeBackend::Batched, 256);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg(mode)).unwrap();
    let client = f32_client(&server.local_addr().to_string());

    // Mixed sizes so completion order differs from submission order;
    // every result must still land on its own sequence number.
    let mut g = Gen::new(7);
    let mut expected = Vec::new();
    let mut handles = Vec::new();
    for i in 0..24 {
        let t_len = if i % 3 == 0 { 200 } else { 4 };
        let (rewards, values, done_mask) = planes(&mut g, t_len, 2);
        let want = svc
            .submit_planes(t_len, 2, &rewards, &values, &done_mask)
            .unwrap()
            .wait()
            .unwrap();
        expected.push(want);
        handles.push(
            client.submit_planes(t_len, 2, &rewards, &values, &done_mask).unwrap(),
        );
    }
    for (i, (handle, want)) in handles.into_iter().zip(expected).enumerate() {
        let got = handle.wait().unwrap();
        assert_eq!(got.advantages.len(), want.advantages.len(), "frame {i}");
        for (a, b) in got.advantages.iter().zip(&want.advantages) {
            assert_eq!(a.to_bits(), b.to_bits(), "frame {i}");
        }
    }
    server.shutdown();
}

both_modes!(identical_quantized_payloads_hit_the_response_cache, cache_hit_body);
fn cache_hit_body(mode: ServerMode) {
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 64, ..cfg(mode) },
    )
    .unwrap();
    let client = NetClient::connect(
        &server.local_addr().to_string(),
        NetClientConfig::default(), // exp5 @ 8 bits — the quantized path
    )
    .unwrap();

    let mut g = Gen::new(3);
    let (t_len, batch) = (24, 3);
    let (rewards, values, done_mask) = planes(&mut g, t_len, batch);
    let first = client.call_planes(t_len, batch, &rewards, &values, &done_mask).unwrap();
    assert!(!first.cache_hit, "first frame must compute");
    let second = client.call_planes(t_len, batch, &rewards, &values, &done_mask).unwrap();
    assert!(second.cache_hit, "identical payload must hit the cache");
    // Cached responses replay the original result exactly.
    for (a, b) in first.advantages.iter().zip(&second.advantages) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // A different payload misses again.
    let (r2, v2, d2) = planes(&mut g, t_len, batch);
    assert!(!client.call_planes(t_len, batch, &r2, &v2, &d2).unwrap().cache_hit);

    let snap = svc.metrics();
    assert_eq!(snap.cache_hits, 1);
    assert_eq!(snap.cache_misses, 2);
    server.shutdown();
}

both_modes!(cache_is_keyed_per_tenant, tenant_cache_body);
fn tenant_cache_body(mode: ServerMode) {
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 64, ..cfg(mode) },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let client = |tenant: &str| {
        NetClient::connect(
            &addr,
            NetClientConfig { tenant: tenant.to_string(), ..NetClientConfig::default() },
        )
        .unwrap()
    };
    let a = client("tenant-a");
    let b = client("tenant-b");
    let mut g = Gen::new(29);
    let (t_len, batch) = (16, 2);
    let (r, v, d) = planes(&mut g, t_len, batch);

    assert!(!a.call_planes(t_len, batch, &r, &v, &d).unwrap().cache_hit);
    assert!(
        a.call_planes(t_len, batch, &r, &v, &d).unwrap().cache_hit,
        "same tenant replaying the same payload must hit"
    );
    // The *identical* payload from another tenant must not replay
    // tenant a's entry — keys are tenant-scoped.
    assert!(
        !b.call_planes(t_len, batch, &r, &v, &d).unwrap().cache_hit,
        "cache must never answer across tenants"
    );
    assert!(b.call_planes(t_len, batch, &r, &v, &d).unwrap().cache_hit);

    let snap = svc.metrics();
    assert_eq!((snap.cache_hits, snap.cache_misses), (2, 2));
    // The per-tenant breakdown saw both tenants' served requests.
    for tenant in ["tenant-a", "tenant-b"] {
        let t = snap
            .tenants
            .iter()
            .find(|t| t.tenant == tenant)
            .unwrap_or_else(|| panic!("{tenant} missing from {snap}"));
        assert_eq!(t.requests, 2);
        assert_eq!(t.elements, 2 * (t_len * batch) as u64);
    }
    server.shutdown();
}

both_modes!(quantized_replies_are_opt_in_lossy_and_cache_consistent, quantized_body);
fn quantized_body(mode: ServerMode) {
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 64, ..cfg(mode) },
    )
    .unwrap();
    let client = NetClient::connect(
        &server.local_addr().to_string(),
        NetClientConfig {
            tenant: "q".to_string(),
            codec: CodecKind::Exp1Baseline, // exact requests
            bits: 8,
            resp: PlaneCodec { kind: CodecKind::Exp5DynamicBlock, bits: 8 },
            auth: None,
        },
    )
    .unwrap();
    let mut g = Gen::new(31);
    let (t_len, batch) = (20, 3);
    let (r, v, d) = planes(&mut g, t_len, batch);
    let exact = svc
        .submit_planes(t_len, batch, &r, &v, &d)
        .unwrap()
        .wait()
        .unwrap();

    let first = client.call_planes(t_len, batch, &r, &v, &d).unwrap();
    assert!(first.quantized, "server must honor the requested reply codec");
    assert!(!first.cache_hit);
    // Bounded reconstruction error against the exact in-process result.
    let q = heppo::quant::UniformQuantizer::new(8);
    for (plane, exact_plane) in [
        (&first.advantages, &exact.advantages),
        (&first.rewards_to_go, &exact.rewards_to_go),
    ] {
        let stats = heppo::quant::BlockStats::of(exact_plane);
        let tol = q.max_in_range_error() * stats.std.abs().max(1e-3) + 1e-4;
        for (got, want) in plane.iter().zip(exact_plane.iter()) {
            assert!((got - want).abs() <= tol, "{got} vs {want} (tol {tol})");
        }
    }
    // A cache hit re-encodes the stored f32 planes under the same reply
    // codec — bit-identical to the first (computed) reply.
    let second = client.call_planes(t_len, batch, &r, &v, &d).unwrap();
    assert!(second.cache_hit && second.quantized);
    for (a, b) in second.advantages.iter().zip(&first.advantages) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    server.shutdown();
}

both_modes!(per_tenant_quotas_refuse_with_typed_error_frames, quota_body);
fn quota_body(mode: ServerMode) {
    let svc = service(2, GaeBackend::Scalar, 128);
    let (t_len, batch) = (16, 4); // 64 elements per frame
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            quota: Some(QuotaConfig {
                elements_per_sec: 0.0, // no refill: a pure burst budget
                burst_elements: (2 * t_len * batch) as f64,
            }),
            cache_entries: 0,
            shed_on_overload: true,
            ..cfg(mode)
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let limited = NetClient::connect(
        &addr,
        NetClientConfig { tenant: "hog".to_string(), ..NetClientConfig::default() },
    )
    .unwrap();
    let mut g = Gen::new(5);
    // Exactly two frames fit the burst; the third must be refused.
    for i in 0..2 {
        let (r, v, d) = planes(&mut g, t_len, batch);
        limited.call_planes(t_len, batch, &r, &v, &d).unwrap_or_else(|e| {
            panic!("frame {i} within budget refused: {e}")
        });
    }
    let (r, v, d) = planes(&mut g, t_len, batch);
    let err = limited.call_planes(t_len, batch, &r, &v, &d).unwrap_err();
    assert_eq!(err.remote_kind(), Some(ErrorKind::Quota), "{err}");

    // Another tenant on the same server has its own untouched bucket.
    let fresh = NetClient::connect(
        &addr,
        NetClientConfig { tenant: "polite".to_string(), ..NetClientConfig::default() },
    )
    .unwrap();
    let (r, v, d) = planes(&mut g, t_len, batch);
    fresh.call_planes(t_len, batch, &r, &v, &d).unwrap();

    assert_eq!(svc.metrics().quota_shed, 1);
    server.shutdown();
}

both_modes!(overload_sheds_with_typed_error_frames, overload_body);
fn overload_body(mode: ServerMode) {
    // One worker pinned busy + a capacity-2 queue: an 8-column frame
    // cannot fully admit, so fail-fast admission must shed it.
    let svc = service(1, GaeBackend::Scalar, 2);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..cfg(mode) },
    )
    .unwrap();
    let client = f32_client(&server.local_addr().to_string());

    // Pin the worker: a large request it will be computing while the
    // frame's columns try to enqueue.
    let mut g = Gen::new(11);
    let big: Vec<Trajectory> = (0..8)
        .map(|_| {
            Trajectory::without_dones(
                g.vec_normal_f32(600_000, 0.0, 1.0),
                g.vec_normal_f32(600_001, 0.0, 1.0),
            )
        })
        .collect();
    let busy = svc.enqueue(big).unwrap();

    let mut shed = 0;
    for _ in 0..4 {
        let (r, v, d) = planes(&mut g, 8, 8);
        match client.call_planes(8, 8, &r, &v, &d) {
            Err(e) if e.remote_kind() == Some(ErrorKind::Shed) => shed += 1,
            Err(e) => panic!("unexpected failure: {e}"),
            Ok(_) => {}
        }
    }
    assert!(shed > 0, "an 8-column frame against a capacity-2 queue must shed");
    assert!(svc.metrics().shed > 0);
    busy.wait().unwrap();
    server.shutdown();
}

both_modes!(malformed_frames_get_a_typed_error_and_a_clean_close, malformed_body);
fn malformed_body(mode: ServerMode) {
    use heppo::net::wire;
    use std::io::Write;

    let svc = service(1, GaeBackend::Scalar, 16);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg(mode)).unwrap();
    let mut raw = std::net::TcpStream::connect(server.local_addr()).unwrap();

    // A length-prefixed frame of garbage: structurally a frame, but the
    // checksum cannot match.
    let garbage = [0xAAu8; 64];
    raw.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
    raw.write_all(&garbage).unwrap();
    raw.flush().unwrap();

    let mut reader = std::io::BufReader::new(raw.try_clone().unwrap());
    let frame = wire::read_frame(&mut reader).unwrap().expect("error frame");
    match wire::decode_frame(&frame).unwrap() {
        wire::Frame::Error(err) => {
            assert_eq!(err.seq, 0, "framing errors are connection-level");
            assert_eq!(err.kind, ErrorKind::Malformed);
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    // The server closes the connection after a framing error.
    assert!(wire::read_frame(&mut reader).unwrap().is_none());
    server.shutdown();
}

both_modes!(disconnect_fails_pending_calls_instead_of_hanging, disconnect_body);
fn disconnect_body(mode: ServerMode) {
    let svc = service(1, GaeBackend::Scalar, 16);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg(mode)).unwrap();
    let client = f32_client(&server.local_addr().to_string());
    let mut g = Gen::new(13);
    let (r, v, d) = planes(&mut g, 8, 2);
    // Sanity round trip, then kill the server and submit again.
    client.call_planes(8, 2, &r, &v, &d).unwrap();
    server.shutdown();
    // The submit may fail at write time or come back as a dead channel;
    // either way it must be an error, promptly, not a hang.
    match client.submit_planes(8, 2, &r, &v, &d) {
        Ok(pending) => {
            assert!(pending.wait().is_err());
        }
        Err(e) => {
            assert!(matches!(e, NetError::Io(_) | NetError::Disconnected), "{e}");
        }
    }
}

// ---------------------------------------------------------------------------
// Untrusted-tenant hardening: HMAC tenant tokens, fuzz-corpus replay,
// and the client-side request deadline.
// ---------------------------------------------------------------------------

/// The deployment signing key shared by every auth scenario; tenants
/// carry only the derived [`AuthKey::token_for`] token, never the key.
fn deployment_key() -> AuthKey {
    AuthKey::new(b"loopback-deployment-key".to_vec()).unwrap()
}

/// An f32 client for tenant `"test"` presenting `auth` (or nothing).
fn signed_client(addr: &str, auth: Option<AuthToken>) -> NetClient {
    NetClient::connect(
        addr,
        NetClientConfig {
            tenant: "test".to_string(),
            codec: CodecKind::Exp1Baseline,
            bits: 8,
            resp: PlaneCodec::F32,
            auth,
        },
    )
    .unwrap()
}

both_modes!(signed_traffic_is_accepted_and_unchanged_by_auth, auth_accept_body);
fn auth_accept_body(mode: ServerMode) {
    let key = deployment_key();
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { auth_key: Some(key.clone()), cache_entries: 64, ..cfg(mode) },
    )
    .unwrap();
    let client = signed_client(&server.local_addr().to_string(), Some(key.token_for("test")));

    // Correctly signed traffic behaves exactly like the no-auth path:
    // f32 results stay bit-identical to in-process submission, and a
    // replayed payload still hits the response cache (the tag rides
    // outside the hashed payload, so cache keys are unchanged).
    let mut g = Gen::new(41);
    let (t_len, batch) = (18, 3);
    let (r, v, d) = planes(&mut g, t_len, batch);
    let local = svc.submit_planes(t_len, batch, &r, &v, &d).unwrap().wait().unwrap();
    let first = client.call_planes(t_len, batch, &r, &v, &d).unwrap();
    assert!(!first.cache_hit);
    for (i, (a, b)) in first.advantages.iter().zip(&local.advantages).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "adv {i}");
    }
    assert!(client.call_planes(t_len, batch, &r, &v, &d).unwrap().cache_hit);

    let snap = svc.metrics();
    assert_eq!(snap.auth_rejected, 0);
    assert_eq!(snap.auth_conns_closed, 0);
    server.shutdown();
}

both_modes!(unsigned_and_tampered_frames_get_typed_auth_errors, auth_reject_body);
fn auth_reject_body(mode: ServerMode) {
    let key = deployment_key();
    let svc = service(2, GaeBackend::Scalar, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { auth_key: Some(key.clone()), auth_strike_limit: 16, ..cfg(mode) },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut g = Gen::new(43);
    let (r, v, d) = planes(&mut g, 8, 2);

    // Unsigned, signed under the wrong key, and signed for a different
    // tenant id: each must be refused with a typed `Auth` error before
    // quota, cache, or admission ever see the frame.
    let wrong_key = AuthKey::new(b"not-the-deployment-key".to_vec()).unwrap();
    let bad_tokens = [
        None,
        Some(wrong_key.token_for("test")),
        Some(key.token_for("somebody-else")),
    ];
    for auth in bad_tokens {
        let client = signed_client(&addr, auth);
        let err = client.call_planes(8, 2, &r, &v, &d).unwrap_err();
        assert_eq!(err.remote_kind(), Some(ErrorKind::Auth), "{err}");
    }

    // The same server keeps serving correctly signed traffic.
    let good = signed_client(&addr, Some(key.token_for("test")));
    good.call_planes(8, 2, &r, &v, &d).unwrap();

    let snap = svc.metrics();
    assert_eq!(snap.auth_rejected, 3);
    assert_eq!(snap.auth_conns_closed, 0, "one strike each must not close");
    let t = snap.tenants.iter().find(|t| t.tenant == "test").unwrap();
    assert_eq!(t.auth_rejected, 3, "rejects attribute the *claimed* tenant id");
    server.shutdown();
}

both_modes!(auth_strikes_close_the_connection_at_the_limit, auth_strike_body);
fn auth_strike_body(mode: ServerMode) {
    let key = deployment_key();
    let svc = service(1, GaeBackend::Scalar, 16);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { auth_key: Some(key), auth_strike_limit: 2, ..cfg(mode) },
    )
    .unwrap();
    let client = signed_client(&server.local_addr().to_string(), None);
    let mut g = Gen::new(47);
    let (r, v, d) = planes(&mut g, 8, 2);

    // Strikes one and two each still earn their typed error frame...
    for strike in 0..2 {
        let err = client.call_planes(8, 2, &r, &v, &d).unwrap_err();
        assert_eq!(err.remote_kind(), Some(ErrorKind::Auth), "strike {strike}: {err}");
    }
    // ...and the second closes the connection: the next submit must
    // fail promptly (at write time or as a dead pending), never hang.
    match client.submit_planes(8, 2, &r, &v, &d) {
        Ok(pending) => assert!(pending.wait().is_err()),
        Err(e) => assert!(matches!(e, NetError::Io(_) | NetError::Disconnected), "{e}"),
    }
    let snap = svc.metrics();
    assert_eq!(snap.auth_rejected, 2);
    assert_eq!(snap.auth_conns_closed, 1);
    server.shutdown();
}

both_modes!(fuzz_corpus_replays_cleanly_against_a_live_server, corpus_replay_body);
fn corpus_replay_body(mode: ServerMode) {
    use std::io::{Read, Write};

    let svc = service(1, GaeBackend::Scalar, 16);
    let server = NetServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg(mode)).unwrap();
    let addr = server.local_addr();

    // Every seed-corpus entry — valid exemplars, named regression
    // mutants, truncations — goes over a real socket on its own
    // connection. The server may answer, refuse, or close; what it
    // must never do is wedge or crash either front-end.
    for entry in heppo::net::fuzzing::seed_corpus() {
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let mut msg = (entry.len() as u32).to_le_bytes().to_vec();
        msg.extend_from_slice(&entry);
        // A write error just means the server already refused and
        // closed — an acceptable outcome for a hostile frame.
        let _ = raw.write_all(&msg).and_then(|_| raw.flush());
        // Drain whatever the server says until it closes or goes
        // quiet; reply *content* is pinned elsewhere — only liveness
        // matters here.
        let mut scratch = [0u8; 4096];
        loop {
            match raw.read(&mut scratch) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    // The server survived the whole corpus: a well-formed request on a
    // fresh connection still computes correctly.
    let client = f32_client(&addr.to_string());
    let mut g = Gen::new(53);
    let (r, v, d) = planes(&mut g, 8, 2);
    client.call_planes(8, 2, &r, &v, &d).unwrap();
    server.shutdown();
}

#[test]
fn client_deadline_times_out_against_a_stalled_server() {
    // A listener that accepts and then never reads: the request sits
    // in kernel buffers while the client's per-call deadline runs down.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let client = f32_client(&listener.local_addr().unwrap().to_string());
    let mut g = Gen::new(59);
    let (r, v, d) = planes(&mut g, 8, 2);
    let pending = client.submit_planes(8, 2, &r, &v, &d).unwrap();
    let held = listener.accept().unwrap();
    let err = pending.wait_timeout(Duration::from_millis(100)).unwrap_err();
    assert!(matches!(err, NetError::Timeout), "{err}");
    drop(held);
}

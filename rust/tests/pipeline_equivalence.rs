//! Pipeline equivalence: at the same seed, the `Sequential` and
//! `Overlapped` schedules must produce identical result streams.
//!
//! Offline (stub-runtime) coverage drives the stage pipeline directly on
//! the cartpole vec-env with a fixed linear policy — the sequential arm
//! runs the inline GAE stage, the overlapped arm double-buffers
//! collection and serves GAE through the `GaeService` plane seam — and
//! compares every per-iteration plane bit-for-bit. Trainer-level
//! `IterStats` equivalence (with real policy feedback through the
//! `train_step` artifact) runs when AOT artifacts and a PJRT runtime
//! are present, and skips otherwise like `trainer_e2e`.

use heppo::coordinator::gae_stage::{codec_stage, run_gae_stage, GaeResult};
use heppo::coordinator::rollout::{collect_into, CollectBuffers, Rollout};
use heppo::coordinator::{
    run_stages, GaeBackend, PhaseProfiler, PipelineMode, PipelineRun, Trainer,
    TrainerConfig,
};
use heppo::envs::vec_env::VecEnv;
use heppo::gae::GaeParams;
use heppo::quant::{CodecKind, RewardValueCodec};
use heppo::service::{GaeService, ServiceConfig};
use heppo::testing::{digest_f32 as digest, linear_policy};
use heppo::util::threadpool::ThreadPool;
use heppo::util::Rng;

/// Per-iteration digest of everything the pipeline produced.
#[derive(Debug, Clone, PartialEq, Eq)]
struct IterDigest {
    rewards: u64,
    values: u64,
    advantages: u64,
    rewards_to_go: u64,
    episodes: usize,
}

/// Run `iters` pipeline iterations on cartpole and digest every stream.
fn run_digests(
    mode: PipelineMode,
    backend: GaeBackend,
    iters: usize,
) -> PipelineRun<IterDigest> {
    let (n_envs, t_len) = (6, 48);
    let mut envs =
        VecEnv::new("cartpole", n_envs, 77, ThreadPool::new(2)).unwrap();
    let mut current_obs = envs.reset_all();
    let obs_dim = envs.obs_dim();
    let mut policy = linear_policy(n_envs, obs_dim, -0.2);
    let mut rng = Rng::new(13);
    let mut collect_prof = PhaseProfiler::new();
    let mut bufs = CollectBuffers::new(n_envs, t_len);
    let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
    let mut gae_prof = PhaseProfiler::new();
    let params = GaeParams::default();
    let service = match mode {
        PipelineMode::Sequential => None,
        PipelineMode::Overlapped => Some(
            GaeService::start(ServiceConfig {
                workers: 3,
                backend,
                queue_capacity: 64,
                gae: params,
                ..ServiceConfig::default()
            })
            .unwrap(),
        ),
    };

    run_stages(
        mode,
        iters,
        |_i, buf: &mut Rollout| {
            collect_into(
                &mut envs,
                &mut policy,
                &mut current_obs,
                t_len,
                &mut rng,
                &mut collect_prof,
                &mut bufs,
                buf,
                false,
            )
        },
        |_i, buf: &mut Rollout| match &service {
            None => {
                run_gae_stage(backend, &params, buf, &mut codec, None, &mut gae_prof)
            }
            Some(svc) => {
                codec_stage(buf, &mut codec, &mut gae_prof);
                let plane = svc
                    .submit_planes(
                        buf.t_len,
                        buf.batch,
                        &buf.rewards,
                        &buf.values,
                        &buf.done_mask,
                    )?
                    .wait()?;
                Ok(GaeResult::from(plane))
            }
        },
        |_i, buf: &mut Rollout, gae: &GaeResult| {
            Ok(IterDigest {
                rewards: digest(&buf.rewards),
                values: digest(&buf.values),
                advantages: digest(&gae.advantages),
                rewards_to_go: digest(&gae.rewards_to_go),
                episodes: buf.finished_returns.len(),
            })
        },
    )
    .unwrap()
}

#[test]
fn sequential_and_overlapped_streams_identical_on_cartpole() {
    // The tentpole equivalence claim: same seed ⇒ the overlapped
    // schedule (double-buffered collection + service-backed GAE) emits
    // exactly the sequential stream, for every servable backend.
    for backend in [GaeBackend::Scalar, GaeBackend::Batched] {
        let seq = run_digests(PipelineMode::Sequential, backend, 5);
        let ovl = run_digests(PipelineMode::Overlapped, backend, 5);
        assert_eq!(
            seq.stats, ovl.stats,
            "{backend:?}: overlapped stream diverged from sequential"
        );
        // Some iteration must actually contain episode ends, or the
        // done-mask path went untested.
        assert!(
            seq.stats.iter().any(|d| d.episodes > 0),
            "cartpole must finish episodes within the run"
        );
    }
}

#[test]
fn hwsim_service_matches_inline_values() {
    // hwsim rides the same seam; advantage planes must match the inline
    // stage (cycle accounting legitimately differs between the inline
    // whole-batch sim and the service's per-group sims, so only the
    // value streams are compared).
    let seq = run_digests(PipelineMode::Sequential, GaeBackend::HwSim, 3);
    let ovl = run_digests(PipelineMode::Overlapped, GaeBackend::HwSim, 3);
    assert_eq!(seq.stats, ovl.stats);
}

#[test]
fn overlapped_lanes_account_handshakes_per_iteration() {
    let iters = 4;
    for mode in [PipelineMode::Sequential, PipelineMode::Overlapped] {
        let run = run_digests(mode, GaeBackend::Batched, iters);
        // GaeCompute + LossAndUpdate cross the PS↔PL boundary once per
        // iteration each, regardless of schedule.
        assert_eq!(
            run.lanes.handshakes(),
            2 * iters as u64,
            "{mode:?} handshake accounting"
        );
        assert_eq!(run.times.iters, iters);
        // Stage accounting covers every stage.
        assert!(run.times.stage_sum() >= run.times.gae);
        assert!(run.times.collect > std::time::Duration::ZERO);
    }
}

// ---------------------------------------------------------------------
// Trainer-level equivalence (artifact-gated, like trainer_e2e).
// ---------------------------------------------------------------------

fn artifacts_available() -> bool {
    heppo::testing::try_runtime(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .is_some()
}

fn base_config(pipeline: PipelineMode) -> TrainerConfig {
    TrainerConfig {
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        codec: CodecKind::Exp5DynamicBlock,
        backend: GaeBackend::Batched,
        iters: 3,
        seed: 23,
        pipeline,
        service_workers: 3,
        ..TrainerConfig::default()
    }
}

#[test]
fn trainer_iterstats_bit_identical_across_modes() {
    if !artifacts_available() {
        return;
    }
    let run = |mode: PipelineMode| {
        let mut t = Trainer::new(base_config(mode)).unwrap();
        t.run().unwrap()
    };
    let seq = run(PipelineMode::Sequential);
    let ovl = run(PipelineMode::Overlapped);
    assert_eq!(seq.len(), ovl.len());
    for (s, o) in seq.iter().zip(&ovl) {
        assert_eq!(s.steps, o.steps);
        assert_eq!(s.episodes, o.episodes);
        assert_eq!(
            s.mean_return.to_bits(),
            o.mean_return.to_bits(),
            "iter {}: mean_return diverged",
            s.iter
        );
        assert_eq!(s.losses.minibatches, o.losses.minibatches);
        assert_eq!(s.losses.pi_loss.to_bits(), o.losses.pi_loss.to_bits());
        assert_eq!(s.losses.v_loss.to_bits(), o.losses.v_loss.to_bits());
        assert_eq!(s.losses.entropy.to_bits(), o.losses.entropy.to_bits());
    }
}

#[test]
fn overlapped_trainer_rejects_hlo_backend() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = base_config(PipelineMode::Overlapped);
    cfg.backend = GaeBackend::Hlo;
    let err = Trainer::new(cfg).unwrap_err().to_string();
    assert!(err.contains("pipeline"), "{err}");
}

//! Steady-state allocation guard + bit-identity property tests for the
//! worker hot path.
//!
//! The guard drives the exact public functions the worker's compute
//! path is built from — `slab_of` + `gae_batched_strided_into` (slab
//! fast path) and `PaddedTile::pack_lane_views` + the same kernel
//! (ragged fallback) — under a counting allocator, and asserts the
//! warmed paths allocate **zero** times per group while the seed-shaped
//! `from_lane_views` path pays ≥ 4 allocations. Counting is
//! thread-local so parallel test threads cannot pollute a measurement.
//!
//! The property test pins the acceptance bar: the slab path, the
//! packed-tile path, and the scalar reference are bit-identical across
//! random ragged and aligned groups (including column windows with
//! `stride > width`).
//!
//! The vec-pool guard pins the response-vector recycling loop
//! (`service::vecpool`): a warmed take→fill→give cycle must be
//! allocation-free and served entirely from pool hits.

use heppo::coordinator::GaeBackend;
use heppo::gae::batched::{gae_batched, gae_batched_strided_into};
use heppo::gae::reference::gae_indexed;
use heppo::gae::{GaeParams, Trajectory};
use heppo::service::batcher::unpack_lanes_into;
use heppo::service::plane::{slab_of, Lane, PlaneSet};
use heppo::service::{GaeService, PaddedTile, ServiceConfig};
use heppo::testing::{check, Gen};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;

thread_local! {
    static TLS_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Pass-through allocator counting per-thread allocations (realloc
/// included — growing a vector is an allocation event).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = TLS_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = TLS_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    TLS_ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

fn plane_set(g: &mut Gen, t_len: usize, batch: usize) -> PlaneSet {
    PlaneSet::new(
        t_len,
        batch,
        g.vec_normal_f32(t_len * batch, 0.0, 1.0),
        g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0),
        (0..t_len * batch)
            .map(|_| if g.bool_p(0.1) { 1.0 } else { 0.0 })
            .collect(),
    )
    .unwrap()
}

fn column_lanes(planes: &Arc<PlaneSet>, cols: std::ops::Range<usize>) -> Vec<Lane> {
    cols.map(|col| Lane::Column { planes: Arc::clone(planes), col }).collect()
}

fn ragged_owned(g: &mut Gen, n: usize, max_t: usize) -> Vec<Lane> {
    (0..n)
        .map(|_| {
            let len = g.usize_in(1, max_t);
            Lane::Owned(Trajectory::new(
                g.vec_normal_f32(len, 0.0, 1.0),
                g.vec_normal_f32(len + 1, 0.0, 1.0),
                (0..len).map(|_| g.bool_p(0.1)).collect(),
            ))
        })
        .collect()
}

#[test]
fn slab_path_steady_state_allocates_nothing() {
    let mut g = Gen::new(1);
    let params = GaeParams::default();
    let planes = Arc::new(plane_set(&mut g, 128, 16));
    let lanes = column_lanes(&planes, 0..16);
    let mut adv = Vec::new();
    let mut rtg = Vec::new();
    let mut lens: Vec<usize> = Vec::new();
    // Warm-up grows the scratch buffers once.
    let slab = slab_of(&lanes).expect("aligned columns form a slab");
    gae_batched_strided_into(
        &params,
        slab.planes.t_len,
        slab.width,
        slab.planes.batch,
        slab.rewards(),
        slab.values(),
        slab.done_mask(),
        &mut adv,
        &mut rtg,
    );
    lens.resize(slab.width, slab.planes.t_len);

    let before = thread_allocs();
    for _ in 0..32 {
        let slab = slab_of(&lanes).unwrap();
        gae_batched_strided_into(
            &params,
            slab.planes.t_len,
            slab.width,
            slab.planes.batch,
            slab.rewards(),
            slab.values(),
            slab.done_mask(),
            &mut adv,
            &mut rtg,
        );
        lens.clear();
        lens.resize(slab.width, slab.planes.t_len);
    }
    assert_eq!(
        thread_allocs() - before,
        0,
        "slab fast path must be allocation-free in steady state"
    );
    assert!(adv.iter().all(|x| x.is_finite()));
}

#[test]
fn packed_scratch_is_allocation_free_and_seed_path_is_not() {
    let mut g = Gen::new(2);
    let params = GaeParams::default();
    let lanes = ragged_owned(&mut g, 12, 64);
    let mut tile = PaddedTile::empty();
    let mut adv = Vec::new();
    let mut rtg = Vec::new();
    // Warm-up.
    tile.pack_lane_views(&lanes);
    gae_batched_strided_into(
        &params,
        tile.t_len,
        tile.lanes,
        tile.lanes,
        &tile.rewards,
        &tile.values,
        &tile.done_mask,
        &mut adv,
        &mut rtg,
    );

    // Warmed scratch repack: zero allocations per group.
    let before = thread_allocs();
    for _ in 0..32 {
        tile.pack_lane_views(&lanes);
        gae_batched_strided_into(
            &params,
            tile.t_len,
            tile.lanes,
            tile.lanes,
            &tile.rewards,
            &tile.values,
            &tile.done_mask,
            &mut adv,
            &mut rtg,
        );
    }
    assert_eq!(
        thread_allocs() - before,
        0,
        "warmed packed fallback must be allocation-free"
    );

    // The seed-shaped path: a fresh tile (4 plane/len vectors) plus a
    // fresh output pair, every single group.
    let before = thread_allocs();
    let fresh = PaddedTile::from_lane_views(&lanes);
    let (batch, _lens) = fresh.into_parts();
    let out = gae_batched(&params, &batch);
    let seed_allocs = thread_allocs() - before;
    assert!(
        seed_allocs >= 4,
        "seed path should allocate >= 4 times per group, counted {seed_allocs}"
    );
    assert_eq!(out.advantages.len(), batch.t_len * batch.batch);
}

#[test]
fn slab_packed_and_scalar_reference_are_bit_identical() {
    check("slab == packed == scalar (random groups)", 25, |g| {
        let params = GaeParams::default();
        // Aligned: a column window (stride >= width) of a wider set.
        let t_len = g.usize_in(1, 48);
        let width = g.usize_in(1, 12);
        let batch = width + g.usize_in(0, 5);
        let col0 = g.usize_in(0, batch - width);
        let planes = Arc::new(plane_set(g, t_len, batch));
        let lanes = column_lanes(&planes, col0..col0 + width);

        let slab = slab_of(&lanes).expect("window must be a slab");
        assert_eq!((slab.col0, slab.width), (col0, width));
        let mut slab_adv = Vec::new();
        let mut slab_rtg = Vec::new();
        gae_batched_strided_into(
            &params,
            t_len,
            slab.width,
            slab.planes.batch,
            slab.rewards(),
            slab.values(),
            slab.done_mask(),
            &mut slab_adv,
            &mut slab_rtg,
        );

        let (tile_batch, lens) = PaddedTile::from_lane_views(&lanes).into_parts();
        let packed = gae_batched(&params, &tile_batch);

        for (i, lane) in lanes.iter().enumerate() {
            let want = gae_indexed(
                &params,
                lane.len(),
                |t| lane.reward(t),
                |t| lane.value(t),
                |t| lane.done(t),
            );
            for t in 0..t_len {
                let w = want.advantages[t].to_bits();
                assert_eq!(slab_adv[t * width + i].to_bits(), w, "slab col {i} t {t}");
                assert_eq!(
                    packed.advantages[t * width + i].to_bits(),
                    w,
                    "packed col {i} t {t}"
                );
                let wr = want.rewards_to_go[t].to_bits();
                assert_eq!(slab_rtg[t * width + i].to_bits(), wr);
                assert_eq!(packed.rewards_to_go[t * width + i].to_bits(), wr);
            }
        }
        assert_eq!(lens, vec![t_len; width]);

        // Ragged: owned lanes through the packed fallback vs scalar.
        let ragged = ragged_owned(g, g.usize_in(1, 8), 24);
        let (rb, rlens) = PaddedTile::from_lane_views(&ragged).into_parts();
        let rout = gae_batched(&params, &rb);
        let mut per_lane = Vec::new();
        unpack_lanes_into(&rlens, rb.batch, &rout.advantages, &rout.rewards_to_go, &mut per_lane);
        for (lane, got) in ragged.iter().zip(&per_lane) {
            let want = gae_indexed(
                &params,
                lane.len(),
                |t| lane.reward(t),
                |t| lane.value(t),
                |t| lane.done(t),
            );
            assert_eq!(got.advantages.len(), lane.len());
            for t in 0..lane.len() {
                assert_eq!(got.advantages[t].to_bits(), want.advantages[t].to_bits());
                assert_eq!(
                    got.rewards_to_go[t].to_bits(),
                    want.rewards_to_go[t].to_bits()
                );
            }
        }
    });
}

#[test]
fn vecpool_steady_take_give_cycle_allocates_nothing() {
    use heppo::service::vecpool;
    // Class 1024 is not touched by the other tests in this binary (the
    // service tests move ≤ 256-element lanes), so parallel test threads
    // cannot drain our warmed class mid-measurement.
    const LEN: usize = 1024;
    // Warm-up: populates the class with enough vectors to cover the
    // loop's peak of two outstanding, and grows the class's own storage.
    for _ in 0..4 {
        let a = vecpool::take(LEN);
        let b = vecpool::take_zeroed(LEN);
        vecpool::give(a);
        vecpool::give(b);
    }
    let stats_before = vecpool::stats();
    let before = thread_allocs();
    for i in 0..64 {
        let mut adv = vecpool::take(LEN);
        adv.resize(LEN, i as f32);
        let mut rtg = vecpool::take_zeroed(LEN);
        rtg[0] = i as f32;
        vecpool::give(adv);
        vecpool::give(rtg);
    }
    assert_eq!(
        thread_allocs() - before,
        0,
        "warmed take/fill/give cycle must be allocation-free"
    );
    let stats_after = vecpool::stats();
    assert!(
        stats_after.hits - stats_before.hits >= 128,
        "all 128 takes must be pool hits, counted {}",
        stats_after.hits - stats_before.hits
    );
}

#[test]
fn service_counts_slab_tiles_for_plane_sets_and_packed_for_ragged() {
    let svc = GaeService::start(ServiceConfig {
        workers: 1,
        backend: GaeBackend::Batched,
        ..ServiceConfig::default()
    })
    .unwrap();
    let mut g = Gen::new(9);
    let (t_len, batch) = (32, 8);
    let planes = plane_set(&mut g, t_len, batch);
    let got = svc
        .submit_plane_set(planes)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(got.advantages.len(), t_len * batch);
    let snap = svc.metrics();
    assert!(snap.slab_tiles > 0, "plane-set traffic must ride the slab path");
    assert_eq!(snap.gathered_bytes, 0, "slab groups must gather zero bytes");
    assert_eq!(snap.packed_tiles, 0);

    // Ragged owned trajectories force the packed fallback.
    let trajs: Vec<Trajectory> = (0..5)
        .map(|i| {
            let len = 6 + i;
            Trajectory::new(
                g.vec_normal_f32(len, 0.0, 1.0),
                g.vec_normal_f32(len + 1, 0.0, 1.0),
                vec![false; len],
            )
        })
        .collect();
    svc.submit(trajs).unwrap();
    let snap = svc.metrics();
    assert!(snap.packed_tiles > 0, "ragged traffic must take the packed fallback");
    assert!(snap.gathered_bytes > 0);
    svc.shutdown();
}

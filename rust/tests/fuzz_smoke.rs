//! Bounded, deterministic fuzz campaign over the wire surface — the
//! offline CI face of the fuzzing battery (`heppo::net::fuzzing`).
//!
//! Each test drives one harness through `campaign()`: seeded inputs
//! mixing raw garbage with seed-corpus mutants, every run reproducible
//! from its printed seed. `HEPPO_FUZZ_ITERS` scales the per-harness
//! budget (default 500; CI pins an explicit value); any panic is a
//! genuine finding — minimize it, name it, and append it to
//! `seed_corpus()` so it replays forever.
//!
//! The campaign also writes its corpus to `results/fuzz_corpus/` so CI
//! can upload it as an artifact and a registry-connected machine can
//! seed `cargo fuzz` with exactly what the smoke run covered.

use heppo::net::fuzzing::{
    campaign, run_codec_roundtrip, run_conn_state, run_frame_decode, seed_corpus,
};

/// Per-harness iteration budget: `HEPPO_FUZZ_ITERS` or 500.
fn iters() -> u64 {
    std::env::var("HEPPO_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500)
}

/// Distinct, stable seeds per harness so one harness's coverage does
/// not shadow another's; printed so a failure is replayable verbatim.
fn run(name: &str, harness: fn(&[u8]), seed: u64) {
    let iters = iters();
    println!("fuzz campaign {name:?}: seed {seed:#x}, {iters} iters");
    campaign(harness, seed, iters);
}

#[test]
fn frame_decode_survives_campaign() {
    run("frame_decode", run_frame_decode, 0xF0A1_0001);
}

#[test]
fn codec_roundtrip_survives_campaign() {
    run("codec_roundtrip", run_codec_roundtrip, 0xF0A1_0002);
}

#[test]
fn conn_state_survives_campaign() {
    run("conn_state", run_conn_state, 0xF0A1_0003);
}

#[test]
fn corpus_is_exported_for_artifact_upload() {
    let dir = std::path::Path::new("results").join("fuzz_corpus");
    std::fs::create_dir_all(&dir).expect("create results/fuzz_corpus");
    let corpus = seed_corpus();
    for (i, entry) in corpus.iter().enumerate() {
        std::fs::write(dir.join(format!("seed-{i:03}.bin")), entry)
            .expect("write corpus entry");
    }
    println!("wrote {} corpus entries to {}", corpus.len(), dir.display());
    // Every exported entry must clear the decode harness — the corpus
    // is the regression suite, so a panicking entry is a red build.
    for entry in &corpus {
        run_frame_decode(entry);
    }
}

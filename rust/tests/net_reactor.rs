//! Reactor-mode integration: behaviors only the epoll front-end has.
//!
//! `net_loopback.rs` already pins every client-observable scenario to
//! byte-identical behavior across both server modes. This file covers
//! the reactor's own machinery over real sockets: the resumable parse
//! under pathological write chunking (1-byte and random splits,
//! interleaved across connections), the slow-consumer shed (typed
//! `Shed` frame + `slow_closed` metric), the metrics RPC, trace-id
//! propagation across the reactor's cross-thread completion hop, and
//! idle-connection fan-in not starving active peers.

#![cfg(target_os = "linux")]

use heppo::coordinator::GaeBackend;
use heppo::gae::GaeParams;
use heppo::net::{
    wire, ErrorKind, NetClient, NetClientConfig, NetServer, NetServerConfig, PlaneCodec,
    ServerMode,
};
use heppo::quant::CodecKind;
use heppo::service::{BatcherConfig, GaeService, ServiceConfig};
use heppo::testing::Gen;
use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn service(workers: usize, queue_capacity: usize) -> Arc<GaeService> {
    Arc::new(
        GaeService::start(ServiceConfig {
            workers,
            backend: GaeBackend::Scalar,
            queue_capacity,
            batcher: BatcherConfig {
                max_batch_lanes: 64,
                tile_lanes: 16,
                max_wait: Duration::from_micros(100),
            },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    )
}

fn reactor_cfg() -> NetServerConfig {
    NetServerConfig { mode: ServerMode::Reactor, ..NetServerConfig::default() }
}

fn request_frame(g: &mut Gen, seq: u64, t_len: usize, batch: usize) -> Vec<u8> {
    let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
    let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
    let done_mask: Vec<f32> = (0..t_len * batch)
        .map(|_| if g.bool_p(0.05) { 1.0 } else { 0.0 })
        .collect();
    wire::encode_request(
        seq,
        "chunky",
        PlaneCodec::F32,
        PlaneCodec::F32,
        0,
        t_len,
        batch,
        &rewards,
        &values,
        &done_mask,
    )
    .unwrap()
    .bytes
}

/// Read `count` response frames and key them by sequence number.
fn read_responses(stream: &TcpStream, count: usize) -> HashMap<u64, Vec<u8>> {
    let clone = stream.try_clone().unwrap();
    clone.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = std::io::BufReader::new(clone);
    let mut by_seq = HashMap::new();
    for _ in 0..count {
        let frame = wire::read_frame(&mut reader).unwrap().expect("response frame");
        match wire::decode_frame(&frame).unwrap() {
            wire::Frame::Response(resp) => {
                assert!(by_seq.insert(resp.seq, frame).is_none(), "duplicate seq");
            }
            other => panic!("expected a response frame, got {other:?}"),
        }
    }
    by_seq
}

/// The tentpole property over real sockets: the same frames delivered
/// whole, as 1-byte trickles, as random splits, and as splits pinned to
/// the length-prefix boundary — interleaved across connections — must
/// produce byte-identical response sets.
#[test]
fn chunked_and_interleaved_writes_match_whole_frame_responses() {
    let svc = service(2, 256);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..reactor_cfg() },
    )
    .unwrap();
    let addr = server.local_addr();
    const FRAMES: usize = 6;

    // One frame set per chunking style; the control connection sends
    // each set whole, so styles with different payloads still compare
    // against their own exact baseline.
    let mut g = Gen::new(42);
    let frame_sets: Vec<Vec<Vec<u8>>> = (0..3)
        .map(|_| {
            (1..=FRAMES as u64)
                .map(|seq| {
                    let (t_len, batch) = (g.usize_in(1, 50), g.usize_in(1, 4));
                    request_frame(&mut g, seq, t_len, batch)
                })
                .collect()
        })
        .collect();

    // Control: whole-frame writes of every set on dedicated conns.
    let mut expected: Vec<HashMap<u64, Vec<u8>>> = Vec::new();
    for set in &frame_sets {
        let mut conn = TcpStream::connect(addr).unwrap();
        for frame in set {
            conn.write_all(frame).unwrap();
        }
        conn.flush().unwrap();
        expected.push(read_responses(&conn, FRAMES));
    }

    // Chunked: style 0 = 1-byte trickle, style 1 = random splits,
    // style 2 = splits pinned around the 4-byte length prefix (the
    // prefix itself arrives in two pieces, the regression case).
    let mut chunk_queues: Vec<std::collections::VecDeque<Vec<u8>>> = frame_sets
        .iter()
        .enumerate()
        .map(|(style, set)| {
            let mut chunks = std::collections::VecDeque::new();
            for frame in set {
                let mut rest: &[u8] = frame;
                while !rest.is_empty() {
                    let take = match style {
                        0 => 1,
                        1 => g.usize_in(1, rest.len().min(64)),
                        _ => {
                            // First two chunks split the prefix at byte
                            // 2, then the body in large pieces.
                            if rest.len() == frame.len() {
                                2
                            } else if rest.len() == frame.len() - 2 {
                                3
                            } else {
                                rest.len().min(512)
                            }
                        }
                    };
                    chunks.push_back(rest[..take].to_vec());
                    rest = &rest[take..];
                }
            }
            chunks
        })
        .collect();
    let conns: Vec<TcpStream> =
        (0..3).map(|_| TcpStream::connect(addr).unwrap()).collect();
    // Interleave: one chunk per connection per round, so partial frames
    // from different connections are in flight simultaneously.
    loop {
        let mut wrote = false;
        for (i, queue) in chunk_queues.iter_mut().enumerate() {
            if let Some(chunk) = queue.pop_front() {
                (&conns[i]).write_all(&chunk).unwrap();
                wrote = true;
            }
        }
        if !wrote {
            break;
        }
    }
    for (i, conn) in conns.iter().enumerate() {
        let got = read_responses(conn, FRAMES);
        assert_eq!(
            got, expected[i],
            "chunking style {i} produced different response bytes"
        );
    }
    assert_eq!(server.frames_received(), 2 * 3 * FRAMES as u64);
    server.shutdown();
}

/// A client that pipelines big requests and never reads must be shed:
/// the write backlog fills past the deadline, the server appends a
/// typed `Shed` error frame (seq 0 — connection-level), counts it in
/// `slow_closed`, and closes the socket.
#[test]
fn slow_consumer_is_shed_with_typed_error_and_metrics_tick() {
    let svc = service(2, 256);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            cache_entries: 0,
            write_backlog_frames: 2,
            slow_conn_deadline: Duration::from_millis(800),
            reactor_threads: 1,
            completer_threads: 2,
            ..reactor_cfg()
        },
    )
    .unwrap();
    let conn = TcpStream::connect(server.local_addr()).unwrap();
    let mut write_half = conn.try_clone().unwrap();
    write_half.set_write_timeout(Some(Duration::from_secs(30))).unwrap();
    // ~260 KB per request / response: a handful of stuck responses
    // overflow the kernel buffers, then the 2-frame backlog.
    let writer = std::thread::spawn(move || {
        let mut g = Gen::new(9);
        for seq in 1..=16u64 {
            let frame = request_frame(&mut g, seq, 8000, 4);
            // EPIPE/timeout once the shed lands is the expected exit.
            if write_half.write_all(&frame).is_err() {
                break;
            }
        }
    });

    let deadline = Instant::now() + Duration::from_secs(30);
    while svc.metrics().slow_closed == 0 {
        assert!(Instant::now() < deadline, "slow consumer was never shed");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Drain what the server managed to send: whole response frames,
    // then the shed notice, then EOF — the kept-partial-head rule means
    // the stream stays framed all the way down.
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = std::io::BufReader::new(&conn);
    let mut shed_frames = 0;
    while let Ok(Some(frame)) = wire::read_frame(&mut reader) {
        if let Ok(wire::Frame::Error(err)) = wire::decode_frame(&frame) {
            assert_eq!(err.kind, ErrorKind::Shed, "unexpected error: {err:?}");
            assert_eq!(err.seq, 0, "slow-consumer sheds are connection-level");
            shed_frames += 1;
        }
    }
    assert_eq!(shed_frames, 1, "exactly one shed notice expected");
    assert_eq!(svc.metrics().slow_closed, 1);
    writer.join().unwrap();
    server.shutdown();
}

/// The metrics RPC answers inline from the reactor loop (it must not
/// queue behind plane compute) and carries the new `slow_closed` field.
#[test]
fn metrics_rpc_over_reactor_reports_cache_and_shed_counters() {
    let svc = service(2, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 64, ..reactor_cfg() },
    )
    .unwrap();
    let client = NetClient::connect(
        &server.local_addr().to_string(),
        NetClientConfig::default(),
    )
    .unwrap();
    let mut g = Gen::new(17);
    let t_len = 12;
    let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
    let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
    let done = vec![0.0; t_len];
    client.call_planes(t_len, 1, &rewards, &values, &done).unwrap();
    let second = client.call_planes(t_len, 1, &rewards, &values, &done).unwrap();
    assert!(second.cache_hit);

    let snap = client.fetch_metrics().unwrap();
    assert_eq!((snap.cache_hits, snap.cache_misses), (1, 1));
    assert_eq!(snap.slow_closed, 0);
    server.shutdown();
}

/// A traced request keeps one trace id across the whole reactor path:
/// decode on the event loop, enqueue, and the completion hop back from
/// the pump thread (`server.reply`).
#[test]
fn traced_request_spans_cross_the_reactor_completion_hop() {
    let svc = service(1, 64);
    let server =
        NetServer::start(Arc::clone(&svc), "127.0.0.1:0", reactor_cfg()).unwrap();
    let client = NetClient::connect(
        &server.local_addr().to_string(),
        NetClientConfig {
            tenant: "traced".to_string(),
            codec: CodecKind::Exp1Baseline,
            bits: 8,
            resp: PlaneCodec::F32,
            auth: None,
        },
    )
    .unwrap();

    heppo::obs::take_events(); // discard unrelated earlier activity
    heppo::obs::set_enabled(true);
    let mut g = Gen::new(23);
    let rewards = g.vec_normal_f32(16, 0.0, 1.0);
    let values = g.vec_normal_f32(17, 0.0, 1.0);
    let done = vec![0.0; 16];
    client.call_planes(16, 1, &rewards, &values, &done).unwrap();
    heppo::obs::set_enabled(false);

    assert_eq!(client.wire_stats().traced_frames, 1);
    let events = heppo::obs::take_events();
    // Other tests may be tracing concurrently; it suffices that *some*
    // trace id (ours is guaranteed complete, the call returned) walked
    // the whole path decode → enqueue → reply → complete.
    let full_chain = events
        .iter()
        .filter(|e| e.name == "server.decode" && e.trace != 0)
        .any(|d| {
            ["server.enqueue", "server.reply", "client.complete"]
                .iter()
                .all(|name| events.iter().any(|e| e.name == *name && e.trace == d.trace))
        });
    assert!(full_chain, "no trace id crossed the whole reactor path intact");
    server.shutdown();
}

/// Hundreds of idle connections must cost the reactor nothing: an
/// active client behind them still gets every answer.
#[test]
fn idle_connection_fanin_does_not_starve_active_clients() {
    let svc = service(2, 128);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { max_connections: 2048, ..reactor_cfg() },
    )
    .unwrap();
    let addr = server.local_addr();
    let idle: Vec<TcpStream> =
        (0..300).map(|_| TcpStream::connect(addr).unwrap()).collect();

    let client = NetClient::connect(
        &addr.to_string(),
        NetClientConfig { resp: PlaneCodec::F32, ..NetClientConfig::default() },
    )
    .unwrap();
    let mut g = Gen::new(5);
    for _ in 0..5 {
        let t_len = g.usize_in(1, 32);
        let rewards = g.vec_normal_f32(t_len * 2, 0.0, 1.0);
        let values = g.vec_normal_f32((t_len + 1) * 2, 0.0, 1.0);
        let done = vec![0.0; t_len * 2];
        let out = client.call_planes(t_len, 2, &rewards, &values, &done).unwrap();
        assert_eq!(out.advantages.len(), t_len * 2);
    }
    assert_eq!(server.frames_received(), 5);
    drop(idle);
    server.shutdown();
}

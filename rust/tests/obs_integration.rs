//! Integration of the cross-layer tracing subsystem ([`heppo::obs`]):
//! one net-loopback request produces a single connected span tree —
//! client submit → wire decode → queue → batch → worker compute →
//! encode → client complete — sharing one trace id; a forced fabric
//! failover keeps both serving-shard attempts on that one timeline; and
//! the fleet view pulls full remote [`MetricsSnapshot`]s over the wire
//! metrics RPC.
//!
//! The span recorder and its drain ([`heppo::obs::take_events`]) are
//! process-global, so every test here serializes on [`OBS_LOCK`] and
//! drains the rings before and after its traced section.

use heppo::coordinator::GaeBackend;
use heppo::fabric::{
    ClientPool, FabricConfig, GaeFabric, PoolConfig, ShardBackend,
};
use heppo::net::{
    NetClient, NetClientConfig, NetServer, NetServerConfig, PlaneCodec,
};
use heppo::obs::{Event, EventKind};
use heppo::quant::CodecKind;
use heppo::service::{BatcherConfig, GaeService, ServiceConfig};
use heppo::util::Rng;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serializes every test that enables tracing or drains the rings.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_guard() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn service(workers: usize, backend: GaeBackend, queue_capacity: usize) -> Arc<GaeService> {
    Arc::new(
        GaeService::start(ServiceConfig {
            workers,
            backend,
            queue_capacity,
            batcher: BatcherConfig {
                max_batch_lanes: 64,
                tile_lanes: 16,
                max_wait: Duration::from_micros(100),
            },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: Default::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    )
}

fn f32_client(addr: &str) -> NetClient {
    NetClient::connect(
        addr,
        NetClientConfig {
            tenant: "test".to_string(),
            codec: CodecKind::Exp1Baseline,
            bits: 8,
            resp: PlaneCodec::F32,
            auth: None,
        },
    )
    .unwrap()
}

fn planes(seed: u64, t_len: usize, batch: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut rewards = vec![0.0f32; t_len * batch];
    let mut values = vec![0.0f32; (t_len + 1) * batch];
    rng.fill_normal_f32(&mut rewards);
    rng.fill_normal_f32(&mut values);
    let done_mask = (0..t_len * batch)
        .map(|_| if rng.uniform() < 0.05 { 1.0 } else { 0.0 })
        .collect();
    (rewards, values, done_mask)
}

fn events_named<'a>(events: &'a [Event], trace: u64, name: &str) -> Vec<&'a Event> {
    events
        .iter()
        .filter(|e| e.trace == trace && e.name == name)
        .collect()
}

#[test]
fn one_loopback_request_is_one_connected_span_tree() {
    let _g = obs_guard();
    let svc = service(2, GaeBackend::Scalar, 256);
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let client = f32_client(&server.local_addr().to_string());

    heppo::obs::take_events(); // discard anything from earlier activity
    heppo::obs::set_enabled(true);
    let (t_len, batch) = (16, 4);
    let (rewards, values, done_mask) = planes(11, t_len, batch);
    let gae = client
        .submit_planes(t_len, batch, &rewards, &values, &done_mask)
        .unwrap()
        .wait()
        .unwrap();
    heppo::obs::set_enabled(false);
    assert_eq!(gae.advantages.len(), t_len * batch);

    let events = heppo::obs::take_events();
    // Exactly one request was submitted while tracing was on; its trace
    // id is the one on the client.submit span.
    let submits: Vec<&Event> = events
        .iter()
        .filter(|e| e.name == "client.submit" && e.kind == EventKind::Begin)
        .collect();
    assert_eq!(submits.len(), 1, "one traced submit, got {submits:?}");
    let trace = submits[0].trace;
    assert_ne!(trace, 0, "an enabled submit must mint a nonzero trace id");

    // Every stage of the request's life shares that id.
    for name in [
        "client.submit",
        "server.decode",
        "server.admit",
        "server.enqueue",
        "service.enqueue",
        "worker.compute",
        "server.encode",
        "client.complete",
    ] {
        assert!(
            !events_named(&events, trace, name).is_empty(),
            "stage {name} missing from trace {trace:#x}: {events:?}"
        );
    }
    // The worker group span joined the same timeline.
    assert!(
        !events_named(&events, trace, "worker.batch").is_empty(),
        "worker.batch must carry the first traced member's id"
    );
    // Causal order holds across threads (all timestamps share the
    // process trace epoch).
    let ts = |name: &str| events_named(&events, trace, name)[0].ts_ns;
    let submit_ts = ts("client.submit");
    let complete_ts = ts("client.complete");
    assert!(submit_ts <= ts("server.decode"), "submit before decode");
    assert!(ts("server.decode") <= complete_ts, "decode before complete");
    assert!(submit_ts <= ts("worker.compute"), "submit before compute");
    assert!(ts("worker.compute") <= complete_ts, "compute before complete");
    // At least two distinct threads contributed (client + server side).
    let tids: std::collections::HashSet<u64> =
        events.iter().filter(|e| e.trace == trace).map(|e| e.tid).collect();
    assert!(tids.len() >= 2, "span tree must cross threads: {tids:?}");

    // The client saw the traced frame and measured its round trip.
    let stats = client.wire_stats();
    assert_eq!(stats.traced_frames, 1);
    assert!(stats.rtt_count >= 1);

    // Export the tree — CI uploads this as the `trace-sample` artifact.
    heppo::obs::export::write_chrome_trace(
        std::path::Path::new("results/trace_sample.json"),
        &events,
    )
    .unwrap();
    let json = std::fs::read_to_string("results/trace_sample.json").unwrap();
    assert!(json.contains("traceEvents") && json.contains("client.submit"));

    server.shutdown();
}

#[test]
fn a_forced_failover_keeps_both_attempts_on_one_timeline() {
    let _g = obs_guard();
    let services: Vec<Arc<GaeService>> =
        (0..2).map(|_| service(1, GaeBackend::Scalar, 256)).collect();
    let slots = services
        .iter()
        .enumerate()
        .map(|(i, s)| (format!("shard-{i}"), ShardBackend::in_process(Arc::clone(s))))
        .collect();
    let fabric = GaeFabric::new(slots, FabricConfig::default()).unwrap();

    // Pick a key whose primary is shard 0, then kill shard 0 so the
    // request must attempt it, fail, and spill to shard 1.
    let key = (0..1024u64)
        .find(|&k| fabric.rank("t", k)[0] == 0)
        .expect("some key must rank shard 0 first");
    services[0].begin_shutdown();

    heppo::obs::take_events();
    heppo::obs::set_enabled(true);
    let (t_len, batch) = (12, 2);
    let (rewards, values, done_mask) = planes(23, t_len, batch);
    let gae = fabric
        .call("t", key, t_len, batch, rewards, values, done_mask)
        .expect("the surviving shard must serve the request");
    heppo::obs::set_enabled(false);
    assert_eq!(gae.shard, 1);
    assert!(gae.failovers >= 1);

    let events = heppo::obs::take_events();
    let attempts: Vec<&Event> =
        events.iter().filter(|e| e.name == "fabric.attempt").collect();
    assert!(!attempts.is_empty());
    let trace = attempts[0].trace;
    assert_ne!(trace, 0);
    assert!(
        attempts.iter().all(|e| e.trace == trace),
        "one request, one trace id across shard attempts: {attempts:?}"
    );
    assert!(
        attempts.len() >= 2,
        "dead primary + survivor = at least two attempts: {attempts:?}"
    );
    // The compute on the surviving shard landed on the same timeline as
    // the failed first attempt.
    assert!(
        !events_named(&events, trace, "worker.compute").is_empty(),
        "survivor's compute must join the request's trace: {events:?}"
    );
    assert!(!events_named(&events, trace, "service.enqueue").is_empty());
}

#[test]
fn fleet_view_pulls_remote_snapshots_over_the_metrics_rpc() {
    let remote_svc = service(1, GaeBackend::Scalar, 256);
    let server = NetServer::start(
        Arc::clone(&remote_svc),
        "127.0.0.1:0",
        NetServerConfig { cache_entries: 0, ..NetServerConfig::default() },
    )
    .unwrap();
    let local_svc = service(1, GaeBackend::Scalar, 256);
    let fabric = GaeFabric::new(
        vec![
            (
                "remote-0".to_string(),
                ShardBackend::remote(
                    &server.local_addr().to_string(),
                    PoolConfig {
                        sockets: 1,
                        codec: PlaneCodec::F32,
                        resp: PlaneCodec::F32,
                        auth: None,
                    },
                )
                .unwrap(),
            ),
            ("local-0".to_string(), ShardBackend::in_process(local_svc)),
        ],
        FabricConfig::default(),
    )
    .unwrap();

    // Deterministically land at least one request on each shard: for
    // each shard, find a key whose rank prefers it.
    let (t_len, batch) = (10, 3);
    for shard in 0..2usize {
        let key = (0..1024u64)
            .find(|&k| fabric.rank("obs", k)[0] == shard)
            .expect("rendezvous must rank every shard first for some key");
        let (rewards, values, done_mask) = planes(31 + shard as u64, t_len, batch);
        let gae = fabric
            .call("obs", key, t_len, batch, rewards, values, done_mask)
            .unwrap();
        assert_eq!(gae.shard, shard);
    }

    let fleet = fabric.fleet();
    let remote = fleet.shards.iter().find(|s| s.label == "remote-0").unwrap();
    let snap = remote
        .service
        .as_ref()
        .expect("a live remote shard must answer the metrics RPC");
    assert!(snap.completed >= 1, "remote snapshot must be populated: {snap:?}");
    assert!(snap.elements > 0);
    let remote_tenant = snap.tenants.iter().find(|t| t.tenant == "obs");
    assert!(
        remote_tenant.is_some_and(|t| t.requests >= 1),
        "remote tenant breakdown must ride the RPC: {:?}",
        snap.tenants
    );
    // The merged fleet breakdown spans both shards' requests.
    let merged = fleet.tenants.iter().find(|t| t.tenant == "obs").unwrap();
    assert!(merged.requests >= 2, "both shards' tenant rows must merge: {fleet}");

    // The RPC also answers outside the fabric, straight off a pool.
    let pool = ClientPool::connect(
        &server.local_addr().to_string(),
        PoolConfig { sockets: 1, codec: PlaneCodec::F32, resp: PlaneCodec::F32, auth: None },
    )
    .unwrap();
    let direct = pool.fetch_metrics().unwrap();
    assert!(direct.completed >= 1);

    // A dead endpoint degrades to None instead of failing the view.
    server.shutdown();
    std::thread::sleep(Duration::from_millis(20));
    let fleet = fabric.fleet();
    let remote = fleet.shards.iter().find(|s| s.label == "remote-0").unwrap();
    assert!(
        remote.service.is_none(),
        "an unreachable shard's snapshot must read None"
    );
}

//! Telemetry-plane integration: the live observability surface over
//! real sockets and a real fabric.
//!
//! Three acceptance scenarios:
//!
//! - **Windowed tail vs lifetime** — loopback load against a
//!   reactor-mode server, scraping the plaintext exposition endpoint
//!   on the *binary* port twice: after a slow burst the 1s-window p99
//!   reflects it immediately while the lifetime p99, diluted by the
//!   fast phase, lags far below.
//! - **Tail-based retention** — the burst's traced slow requests are
//!   promoted into the exemplar store server-side; their trace ids
//!   appear both as OpenMetrics exemplars on the windowed p99 rows
//!   and in the `GET /traces` Chrome-trace export, in the same hex
//!   form, with no client-side cooperation beyond sending a trace id.
//! - **Fleet SLO health** — forcing a shard failure (an overload that
//!   sheds live requests) flips the fleet's burn-rate health to
//!   `Critical` within one window; recovery traffic dilutes the burn
//!   back under budget and the fleet returns to `Ok`.
//!
//! Latency here is made deterministic, not sampled: the batcher
//! lingers `max_wait` only when a drain finds company, so a burst
//! pipelined behind a large head request always forms a group and
//! always pays the linger, while sequential singles never do.

#![cfg(target_os = "linux")]

use heppo::coordinator::GaeBackend;
use heppo::fabric::{FabricConfig, GaeFabric, ShardBackend};
use heppo::gae::GaeParams;
use heppo::net::{wire, NetServer, NetServerConfig, PlaneCodec, ServerMode};
use heppo::obs::telemetry::trace_hex;
use heppo::obs::SloHealth;
use heppo::service::{BatcherConfig, GaeService, ServiceConfig};
use heppo::testing::Gen;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A service whose only latency knob is the batcher linger: solo
/// requests flush immediately (fast), grouped requests wait the full
/// `max_wait` (deterministically slow).
fn linger_service(max_wait: Duration) -> Arc<GaeService> {
    Arc::new(
        GaeService::start(ServiceConfig {
            workers: 1,
            backend: GaeBackend::Scalar,
            queue_capacity: 256,
            batcher: BatcherConfig { max_batch_lanes: 64, tile_lanes: 16, max_wait },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    )
}

fn request_frame(
    g: &mut Gen,
    seq: u64,
    trace: u64,
    t_len: usize,
    batch: usize,
) -> Vec<u8> {
    let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
    let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
    let done_mask: Vec<f32> = (0..t_len * batch)
        .map(|_| if g.bool_p(0.05) { 1.0 } else { 0.0 })
        .collect();
    wire::encode_request(
        seq,
        "telemetry",
        PlaneCodec::F32,
        PlaneCodec::F32,
        trace,
        t_len,
        batch,
        &rewards,
        &values,
        &done_mask,
    )
    .unwrap()
    .bytes
}

/// One-shot plaintext scrape over the binary port: `(status_line,
/// body)`. The server answers and closes, so read-to-EOF terminates.
fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: heppo\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    let (head, body) = raw.split_once("\r\n\r\n").expect("response has a blank line");
    let status = head.lines().next().unwrap_or("").to_string();
    (status, body.to_string())
}

/// Value of the first sample whose name matches and whose label set
/// contains every `labels` fragment. Exemplar suffixes (` # {...}`)
/// are stripped before the value parse.
fn metric_value(body: &str, name: &str, labels: &[&str]) -> f64 {
    for line in body.lines() {
        if !line.starts_with(name) || !line[name.len()..].starts_with('{') {
            continue;
        }
        if !labels.iter().all(|l| line.contains(l)) {
            continue;
        }
        let sample = line.split(" # ").next().unwrap();
        let value = sample.rsplit(' ').next().unwrap();
        return value.parse().unwrap_or_else(|_| panic!("unparsable sample: {line}"));
    }
    panic!("no sample {name}{labels:?} in exposition page:\n{body}");
}

/// The tentpole scenario: real loopback load, two scrapes of the
/// exposition endpoint on the binary port, windowed-vs-lifetime p99
/// divergence after a slow burst, and trace retention visible in both
/// the exposition exemplars and the Chrome-trace export.
#[test]
fn exposition_reports_windowed_tail_and_retains_slow_traces() {
    heppo::obs::set_enabled(true);
    let svc = linger_service(Duration::from_millis(150));
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            mode: ServerMode::Reactor,
            cache_entries: 0,
            ..NetServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(stream);
    let mut g = Gen::new(7);

    // Phase A: sequential singles — each flushes solo, no linger, so
    // they are as fast as the stack can answer. Enough of them that
    // the later slow burst (even retried) stays under 1% of lifetime.
    const FAST: u64 = 1_500;
    for seq in 1..=FAST {
        writer.write_all(&request_frame(&mut g, seq, 0, 8, 1)).unwrap();
        let frame = wire::read_frame(&mut reader).unwrap().expect("response");
        match wire::decode_frame(&frame).unwrap() {
            wire::Frame::Response(r) => assert_eq!(r.seq, seq),
            other => panic!("expected response, got {other:?}"),
        }
    }

    // Scrape #1: all-fast traffic — lifetime and windowed agree.
    let (status, page1) = http_get(addr, "/metrics");
    assert!(status.contains("200"), "scrape #1 status: {status}");
    assert!(page1.contains(&format!("shard=\"{addr}\"")), "shard label is the bound address");
    assert!(metric_value(&page1, "heppo_requests_completed_total", &[]) >= FAST as f64);
    let life_p99_fast = metric_value(
        &page1,
        "heppo_latency_us",
        &["phase=\"total\"", "quantile=\"0.99\""],
    );
    assert!(
        life_p99_fast < 40_000.0,
        "sequential singles should be far under the linger: p99 {life_p99_fast}µs"
    );
    assert_eq!(metric_value(&page1, "heppo_slo_health", &[]), 0.0, "healthy so far");

    // Phase B: the slow burst. A large untraced head request occupies
    // the single worker; three small traced requests pipelined behind
    // it in the same write land in the queue together, form a group,
    // and linger the full 150ms — deterministically slow, and far over
    // the retention threshold the fast phase trained. The burst is
    // aligned to the server's metrics second (via the uptime gauge) so
    // burst and scrape share one 1s window; a boundary race retries.
    let traces = [0x51d0_0001u64, 0x51d0_0002, 0x51d0_0003];
    let mut page2 = String::new();
    for attempt in 0..3 {
        let (_, probe) = http_get(addr, "/metrics");
        let up = metric_value(&probe, "heppo_uptime_seconds", &[]);
        let frac = up - up.floor();
        if frac > 0.25 {
            std::thread::sleep(Duration::from_secs_f64(1.02 - frac));
        }
        let base = 100_000 + attempt as u64 * 10;
        let mut burst = request_frame(&mut g, base, 0, 20_000, 4);
        for (i, trace) in traces.iter().enumerate() {
            burst.extend(request_frame(&mut g, base + 1 + i as u64, *trace, 8, 1));
        }
        writer.write_all(&burst).unwrap();
        for _ in 0..4 {
            let frame = wire::read_frame(&mut reader).unwrap().expect("burst response");
            assert!(matches!(
                wire::decode_frame(&frame).unwrap(),
                wire::Frame::Response(_)
            ));
        }
        let (_, page) = http_get(addr, "/metrics");
        if metric_value(&page, "heppo_window_completed", &["window=\"1s\""]) >= 3.0 {
            page2 = page;
            break;
        }
    }
    assert!(!page2.is_empty(), "burst never landed inside one exposition second");

    // Scrape #2: the 1s window is dominated by the burst, so its p99
    // carries the linger; the lifetime p99 is still diluted by 1500
    // fast singles and lags far behind.
    let win_p99 = metric_value(
        &page2,
        "heppo_window_latency_us",
        &["window=\"1s\"", "quantile=\"0.99\""],
    );
    let life_p99 = metric_value(
        &page2,
        "heppo_latency_us",
        &["phase=\"total\"", "quantile=\"0.99\""],
    );
    assert!(
        win_p99 >= 80_000.0,
        "1s-window p99 must reflect the 150ms linger, got {win_p99}µs"
    );
    assert!(
        life_p99 < 40_000.0,
        "lifetime p99 must still be diluted by the fast phase, got {life_p99}µs"
    );
    assert!(
        win_p99 > 2.0 * life_p99,
        "windowed p99 ({win_p99}µs) should dwarf lifetime p99 ({life_p99}µs)"
    );

    // Retention: the slow traced requests were promoted server-side;
    // their ids ride the windowed p99 rows as OpenMetrics exemplars…
    assert!(metric_value(&page2, "heppo_exemplars_retained_total", &[]) >= 1.0);
    let exemplar_hexes: Vec<String> = traces.iter().map(|t| trace_hex(*t)).collect();
    let on_page: Vec<&String> = exemplar_hexes
        .iter()
        .filter(|h| page2.contains(&format!("trace_id=\"{h}\"")))
        .collect();
    assert!(
        !on_page.is_empty(),
        "no burst trace id exposed as an exemplar:\n{page2}"
    );

    // …and the same hex ids stitch into the Chrome-trace export.
    let (status, chrome) = http_get(addr, "/traces");
    assert!(status.contains("200"), "traces status: {status}");
    assert!(chrome.contains("traceEvents"));
    for hex in &on_page {
        assert!(
            chrome.contains(hex.as_str()),
            "exemplar {hex} missing from the Chrome-trace export"
        );
    }

    // Keep the scraped pages as CI artifacts: a loaded exposition page
    // (windowed rows + exemplars) and the retained Chrome trace.
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/exposition_sample.txt", &page2).unwrap();
    std::fs::write("results/trace_retained.json", &chrome).unwrap();

    server.shutdown();
    svc.begin_shutdown();
}

/// Both front-ends answer plaintext on the binary port: the threads
/// mode serves the same pages, wrong paths 404, wrong methods 405, and
/// the binary protocol keeps working beside the scrapes.
#[test]
fn threads_mode_serves_the_same_exposition_beside_binary_frames() {
    let svc = linger_service(Duration::from_micros(100));
    let server = NetServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        NetServerConfig {
            mode: ServerMode::Threads,
            cache_entries: 0,
            ..NetServerConfig::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // Binary request on one connection…
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(stream);
    let mut g = Gen::new(11);
    writer.write_all(&request_frame(&mut g, 1, 0, 16, 2)).unwrap();
    let frame = wire::read_frame(&mut reader).unwrap().expect("response");
    assert!(matches!(wire::decode_frame(&frame).unwrap(), wire::Frame::Response(_)));

    // …and scrapes on others, against the same port.
    let (status, body) = http_get(addr, "/metrics");
    assert!(status.contains("200"), "{status}");
    assert!(body.contains(&format!("shard=\"{addr}\"")));
    assert!(metric_value(&body, "heppo_requests_completed_total", &[]) >= 1.0);
    let (status, body) = http_get(addr, "/traces");
    assert!(status.contains("200"), "{status}");
    assert!(body.contains("traceEvents"));
    let (status, _) = http_get(addr, "/nope");
    assert!(status.contains("404"), "{status}");

    // Only `GET ` sniffs as plaintext; other methods would parse as a
    // (hopeless) binary frame, so the 405 arm is covered by the proto
    // unit tests rather than over a socket.

    // The binary connection survived the scrapes.
    writer.write_all(&request_frame(&mut g, 2, 0, 16, 2)).unwrap();
    let frame = wire::read_frame(&mut reader).unwrap().expect("response");
    assert!(matches!(wire::decode_frame(&frame).unwrap(), wire::Frame::Response(_)));

    server.shutdown();
    svc.begin_shutdown();
}

/// Forced shard failure → fleet `Critical` within one window → diluted
/// recovery → `Ok`. The failure is a real overload: a single-worker
/// shard with a 2-deep queue sheds live submissions, which burns the
/// 99.9% availability budget orders of magnitude past the fast-burn
/// bar in both fast windows.
#[test]
fn fleet_slo_health_flips_critical_on_forced_failure_then_recovers() {
    let svc = Arc::new(
        GaeService::start(ServiceConfig {
            workers: 1,
            backend: GaeBackend::Scalar,
            queue_capacity: 2,
            batcher: BatcherConfig {
                max_batch_lanes: 4,
                tile_lanes: 4,
                max_wait: Duration::from_micros(100),
            },
            sim_rows: 16,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            ..ServiceConfig::default()
        })
        .unwrap(),
    );
    let fabric = GaeFabric::new(
        vec![("solo".to_string(), ShardBackend::in_process(Arc::clone(&svc)))],
        FabricConfig {
            cooldown: Duration::from_millis(50),
            max_attempts: 2,
            request_timeout: None,
        },
    )
    .unwrap();
    let mut g = Gen::new(23);
    let mut key = 0u64;

    fn planes(g: &mut Gen, t_len: usize, batch: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            g.vec_normal_f32(t_len * batch, 0.0, 1.0),
            g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0),
            vec![0.0f32; t_len * batch],
        )
    }

    // Warm-up traffic on a healthy shard: Ok.
    for _ in 0..20 {
        key += 1;
        let (rewards, values, done_mask) = planes(&mut g, 8, 1);
        fabric.call("slo", key, 8, 1, rewards, values, done_mask).unwrap();
    }
    assert_eq!(fabric.fleet().health, SloHealth::Ok, "{}", fabric.fleet());

    // Force the failure: ten large requests submitted back-to-back.
    // The first occupies the only worker for milliseconds, two fit the
    // queue, and the rest shed instantly — live requests failing, not
    // injected counters. Shed-vs-completed in the 1s and 10s windows
    // then burns the availability budget at ~100x, and the fleet goes
    // Critical. (A second boundary can split the burst off the
    // snapshot's current window; the outer loop re-forces it.)
    let mut went_critical = false;
    for _ in 0..3 {
        // Payloads generated up front so the submit loop itself is
        // microseconds — far inside the first request's compute time.
        let t_len = 30_000;
        let payloads: Vec<_> = (0..10).map(|_| planes(&mut g, t_len, 2)).collect();
        let mut pending = Vec::new();
        for (rewards, values, done_mask) in payloads {
            key += 1;
            // Shed submissions fail here or on wait; both are the point.
            if let Ok(p) = fabric.submit("slo", key, t_len, 2, rewards, values, done_mask)
            {
                pending.push(p);
            }
        }
        let fleet = fabric.fleet();
        if fleet.health == SloHealth::Critical {
            went_critical = true;
        }
        assert!(
            fleet.to_string().contains("slo"),
            "fleet display carries the verdict: {fleet}"
        );
        for p in pending {
            let _ = p.wait();
        }
        if went_critical {
            break;
        }
    }
    assert!(went_critical, "overload never flipped the fleet Critical");

    // Recovery: the shard is fine — only its recent window is burned.
    // Healthy traffic dilutes shed-vs-total in every window below the
    // burn bars (the 1s window clears by itself), and the fleet walks
    // back to Ok without any restart.
    let deadline = Instant::now() + Duration::from_secs(30);
    let recovered = loop {
        for _ in 0..500 {
            key += 1;
            let (rewards, values, done_mask) = planes(&mut g, 4, 1);
            let _ = fabric.call("slo", key, 4, 1, rewards, values, done_mask);
        }
        let fleet = fabric.fleet();
        if fleet.health == SloHealth::Ok {
            break true;
        }
        if Instant::now() > deadline {
            eprintln!("still {} at deadline:\n{fleet}", fleet.health);
            break false;
        }
        std::thread::sleep(Duration::from_millis(200));
    };
    assert!(recovered, "fleet never recovered to Ok");
    svc.begin_shutdown();
}

//! Integration: the GAE serving subsystem end to end — queue
//! backpressure and admission control, batcher padding/mask correctness
//! through the full service, concurrent multi-client traffic on every
//! backend, and shutdown semantics.

use heppo::coordinator::GaeBackend;
use heppo::gae::reference::gae_trajectory;
use heppo::gae::{GaeParams, Trajectory};
use heppo::service::{
    BatcherConfig, BoundedQueue, GaeService, PaddedTile, PushError, ServiceConfig,
    ServiceError,
};
use heppo::testing::{check, Gen};
use std::sync::Arc;
use std::time::Duration;

fn ragged_request(g: &mut Gen, n_traj: usize, max_t: usize) -> Vec<Trajectory> {
    heppo::testing::ragged_trajectories(g.rng(), n_traj, 1, max_t, 0.1)
}

fn service(workers: usize, backend: GaeBackend, queue_capacity: usize) -> GaeService {
    GaeService::start(ServiceConfig {
        workers,
        backend,
        queue_capacity,
        batcher: BatcherConfig {
            max_batch_lanes: 64,
            tile_lanes: 16,
            max_wait: Duration::from_micros(100),
        },
        sim_rows: 16,
        scalar_route_max_elements: 0,
        gae: GaeParams::default(),
        ..ServiceConfig::default()
    })
    .unwrap()
}

// ---------------------------------------------------------------- queue

#[test]
fn queue_backpressure_blocks_then_resumes() {
    let q = Arc::new(BoundedQueue::new(2));
    q.push(1u32).unwrap();
    q.push(2).unwrap();
    // try_push sheds at the admission limit.
    assert!(matches!(q.try_push(3), Err(PushError::Full(3))));

    // A blocking push parks until a consumer frees a slot.
    let qp = Arc::clone(&q);
    let producer = std::thread::spawn(move || qp.push(3));
    std::thread::sleep(Duration::from_millis(20));
    assert_eq!(q.len(), 2, "producer must be parked while the queue is full");
    assert_eq!(q.pop(), Some(1));
    producer.join().unwrap().unwrap();
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), Some(3));
    assert_eq!(q.peak_depth(), 2);
}

#[test]
fn queue_close_releases_producers_and_consumers() {
    let q = Arc::new(BoundedQueue::<u8>::new(1));
    q.push(0).unwrap();
    let qp = Arc::clone(&q);
    let blocked_producer = std::thread::spawn(move || qp.push(1));
    let qc = Arc::clone(&q);
    let draining_consumer = std::thread::spawn(move || {
        let mut got = Vec::new();
        while let Some(v) = qc.pop() {
            got.push(v);
        }
        got
    });
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    // The producer either won the race before close or was refused at it.
    let _ = blocked_producer.join().unwrap();
    let drained = draining_consumer.join().unwrap();
    assert!(!drained.is_empty());
    assert!(matches!(q.try_push(9), Err(PushError::Closed(9))));
}

// -------------------------------------------------------------- batcher

#[test]
fn padded_tiles_match_reference_through_the_service() {
    // Ragged lanes + terminals, forced through [T, B] tiles (tile_lanes
    // 16 < lanes per request) on the batched backend: padding and the
    // segment mask must be invisible in the results.
    let svc = service(2, GaeBackend::Batched, 64);
    check("service(batched) == reference", 8, |g| {
        let trajs = ragged_request(g, 24, 48);
        let resp = svc.submit(trajs.clone()).unwrap();
        assert_eq!(resp.outputs.len(), trajs.len());
        for (traj, got) in trajs.iter().zip(&resp.outputs) {
            let want = gae_trajectory(&GaeParams::default(), traj);
            assert_eq!(got.advantages.len(), traj.len(), "mask must trim padding");
            for t in 0..traj.len() {
                assert!(
                    (got.advantages[t] - want.advantages[t]).abs() < 1e-4,
                    "adv t={t}: {} vs {}",
                    got.advantages[t],
                    want.advantages[t]
                );
                assert!((got.rewards_to_go[t] - want.rewards_to_go[t]).abs() < 1e-4);
            }
        }
    });
}

#[test]
fn padded_tile_mask_accounts_every_element() {
    let mut g = Gen::new(7);
    let trajs = ragged_request(&mut g, 9, 33);
    let lanes: Vec<&Trajectory> = trajs.iter().collect();
    let tile = PaddedTile::from_lanes(&lanes);
    let real: usize = trajs.iter().map(|t| t.len()).sum();
    assert_eq!(tile.real_elements(), real);
    let mask = tile.segment_mask();
    assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), real);
    assert_eq!(
        mask.iter().filter(|&&m| m == 0.0).count(),
        tile.padded_elements() - real
    );
}

// ------------------------------------------------------------- service

#[test]
fn every_backend_serves_correct_results_under_concurrency() {
    for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
        let svc = service(4, backend, 128);
        let svc_ref = &svc;
        std::thread::scope(|s| {
            for client in 0..8u64 {
                s.spawn(move || {
                    let mut g = Gen::new(100 + client);
                    for _ in 0..6 {
                        let trajs = ragged_request(&mut g, 4, 32);
                        let resp = svc_ref.submit(trajs.clone()).unwrap();
                        for (traj, got) in trajs.iter().zip(&resp.outputs) {
                            let want = gae_trajectory(&GaeParams::default(), traj);
                            for t in 0..traj.len() {
                                assert!(
                                    (got.advantages[t] - want.advantages[t]).abs() < 1e-3,
                                    "{backend:?}"
                                );
                            }
                        }
                        if backend == GaeBackend::HwSim {
                            assert!(resp.hw_cycles.unwrap() > 0);
                        }
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 48, "{backend:?}");
        assert_eq!(snap.shed, 0, "{backend:?}");
        assert!(snap.elements > 0);
        assert!(snap.total_us.p99 >= snap.total_us.p50);
    }
}

#[test]
fn submit_many_is_pipelined_and_ordered() {
    let svc = service(4, GaeBackend::HwSim, 128);
    let mut g = Gen::new(11);
    let requests: Vec<Vec<Trajectory>> =
        (0..20).map(|_| ragged_request(&mut g, 3, 24)).collect();
    let want: Vec<Vec<usize>> = requests
        .iter()
        .map(|r| r.iter().map(|t| t.len()).collect())
        .collect();
    let results = svc.submit_many(requests);
    assert_eq!(results.len(), 20);
    for (resp, want_lens) in results.into_iter().zip(want) {
        let resp = resp.unwrap();
        let got_lens: Vec<usize> =
            resp.outputs.iter().map(|o| o.advantages.len()).collect();
        assert_eq!(got_lens, want_lens, "responses must keep request order");
    }
}

#[test]
fn admission_control_sheds_when_the_queue_is_at_its_limit() {
    // One worker pinned on a large request + capacity-1 queue: a burst
    // must shed deterministically once depth hits the limit.
    let svc = GaeService::start(ServiceConfig {
        workers: 1,
        backend: GaeBackend::Scalar,
        queue_capacity: 1,
        batcher: BatcherConfig {
            max_batch_lanes: 1, // no coalescing: one request per flush
            tile_lanes: 16,
            max_wait: Duration::from_micros(1),
        },
        sim_rows: 16,
        scalar_route_max_elements: 0,
        gae: GaeParams::default(),
        ..ServiceConfig::default()
    })
    .unwrap();
    let mut g = Gen::new(5);
    // A chunky request to keep the single worker busy.
    let big: Vec<Trajectory> = (0..64)
        .map(|_| {
            Trajectory::without_dones(
                g.vec_normal_f32(2048, 0.0, 1.0),
                g.vec_normal_f32(2049, 0.0, 1.0),
            )
        })
        .collect();
    let busy = svc.enqueue(big).unwrap();
    // Flood far past the queue limit; with depth 1 at least some of the
    // burst must be shed.
    let mut shed = 0;
    let mut admitted = Vec::new();
    for _ in 0..64 {
        match svc.enqueue(ragged_request(&mut g, 1, 8)) {
            Ok(h) => admitted.push(h),
            Err(ServiceError::Overloaded { limit, .. }) => {
                assert_eq!(limit, 1);
                shed += 1;
            }
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(shed > 0, "burst past a capacity-1 queue must shed");
    busy.wait().unwrap();
    for h in admitted {
        h.wait().unwrap();
    }
    let snap = svc.metrics();
    assert_eq!(snap.shed, shed);
    assert_eq!(snap.completed + snap.shed, snap.submitted);
    assert!(snap.peak_queue_depth <= 1);
}

#[test]
fn metrics_snapshot_counts_real_elements_not_padding() {
    let svc = service(1, GaeBackend::Batched, 32);
    let mut g = Gen::new(13);
    let trajs = ragged_request(&mut g, 7, 40);
    let real: usize = trajs.iter().map(|t| t.len()).sum();
    let resp = svc.submit(trajs).unwrap();
    assert_eq!(resp.elements(), real);
    let snap = svc.shutdown();
    assert_eq!(snap.elements as usize, real);
    assert!(snap.sustained_elem_per_sec > 0.0);
}

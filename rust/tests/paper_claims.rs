//! Integration: the paper's quantitative headline claims, checked
//! end-to-end against this implementation (no artifacts needed).

use heppo::gae::{GaeParams, Trajectory};
use heppo::hwsim::pe::{run_pe, PeConfig};
use heppo::hwsim::{GaeHwSim, ResourceModel, SimConfig};
use heppo::memory::{BlockLayout, BramSpec, DramSpec};
use heppo::quant::{CodecKind, RewardValueCodec};
use heppo::util::Rng;

fn workload(n: usize, t: usize, seed: u64) -> Vec<Trajectory> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut r = vec![0.0f32; t];
            let mut v = vec![0.0f32; t + 1];
            rng.fill_normal_f32(&mut r);
            rng.fill_normal_f32(&mut v);
            Trajectory::without_dones(r, v)
        })
        .collect()
}

#[test]
fn claim_one_pe_300m_elements_per_sec() {
    // §V-D-1: "a single PE is estimated to handle 300 million elements
    // per second".
    let rep = GaeHwSim::new(SimConfig { rows: 1, ..SimConfig::paper_default() })
        .simulate(&workload(1, 65_536, 0));
    let eps = rep.elements_per_sec();
    assert!((eps / 300e6 - 1.0).abs() < 0.01, "one PE: {eps:.3e} elem/s");
}

#[test]
fn claim_2e6x_over_9k_baseline() {
    // §V-D-3: 64 PEs vs the ≈9000 elem/s unbatched loop ⇒ ~2×10⁶×.
    let rep = GaeHwSim::paper_default().simulate(&workload(64, 1024, 1));
    let speedup = rep.elements_per_sec() / 9_000.0;
    assert!(
        (1.5e6..3.0e6).contains(&speedup),
        "speedup vs python loop = {speedup:.3e}"
    );
}

#[test]
fn claim_4x_memory_reduction() {
    // Abstract: "a 4x reduction in memory usage" (32-bit → 8-bit).
    let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
    let mut rng = Rng::new(2);
    let n = 64 * 1024;
    let mut r = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    rng.fill_normal_f32(&mut r);
    rng.fill_normal_f32(&mut v);
    let rep = codec.transform(&mut r, &mut v);
    let red = rep.reduction_vs_f32(n);
    assert!(red > 3.99, "reduction = {red}");

    // And the layout side: quantization alone is 4x; with the in-place
    // overwrite of §IV-3 the total on-chip saving is 8x.
    let f32_none = BlockLayout::paper_example(4).total_bytes(false);
    let q8_inplace = BlockLayout::paper_example(1).total_bytes(true);
    assert_eq!(f32_none / BlockLayout::paper_example(1).total_bytes(false), 4);
    assert_eq!(f32_none / q8_inplace, 8);
}

#[test]
fn claim_table4_resources_exact() {
    let m = ResourceModel::default();
    let t = m.total(2, 64);
    assert_eq!((t.luts, t.ffs, t.dsps), (12_864, 54_336, 768));
}

#[test]
fn claim_dram_cannot_feed_64_pes() {
    // §IV-A: 83.3 B/cycle available vs 512 needed.
    let d = DramSpec::default();
    assert!(d.shortfall(64, 4) > 400.0);
    // …and the 32-block BRAM stack can (256 B/cycle for 8-bit elements).
    assert_eq!(BramSpec::default().peak_bandwidth(32), 256);
}

#[test]
fn claim_k2_lookahead_is_bubble_free_and_k1_is_not() {
    // §III-B / Fig. 4.
    let params = GaeParams::default();
    let mut rng = Rng::new(3);
    let mut r = vec![0.0f32; 4096];
    let mut v = vec![0.0f32; 4097];
    rng.fill_normal_f32(&mut r);
    rng.fill_normal_f32(&mut v);
    let k1 = run_pe(
        &PeConfig { lookahead: 1, mul_latency: 2, frontend_latency: 4 },
        &params, &r, &v,
    );
    let k2 = run_pe(
        &PeConfig { lookahead: 2, mul_latency: 2, frontend_latency: 4 },
        &params, &r, &v,
    );
    assert!(k1.bubbles > 0);
    assert_eq!(k2.bubbles, 0);
    // And the resource model says only k >= 2 closes 300 MHz.
    let m = ResourceModel::default();
    assert!(m.fmax_hz(1) < 300e6);
    assert_eq!(m.fmax_hz(2), 300e6);
}

#[test]
fn claim_gae_phase_time_is_negligible_after_acceleration() {
    // §V-D-3: the accelerated GAE stage takes microseconds for a full
    // 64×1024 collection — vs ~7.3 s at the 9000 elem/s baseline rate.
    let rep = GaeHwSim::paper_default().simulate(&workload(64, 1024, 4));
    let accel = rep.wall_time().as_secs_f64();
    let baseline = 64.0 * 1024.0 / 9000.0;
    assert!(accel < 5e-6, "accelerated pass = {accel}s");
    assert!(baseline / accel > 1e6);
}

#[test]
fn claim_dynamic_std_preserves_reward_ordering_across_epochs() {
    // §II-A: the property that makes DS work where per-epoch z-scoring
    // fails.
    let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
    let mut rng = Rng::new(5);
    let mut early: Vec<f32> = (0..4000).map(|_| rng.normal_with(1.0, 0.3) as f32).collect();
    let mut late: Vec<f32> = (0..4000).map(|_| rng.normal_with(6.0, 0.3) as f32).collect();
    let mut v = vec![0.0f32; 4000];
    codec.transform(&mut early, &mut v.clone());
    codec.transform(&mut late, &mut v);
    let m_early = early.iter().sum::<f32>() / 4000.0;
    let m_late = late.iter().sum::<f32>() / 4000.0;
    assert!(m_late > m_early + 0.5, "{m_early} vs {m_late}");
}

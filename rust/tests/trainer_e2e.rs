//! Integration: the full trainer runs end-to-end through every backend
//! and improves on CartPole (needs `make artifacts`).

use heppo::coordinator::{GaeBackend, Trainer, TrainerConfig};
use heppo::quant::CodecKind;

fn base_config() -> TrainerConfig {
    TrainerConfig {
        artifact_dir: concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").into(),
        codec: CodecKind::Exp1Baseline,
        iters: 2,
        seed: 11,
        ..TrainerConfig::default()
    }
}

/// These tests need the AOT artifacts and a real PJRT runtime; in the
/// offline build (xla stub, no `make artifacts`) they skip.
fn artifacts_available() -> bool {
    heppo::testing::try_runtime(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .is_some()
}

#[test]
fn all_backends_run_one_iteration() {
    if !artifacts_available() {
        return;
    }
    for backend in [
        GaeBackend::Scalar,
        GaeBackend::Batched,
        GaeBackend::Hlo,
        GaeBackend::HwSim,
    ] {
        let mut cfg = base_config();
        cfg.backend = backend;
        cfg.iters = 1;
        let mut t = Trainer::new(cfg).unwrap_or_else(|e| panic!("{backend:?}: {e:#}"));
        let stats = t.run().unwrap_or_else(|e| panic!("{backend:?}: {e:#}"));
        assert_eq!(stats.len(), 1);
        assert!(stats[0].steps > 0);
        assert!(stats[0].losses.minibatches > 0);
        if backend == GaeBackend::HwSim {
            assert!(stats[0].hw_cycles.unwrap() > 0);
        }
    }
}

#[test]
fn backends_produce_identical_learning_signal() {
    if !artifacts_available() {
        return;
    }
    // Same seed + codec: the first iteration's losses must agree across
    // scalar/batched/hwsim backends (HLO kernel has f32 reassociation
    // drift, checked separately in runtime_artifacts).
    let mut losses = Vec::new();
    for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
        let mut cfg = base_config();
        cfg.backend = backend;
        cfg.iters = 1;
        let mut t = Trainer::new(cfg).unwrap();
        let stats = t.run().unwrap();
        losses.push(stats[0].losses);
    }
    for other in &losses[1..] {
        assert!((losses[0].pi_loss - other.pi_loss).abs() < 1e-4);
        assert!((losses[0].v_loss - other.v_loss).abs() < 1e-3);
    }
}

#[test]
fn cartpole_improves_within_25_iterations() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = base_config();
    cfg.iters = 25;
    let mut t = Trainer::new(cfg).unwrap();
    let stats = t.run().unwrap();
    let early = &stats[2];
    let late = stats.last().unwrap();
    assert!(
        late.mean_return > early.mean_return + 10.0,
        "return must climb: {} -> {}",
        early.mean_return,
        late.mean_return
    );
}

#[test]
fn profiler_covers_every_phase() {
    if !artifacts_available() {
        return;
    }
    use heppo::coordinator::Phase;
    let mut cfg = base_config();
    cfg.backend = GaeBackend::Hlo;
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    for phase in Phase::ALL {
        if phase == Phase::GaeMemoryWrite {
            continue; // in-place write is folded into compute
        }
        assert!(
            t.profiler.total(phase) > std::time::Duration::ZERO,
            "phase {phase:?} never timed"
        );
    }
    // The phase machine performed 2 handshakes per iteration.
    assert_eq!(t.phases.handshakes(), 2 * 2);
}

#[test]
fn hwsim_backend_reports_paper_scale_cycles() {
    if !artifacts_available() {
        return;
    }
    let mut cfg = base_config();
    cfg.backend = GaeBackend::HwSim;
    cfg.iters = 1;
    let mut t = Trainer::new(cfg).unwrap();
    let stats = t.run().unwrap();
    let cycles = stats[0].hw_cycles.unwrap();
    // 128x16 = 2048 elements on 64 rows: a few hundred cycles, not
    // thousands (the whole point of the parallel array).
    assert!(cycles < 5_000, "cycles = {cycles}");
}

#[test]
fn timeseries_emits_learning_health_per_iteration() {
    if !artifacts_available() {
        return;
    }
    let path = std::env::temp_dir()
        .join(format!("heppo_e2e_timeseries_{}.jsonl", std::process::id()));
    let mut cfg = base_config();
    cfg.iters = 3;
    cfg.timeseries_path = Some(path.to_str().unwrap().to_string());
    let mut t = Trainer::new(cfg).unwrap();
    t.run().unwrap();
    assert_eq!(t.timeseries_records(), 3);
    let text = std::fs::read_to_string(&path).unwrap();
    let rows: Vec<heppo::obs::timeseries::LearningHealthRecord> = text
        .lines()
        .map(|l| {
            let j = heppo::util::json::Json::parse(l).unwrap();
            heppo::obs::timeseries::LearningHealthRecord::from_json(&j).unwrap()
        })
        .collect();
    assert_eq!(rows.len(), 3);
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(r.iter, i);
        assert!(r.env_steps > 0);
        // Standardization is on by default: post moments are ~N(0,1).
        assert!(r.adv_mean_post.abs() < 1e-3, "adv_mean_post {}", r.adv_mean_post);
        assert!((r.adv_std_post - 1.0).abs() < 1e-3, "adv_std_post {}", r.adv_std_post);
        assert!(r.adv_std_pre > 0.0);
        // A single PPO update stays near the old policy: the KL estimate
        // must be finite and small, and the clip fraction a sane rate.
        assert!(r.approx_kl.is_finite());
        assert!(r.approx_kl.abs() < 1.0, "approx_kl {}", r.approx_kl);
        assert!((0.0..=1.0).contains(&r.clip_fraction));
        assert!(r.value_explained_variance.is_finite());
        assert!(r.value_explained_variance >= -1.0);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn codec_variants_all_train() {
    if !artifacts_available() {
        return;
    }
    for codec in CodecKind::all() {
        let mut cfg = base_config();
        cfg.codec = codec;
        cfg.iters = 1;
        let mut t = Trainer::new(cfg).unwrap();
        let stats = t.run().unwrap_or_else(|e| panic!("{codec:?}: {e:#}"));
        assert!(stats[0].losses.minibatches > 0, "{codec:?}");
    }
}

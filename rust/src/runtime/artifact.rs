//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` emits `manifest.json` describing every lowered
//! computation (inputs/outputs shapes + dtypes + metadata) and binary
//! blob (seeded initial parameters). This module is the single source of
//! truth the coordinator uses for tensor shapes — nothing is hardcoded
//! on the rust side.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j
                .req("shape")?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("bad shape"))?,
            dtype: j
                .req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow!("bad dtype"))?
                .to_string(),
        })
    }
}

/// One artifact (HLO computation or raw blob).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub is_blob: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Typed metadata accessors.
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("artifact {}: missing meta {key}", self.name))
    }

    pub fn meta_f64(&self, key: &str) -> Result<f64> {
        self.meta
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("artifact {}: missing meta {key}", self.name))
    }

    pub fn meta_bool(&self, key: &str) -> Result<bool> {
        self.meta
            .get(key)
            .and_then(Json::as_bool)
            .ok_or_else(|| anyhow!("artifact {}: missing meta {key}", self.name))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub geometry: Geometry,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// Rollout/batch geometry shared between aot.py and the coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Geometry {
    pub num_envs: usize,
    pub rollout_t: usize,
    pub minibatch: usize,
    pub gamma: f32,
    pub lambda: f32,
    pub quant_bits: usize,
    pub quant_range: f32,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (tests feed synthetic manifests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest.json parse")?;
        let geo = root.req("geometry")?;
        let geometry = Geometry {
            num_envs: geo.req("num_envs")?.as_usize().unwrap(),
            rollout_t: geo.req("rollout_t")?.as_usize().unwrap(),
            minibatch: geo.req("minibatch")?.as_usize().unwrap(),
            gamma: geo.req("gamma")?.as_f64().unwrap() as f32,
            lambda: geo.req("lambda")?.as_f64().unwrap() as f32,
            quant_bits: geo.req("quant_bits")?.as_usize().unwrap(),
            quant_range: geo.req("quant_range")?.as_f64().unwrap() as f32,
        };
        let mut artifacts = BTreeMap::new();
        for (name, a) in root
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a
                        .req("file")?
                        .as_str()
                        .ok_or_else(|| anyhow!("bad file"))?
                        .to_string(),
                    is_blob: a.get("blob").and_then(Json::as_bool).unwrap_or(false),
                    inputs,
                    outputs,
                    meta: a.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        Ok(Manifest { dir, geometry, artifacts })
    }

    /// Artifact lookup with a clear error.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of an artifact's file.
    pub fn path_of(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }

    /// Load a raw little-endian `f32` blob artifact.
    pub fn load_blob_f32(&self, name: &str) -> Result<Vec<f32>> {
        let spec = self.get(name)?;
        anyhow::ensure!(spec.is_blob, "artifact {name} is not a blob");
        let bytes = std::fs::read(self.path_of(name)?)?;
        anyhow::ensure!(bytes.len() % 4 == 0, "blob {name} truncated");
        let out = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect::<Vec<f32>>();
        let want = spec.outputs[0].elem_count();
        anyhow::ensure!(out.len() == want, "blob {name}: {} vs {want} elems", out.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "geometry": {"num_envs": 16, "rollout_t": 128, "minibatch": 256,
                   "gamma": 0.99, "lambda": 0.95,
                   "quant_bits": 8, "quant_range": 5.0},
      "artifacts": {
        "cartpole_policy_fwd": {
          "file": "cartpole_policy_fwd.hlo.txt",
          "inputs": [{"shape": [9155], "dtype": "float32"},
                      {"shape": [16, 4], "dtype": "float32"}],
          "outputs": [{"shape": [16, 2], "dtype": "float32"},
                       {"shape": [16], "dtype": "float32"}],
          "meta": {"kind": "policy_fwd", "param_count": 9155,
                   "discrete": true, "obs_dim": 4}
        },
        "cartpole_init_params": {
          "file": "cartpole_init_params.f32",
          "blob": true,
          "inputs": [],
          "outputs": [{"shape": [4], "dtype": "float32"}],
          "meta": {"kind": "init_params"}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.geometry.num_envs, 16);
        assert!((m.geometry.gamma - 0.99).abs() < 1e-6);
        let a = m.get("cartpole_policy_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].shape, vec![16, 4]);
        assert_eq!(a.inputs[1].elem_count(), 64);
        assert_eq!(a.meta_usize("param_count").unwrap(), 9155);
        assert!(a.meta_bool("discrete").unwrap());
        assert!(!a.is_blob);
        assert!(m.get("cartpole_init_params").unwrap().is_blob);
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("cartpole_policy_fwd"), "{err}");
    }

    #[test]
    fn blob_roundtrip() {
        let dir = std::env::temp_dir().join("heppo_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let vals = [1.0f32, -2.5, 3.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(dir.join("cartpole_init_params.f32"), bytes).unwrap();
        let m = Manifest::parse(SAMPLE, dir.clone()).unwrap();
        assert_eq!(m.load_blob_f32("cartpole_init_params").unwrap(), vals);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_manifest() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }
}

//! Offline stand-in for the `xla` (xla_extension) crate.
//!
//! The PJRT native library is not part of the offline crate set, so
//! [`client`](super::client) is compiled against this API-shaped stub
//! instead. Every entry point that would touch PJRT returns
//! [`XlaError::Unavailable`]; [`PjRtClient::cpu`] fails first, so
//! `Runtime::new` reports a single clear error and everything gated on
//! a runtime (the `hlo` backend, artifact tests) degrades gracefully.
//!
//! When a real `xla_extension` build is present, point the `xla` alias
//! in `client.rs` back at the external crate — the call surface here
//! (`Literal::vec1/reshape/to_tuple/convert/to_vec`, `PjRtClient::cpu/
//! compile/platform_name`, `PjRtLoadedExecutable::execute`,
//! `HloModuleProto::from_text_file`, `XlaComputation::from_proto`) is a
//! strict subset of xla-rs 0.5.

use std::fmt;

/// Error type for all stubbed PJRT operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum XlaError {
    /// The PJRT runtime is not present in this build.
    Unavailable,
    /// Host-side shape bookkeeping failed (a real bug, not a missing
    /// runtime): element count vs. requested dims.
    ShapeMismatch { elems: usize, dims: Vec<i64> },
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::Unavailable => f.write_str(
                "PJRT/xla_extension is unavailable in this offline build \
                 (HLO artifacts cannot execute; use the scalar/batched/hwsim \
                 backends, or link the real `xla` crate)",
            ),
            XlaError::ShapeMismatch { elems, dims } => write!(
                f,
                "literal reshape mismatch: {elems} elements vs dims {dims:?}"
            ),
        }
    }
}

impl std::error::Error for XlaError {}

/// Element types an output literal can be converted to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

/// Host literal: data + dims. Construction works (it is pure host-side
/// bookkeeping); anything that would need the native runtime errors.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

/// Marker trait for element types [`Literal::to_vec`] can produce.
pub trait LiteralElem: Sized {
    fn from_f32(x: f32) -> Self;
}

impl LiteralElem for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(XlaError::ShapeMismatch {
                elems: self.data.len(),
                dims: dims.to_vec(),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal (requires the native runtime).
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::Unavailable)
    }

    /// Convert to another element type (requires the native runtime).
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, XlaError> {
        Err(XlaError::Unavailable)
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::Unavailable)
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::Unavailable)
    }
}

/// The PJRT client. [`PjRtClient::cpu`] is the single failure point the
/// rest of the runtime funnels through.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::Unavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }
}

/// Parsed HLO module (never constructed by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::Unavailable)
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_roundtrips_host_side() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        // Shape bugs report as shape bugs, not as a missing runtime.
        let err = lit.reshape(&[3]).unwrap_err();
        assert_eq!(err, XlaError::ShapeMismatch { elems: 4, dims: vec![3] });
        assert!(err.to_string().contains("reshape mismatch"));
    }
}

//! PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times with plain `f32` tensors.
//!
//! Follows /opt/xla-example/load_hlo: the interchange format is HLO
//! *text* (`HloModuleProto::from_text_file`) because the crate's
//! xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos.
//! aot.py lowers with `return_tuple=True`, so every execution returns a
//! tuple literal which we decompose into per-output tensors.

use super::artifact::{ArtifactSpec, Manifest};
// The offline crate set has no xla_extension; compile against the
// API-shaped stub. Point this alias at the external `xla` crate to run
// on a machine with the PJRT native library installed.
use super::xla_stub as xla;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "tensor data/shape mismatch"
        );
        Tensor { data, shape }
    }

    pub fn scalar(x: f32) -> Self {
        Tensor { data: vec![x], shape: vec![] }
    }

    pub fn vec1(data: Vec<f32>) -> Self {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Build the PJRT literal for this tensor. Public so hot paths can
    /// pre-build invariant inputs once and pass them by reference via
    /// [`Executable::call_literals`] (§Perf: the policy parameters are
    /// invariant across the T steps of a rollout — re-encoding them per
    /// step dominated DNN-inference time before this path existed).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 tensors; validates arity and shapes against the
    /// manifest, returns one tensor per manifest output.
    pub fn call(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {}: got {} inputs, manifest says {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            anyhow::ensure!(
                t.shape == s.shape,
                "artifact {} input {i}: shape {:?} != manifest {:?}",
                self.spec.name,
                t.shape,
                s.shape
            );
        }
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.call_literals(&refs)
    }

    /// Execute with pre-built literals (no per-call encoding of inputs
    /// the caller already holds). Arity is validated; shape agreement is
    /// the caller's contract.
    pub fn call_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            literals.len() == self.spec.inputs.len(),
            "artifact {}: got {} literals, manifest says {}",
            self.spec.name,
            literals.len(),
            self.spec.inputs.len()
        );
        let result = self.exe.execute::<&xla::Literal>(literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {}: {} outputs vs manifest {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                // uint16 outputs (quant codes) are converted to f32 lanes.
                let lit = if spec.dtype == "float32" {
                    lit
                } else {
                    lit.convert(xla::PrimitiveType::F32)?
                };
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor { data, shape: spec.shape.clone() })
            })
            .collect()
    }
}

/// The runtime: one PJRT CPU client + a compiled-executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Create from an artifact directory (compiles lazily on first use).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { manifest, client, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let spec = self.manifest.get(name)?.clone();
        anyhow::ensure!(!spec.is_blob, "artifact {name} is a blob, not HLO");
        let path = self.manifest.path_of(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {name}"))?;
        let exe = Rc::new(Executable { spec, exe });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// One-shot convenience: load + call.
    pub fn call(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?.call(inputs)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_check() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(Tensor::scalar(5.0).shape, Vec::<usize>::new());
        assert_eq!(Tensor::zeros(&[3, 4]).data.len(), 12);
    }

    #[test]
    #[should_panic(expected = "tensor data/shape mismatch")]
    fn tensor_mismatch_panics() {
        Tensor::new(vec![1.0], vec![2, 2]);
    }
}

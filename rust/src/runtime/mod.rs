//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the coordinator's hot path (no python anywhere at runtime).
//!
//! - [`artifact`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`), exposing every artifact's I/O signature
//!   and metadata, plus raw `f32` blobs (initial parameters).
//! - [`client`] — wraps the `xla` crate: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `compile` → `execute`, with a
//!   typed f32-tensor call interface and per-artifact executable cache.

pub mod artifact;
pub mod client;
pub mod xla_stub;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{Runtime, Tensor};

//! Mini property-test harness (proptest is unavailable offline).
//!
//! A property is a closure over a per-case [`Gen`]; [`check`] runs it for
//! `n` seeded cases and reports the failing seed so a failure reproduces
//! deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image)
//! use heppo::testing::{check, Gen};
//! check("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.f32_in(-1e3, 1e3);
//!     let b = g.f32_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::gae::Trajectory;
use crate::runtime::Runtime;
use crate::util::Rng;

/// Random variable-length GAE trajectories — the shared traffic shape
/// used by the service tests/benches and the load-generator example.
/// Lengths are uniform in `[min_t, max_t]` (min 1); each step
/// terminates with probability `done_p`.
pub fn ragged_trajectories(
    rng: &mut Rng,
    n: usize,
    min_t: usize,
    max_t: usize,
    done_p: f64,
) -> Vec<Trajectory> {
    let min_t = min_t.max(1);
    let max_t = max_t.max(min_t);
    (0..n)
        .map(|_| {
            let t_len = min_t + rng.below((max_t - min_t + 1) as u64) as usize;
            let mut rewards = vec![0.0f32; t_len];
            let mut values = vec![0.0f32; t_len + 1];
            rng.fill_normal_f32(&mut rewards);
            rng.fill_normal_f32(&mut values);
            let dones = (0..t_len).map(|_| rng.uniform() < done_p).collect();
            Trajectory::new(rewards, values, dones)
        })
        .collect()
}

/// FNV-1a over f32 bit patterns — a bit-exact stream digest shared by
/// the pipeline-equivalence tests and the overlap bench (two schedules
/// agree iff their digests agree).
pub fn digest_f32(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in xs {
        h ^= x.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A deterministic, parameter-free linear policy over `[B, obs_dim]`
/// observations: logits `±s` and value `0.25·s` from a fixed projection
/// seeded by `phase`. No feedback from any update stage, so sequential
/// and overlapped schedules see identical trajectories — the stage-set
/// shape the pipeline driver's equivalence tests and benches need.
pub fn linear_policy(
    batch: usize,
    obs_dim: usize,
    phase: f32,
) -> impl FnMut(&[f32]) -> crate::Result<(Vec<f32>, Vec<f32>)> + Send {
    let weights: Vec<f32> = (0..obs_dim)
        .map(|k| ((k as f32) * 0.37 + phase).sin())
        .collect();
    move |obs: &[f32]| {
        let mut dist = vec![0.0f32; batch * 2];
        let mut values = vec![0.0f32; batch];
        for i in 0..batch {
            let o = &obs[i * obs_dim..(i + 1) * obs_dim];
            let s: f32 = o.iter().zip(&weights).map(|(&x, &w)| x * w).sum();
            dist[i * 2] = s;
            dist[i * 2 + 1] = -s;
            values[i] = 0.25 * s;
        }
        Ok((dist, values))
    }
}

/// Gate for artifact-dependent integration tests: `Some(Runtime)` only
/// when `dir` holds a manifest **and** the PJRT client initializes
/// (i.e. a real `xla_extension` is linked, not the offline stub).
/// Prints why it skipped otherwise.
pub fn try_runtime(dir: &str) -> Option<Runtime> {
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir} (run `make artifacts`)");
        return None;
    }
    match Runtime::new(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable: {e:#}");
            None
        }
    }
}

/// Per-case value generator (a thin, purpose-named layer over [`Rng`]).
pub struct Gen {
    rng: Rng,
    /// Seed of this case, printed on failure.
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    /// Bernoulli with probability `p`.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of uniform f32s.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of normals with given mean/std (f32).
    pub fn vec_normal_f32(&mut self, len: usize, mean: f64, std: f64) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_with(mean, std) as f32).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Access the underlying RNG for anything richer.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` deterministic seeds derived from the property
/// name; panics (via the property's own asserts) on the first failure,
/// after printing the reproducing seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen)) {
    // Stable name hash (FNV-1a) so each property gets its own stream.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let seed = h.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut gen = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut gen)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{cases} (seed {seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let n = AtomicU64::new(0);
        check("counter", 25, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("always fails", 5, |_| panic!("boom"));
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        assert_eq!(a.vec_f32(16, -1.0, 1.0), b.vec_f32(16, -1.0, 1.0));
    }

    #[test]
    fn usize_in_bounds() {
        check("usize_in bounds", 200, |g| {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        });
    }
}

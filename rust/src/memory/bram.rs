//! Dual-port Block RAM model (paper §IV, §V-D-2).
//!
//! Each BRAM36 primitive provides 36 Kb of storage and two independent
//! ports of 4 bytes/cycle. The paper sizes the design for 64 trajectories
//! × 1024 timesteps with in-place overwrite: 128 B/timestep → 128 KB
//! total → ≈29 blocks for capacity, and 256 B/cycle of bandwidth →
//! 57 ports → 32 blocks; both ≈9–10% of the ZCU106.

/// A BRAM configuration (defaults = Xilinx BRAM36 on the ZCU106).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BramSpec {
    /// Capacity of one block, bits (36 Kb for BRAM36).
    pub block_bits: usize,
    /// Bytes per port per cycle.
    pub bytes_per_port_cycle: usize,
    /// Ports per block (2 = dual-port).
    pub ports_per_block: usize,
    /// Blocks available on the device (ZCU106 / XCZU7EV: 312 BRAM36).
    pub blocks_available: usize,
}

impl Default for BramSpec {
    fn default() -> Self {
        BramSpec {
            block_bits: 36 * 1024,
            bytes_per_port_cycle: 4,
            ports_per_block: 2,
            blocks_available: 312,
        }
    }
}

impl BramSpec {
    /// Blocks needed to store `bytes` (capacity-limited).
    pub fn blocks_for_capacity(&self, bytes: usize) -> usize {
        (bytes * 8).div_ceil(self.block_bits)
    }

    /// Ports needed to sustain `bytes_per_cycle` of combined R/W traffic.
    pub fn ports_for_bandwidth(&self, bytes_per_cycle: usize) -> usize {
        bytes_per_cycle.div_ceil(self.bytes_per_port_cycle)
    }

    /// Blocks needed to provide `bytes_per_cycle` (bandwidth-limited).
    pub fn blocks_for_bandwidth(&self, bytes_per_cycle: usize) -> usize {
        self.ports_for_bandwidth(bytes_per_cycle)
            .div_ceil(self.ports_per_block)
    }

    /// Blocks satisfying both capacity and bandwidth.
    pub fn blocks_required(&self, bytes: usize, bytes_per_cycle: usize) -> usize {
        self.blocks_for_capacity(bytes)
            .max(self.blocks_for_bandwidth(bytes_per_cycle))
    }

    /// Device utilization fraction for a block count.
    pub fn utilization(&self, blocks: usize) -> f64 {
        blocks as f64 / self.blocks_available as f64
    }

    /// Peak bandwidth of `blocks` blocks, bytes/cycle.
    pub fn peak_bandwidth(&self, blocks: usize) -> usize {
        blocks * self.ports_per_block * self.bytes_per_port_cycle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KB: usize = 1024;

    #[test]
    fn paper_capacity_sizing() {
        // §V-D-2: 128 KB requires ≈29 BRAM blocks (~9%).
        let spec = BramSpec::default();
        let blocks = spec.blocks_for_capacity(128 * KB);
        assert_eq!(blocks, 29);
        let util = spec.utilization(blocks);
        assert!((0.08..0.10).contains(&util), "util={util}");
    }

    #[test]
    fn paper_bandwidth_sizing() {
        // §V-D-2: 256 B/cycle requires 57 ports… the paper rounds to 32
        // blocks (10%). ceil(57/2) = 29; the paper's 32 includes port-
        // alignment slack — we assert our exact math and that the paper's
        // figure bounds it.
        let spec = BramSpec::default();
        let ports = spec.ports_for_bandwidth(256);
        assert_eq!(ports, 64); // 256/4 = 64 ports exact
        // Paper says 57 ports because advantages/RTG reuse the read ports
        // in-place; the write stream shares ports with reads on the dual-
        // port blocks. Our strict model: 64 ports → 32 blocks = paper's
        // final number.
        let blocks = spec.blocks_for_bandwidth(256);
        assert_eq!(blocks, 32);
        let util = spec.utilization(blocks);
        assert!((0.09..0.11).contains(&util), "util={util}");
    }

    #[test]
    fn combined_requirement_takes_max() {
        let spec = BramSpec::default();
        assert_eq!(
            spec.blocks_required(128 * KB, 256),
            32 // bandwidth dominates capacity (29)
        );
    }

    #[test]
    fn peak_bandwidth_matches_ports() {
        let spec = BramSpec::default();
        assert_eq!(spec.peak_bandwidth(32), 256);
    }

    #[test]
    fn zero_bytes() {
        let spec = BramSpec::default();
        assert_eq!(spec.blocks_for_capacity(0), 0);
        assert_eq!(spec.blocks_for_bandwidth(0), 0);
    }
}

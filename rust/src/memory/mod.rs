//! On-chip memory subsystem models (paper §IV "Data Layout").
//!
//! - [`bram`] — dual-port Block RAM: 36 Kb blocks, 4 bytes/port/cycle;
//!   block-count and port-count sizing (paper §V-D-2).
//! - [`dram`] — DDR4-3200 bandwidth model: the 83.3 bytes/cycle vs 512
//!   bytes/cycle shortfall argument of §IV-A.
//! - [`layout`] — the timestep-major 2-D memory-block layout (Fig. 6):
//!   rewards/values of all trajectories at timestep *t* share a row.
//! - [`filo`] — the FILO (stack) storage mechanism with dual-port
//!   in-place overwrite (Algorithm 2): push forward during collection,
//!   pop backward during GAE, advantages/RTGs overwrite rewards/values.

pub mod bram;
pub mod dram;
pub mod filo;
pub mod layout;

pub use bram::BramSpec;
pub use dram::DramSpec;
pub use filo::FiloStack;
pub use layout::BlockLayout;

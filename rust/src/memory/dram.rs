//! External DRAM bandwidth model — the §IV-A bottleneck argument.
//!
//! "Assuming a typical DDR4-3200 bandwidth of 25 GB/s and a clock
//! frequency of 300 MHz, the available bandwidth per cycle is
//! 83.3 bytes/cycle … a shortfall of 428.7 bytes per cycle" against the
//! 512 B/cycle required to feed 64 PEs with f32 rewards+values. This is
//! why HEPPO-GAE stores the working set in on-chip BRAM.

/// A DRAM interface model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramSpec {
    /// Sustained bandwidth, bytes/second (DDR4-3200: 25 GB/s).
    pub bandwidth_bytes_per_sec: f64,
    /// Accelerator clock, Hz (300 MHz).
    pub clock_hz: f64,
}

impl Default for DramSpec {
    fn default() -> Self {
        DramSpec { bandwidth_bytes_per_sec: 25e9, clock_hz: 300e6 }
    }
}

impl DramSpec {
    /// Bytes deliverable per accelerator cycle.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bandwidth_bytes_per_sec / self.clock_hz
    }

    /// Bytes/cycle needed to feed `pes` processing elements reading one
    /// reward + one value of `elem_bytes` each per cycle.
    pub fn required_bytes_per_cycle(pes: usize, elem_bytes: usize) -> f64 {
        (pes * 2 * elem_bytes) as f64
    }

    /// Shortfall (positive ⇒ DRAM cannot keep up).
    pub fn shortfall(&self, pes: usize, elem_bytes: usize) -> f64 {
        Self::required_bytes_per_cycle(pes, elem_bytes) - self.bytes_per_cycle()
    }

    /// Largest PE count this DRAM can feed at `elem_bytes` per element.
    pub fn max_sustainable_pes(&self, elem_bytes: usize) -> usize {
        (self.bytes_per_cycle() / (2 * elem_bytes) as f64).floor() as usize
    }

    /// Effective elements/second if DRAM is the only limiter for `pes`
    /// PEs (each element = reward + value read).
    pub fn dram_limited_elements_per_sec(&self, pes: usize, elem_bytes: usize) -> f64 {
        let demand = Self::required_bytes_per_cycle(pes, elem_bytes);
        let supply = self.bytes_per_cycle();
        let duty = (supply / demand).min(1.0);
        duty * pes as f64 * self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bytes_per_cycle() {
        // 25e9 / 300e6 = 83.33 B/cycle.
        let d = DramSpec::default();
        assert!((d.bytes_per_cycle() - 83.333).abs() < 0.01);
    }

    #[test]
    fn paper_shortfall() {
        // 64 PEs × (reward+value) × 4 B = 512 B/cycle; shortfall 428.7.
        let d = DramSpec::default();
        assert_eq!(DramSpec::required_bytes_per_cycle(64, 4), 512.0);
        let s = d.shortfall(64, 4);
        assert!((s - 428.666).abs() < 0.01, "shortfall={s}");
    }

    #[test]
    fn dram_can_feed_only_about_10_f32_pes() {
        let d = DramSpec::default();
        let max = d.max_sustainable_pes(4);
        assert_eq!(max, 10); // 83.33 / 8
    }

    #[test]
    fn quantization_quadruples_sustainable_pes() {
        // 8-bit elements: 83.33 / 2 = 41 PEs — quantization directly
        // relieves the §IV-A bottleneck.
        let d = DramSpec::default();
        assert_eq!(d.max_sustainable_pes(1), 41);
    }

    #[test]
    fn duty_cycle_throughput() {
        let d = DramSpec::default();
        // 64 f32 PEs run at 83.33/512 duty ⇒ 19.2 G × 0.1628 ≈ 3.125 G elem/s.
        let eps = d.dram_limited_elements_per_sec(64, 4);
        assert!((eps / 1e9 - 3.125).abs() < 0.01, "eps={eps}");
        // 1 PE is unconstrained: full 300 M elem/s.
        let one = d.dram_limited_elements_per_sec(1, 4);
        assert!((one - 300e6).abs() < 1.0);
    }
}

//! FILO stack memory (paper §IV-2/3, Algorithm 2, Fig. 6).
//!
//! Rewards and values are *pushed* row-by-row as timesteps are collected
//! and *popped* in reverse during the GAE pass — a stack discipline that
//! matches GAE's backward iteration exactly. Dual-port BRAM lets the
//! same cycle read (r, v) at row `t` through port A and write back
//! (advantage, RTG) through port B, overwriting in place and halving the
//! footprint.
//!
//! This type is the *functional* model used by the coordinator's storage
//! stage (the cycle-accurate port-level model lives in
//! [`crate::hwsim`]). Elements are stored quantized (`u16` codewords) or
//! raw (`f32`) depending on the codec in front of it; here we store
//! generic elements.

/// One plane of `[T, B]` stack storage (e.g. the reward plane).
#[derive(Debug, Clone)]
pub struct FiloStack<T> {
    batch: usize,
    capacity_rows: usize,
    rows: Vec<Vec<T>>,
}

/// Errors from stack misuse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FiloError {
    Full(usize),
    Empty,
    Width { got: usize, want: usize },
}

impl std::fmt::Display for FiloError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FiloError::Full(rows) => write!(f, "stack is full ({rows} rows)"),
            FiloError::Empty => write!(f, "stack is empty"),
            FiloError::Width { got, want } => {
                write!(f, "row width {got} != batch {want}")
            }
        }
    }
}

impl std::error::Error for FiloError {}

impl<T: Clone> FiloStack<T> {
    /// A stack able to hold `capacity_rows` rows of `batch` elements.
    pub fn new(batch: usize, capacity_rows: usize) -> Self {
        FiloStack { batch, capacity_rows, rows: Vec::with_capacity(capacity_rows) }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.rows.len() == self.capacity_rows
    }

    /// Push one timestep row (Algorithm 2 "Data Insertion").
    pub fn push_row(&mut self, row: &[T]) -> Result<(), FiloError> {
        if row.len() != self.batch {
            return Err(FiloError::Width { got: row.len(), want: self.batch });
        }
        if self.is_full() {
            return Err(FiloError::Full(self.capacity_rows));
        }
        self.rows.push(row.to_vec());
        Ok(())
    }

    /// Pop the top (most recent) row — GAE iterates backward.
    pub fn pop_row(&mut self) -> Result<Vec<T>, FiloError> {
        self.rows.pop().ok_or(FiloError::Empty)
    }

    /// Read the top row without popping.
    pub fn peek_row(&self) -> Option<&[T]> {
        self.rows.last().map(|r| r.as_slice())
    }

    /// Dual-port in-place exchange: read the top row and overwrite it
    /// with `replacement` in the same operation (§IV-3 "In-Place Updates
    /// and Dual-Port Memory" — advantages overwrite rewards, RTGs
    /// overwrite values). The row stays resident; a subsequent
    /// [`FiloStack::pop_row`] would return the replacement.
    pub fn exchange_top(&mut self, replacement: &[T]) -> Result<Vec<T>, FiloError> {
        if replacement.len() != self.batch {
            return Err(FiloError::Width { got: replacement.len(), want: self.batch });
        }
        let top = self.rows.last_mut().ok_or(FiloError::Empty)?;
        let old = std::mem::replace(top, replacement.to_vec());
        Ok(old)
    }

    /// Descend the stack in place: call `f(t, row)` for t = top..0 with
    /// mutable access, modelling the full backward GAE sweep with
    /// overwrite but leaving the data resident for the PS to read back.
    pub fn for_each_backward_mut(&mut self, mut f: impl FnMut(usize, &mut [T])) {
        for (t, row) in self.rows.iter_mut().enumerate().rev() {
            f(t, row);
        }
    }

    /// Row access by index (PS-side readback after the GAE phase).
    pub fn row(&self, t: usize) -> Option<&[T]> {
        self.rows.get(t).map(|r| r.as_slice())
    }

    /// Clear for the next iteration.
    pub fn reset(&mut self) {
        self.rows.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn push_pop_is_filo() {
        let mut s: FiloStack<u16> = FiloStack::new(2, 4);
        s.push_row(&[1, 2]).unwrap();
        s.push_row(&[3, 4]).unwrap();
        assert_eq!(s.pop_row().unwrap(), vec![3, 4]);
        assert_eq!(s.pop_row().unwrap(), vec![1, 2]);
        assert_eq!(s.pop_row(), Err(FiloError::Empty));
    }

    #[test]
    fn capacity_enforced() {
        let mut s: FiloStack<u16> = FiloStack::new(1, 2);
        s.push_row(&[0]).unwrap();
        s.push_row(&[1]).unwrap();
        assert_eq!(s.push_row(&[2]), Err(FiloError::Full(2)));
    }

    #[test]
    fn width_enforced() {
        let mut s: FiloStack<u16> = FiloStack::new(3, 2);
        assert_eq!(
            s.push_row(&[1, 2]),
            Err(FiloError::Width { got: 2, want: 3 })
        );
    }

    #[test]
    fn exchange_top_overwrites_in_place() {
        let mut s: FiloStack<u16> = FiloStack::new(2, 4);
        s.push_row(&[10, 20]).unwrap();
        s.push_row(&[30, 40]).unwrap();
        let old = s.exchange_top(&[7, 8]).unwrap();
        assert_eq!(old, vec![30, 40]);
        assert_eq!(s.peek_row().unwrap(), &[7, 8]);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn backward_sweep_emulates_algorithm2() {
        // Algorithm 2: push T rows of (reward,value); sweep backward
        // computing adv/rtg and storing them in place; PS reads back in
        // forward order.
        let mut rewards: FiloStack<f32> = FiloStack::new(2, 8);
        let t_len = 5;
        for t in 0..t_len {
            rewards.push_row(&[t as f32, 10.0 + t as f32]).unwrap();
        }
        let mut seen = Vec::new();
        rewards.for_each_backward_mut(|t, row| {
            seen.push(t);
            for x in row.iter_mut() {
                *x = -*x; // stand-in for the adv computation
            }
        });
        assert_eq!(seen, vec![4, 3, 2, 1, 0]);
        assert_eq!(rewards.row(2).unwrap(), &[-2.0, -12.0]);
    }

    #[test]
    fn exchange_top_error_paths() {
        // Empty stack: nothing to exchange.
        let mut s: FiloStack<u16> = FiloStack::new(2, 4);
        assert_eq!(s.exchange_top(&[1, 2]), Err(FiloError::Empty));
        // Wrong width is rejected before touching the resident row.
        s.push_row(&[5, 6]).unwrap();
        assert_eq!(
            s.exchange_top(&[1, 2, 3]),
            Err(FiloError::Width { got: 3, want: 2 })
        );
        assert_eq!(s.peek_row().unwrap(), &[5, 6], "failed exchange must not corrupt");
    }

    #[test]
    fn error_display_is_descriptive() {
        assert_eq!(FiloError::Full(32).to_string(), "stack is full (32 rows)");
        assert_eq!(FiloError::Empty.to_string(), "stack is empty");
        assert_eq!(
            FiloError::Width { got: 2, want: 3 }.to_string(),
            "row width 2 != batch 3"
        );
    }

    #[test]
    fn dual_port_overwrite_round_trip() {
        // §IV-3: the GAE pass reads (r, v) from the top and writes back
        // (adv, rtg) in place, then the PS pops the results — a full
        // overwrite-in-place round trip through both ports.
        let mut s: FiloStack<f32> = FiloStack::new(2, 4);
        for t in 0..4 {
            s.push_row(&[t as f32, t as f32 + 10.0]).unwrap();
        }
        // Backward sweep: exchange each top row for its "computed" form.
        let mut popped = Vec::new();
        for _ in 0..4 {
            let old = s.peek_row().unwrap().to_vec();
            let new: Vec<f32> = old.iter().map(|x| x * 2.0).collect();
            let returned = s.exchange_top(&new).unwrap();
            assert_eq!(returned, old, "exchange returns the pre-overwrite row");
            popped.push(s.pop_row().unwrap());
        }
        // Pops see the replacements, newest first.
        assert_eq!(popped[0], vec![6.0, 26.0]);
        assert_eq!(popped[3], vec![0.0, 20.0]);
        assert!(s.is_empty());
        assert_eq!(s.pop_row(), Err(FiloError::Empty));
        // The stack is reusable after draining (next PPO iteration).
        s.push_row(&[1.0, 2.0]).unwrap();
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn randomized_push_pop_mirror() {
        check("filo == Vec mirror", 30, |g| {
            let batch = g.usize_in(1, 8);
            let cap = g.usize_in(1, 32);
            let mut stack: FiloStack<u16> = FiloStack::new(batch, cap);
            let mut mirror: Vec<Vec<u16>> = Vec::new();
            for _ in 0..200 {
                if g.bool() && !stack.is_full() {
                    let row: Vec<u16> =
                        (0..batch).map(|_| g.usize_in(0, 255) as u16).collect();
                    stack.push_row(&row).unwrap();
                    mirror.push(row);
                } else if !stack.is_empty() {
                    assert_eq!(stack.pop_row().unwrap(), mirror.pop().unwrap());
                }
                assert_eq!(stack.len(), mirror.len());
            }
        });
    }
}

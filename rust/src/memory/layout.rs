//! Timestep-major memory-block layout (paper Fig. 6 / §IV-1).
//!
//! The 2-D arrays are indexed `[timestep][trajectory]`: one address holds
//! the same timestep of all trajectories, so a single fetched row feeds
//! all parallel PEs. Addresses ascend with timestep during collection
//! (push) and descend during GAE (pop) — see [`super::filo`].

/// Address mapping for a `[T, B]` block layout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockLayout {
    /// Timesteps.
    pub t_len: usize,
    /// Trajectories (elements per row).
    pub batch: usize,
    /// Bytes per element as stored (4 for f32, 1 for 8-bit codewords).
    pub elem_bytes: usize,
}

impl BlockLayout {
    pub fn new(t_len: usize, batch: usize, elem_bytes: usize) -> Self {
        assert!(elem_bytes > 0);
        BlockLayout { t_len, batch, elem_bytes }
    }

    /// Paper's running example: 64 trajectories × 1024 timesteps.
    pub fn paper_example(elem_bytes: usize) -> Self {
        Self::new(1024, 64, elem_bytes)
    }

    /// Linear element index of `(t, i)` — row-major over timesteps.
    #[inline]
    pub fn index(&self, t: usize, i: usize) -> usize {
        debug_assert!(t < self.t_len && i < self.batch);
        t * self.batch + i
    }

    /// Byte address of row `t` within one array.
    #[inline]
    pub fn row_addr(&self, t: usize) -> usize {
        t * self.row_bytes()
    }

    /// Bytes per row (one timestep of all trajectories).
    #[inline]
    pub fn row_bytes(&self) -> usize {
        self.batch * self.elem_bytes
    }

    /// Total bytes for one array (e.g. the reward plane).
    pub fn array_bytes(&self) -> usize {
        self.t_len * self.row_bytes()
    }

    /// Bytes per timestep for the *pair* of planes the GAE pass reads
    /// (rewards + values), as §IV-A counts them.
    pub fn bytes_per_timestep_rv(&self) -> usize {
        2 * self.row_bytes()
    }

    /// Total storage for rewards+values, with or without in-place
    /// overwrite of advantages/RTGs (in-place halves the requirement —
    /// §IV-3).
    pub fn total_bytes(&self, in_place: bool) -> usize {
        let planes = if in_place { 2 } else { 4 };
        planes * self.array_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_numbers() {
        // §IV-A: 64 trajectories, f32 → "512 bytes per timestep" counting
        // rewards+values (128 elements).
        let l = BlockLayout::paper_example(4);
        assert_eq!(l.bytes_per_timestep_rv(), 512);
        // §V-D-2: with 8-bit elements and in-place overwrite, 128 B per
        // timestep and 128 KB total for 1024 timesteps.
        let q = BlockLayout::paper_example(1);
        // read row (rewards+values) + write row (adv+rtg) = 2 × 128 B...
        // storage: 2 planes × 1024 × 64 × 1 B = 128 KB? The paper counts
        // 128 B/timestep as the *stored* footprint (two planes of 64 B).
        assert_eq!(q.total_bytes(true), 128 * 1024);
        assert_eq!(q.total_bytes(true) / q.t_len, 128);
    }

    #[test]
    fn row_major_over_timesteps() {
        let l = BlockLayout::new(4, 3, 1);
        assert_eq!(l.index(0, 0), 0);
        assert_eq!(l.index(0, 2), 2);
        assert_eq!(l.index(1, 0), 3);
        assert_eq!(l.row_addr(2), 6);
    }

    #[test]
    fn in_place_halves_storage() {
        let l = BlockLayout::new(128, 16, 4);
        assert_eq!(l.total_bytes(false), 2 * l.total_bytes(true));
    }

    #[test]
    fn quantization_quarters_storage() {
        let f32_layout = BlockLayout::new(1024, 64, 4);
        let q8_layout = BlockLayout::new(1024, 64, 1);
        assert_eq!(
            f32_layout.total_bytes(true) / q8_layout.total_bytes(true),
            4
        );
    }
}

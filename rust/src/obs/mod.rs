//! Cross-layer tracing & telemetry: request-scoped spans from wire to
//! worker, with Chrome-trace export.
//!
//! The service stack already aggregates (per-phase histograms in
//! [`crate::service::metrics`], trainer phase timers in the
//! coordinator's profiler), but aggregates cannot show *causality*: to
//! prove the overlapped pipeline actually overlaps, or to find which
//! stage delayed one slow request, you need the decode, quota, cache,
//! queue-wait, batch, compute, encode, and write of a **single request**
//! on one timeline — even when a fabric failover moved the request
//! between shards mid-flight.
//!
//! Design, in the order the constraints force it:
//!
//! - **Disabled means free.** Tracing is compiled in everywhere
//!   (including the worker slab hot path, which carries a zero-allocation
//!   guarantee), so the disabled path must be a single `Relaxed` atomic
//!   load and nothing else — no thread-local touch, no timestamp, no
//!   allocation. `benches/trace_overhead.rs` enforces this.
//! - **Enabled means bounded.** Each recording thread owns a
//!   fixed-capacity ring ([`trace::RING_CAPACITY`] events, preallocated
//!   on first record) that overwrites its oldest entry when full.
//!   Steady-state recording allocates nothing: events are `Copy` and
//!   span names are `&'static str`.
//! - **Trace ids ride the wire.** A request-scoped id is minted at
//!   client submit ([`trace::mint_trace_id`]) and carried in the wire
//!   frame *header* behind a flag bit — outside the hashed payload, so
//!   identical payloads still share a response-cache entry — then
//!   propagated through the net server, the service queue, the batcher,
//!   the worker, and echoed back in the response. The fabric router
//!   reuses one id across failover attempts, so both serving-shard
//!   attempts land on the same timeline.
//!
//! Exporters ([`export`]) emit Chrome-trace/Perfetto JSON (open in
//! `chrome://tracing` or <https://ui.perfetto.dev>) and line-delimited
//! JSON for ad-hoc grepping; both tag events with their trace id.

pub mod export;
pub mod trace;

pub use trace::{
    enabled, instant, mint_trace_id, set_enabled, span, span_begin, span_end,
    take_events, Event, EventKind, Span,
};

//! Cross-layer tracing & telemetry: request-scoped spans from wire to
//! worker, with Chrome-trace export.
//!
//! The service stack already aggregates (per-phase histograms in
//! [`crate::service::metrics`], trainer phase timers in the
//! coordinator's profiler), but aggregates cannot show *causality*: to
//! prove the overlapped pipeline actually overlaps, or to find which
//! stage delayed one slow request, you need the decode, quota, cache,
//! queue-wait, batch, compute, encode, and write of a **single request**
//! on one timeline — even when a fabric failover moved the request
//! between shards mid-flight.
//!
//! Design, in the order the constraints force it:
//!
//! - **Disabled means free.** Tracing is compiled in everywhere
//!   (including the worker slab hot path, which carries a zero-allocation
//!   guarantee), so the disabled path must be a single `Relaxed` atomic
//!   load and nothing else — no thread-local touch, no timestamp, no
//!   allocation. `benches/trace_overhead.rs` enforces this.
//! - **Enabled means bounded.** Each recording thread owns a
//!   fixed-capacity ring ([`trace::RING_CAPACITY`] events, preallocated
//!   on first record) that overwrites its oldest entry when full.
//!   Steady-state recording allocates nothing: events are `Copy` and
//!   span names are `&'static str`.
//! - **Trace ids ride the wire.** A request-scoped id is minted at
//!   client submit ([`trace::mint_trace_id`]) and carried in the wire
//!   frame *header* behind a flag bit — outside the hashed payload, so
//!   identical payloads still share a response-cache entry — then
//!   propagated through the net server, the service queue, the batcher,
//!   the worker, and echoed back in the response. The fabric router
//!   reuses one id across failover attempts, so both serving-shard
//!   attempts land on the same timeline.
//!
//! Exporters ([`export`]) emit Chrome-trace/Perfetto JSON (open in
//! `chrome://tracing` or <https://ui.perfetto.dev>) and line-delimited
//! JSON for ad-hoc grepping; both tag events with their trace id.
//!
//! # Observability: the live telemetry plane
//!
//! Spans and lifetime histograms answer "what happened since start";
//! the telemetry plane answers "what is happening *now*" and keeps the
//! evidence for the requests that went wrong:
//!
//! - **Windowed vs lifetime metrics.** Every
//!   [`MetricsSnapshot`](crate::service::metrics::MetricsSnapshot)
//!   carries, alongside its lifetime counters/quantiles, three
//!   `windows` rows (last 1s/10s/60s: request rate, element rate,
//!   error/slow counts, and p50/p95/p99 of the total phase) backed by
//!   per-second histogram rings ([`crate::stats::windowed`]). Rotation
//!   rides the recording path — no ticker thread, zero steady-state
//!   allocation (`benches/telemetry_overhead.rs` enforces it), and
//!   idle seconds age out by stamp so a quiet shard reports empty
//!   windows, not a frozen p99.
//! - **Exposition endpoint.** Both net-server front-ends sniff plain
//!   `GET` requests on the binary listen socket: `GET /metrics` returns
//!   the [`telemetry::prometheus_text`] rendering of the live snapshot
//!   and `GET /traces` returns the retained exemplars as one
//!   Chrome-trace JSON document. The same windowed rows also ride the
//!   wire metrics RPC (protocol v5), so `GaeFabric::fleet()` reports
//!   recent rates per shard.
//! - **Tail-sampling policy.** The always-on rings stay the recording
//!   substrate; at request completion the service promotes a span tree
//!   into the bounded [`telemetry::ExemplarStore`] only when the
//!   request was slow (above an adaptive threshold: the 10s-window p99
//!   plus a small margin, falling back to the SLO latency objective
//!   until the window has enough samples), errored, shed, or failed
//!   over. Retained ids are attached to the windowed p99 exposition
//!   rows as exemplars and queryable over the wire trace RPC.
//! - **SLO configuration.** [`slo::SloConfig`] (a
//!   [`ServiceConfig`](crate::service::ServiceConfig) field) sets the
//!   latency objective/target and availability target; the snapshot
//!   evaluates them per window into multi-window burn rates and an
//!   `Ok/Warn/Critical` [`slo::SloHealth`], surfaced per shard in
//!   `FleetSnapshot` and the exposition.
//!
//! # Numerics observability
//!
//! The system plane above tells you the service is fast and available;
//! the *numerics* plane ([`numerics`]) tells you the quantization is
//! still telling the truth. On every path where an f32 plane and its
//! 8-bit image coexist (wire plane encode/decode, the codec round
//! trip), the stack measures reconstruction error (max-abs + MSE),
//! end-code saturation rate, 256-code utilization, and Welford-tracked
//! (μ,σ) drift of the per-plane block stats — per shard and per
//! tenant, on the same per-second ring machinery and the same
//! zero-alloc record-path bar as the windowed metrics
//! (`benches/telemetry_overhead.rs` enforces it). A
//! [`numerics::NumericsHealth`] verdict from the 1s window (saturation
//! ≥ 0.5%/2%, upward σ-drift ≥ 0.5/2.0 → Warn/Critical) folds into the
//! SLO → `FleetSnapshot.health` chain, and a critically-saturated
//! plane is retained as a trace exemplar
//! ([`RetainReason::Saturated`]) grep-able in `GET /metrics` and
//! `GET /traces`. On the training side, [`timeseries`] writes a
//! per-iteration learning-health JSONL record (mean return, advantage
//! moments pre/post standardization, value explained-variance,
//! approx-KL, clip fraction), so learning curves are grep-able files
//! rather than final numbers.

pub mod export;
pub mod numerics;
pub mod slo;
pub mod telemetry;
pub mod timeseries;
pub mod trace;

pub use numerics::{
    NumericsAccum, NumericsHealth, NumericsSnapshot, NumericsWindow, PlaneNumerics,
};
pub use slo::{SloConfig, SloHealth, SloReport};
pub use telemetry::{prometheus_text, ExemplarMeta, ExemplarStore, RetainReason};
pub use timeseries::{JsonlWriter, LearningHealthRecord};
pub use trace::{
    enabled, instant, mint_trace_id, set_enabled, span, span_begin, span_end,
    take_events, trace_events, Event, EventKind, Span,
};

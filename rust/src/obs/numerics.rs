//! Numerics observability: live quantization-health accumulators.
//!
//! The paper's claim chain — standardize, quantize at 8 bits, keep
//! learning — holds only while the planes actually look like the
//! calibrated distribution. This module measures that continuously, on
//! the paths where the f32 and quantized representations are *both
//! already in hand* (wire plane encode/decode, the codec round trip),
//! so observation costs no extra pass:
//!
//! - **Reconstruction error** — max-abs and MSE between the original
//!   plane and its quantize→dequantize image, in plane units.
//! - **Clip/saturation rate** — fraction of elements landing on the
//!   quantizer's end codes. With per-plane block standardization the
//!   ±5σ range clips ≤ 1/25 = 4% of *any* distribution (Chebyshev), and
//!   < 0.0001% of a Gaussian — so a rate past
//!   [`SATURATION_WARN`]/[`SATURATION_CRITICAL`] means the plane has
//!   outliers the codec is destroying.
//! - **Code utilization** — how much of the 256-code space the plane
//!   actually occupies (a plane using 4 codes is over-ranged: its
//!   effective resolution collapsed).
//! - **(μ,σ) drift** — Welford streams over the per-plane block stats
//!   ([`crate::stats::Welford`]), lifetime vs windowed; the windowed σ
//!   running *ahead* of the lifetime baseline is the early sign of the
//!   saturation failure mode.
//!
//! Accumulators are windowed on the [`crate::stats::windowed`] ring
//! machinery (per-second buckets, stamp-rotated on the record path) and
//! held to the telemetry plane's bar: the steady-state record path
//! allocates nothing and gathers nothing — `benches/telemetry_overhead`
//! enforces it.
//!
//! [`NumericsHealth`] folds the windowed verdict into the SLO health
//! chain (`obs/slo.rs` → `FleetSnapshot.health`), so a tenant whose
//! planes start saturating pages fleet-wide within one window.

use crate::obs::slo::SloHealth;
use crate::quant::UniformQuantizer;
use crate::stats::windowed::{RingSlot, WindowedSlots};
use crate::stats::Welford;

/// Words in the 256-bit used-code set (8-bit operating point; wider
/// codes fold down, narrower ones use a prefix).
pub const CODE_SET_WORDS: usize = 4;

/// Windowed saturation rate that degrades the verdict to `Warn`. A
/// block-standardized Gaussian plane clips ~1e-6 of its mass at ±5σ;
/// half a percent is already three orders of magnitude off nominal.
pub const SATURATION_WARN: f64 = 0.005;

/// Windowed saturation rate that degrades the verdict to `Critical`.
/// Chebyshev bounds *any* standardized distribution at 4% past ±5σ; a
/// plane clipping 2% is approaching the worst case any input could
/// produce — its tail is being flattened wholesale.
pub const SATURATION_CRITICAL: f64 = 0.02;

/// Upward windowed-σ drift (relative to the lifetime baseline) that
/// degrades to `Warn`: the window's planes are half again wider than
/// history.
pub const SIGMA_DRIFT_WARN: f64 = 0.5;

/// Upward windowed-σ drift that degrades to `Critical` (3× the
/// calibrated width).
pub const SIGMA_DRIFT_CRITICAL: f64 = 2.0;

/// Minimum elements in a window (or plane) before a verdict is drawn —
/// a four-element plane with one clipped value is noise, not a page.
pub const MIN_HEALTH_ELEMENTS: u64 = 64;

/// Lifetime planes required before σ-drift is trusted (the baseline
/// must exist before deviation from it means anything).
pub const MIN_BASELINE_PLANES: u64 = 8;

/// Floor for the drift denominator.
const SIGMA_FLOOR: f64 = 1e-6;

/// Default ring depth, matching the service metrics plane.
pub const NUMERICS_RING_SECS: usize = 64;

/// Measurements for one quantized plane, taken where the f32 and coded
/// representations coexist. Plain data: filling one is a few ALU ops
/// per element on the encode/decode loops, and recording one into an
/// accumulator is O(1).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlaneNumerics {
    /// Elements observed.
    pub elements: u64,
    /// Elements on the quantizer's end codes (saturated).
    pub clipped: u64,
    /// Whether reconstruction error was measurable on this path (encode
    /// sides see both planes; a decoder alone sees only codes).
    pub err_measured: bool,
    /// Max |original − reconstructed|, in plane units.
    pub max_abs_err: f32,
    /// Σ (original − reconstructed)², in plane units².
    pub sum_sq_err: f64,
    /// 256-bit set of codes used (codes wider than 8 bits fold down).
    pub code_set: [u64; CODE_SET_WORDS],
    /// Block mean the plane was standardized with.
    pub mean: f32,
    /// Block σ the plane was standardized with.
    pub std: f32,
}

impl PlaneNumerics {
    /// Note one codeword: element count, end-code saturation, and the
    /// used-code set.
    #[inline]
    pub fn note_code(&mut self, code: u16, bits: u8) {
        self.elements += 1;
        let max_code = ((1u32 << bits) - 1) as u16;
        self.clipped += (code == 0 || code == max_code) as u64;
        let folded = if bits > 8 { code >> (bits - 8) } else { code } as usize;
        self.code_set[(folded >> 6) & (CODE_SET_WORDS - 1)] |= 1u64 << (folded & 63);
    }

    /// Note one element's reconstruction error (plane units).
    #[inline]
    pub fn note_err(&mut self, abs_err: f32) {
        self.err_measured = true;
        self.max_abs_err = self.max_abs_err.max(abs_err);
        self.sum_sq_err += (abs_err as f64) * (abs_err as f64);
    }

    /// Record the block stats the plane was standardized with.
    #[inline]
    pub fn set_block(&mut self, mean: f32, std: f32) {
        self.mean = mean;
        self.std = std;
    }

    /// Fraction of elements on the end codes.
    pub fn saturation_rate(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.clipped as f64 / self.elements as f64
        }
    }

    /// Distinct codes used (after folding to 8 bits).
    pub fn codes_used(&self) -> u32 {
        self.code_set.iter().map(|w| w.count_ones()).sum()
    }

    /// Whether this single plane saturates past the `Critical` bar —
    /// the per-record trigger for exemplar retention.
    pub fn is_critically_saturated(&self) -> bool {
        self.elements >= MIN_HEALTH_ELEMENTS
            && self.saturation_rate() >= SATURATION_CRITICAL
    }

    /// Measure a plane post-hoc from its original and round-tripped
    /// copies plus the standardization stats that sat between them
    /// (the codec path: planes were transformed in place, so the codes
    /// are re-derived here). Errors land in `reconstructed`'s units.
    pub fn measure(
        original: &[f32],
        reconstructed: &[f32],
        q: &UniformQuantizer,
        mean: f32,
        std: f32,
        destandardized: bool,
    ) -> PlaneNumerics {
        let mut pn = PlaneNumerics::default();
        pn.set_block(mean, std);
        let err_scale = if destandardized { std } else { 1.0 };
        for (&x, &r) in original.iter().zip(reconstructed) {
            let z = (x - mean) / std;
            let code = q.quantize(z);
            pn.note_code(code, q.bits);
            let recon_z = q.dequantize(code);
            // `r` is the plane the trainer reads back; measuring against
            // the re-derived code keeps this exact even when the caller
            // destandardized in place.
            debug_assert!(
                !destandardized || (recon_z * std + mean - r).abs() <= 1e-3 * std.abs().max(1.0),
                "re-derived code disagrees with the stored plane"
            );
            let _ = r;
            pn.note_err((recon_z - z).abs() * err_scale);
        }
        pn
    }
}

/// One per-second ring bucket: plane measurements folded together.
#[derive(Debug, Clone, Default)]
pub struct NumericsBucket {
    pub planes: u64,
    pub elements: u64,
    pub clipped: u64,
    /// Elements whose reconstruction error was measured.
    pub err_elements: u64,
    pub sum_sq_err: f64,
    pub max_abs_err: f64,
    pub code_set: [u64; CODE_SET_WORDS],
    /// Welford stream over per-plane block σ (one sample per plane).
    pub sigma: Welford,
    /// Welford stream over per-plane block μ.
    pub mu: Welford,
}

impl NumericsBucket {
    #[inline]
    fn record(&mut self, pn: &PlaneNumerics) {
        self.planes += 1;
        self.elements += pn.elements;
        self.clipped += pn.clipped;
        if pn.err_measured {
            self.err_elements += pn.elements;
            self.sum_sq_err += pn.sum_sq_err;
            self.max_abs_err = self.max_abs_err.max(pn.max_abs_err as f64);
        }
        for (s, p) in self.code_set.iter_mut().zip(&pn.code_set) {
            *s |= p;
        }
        self.sigma.push(pn.std as f64);
        self.mu.push(pn.mean as f64);
    }
}

impl RingSlot for NumericsBucket {
    fn reset(&mut self) {
        *self = NumericsBucket::default();
    }

    fn merge_into(&self, out: &mut Self) {
        out.planes += self.planes;
        out.elements += self.elements;
        out.clipped += self.clipped;
        out.err_elements += self.err_elements;
        out.sum_sq_err += self.sum_sq_err;
        out.max_abs_err = out.max_abs_err.max(self.max_abs_err);
        for (o, s) in out.code_set.iter_mut().zip(&self.code_set) {
            *o |= s;
        }
        out.sigma.merge(&self.sigma);
        out.mu.merge(&self.mu);
    }
}

/// A merged view over the last `span_secs` seconds — the row the
/// snapshot, Prometheus page, and wire metrics RPC all carry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NumericsWindow {
    pub span_secs: u64,
    pub planes: u64,
    pub elements: u64,
    pub clipped: u64,
    pub err_elements: u64,
    /// Mean squared reconstruction error over error-measured elements.
    pub mse: f64,
    pub max_abs_err: f64,
    pub codes_used: u32,
    /// `codes_used` over the (≤256-entry) code space.
    pub code_utilization: f64,
    /// Mean per-plane block σ in the window.
    pub sigma_mean: f64,
    /// Mean per-plane block μ in the window.
    pub mu_mean: f64,
    /// Upward drift of the windowed σ vs the lifetime baseline:
    /// `max(0, windowed/lifetime − 1)`. Only widening counts — a
    /// narrower plane wastes codes but saturates nothing.
    pub sigma_drift: f64,
    pub saturation_rate: f64,
}

/// Health verdict for the numerics plane. Ordered so `max` picks the
/// worst across tenants and shards, mirroring [`SloHealth`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum NumericsHealth {
    #[default]
    Ok,
    Warn,
    Critical,
}

impl NumericsHealth {
    pub fn as_str(&self) -> &'static str {
        match self {
            NumericsHealth::Ok => "ok",
            NumericsHealth::Warn => "warn",
            NumericsHealth::Critical => "critical",
        }
    }

    /// Wire code.
    pub fn code(&self) -> u8 {
        match self {
            NumericsHealth::Ok => 0,
            NumericsHealth::Warn => 1,
            NumericsHealth::Critical => 2,
        }
    }

    /// Wire decode; unknown codes read as `Critical` (same fail-loud
    /// posture as [`SloHealth`]).
    pub fn from_code(code: u8) -> NumericsHealth {
        match code {
            0 => NumericsHealth::Ok,
            1 => NumericsHealth::Warn,
            _ => NumericsHealth::Critical,
        }
    }

    /// Fold into the SLO chain: a numerics page is an SLO page.
    pub fn to_slo(self) -> SloHealth {
        match self {
            NumericsHealth::Ok => SloHealth::Ok,
            NumericsHealth::Warn => SloHealth::Warn,
            NumericsHealth::Critical => SloHealth::Critical,
        }
    }

    /// Verdict for one windowed view: saturation and σ-drift each have
    /// Warn/Critical bars; the worst wins. Windows below
    /// [`MIN_HEALTH_ELEMENTS`] abstain (`Ok`).
    pub fn evaluate(win: &NumericsWindow) -> NumericsHealth {
        if win.elements < MIN_HEALTH_ELEMENTS {
            return NumericsHealth::Ok;
        }
        if win.saturation_rate >= SATURATION_CRITICAL
            || win.sigma_drift >= SIGMA_DRIFT_CRITICAL
        {
            NumericsHealth::Critical
        } else if win.saturation_rate >= SATURATION_WARN
            || win.sigma_drift >= SIGMA_DRIFT_WARN
        {
            NumericsHealth::Warn
        } else {
            NumericsHealth::Ok
        }
    }
}

/// Lifetime + windowed quantization-health accumulator (one per shard,
/// plus one per tenant). The record path is a handful of adds and one
/// stamp compare; storage is allocated at construction.
#[derive(Debug, Clone)]
pub struct NumericsAccum {
    pub planes: u64,
    pub elements: u64,
    pub clipped: u64,
    pub err_elements: u64,
    pub sum_sq_err: f64,
    pub max_abs_err: f64,
    /// Lifetime Welford streams over per-plane block stats — the drift
    /// baseline the windowed σ is compared against.
    pub sigma: Welford,
    pub mu: Welford,
    ring: WindowedSlots<NumericsBucket>,
}

impl Default for NumericsAccum {
    fn default() -> Self {
        NumericsAccum::new(NUMERICS_RING_SECS)
    }
}

impl NumericsAccum {
    pub fn new(ring_secs: usize) -> NumericsAccum {
        NumericsAccum {
            planes: 0,
            elements: 0,
            clipped: 0,
            err_elements: 0,
            sum_sq_err: 0.0,
            max_abs_err: 0.0,
            sigma: Welford::new(),
            mu: Welford::new(),
            ring: WindowedSlots::new(ring_secs),
        }
    }

    /// Fold one plane's measurements in — the steady-state record path
    /// (0 allocations: the bucket rotates by in-place reset).
    #[inline]
    pub fn record(&mut self, now_sec: u64, pn: &PlaneNumerics) {
        self.planes += 1;
        self.elements += pn.elements;
        self.clipped += pn.clipped;
        if pn.err_measured {
            self.err_elements += pn.elements;
            self.sum_sq_err += pn.sum_sq_err;
            self.max_abs_err = self.max_abs_err.max(pn.max_abs_err as f64);
        }
        self.sigma.push(pn.std as f64);
        self.mu.push(pn.mean as f64);
        self.ring.slot_mut(now_sec).record(pn);
    }

    /// The merged view of the last `span_secs` seconds, with σ-drift
    /// computed against the lifetime baseline.
    pub fn window(&self, now_sec: u64, span_secs: u64) -> NumericsWindow {
        let b = self.ring.merged(now_sec, span_secs);
        let life_sigma = self.sigma.mean();
        let win_sigma = b.sigma.mean();
        let sigma_drift = if self.sigma.count() < MIN_BASELINE_PLANES || b.planes == 0 {
            0.0
        } else {
            (win_sigma / life_sigma.max(SIGMA_FLOOR) - 1.0).max(0.0)
        };
        NumericsWindow {
            span_secs,
            planes: b.planes,
            elements: b.elements,
            clipped: b.clipped,
            err_elements: b.err_elements,
            mse: if b.err_elements == 0 { 0.0 } else { b.sum_sq_err / b.err_elements as f64 },
            max_abs_err: b.max_abs_err,
            codes_used: b.code_set.iter().map(|w| w.count_ones()).sum(),
            code_utilization: b.code_set.iter().map(|w| w.count_ones()).sum::<u32>() as f64
                / 256.0,
            sigma_mean: win_sigma,
            mu_mean: b.mu.mean(),
            sigma_drift,
            saturation_rate: if b.elements == 0 {
                0.0
            } else {
                b.clipped as f64 / b.elements as f64
            },
        }
    }

    /// The fast verdict: the 1s window, so Critical lands — and clears
    /// — within one window of the traffic that caused it.
    pub fn health(&self, now_sec: u64) -> NumericsHealth {
        NumericsHealth::evaluate(&self.window(now_sec, 1))
    }
}

/// Point-in-time numerics rows carried by
/// [`MetricsSnapshot`](crate::service::MetricsSnapshot): lifetime
/// aggregates plus the standard 1/10/60s windows and the 1s-window
/// verdict.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NumericsSnapshot {
    pub planes: u64,
    pub elements: u64,
    pub clipped: u64,
    pub err_elements: u64,
    pub sum_sq_err: f64,
    pub max_abs_err: f64,
    /// Lifetime mean/σ of the per-plane block σ stream.
    pub sigma_mean: f64,
    pub sigma_std: f64,
    pub mu_mean: f64,
    pub windows: [NumericsWindow; 3],
    /// Worst of the shard-wide and per-tenant 1s verdicts.
    pub health: NumericsHealth,
    /// Saturation exemplars retained since start.
    pub saturated_exemplars: u64,
}

impl NumericsSnapshot {
    /// Lifetime mean squared reconstruction error.
    pub fn mse(&self) -> f64 {
        if self.err_elements == 0 {
            0.0
        } else {
            self.sum_sq_err / self.err_elements as f64
        }
    }

    /// The view for a span (1, 10 or 60 seconds).
    pub fn window(&self, span_secs: u64) -> &NumericsWindow {
        self.windows
            .iter()
            .find(|w| w.span_secs == span_secs)
            .unwrap_or(&self.windows[0])
    }

    /// Lifetime saturation rate.
    pub fn saturation_rate(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.clipped as f64 / self.elements as f64
        }
    }
}

impl NumericsAccum {
    /// Build the snapshot rows (snapshot path — allocation is fine
    /// here; the record path above is the one held to zero).
    pub fn snapshot(&self, now_sec: u64, saturated_exemplars: u64) -> NumericsSnapshot {
        let windows = [1u64, 10, 60].map(|s| self.window(now_sec, s));
        NumericsSnapshot {
            planes: self.planes,
            elements: self.elements,
            clipped: self.clipped,
            err_elements: self.err_elements,
            sum_sq_err: self.sum_sq_err,
            max_abs_err: self.max_abs_err,
            sigma_mean: self.sigma.mean(),
            sigma_std: self.sigma.std_population(),
            mu_mean: self.mu.mean(),
            health: NumericsHealth::evaluate(&windows[0]),
            windows,
            saturated_exemplars,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quantized_plane(data: &[f32], q: &UniformQuantizer) -> PlaneNumerics {
        let stats = crate::quant::BlockStats::of(data);
        let mut pn = PlaneNumerics::default();
        pn.set_block(stats.mean, stats.std);
        for &x in data {
            let z = (x - stats.mean) / stats.std;
            let code = q.quantize(z);
            pn.note_code(code, q.bits);
            pn.note_err((q.dequantize(code) - z).abs() * stats.std);
        }
        pn
    }

    #[test]
    fn constant_plane_sigma_zero_is_finite_and_healthy() {
        // σ=0 planes standardize through the STD_FLOOR; every element
        // maps to the σ-floored z=0 code, nothing clips, one code used.
        let q = UniformQuantizer::new(8);
        let pn = quantized_plane(&[4.2f32; 256], &q);
        assert_eq!(pn.elements, 256);
        assert_eq!(pn.clipped, 0, "constant plane must not saturate");
        assert_eq!(pn.codes_used(), 1);
        assert!(pn.max_abs_err.is_finite() && pn.sum_sq_err.is_finite());
        let mut acc = NumericsAccum::new(8);
        acc.record(1, &pn);
        let w = acc.window(1, 1);
        assert_eq!(w.saturation_rate, 0.0);
        assert!(w.sigma_mean.abs() < 1e-3);
        assert_eq!(NumericsHealth::evaluate(&w), NumericsHealth::Ok);
    }

    #[test]
    fn all_clipped_plane_reports_saturation_one() {
        // A two-sided spike train standardizes to z = ±1/… far past the
        // ±5σ range? No — build it directly: alternate huge outliers so
        // every element lands on an end code.
        let q = UniformQuantizer::new(8);
        let mut pn = PlaneNumerics::default();
        for i in 0..128u32 {
            let z = if i % 2 == 0 { 50.0 } else { -50.0 };
            let code = q.quantize(z);
            pn.note_code(code, q.bits);
            pn.note_err((q.dequantize(code) - z).abs());
        }
        assert_eq!(pn.saturation_rate(), 1.0);
        assert_eq!(pn.codes_used(), 2, "only the two end codes");
        assert!(pn.is_critically_saturated());
        let mut acc = NumericsAccum::new(8);
        acc.record(0, &pn);
        let w = acc.window(0, 1);
        assert_eq!(w.saturation_rate, 1.0);
        assert_eq!(NumericsHealth::evaluate(&w), NumericsHealth::Critical);
    }

    #[test]
    fn empty_windows_age_out_by_stamp() {
        let q = UniformQuantizer::new(8);
        let mut acc = NumericsAccum::new(8);
        let data: Vec<f32> = (0..256).map(|i| (i as f32 * 0.37).sin()).collect();
        acc.record(5, &quantized_plane(&data, &q));
        assert_eq!(acc.window(5, 1).elements, 256);
        // Much later, the window is empty — no frozen saturation rate —
        // and the verdict abstains; lifetime rows persist.
        let w = acc.window(500, 10);
        assert_eq!(w.elements, 0);
        assert_eq!(w.planes, 0);
        assert_eq!(w.saturation_rate, 0.0);
        assert_eq!(w.codes_used, 0);
        assert_eq!(NumericsHealth::evaluate(&w), NumericsHealth::Ok);
        assert_eq!(acc.elements, 256, "lifetime aggregate survives aging");
        // The aliasing second (5 % 8 == 13 % 8) resets in place.
        assert_eq!(acc.window(13, 1).elements, 0);
    }

    #[test]
    fn welford_merge_across_window_rotation_matches_sequential() {
        // Planes recorded across two different seconds merge their
        // bucket Welford streams; the merged (μ,σ)-of-σ must equal one
        // stream that saw every plane in order.
        let q = UniformQuantizer::new(8);
        let mut acc = NumericsAccum::new(8);
        let mut reference = Welford::new();
        for sec in [7u64, 8] {
            for k in 0..5 {
                let scale = 1.0 + 0.3 * (sec as f32 - 7.0) + 0.1 * k as f32;
                let data: Vec<f32> =
                    (0..128).map(|i| (i as f32 * 0.71).sin() * scale).collect();
                let pn = quantized_plane(&data, &q);
                reference.push(pn.std as f64);
                acc.record(sec, &pn);
            }
        }
        let w = acc.window(8, 2);
        assert_eq!(w.planes, 10);
        assert!((w.sigma_mean - reference.mean()).abs() < 1e-12);
        // And the lifetime stream agrees (same samples, same math).
        assert!((acc.sigma.mean() - reference.mean()).abs() < 1e-12);
        assert!(
            (acc.sigma.std_population() - reference.std_population()).abs() < 1e-12
        );
    }

    #[test]
    fn health_walks_ok_warn_critical_and_recovers() {
        let q = UniformQuantizer::new(8);
        let mut acc = NumericsAccum::new(64);

        // Plane generator with a controllable outlier fraction: spikes
        // at 100× the base scale blow past ±5σ of the block σ.
        let plane = |outliers_per_256: usize, seed: f32| -> PlaneNumerics {
            let data: Vec<f32> = (0..256)
                .map(|i| {
                    if i < outliers_per_256 {
                        if i % 2 == 0 { 100.0 } else { -100.0 }
                    } else {
                        ((i as f32 + seed) * 0.37).sin()
                    }
                })
                .collect();
            quantized_plane(&data, &q)
        };

        // Baseline: clean planes → Ok.
        for k in 0..10 {
            acc.record(10, &plane(0, k as f32));
        }
        assert_eq!(acc.health(10), NumericsHealth::Ok);

        // Mild outliers in the next second: saturation past 0.5% → Warn.
        for k in 0..4 {
            acc.record(11, &plane(2, k as f32));
        }
        let w = acc.window(11, 1);
        assert!(w.saturation_rate >= SATURATION_WARN, "{}", w.saturation_rate);
        assert!(w.saturation_rate < SATURATION_CRITICAL);
        assert_eq!(acc.health(11), NumericsHealth::Warn);

        // Heavy outliers: past 2% → Critical, with σ-drift climbing too.
        for k in 0..4 {
            acc.record(12, &plane(16, k as f32));
        }
        let w = acc.window(12, 1);
        assert!(w.saturation_rate >= SATURATION_CRITICAL, "{}", w.saturation_rate);
        assert!(w.sigma_drift > 0.0, "spiky planes must widen σ: {}", w.sigma_drift);
        assert_eq!(acc.health(12), NumericsHealth::Critical);

        // Recovery: clean traffic one window later → Ok, even though
        // the lifetime baseline now carries the spiky planes (drift
        // only counts widening, so the narrower recovery σ is clean).
        for k in 0..10 {
            acc.record(13, &plane(0, k as f32));
        }
        assert_eq!(acc.health(13), NumericsHealth::Ok);
    }

    #[test]
    fn sigma_drift_alone_can_page() {
        let q = UniformQuantizer::new(8);
        let mut acc = NumericsAccum::new(64);
        let plane = |scale: f32, seed: f32| -> PlaneNumerics {
            let data: Vec<f32> =
                (0..256).map(|i| ((i as f32 + seed) * 0.37).sin() * scale).collect();
            quantized_plane(&data, &q)
        };
        for k in 0..10 {
            acc.record(20, &plane(1.0, k as f32));
        }
        assert_eq!(acc.health(20), NumericsHealth::Ok);
        // Planes 10× wider: nothing need clip (block std renormalizes),
        // but the σ stream has left its baseline far behind.
        for k in 0..4 {
            acc.record(21, &plane(10.0, k as f32));
        }
        let w = acc.window(21, 1);
        assert!(w.sigma_drift >= SIGMA_DRIFT_CRITICAL, "{}", w.sigma_drift);
        assert_eq!(acc.health(21), NumericsHealth::Critical);
    }

    #[test]
    fn health_codes_roundtrip_and_order() {
        for h in [NumericsHealth::Ok, NumericsHealth::Warn, NumericsHealth::Critical] {
            assert_eq!(NumericsHealth::from_code(h.code()), h);
        }
        assert_eq!(NumericsHealth::from_code(250), NumericsHealth::Critical);
        assert!(NumericsHealth::Critical > NumericsHealth::Warn);
        assert!(NumericsHealth::Warn > NumericsHealth::Ok);
        assert_eq!(NumericsHealth::Critical.to_slo(), SloHealth::Critical);
        assert_eq!(NumericsHealth::Ok.to_slo(), SloHealth::Ok);
    }

    #[test]
    fn measure_matches_inline_accounting() {
        // The codec path's post-hoc `measure` must agree with the
        // encode loop's inline accounting.
        let q = UniformQuantizer::new(8);
        let data: Vec<f32> = (0..512).map(|i| (i as f32 * 0.13).sin() * 3.0).collect();
        let inline = quantized_plane(&data, &q);
        let stats = crate::quant::BlockStats::of(&data);
        let mut recon = data.clone();
        for x in recon.iter_mut() {
            *x = q.roundtrip((*x - stats.mean) / stats.std) * stats.std + stats.mean;
        }
        let measured =
            PlaneNumerics::measure(&data, &recon, &q, stats.mean, stats.std, true);
        assert_eq!(measured.elements, inline.elements);
        assert_eq!(measured.clipped, inline.clipped);
        assert_eq!(measured.code_set, inline.code_set);
        assert!((measured.max_abs_err - inline.max_abs_err).abs() < 1e-6);
        assert!((measured.sum_sq_err - inline.sum_sq_err).abs() < 1e-6);
    }

    #[test]
    fn snapshot_rows_cover_windows_and_lifetime() {
        let q = UniformQuantizer::new(8);
        let mut acc = NumericsAccum::new(64);
        let data: Vec<f32> = (0..256).map(|i| (i as f32 * 0.29).sin()).collect();
        for sec in 0..3u64 {
            acc.record(sec, &quantized_plane(&data, &q));
        }
        let snap = acc.snapshot(2, 1);
        assert_eq!(snap.planes, 3);
        assert_eq!(snap.window(1).planes, 1);
        assert_eq!(snap.window(10).planes, 3);
        assert_eq!(snap.saturated_exemplars, 1);
        assert_eq!(snap.health, NumericsHealth::Ok);
        assert!(snap.mse() >= 0.0);
        assert!(snap.window(1).code_utilization > 0.1, "healthy plane uses many codes");
    }
}

//! Learning-curve time series: per-iteration JSONL records.
//!
//! The paper's 1.5×-cumulative-reward claim is a *curve*, not a final
//! number — comparing standardization configurations requires the whole
//! trajectory. [`JsonlWriter`] appends one JSON object per line to a
//! file (the format every plotting/grep toolchain already reads), and
//! [`LearningHealthRecord`] is the record the trainer emits each
//! iteration: return statistics plus the PPO-health scalars
//! (advantage moments pre/post standardization, value
//! explained-variance, approx-KL, clip fraction) that explain *why* a
//! curve went flat.
//!
//! The writer lives on the trainer's iteration boundary — file I/O per
//! *iteration*, not per step — so it carries no zero-alloc obligation;
//! it flushes per record so a killed run keeps every completed line.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// Append-only JSONL sink: one [`Json`] document per line.
#[derive(Debug)]
pub struct JsonlWriter {
    out: BufWriter<File>,
    path: String,
    records: u64,
}

impl JsonlWriter {
    /// Create (truncating) a JSONL file; parent directories are created.
    pub fn create(path: &str) -> anyhow::Result<JsonlWriter> {
        if let Some(dir) = Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let f = File::create(path)?;
        Ok(JsonlWriter { out: BufWriter::new(f), path: path.to_string(), records: 0 })
    }

    /// Open for appending (resumed runs extend their curve).
    pub fn append(path: &str) -> anyhow::Result<JsonlWriter> {
        if let Some(dir) = Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlWriter { out: BufWriter::new(f), path: path.to_string(), records: 0 })
    }

    /// Write one record and flush it to disk.
    pub fn write(&mut self, record: &Json) -> anyhow::Result<()> {
        writeln!(self.out, "{record}")?;
        self.out.flush()?;
        self.records += 1;
        Ok(())
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    pub fn records_written(&self) -> u64 {
        self.records
    }
}

/// One per-iteration learning-health row. All advantage statistics are
/// computed over the full rollout batch; `adv_*_post` reflect exactly
/// what the PPO update consumed (identical to `adv_*_pre` when
/// standardization is off).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LearningHealthRecord {
    pub iter: usize,
    pub env_steps: u64,
    pub episodes: u64,
    /// Rolling mean episodic return (raw reward units).
    pub mean_return: f32,
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub adv_mean_pre: f32,
    pub adv_std_pre: f32,
    pub adv_mean_post: f32,
    pub adv_std_post: f32,
    /// 1 − Var(returns-to-go − values) / Var(returns-to-go): how much
    /// of the return variance the critic explains (1 = perfect, ≤ 0 =
    /// worse than predicting the mean).
    pub value_explained_variance: f32,
    /// Mean(logp_old − logp_new) over the rollout after the update — a
    /// first-order KL(old‖new) estimate.
    pub approx_kl: f32,
    /// Fraction of transitions whose post-update ratio left the
    /// `1 ± clip_eps` trust region.
    pub clip_fraction: f32,
}

impl LearningHealthRecord {
    /// Render as the JSONL row.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iter", Json::from(self.iter)),
            ("env_steps", Json::Num(self.env_steps as f64)),
            ("episodes", Json::Num(self.episodes as f64)),
            ("mean_return", Json::Num(self.mean_return as f64)),
            ("pi_loss", Json::Num(self.pi_loss as f64)),
            ("v_loss", Json::Num(self.v_loss as f64)),
            ("entropy", Json::Num(self.entropy as f64)),
            ("adv_mean_pre", Json::Num(self.adv_mean_pre as f64)),
            ("adv_std_pre", Json::Num(self.adv_std_pre as f64)),
            ("adv_mean_post", Json::Num(self.adv_mean_post as f64)),
            ("adv_std_post", Json::Num(self.adv_std_post as f64)),
            (
                "value_explained_variance",
                Json::Num(self.value_explained_variance as f64),
            ),
            ("approx_kl", Json::Num(self.approx_kl as f64)),
            ("clip_fraction", Json::Num(self.clip_fraction as f64)),
        ])
    }

    /// Parse one JSONL row back (the bench/plot side).
    pub fn from_json(j: &Json) -> anyhow::Result<LearningHealthRecord> {
        let f = |key: &str| -> anyhow::Result<f32> {
            Ok(j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{key} is not a number"))?
                as f32)
        };
        Ok(LearningHealthRecord {
            iter: j.req("iter")?.as_usize().unwrap_or(0),
            env_steps: f("env_steps")? as u64,
            episodes: f("episodes")? as u64,
            mean_return: f("mean_return")?,
            pi_loss: f("pi_loss")?,
            v_loss: f("v_loss")?,
            entropy: f("entropy")?,
            adv_mean_pre: f("adv_mean_pre")?,
            adv_std_pre: f("adv_std_pre")?,
            adv_mean_post: f("adv_mean_post")?,
            adv_std_post: f("adv_std_post")?,
            value_explained_variance: f("value_explained_variance")?,
            approx_kl: f("approx_kl")?,
            clip_fraction: f("clip_fraction")?,
        })
    }
}

/// Helper: explained variance 1 − Var(target − pred)/Var(target),
/// clamped to a floor of −1 so a catastrophically wrong critic reads
/// as −1, not −∞. Returns 0 when the target is (near-)constant.
pub fn explained_variance(targets: &[f32], preds: &[f32]) -> f32 {
    debug_assert_eq!(targets.len(), preds.len());
    if targets.is_empty() {
        return 0.0;
    }
    let n = targets.len() as f64;
    let t_mean = targets.iter().map(|&t| t as f64).sum::<f64>() / n;
    let t_var =
        targets.iter().map(|&t| (t as f64 - t_mean).powi(2)).sum::<f64>() / n;
    if t_var < 1e-12 {
        return 0.0;
    }
    let r_mean = targets
        .iter()
        .zip(preds)
        .map(|(&t, &p)| t as f64 - p as f64)
        .sum::<f64>()
        / n;
    let r_var = targets
        .iter()
        .zip(preds)
        .map(|(&t, &p)| (t as f64 - p as f64 - r_mean).powi(2))
        .sum::<f64>()
        / n;
    ((1.0 - r_var / t_var) as f32).max(-1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;

    #[test]
    fn jsonl_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("heppo_timeseries_test");
        let path = dir.join("curve.jsonl");
        let path = path.to_str().unwrap().to_string();
        let mut w = JsonlWriter::create(&path).unwrap();
        for i in 0..3 {
            let rec = LearningHealthRecord {
                iter: i,
                env_steps: (i as u64 + 1) * 512,
                episodes: i as u64,
                mean_return: 10.0 * i as f32,
                pi_loss: -0.01,
                v_loss: 0.5,
                entropy: 1.1,
                adv_mean_pre: 0.2,
                adv_std_pre: 1.7,
                adv_mean_post: 0.0,
                adv_std_post: 1.0,
                value_explained_variance: 0.8,
                approx_kl: 0.015,
                clip_fraction: 0.12,
            };
            w.write(&rec.to_json()).unwrap();
        }
        assert_eq!(w.records_written(), 3);
        drop(w);

        let f = std::fs::File::open(&path).unwrap();
        let lines: Vec<String> =
            std::io::BufReader::new(f).lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        let rec = LearningHealthRecord::from_json(&Json::parse(&lines[2]).unwrap())
            .unwrap();
        assert_eq!(rec.iter, 2);
        assert_eq!(rec.env_steps, 1536);
        assert!((rec.mean_return - 20.0).abs() < 1e-6);
        assert!((rec.adv_std_post - 1.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_extends_existing_curve() {
        let dir = std::env::temp_dir().join("heppo_timeseries_append");
        let path = dir.join("curve.jsonl").to_str().unwrap().to_string();
        let mut w = JsonlWriter::create(&path).unwrap();
        w.write(&Json::obj(vec![("iter", Json::from(0usize))])).unwrap();
        drop(w);
        let mut w = JsonlWriter::append(&path).unwrap();
        w.write(&Json::obj(vec![("iter", Json::from(1usize))])).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn explained_variance_behaves() {
        let t = [1.0f32, 2.0, 3.0, 4.0];
        assert!((explained_variance(&t, &t) - 1.0).abs() < 1e-6);
        // Predicting the mean explains nothing.
        let mean = [2.5f32; 4];
        assert!(explained_variance(&t, &mean).abs() < 1e-6);
        // Catastrophic critic clamps at −1.
        let bad = [100.0f32, -100.0, 100.0, -100.0];
        assert_eq!(explained_variance(&t, &bad), -1.0);
        // Constant target → 0 by convention.
        assert_eq!(explained_variance(&[5.0f32; 4], &t), 0.0);
        assert_eq!(explained_variance(&[], &[]), 0.0);
    }
}

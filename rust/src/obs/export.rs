//! Trace exporters: Chrome-trace/Perfetto JSON and line-delimited JSON.
//!
//! The Chrome-trace form is the `traceEvents` array format consumed by
//! `chrome://tracing` and <https://ui.perfetto.dev>: `B`/`E` duration
//! events nest per thread track, `i` instants draw markers, and each
//! event's request trace id rides in `args.trace` (as a hex string —
//! trace ids are full u64s and would lose bits as a JSON double).
//! The JSONL form emits one compact object per line for `grep`/`jq`.

use crate::obs::trace::{Event, EventKind};
use crate::util::json::Json;
use std::path::Path;

fn phase(kind: EventKind) -> &'static str {
    match kind {
        EventKind::Begin => "B",
        EventKind::End => "E",
        EventKind::Instant => "i",
    }
}

fn event_json(e: &Event) -> Json {
    let mut pairs = vec![
        ("name", Json::from(e.name)),
        ("ph", Json::from(phase(e.kind))),
        ("pid", Json::from(1usize)),
        ("tid", Json::Num(e.tid as f64)),
        // Chrome-trace timestamps are microseconds (fractional allowed).
        ("ts", Json::Num(e.ts_ns as f64 / 1000.0)),
    ];
    if e.kind == EventKind::Instant {
        // Thread-scoped instant marker.
        pairs.push(("s", Json::from("t")));
    }
    if e.trace != 0 {
        pairs.push((
            "args",
            Json::obj(vec![("trace", Json::Str(format!("{:#018x}", e.trace)))]),
        ));
    }
    Json::obj(pairs)
}

/// Build the Chrome-trace document for a batch of events.
pub fn chrome_trace(events: &[Event]) -> Json {
    Json::obj(vec![
        ("traceEvents", Json::Arr(events.iter().map(event_json).collect())),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

/// Write [`chrome_trace`] to `path`, creating parent directories.
pub fn write_chrome_trace(path: &Path, events: &[Event]) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, chrome_trace(events).to_string())?;
    Ok(())
}

/// One compact JSON object per event, newline-delimited.
pub fn jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        let mut pairs = vec![
            ("ph", Json::from(phase(e.kind))),
            ("name", Json::from(e.name)),
            ("tid", Json::Num(e.tid as f64)),
            ("ts_ns", Json::Num(e.ts_ns as f64)),
        ];
        if e.trace != 0 {
            pairs.push(("trace", Json::Str(format!("{:#018x}", e.trace))));
        }
        out.push_str(&Json::obj(pairs).to_string());
        out.push('\n');
    }
    out
}

/// Write [`jsonl`] to `path`, creating parent directories.
pub fn write_jsonl(path: &Path, events: &[Event]) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, jsonl(events))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        vec![
            Event {
                kind: EventKind::Begin,
                name: "client.submit",
                trace: 0xDEAD_BEEF_0000_0001,
                ts_ns: 1_500,
                tid: 1,
            },
            Event {
                kind: EventKind::Instant,
                name: "worker.compute",
                trace: 0xDEAD_BEEF_0000_0001,
                ts_ns: 2_000,
                tid: 2,
            },
            Event {
                kind: EventKind::End,
                name: "client.submit",
                trace: 0,
                ts_ns: 3_000,
                tid: 1,
            },
        ]
    }

    #[test]
    fn chrome_trace_is_valid_json_with_expected_shape() {
        let doc = chrome_trace(&sample()).to_string();
        let v = Json::parse(&doc).unwrap();
        let events = v.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        let b = &events[0];
        assert_eq!(b.req("ph").unwrap().as_str().unwrap(), "B");
        assert_eq!(b.req("name").unwrap().as_str().unwrap(), "client.submit");
        assert_eq!(b.req("ts").unwrap().as_f64().unwrap(), 1.5);
        let trace = b.req("args").unwrap().req("trace").unwrap();
        assert_eq!(trace.as_str().unwrap(), "0xdeadbeef00000001");
        // Instants carry the scope marker; untraced events omit args.
        assert_eq!(events[1].req("s").unwrap().as_str().unwrap(), "t");
        assert!(events[2].get("args").is_none());
    }

    #[test]
    fn jsonl_emits_one_parseable_line_per_event() {
        let text = jsonl(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            Json::parse(line).unwrap();
        }
        assert!(lines[1].contains("worker.compute"));
        assert!(lines[1].contains("0xdeadbeef00000001"));
    }
}

//! Live-telemetry plumbing: tail-based trace retention and the
//! Prometheus-text exposition.
//!
//! **Tail-based retention.** The per-thread trace rings
//! ([`crate::obs::trace`]) are always-on circular buffers: cheap, but a
//! ring only holds the last ~8k events, so by the time someone asks
//! "why was that request slow" the evidence is usually overwritten.
//! The [`ExemplarStore`] flips the sampling decision to *request
//! completion*, when the outcome is known: a request that finished slow
//! (above the metrics plane's adaptive window-p99 threshold), errored,
//! was shed, or failed over gets its span tree copied out of the rings
//! (non-destructively, via [`trace::trace_events`]) into a bounded
//! retained set — exactly the traces that explain a bad window, and
//! nothing else. Healthy traffic costs one threshold compare.
//!
//! **Exposition.** [`prometheus_text`] renders a [`MetricsSnapshot`]
//! in the Prometheus text format: lifetime counters, windowed
//! rate/quantile rows per 1s/10s/60s window, SLO burn-rate gauges, and
//! retained trace ids attached to the windowed p99 rows as
//! OpenMetrics-style exemplars (`# {trace_id="0x…"} latency`), using
//! the same `0x`-hex id format as the Chrome-trace exporter so an id
//! scraped from the endpoint greps straight into the exported trace.

use crate::obs::trace::{self, Event};
use crate::service::metrics::MetricsSnapshot;
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Retained exemplars kept per service (oldest evicted past this).
pub const DEFAULT_EXEMPLAR_CAPACITY: usize = 32;

/// Why a request's trace was promoted into the retained set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetainReason {
    /// Completed above the adaptive window-p99 latency threshold.
    Slow,
    /// Failed with a request/protocol error.
    Error,
    /// Refused by admission control or a tenant quota.
    Shed,
    /// Completed only after a fabric failover retry.
    FailedOver,
    /// Carried a quantized plane saturating past the numerics-plane
    /// Critical threshold ([`crate::obs::numerics`]).
    Saturated,
}

impl RetainReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RetainReason::Slow => "slow",
            RetainReason::Error => "error",
            RetainReason::Shed => "shed",
            RetainReason::FailedOver => "failed_over",
            RetainReason::Saturated => "saturated",
        }
    }

    /// Stable numeric code for the wire.
    pub fn code(self) -> u8 {
        match self {
            RetainReason::Slow => 0,
            RetainReason::Error => 1,
            RetainReason::Shed => 2,
            RetainReason::FailedOver => 3,
            RetainReason::Saturated => 4,
        }
    }

    /// Inverse of [`RetainReason::code`]; unknown codes decode as
    /// `Error` (the conservative reading of an unrecognized reason).
    pub fn from_code(code: u8) -> RetainReason {
        match code {
            0 => RetainReason::Slow,
            2 => RetainReason::Shed,
            3 => RetainReason::FailedOver,
            4 => RetainReason::Saturated,
            _ => RetainReason::Error,
        }
    }
}

/// The wire/exposition-portable half of a retained exemplar (the event
/// payload stays process-local; only ids and outcomes travel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExemplarMeta {
    /// Request trace id (nonzero — untraced requests are never retained).
    pub trace: u64,
    pub reason: RetainReason,
    /// End-to-end latency of the retained request, microseconds.
    pub total_us: f64,
    /// Seconds since service start when the request was retained.
    pub when_sec: u64,
}

/// One retained request: its meta plus the span tree captured from the
/// trace rings at promotion time.
#[derive(Debug, Clone)]
pub struct Exemplar {
    pub meta: ExemplarMeta,
    pub events: Vec<Event>,
}

/// Bounded store of retained exemplars, newest kept.
///
/// Promotion is rare by construction (tail events only), so the store
/// tolerates a mutex and per-promotion allocation; the *decision* not
/// to promote — the hot-path case — costs the caller one compare.
pub struct ExemplarStore {
    cap: usize,
    inner: Mutex<VecDeque<Exemplar>>,
    retained: AtomicU64,
    evicted: AtomicU64,
}

impl ExemplarStore {
    pub fn new(cap: usize) -> ExemplarStore {
        ExemplarStore {
            cap: cap.max(1),
            inner: Mutex::new(VecDeque::new()),
            retained: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    /// Promote one request: snapshot its events out of the trace rings
    /// (empty while tracing is disabled — the meta is still retained)
    /// and evict the oldest exemplar past capacity.
    pub fn retain(&self, meta: ExemplarMeta) {
        let events = trace::trace_events(meta.trace);
        let mut q = self.inner.lock().unwrap();
        q.push_back(Exemplar { meta, events });
        self.retained.fetch_add(1, Ordering::Relaxed);
        while q.len() > self.cap {
            q.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// `(retained, evicted)` lifetime totals.
    pub fn counts(&self) -> (u64, u64) {
        (self.retained.load(Ordering::Relaxed), self.evicted.load(Ordering::Relaxed))
    }

    /// Clones of up to `limit` retained exemplars (meta + events),
    /// newest first — the trace RPC's response body.
    pub fn snapshot(&self, limit: usize) -> Vec<Exemplar> {
        let q = self.inner.lock().unwrap();
        q.iter().rev().take(limit).cloned().collect()
    }

    /// Up to `limit` most recent exemplar metas, newest first.
    pub fn metas(&self, limit: usize) -> Vec<ExemplarMeta> {
        let q = self.inner.lock().unwrap();
        q.iter().rev().take(limit).map(|e| e.meta).collect()
    }

    /// Every retained event across all exemplars, time-sorted — the
    /// input to one combined Chrome-trace export.
    pub fn all_events(&self) -> Vec<Event> {
        let q = self.inner.lock().unwrap();
        let mut out: Vec<Event> = q.iter().flat_map(|e| e.events.iter().copied()).collect();
        out.sort_by_key(|e| e.ts_ns);
        out
    }

    /// Events of one retained trace, if present.
    pub fn events_for(&self, trace: u64) -> Option<Vec<Event>> {
        let q = self.inner.lock().unwrap();
        q.iter().rev().find(|e| e.meta.trace == trace).map(|e| e.events.clone())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Trace ids rendered for humans/exposition: `0x`-prefixed zero-padded
/// hex, identical to the Chrome-trace exporter's `args.trace` so ids
/// grep across both.
pub fn trace_hex(trace: u64) -> String {
    format!("{trace:#018x}")
}

fn label_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render a [`MetricsSnapshot`] in the Prometheus text exposition
/// format, labeled with `shard`. Lifetime counters use `_total` names;
/// windowed rows carry a `window` label (`1s`/`10s`/`60s`); the
/// windowed p99 rows attach the most recent retained exemplar's trace
/// id in the OpenMetrics exemplar syntax.
pub fn prometheus_text(snap: &MetricsSnapshot, shard: &str) -> String {
    let shard = label_escape(shard);
    let mut out = String::with_capacity(4096);
    let _ = writeln!(out, "# TYPE heppo_uptime_seconds gauge");
    let _ = writeln!(
        out,
        "heppo_uptime_seconds{{shard=\"{shard}\"}} {:.3}",
        snap.uptime.as_secs_f64()
    );
    for (name, v) in [
        ("heppo_requests_submitted_total", snap.submitted),
        ("heppo_requests_completed_total", snap.completed),
        ("heppo_requests_shed_total", snap.shed),
        ("heppo_requests_quota_shed_total", snap.quota_shed),
        ("heppo_cache_hits_total", snap.cache_hits),
        ("heppo_cache_misses_total", snap.cache_misses),
        ("heppo_slow_conns_closed_total", snap.slow_closed),
        ("heppo_auth_rejected_total", snap.auth_rejected),
        ("heppo_auth_conns_closed_total", snap.auth_conns_closed),
        ("heppo_elements_total", snap.elements),
        ("heppo_batches_total", snap.batches),
        ("heppo_trace_dropped_events_total", snap.trace_dropped_events),
        ("heppo_exemplars_retained_total", snap.exemplars_retained),
        ("heppo_exemplars_evicted_total", snap.exemplars_evicted),
    ] {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name}{{shard=\"{shard}\"}} {v}");
    }
    let _ = writeln!(out, "# TYPE heppo_queue_depth gauge");
    let _ = writeln!(out, "heppo_queue_depth{{shard=\"{shard}\"}} {}", snap.queue_depth);
    let _ = writeln!(
        out,
        "heppo_queue_depth{{shard=\"{shard}\",kind=\"peak\"}} {}",
        snap.peak_queue_depth
    );

    // Lifetime per-phase quantiles.
    let _ = writeln!(out, "# TYPE heppo_latency_us gauge");
    for (phase, q) in [
        ("queue", &snap.queue_us),
        ("batch", &snap.batch_us),
        ("compute", &snap.compute_us),
        ("encode", &snap.encode_us),
        ("total", &snap.total_us),
    ] {
        for (quantile, v) in [("0.5", q.p50), ("0.95", q.p95), ("0.99", q.p99)] {
            let _ = writeln!(
                out,
                "heppo_latency_us{{shard=\"{shard}\",phase=\"{phase}\",quantile=\"{quantile}\"}} {v:.1}"
            );
        }
    }

    // Windowed rows: recent rates + quantiles, exemplar on the p99s.
    let exemplar = snap.recent_exemplars.first();
    let _ = writeln!(out, "# TYPE heppo_window_rate_rps gauge");
    let _ = writeln!(out, "# TYPE heppo_window_latency_us gauge");
    for w in &snap.windows {
        let win = format!("{}s", w.span_secs);
        let _ = writeln!(
            out,
            "heppo_window_rate_rps{{shard=\"{shard}\",window=\"{win}\"}} {:.3}",
            w.rate_rps
        );
        let _ = writeln!(
            out,
            "heppo_window_elem_per_sec{{shard=\"{shard}\",window=\"{win}\"}} {:.1}",
            w.elem_per_sec
        );
        for (name, v) in [
            ("heppo_window_completed", w.completed),
            ("heppo_window_errors", w.errors),
            ("heppo_window_slow", w.slow),
        ] {
            let _ = writeln!(out, "{name}{{shard=\"{shard}\",window=\"{win}\"}} {v}");
        }
        for (quantile, v) in
            [("0.5", w.total_us.p50), ("0.95", w.total_us.p95), ("0.99", w.total_us.p99)]
        {
            let _ = write!(
                out,
                "heppo_window_latency_us{{shard=\"{shard}\",window=\"{win}\",quantile=\"{quantile}\"}} {v:.1}"
            );
            if quantile == "0.99" {
                if let Some(m) = exemplar {
                    let _ = write!(
                        out,
                        " # {{trace_id=\"{}\",reason=\"{}\"}} {:.1}",
                        trace_hex(m.trace),
                        m.reason.as_str(),
                        m.total_us
                    );
                }
            }
            out.push('\n');
        }
    }

    // SLO burn rates and the combined health gauge.
    let _ = writeln!(out, "# TYPE heppo_slo_burn_rate gauge");
    for (win, burn) in [
        ("1s", snap.slo.burn_1s),
        ("10s", snap.slo.burn_10s),
        ("60s", snap.slo.burn_60s),
    ] {
        let _ = writeln!(
            out,
            "heppo_slo_burn_rate{{shard=\"{shard}\",window=\"{win}\"}} {burn:.3}"
        );
    }
    let _ = writeln!(out, "# TYPE heppo_slo_health gauge");
    let _ = writeln!(
        out,
        "heppo_slo_health{{shard=\"{shard}\",state=\"{}\"}} {}",
        snap.slo.health.as_str(),
        snap.slo.health.code()
    );

    // Numerics plane: lifetime quantization-health counters, windowed
    // saturation/utilization/drift gauges, the 1s verdict, and the
    // lifetime wire-transport reduction (the paper's 4x claim as a
    // scrapeable gauge). The saturation exemplar (newest retained
    // `Saturated` trace) is attached to the window saturation rows so
    // an offending plane greps from the exposition into `GET /traces`.
    let n = &snap.numerics;
    for (name, v) in [
        ("heppo_quant_planes_total", n.planes),
        ("heppo_quant_elements_total", n.elements),
        ("heppo_quant_clipped_total", n.clipped),
        ("heppo_quant_saturated_exemplars_total", n.saturated_exemplars),
        ("heppo_wire_payload_bytes_total", snap.wire_payload_bytes),
        ("heppo_wire_f32_bytes_total", snap.wire_f32_bytes),
    ] {
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name}{{shard=\"{shard}\"}} {v}");
    }
    let _ = writeln!(out, "# TYPE heppo_wire_reduction_vs_f32 gauge");
    let _ = writeln!(
        out,
        "heppo_wire_reduction_vs_f32{{shard=\"{shard}\"}} {:.4}",
        snap.wire_reduction_vs_f32()
    );
    let _ = writeln!(out, "# TYPE heppo_quant_mse gauge");
    let _ = writeln!(out, "heppo_quant_mse{{shard=\"{shard}\"}} {:.6e}", n.mse());
    let _ = writeln!(out, "# TYPE heppo_quant_max_abs_err gauge");
    let _ =
        writeln!(out, "heppo_quant_max_abs_err{{shard=\"{shard}\"}} {:.6e}", n.max_abs_err);
    let saturated_exemplar = snap
        .recent_exemplars
        .iter()
        .find(|m| m.reason == RetainReason::Saturated);
    let _ = writeln!(out, "# TYPE heppo_quant_window_saturation_rate gauge");
    let _ = writeln!(out, "# TYPE heppo_quant_window_code_utilization gauge");
    let _ = writeln!(out, "# TYPE heppo_quant_window_sigma_drift gauge");
    for w in &n.windows {
        let win = format!("{}s", w.span_secs);
        let _ = write!(
            out,
            "heppo_quant_window_saturation_rate{{shard=\"{shard}\",window=\"{win}\"}} {:.6}",
            w.saturation_rate
        );
        if let Some(m) = saturated_exemplar {
            let _ = write!(
                out,
                " # {{trace_id=\"{}\",reason=\"{}\"}} {:.1}",
                trace_hex(m.trace),
                m.reason.as_str(),
                m.total_us
            );
        }
        out.push('\n');
        let _ = writeln!(
            out,
            "heppo_quant_window_code_utilization{{shard=\"{shard}\",window=\"{win}\"}} {:.4}",
            w.code_utilization
        );
        let _ = writeln!(
            out,
            "heppo_quant_window_sigma_drift{{shard=\"{shard}\",window=\"{win}\"}} {:.4}",
            w.sigma_drift
        );
    }
    let _ = writeln!(out, "# TYPE heppo_numerics_health gauge");
    let _ = writeln!(
        out,
        "heppo_numerics_health{{shard=\"{shard}\",state=\"{}\"}} {}",
        n.health.as_str(),
        n.health.code()
    );
    // Per-tenant numerics: saturation + verdict for tenants that sent
    // quantized planes (bounded by the tenant-map cap upstream).
    let _ = writeln!(out, "# TYPE heppo_tenant_quant_saturation_1s gauge");
    let _ = writeln!(out, "# TYPE heppo_tenant_numerics_health gauge");
    let _ = writeln!(out, "# TYPE heppo_tenant_wire_reduction_vs_f32 gauge");
    for t in &snap.tenants {
        if t.quant_planes == 0 && t.wire_payload_bytes == 0 {
            continue;
        }
        let tenant = label_escape(&t.tenant);
        let _ = writeln!(
            out,
            "heppo_tenant_quant_saturation_1s{{shard=\"{shard}\",tenant=\"{tenant}\"}} {:.6}",
            t.quant_saturation_1s
        );
        let _ = writeln!(
            out,
            "heppo_tenant_numerics_health{{shard=\"{shard}\",tenant=\"{tenant}\",state=\"{}\"}} {}",
            t.numerics_health.as_str(),
            t.numerics_health.code()
        );
        let _ = writeln!(
            out,
            "heppo_tenant_wire_reduction_vs_f32{{shard=\"{shard}\",tenant=\"{tenant}\"}} {:.4}",
            t.wire_reduction_vs_f32()
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(trace: u64, reason: RetainReason) -> ExemplarMeta {
        ExemplarMeta { trace, reason, total_us: 1234.5, when_sec: 7 }
    }

    // Tracing stays disabled in these tests (event capture is covered
    // by the telemetry integration test in its own process), so store
    // mechanics don't race the trace module's ring-draining tests.

    #[test]
    fn store_bounds_retention_and_counts_evictions() {
        let store = ExemplarStore::new(4);
        for i in 1..=10u64 {
            store.retain(meta(i, RetainReason::Slow));
        }
        assert_eq!(store.len(), 4);
        assert_eq!(store.counts(), (10, 6));
        let metas = store.metas(8);
        assert_eq!(metas.len(), 4);
        // Newest first; the oldest six were evicted.
        assert_eq!(metas[0].trace, 10);
        assert_eq!(metas[3].trace, 7);
        assert!(store.events_for(10).is_some());
        assert!(store.events_for(1).is_none(), "evicted exemplars are gone");
    }

    #[test]
    fn reason_codes_round_trip() {
        for r in [
            RetainReason::Slow,
            RetainReason::Error,
            RetainReason::Shed,
            RetainReason::FailedOver,
            RetainReason::Saturated,
        ] {
            assert_eq!(RetainReason::from_code(r.code()), r);
        }
        assert_eq!(RetainReason::from_code(99), RetainReason::Error);
    }

    #[test]
    fn trace_hex_matches_chrome_export_format() {
        assert_eq!(trace_hex(0xDEAD_BEEF_0000_0001), "0xdeadbeef00000001");
        assert_eq!(trace_hex(1), "0x0000000000000001");
    }

    #[test]
    fn prometheus_text_renders_windows_slo_and_exemplars() {
        use crate::service::metrics::{ServiceMetrics, SnapshotInputs};
        use crate::service::request::RequestTiming;
        use std::time::Duration;
        let m = ServiceMetrics::new();
        m.record_submitted();
        let slow = Duration::from_millis(200);
        let t = RequestTiming {
            queue: Duration::from_micros(10),
            batch: Duration::ZERO,
            compute: slow,
            group_compute: slow,
            encode: Duration::ZERO,
            total: slow,
        };
        // A traced, objective-busting completion: retained as an exemplar.
        m.record_completion(64, &t, 0xABCD_EF01_2345_6789);
        let snap = m.snapshot(SnapshotInputs::default());
        let text = prometheus_text(&snap, "shard-0");
        for needle in [
            "heppo_requests_completed_total{shard=\"shard-0\"} 1",
            "heppo_window_rate_rps{shard=\"shard-0\",window=\"1s\"}",
            "heppo_window_latency_us{shard=\"shard-0\",window=\"10s\",quantile=\"0.99\"}",
            "heppo_slo_burn_rate{shard=\"shard-0\",window=\"60s\"}",
            "heppo_slo_health{shard=\"shard-0\"",
            "heppo_exemplars_retained_total{shard=\"shard-0\"} 1",
            "trace_id=\"0xabcdef0123456789\"",
            "reason=\"slow\"",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn label_values_are_escaped() {
        let escaped = label_escape("a\"b\\c");
        assert_eq!(escaped, "a\\\"b\\\\c");
    }

    #[test]
    fn prometheus_text_renders_numerics_rows_with_saturation_exemplar() {
        use crate::obs::numerics::PlaneNumerics;
        use crate::quant::UniformQuantizer;
        use crate::service::metrics::{ServiceMetrics, SnapshotInputs};
        let m = ServiceMetrics::new();
        let q = UniformQuantizer::new(8);
        let mut pn = PlaneNumerics::default();
        pn.set_block(0.0, 17.0);
        for i in 0..256u32 {
            let z = if i % 8 == 0 { 50.0 } else { (i as f32 * 0.37).sin() };
            let code = q.quantize(z);
            pn.note_code(code, 8);
            pn.note_err((q.dequantize(code) - z).abs() * 17.0);
        }
        m.record_wire_frame("spiky", 1000, 4000);
        m.record_plane_numerics("spiky", &pn, 0x0BAD_5A70_0000_0001);
        let snap = m.snapshot(SnapshotInputs::default());
        let text = prometheus_text(&snap, "s0");
        for needle in [
            "heppo_quant_planes_total{shard=\"s0\"} 1",
            "heppo_quant_clipped_total{shard=\"s0\"} 32",
            "heppo_quant_window_saturation_rate{shard=\"s0\",window=\"1s\"}",
            "heppo_quant_window_code_utilization{shard=\"s0\"",
            "heppo_quant_window_sigma_drift{shard=\"s0\"",
            "heppo_numerics_health{shard=\"s0\",state=\"critical\"} 2",
            "heppo_wire_reduction_vs_f32{shard=\"s0\"} 4.0000",
            "heppo_tenant_quant_saturation_1s{shard=\"s0\",tenant=\"spiky\"}",
            "heppo_tenant_numerics_health{shard=\"s0\",tenant=\"spiky\",state=\"critical\"}",
            "trace_id=\"0x0bad5a7000000001\"",
            "reason=\"saturated\"",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}

//! Span recording: per-thread bounded rings behind one global switch.
//!
//! The recording fast path is deliberately two-tier. When tracing is
//! disabled (the default), every instrumentation site reduces to one
//! `Relaxed` load of [`ENABLED`] — no thread-local access, no clock
//! read — so instrumented hot loops keep their zero-overhead and
//! zero-allocation guarantees. When enabled, a thread's first record
//! registers a preallocated fixed-capacity ring in a global registry;
//! every later record is a clock read plus an uncontended mutex push
//! into that ring, overwriting the oldest event once full (tracing a
//! long run bounds memory instead of growing it).

use std::cell::OnceCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Events each recording thread retains before overwriting its oldest.
pub const RING_CAPACITY: usize = 8192;

/// What one recorded event marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A span opened (matched by an [`EventKind::End`] with the same
    /// name on the same thread).
    Begin,
    /// A span closed.
    End,
    /// A point event with no duration.
    Instant,
}

/// One recorded trace event. `Copy` with a `&'static str` name so the
/// record path never allocates.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub kind: EventKind,
    pub name: &'static str,
    /// Request-scoped trace id; `0` = not tied to a request.
    pub trace: u64,
    /// Nanoseconds since the process trace epoch (first clock use).
    pub ts_ns: u64,
    /// Recorder-assigned thread id (dense, starts at 1).
    pub tid: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing on? One `Relaxed` load — this is the entire cost of an
/// instrumentation site while tracing is disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Switch recording on or off (off drops nothing already recorded).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process trace epoch.
fn now_ns() -> u64 {
    let epoch = *EPOCH.get_or_init(Instant::now);
    Instant::now().saturating_duration_since(epoch).as_nanos() as u64
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

static TRACE_SEED: OnceLock<u64> = OnceLock::new();
static NEXT_TRACE: AtomicU64 = AtomicU64::new(0);

/// Mint a fresh nonzero request-scoped trace id: a process-unique seed
/// (wall clock × pid, mixed) combined with a monotonic counter, so ids
/// from concurrent clients almost never collide and `0` stays reserved
/// for "untraced".
pub fn mint_trace_id() -> u64 {
    let seed = *TRACE_SEED.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        splitmix64(nanos ^ ((std::process::id() as u64) << 32))
    });
    let n = NEXT_TRACE.fetch_add(1, Ordering::Relaxed);
    splitmix64(seed ^ n).max(1)
}

/// One thread's bounded event ring.
struct Ring {
    events: Vec<Event>,
    /// Next overwrite index once the ring is full.
    head: usize,
    /// Events overwritten since the last drain.
    dropped: u64,
}

struct ThreadRecorder {
    tid: u64,
    ring: Mutex<Ring>,
}

/// Every thread that ever recorded. Recorders outlive their threads
/// (the `Arc` keeps a dead thread's tail drainable) and the list is
/// bounded by the number of threads the process ever spawned.
static REGISTRY: Mutex<Vec<Arc<ThreadRecorder>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: OnceCell<Arc<ThreadRecorder>> = const { OnceCell::new() };
}

fn record(kind: EventKind, name: &'static str, trace: u64) {
    let ts_ns = now_ns();
    LOCAL.with(|cell| {
        let rec = cell.get_or_init(|| {
            let rec = Arc::new(ThreadRecorder {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring {
                    events: Vec::with_capacity(RING_CAPACITY),
                    head: 0,
                    dropped: 0,
                }),
            });
            REGISTRY.lock().unwrap().push(Arc::clone(&rec));
            rec
        });
        let mut ring = rec.ring.lock().unwrap();
        let e = Event { kind, name, trace, ts_ns, tid: rec.tid };
        if ring.events.len() < RING_CAPACITY {
            ring.events.push(e);
        } else {
            let head = ring.head;
            ring.events[head] = e;
            ring.head = (head + 1) % RING_CAPACITY;
            ring.dropped += 1;
        }
    });
}

/// Record a span opening (no-op while disabled).
#[inline]
pub fn span_begin(name: &'static str, trace: u64) {
    if enabled() {
        record(EventKind::Begin, name, trace);
    }
}

/// Record a span closing (no-op while disabled).
#[inline]
pub fn span_end(name: &'static str, trace: u64) {
    if enabled() {
        record(EventKind::End, name, trace);
    }
}

/// Record a point event (no-op while disabled).
#[inline]
pub fn instant(name: &'static str, trace: u64) {
    if enabled() {
        record(EventKind::Instant, name, trace);
    }
}

/// RAII span: begins on construction, ends on drop. Remembers whether
/// it actually opened, so flipping tracing on mid-span cannot emit an
/// unmatched `End`.
pub struct Span {
    name: &'static str,
    trace: u64,
    armed: bool,
}

/// Open a scope-bound span (no-op guard while disabled).
#[inline]
pub fn span(name: &'static str, trace: u64) -> Span {
    let armed = enabled();
    if armed {
        record(EventKind::Begin, name, trace);
    }
    Span { name, trace, armed }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            record(EventKind::End, self.name, self.trace);
        }
    }
}

/// Drain every thread's ring: returns all retained events sorted by
/// timestamp and leaves the rings empty (capacity kept, so draining
/// does not disturb the steady-state no-allocation property).
pub fn take_events() -> Vec<Event> {
    let recorders: Vec<Arc<ThreadRecorder>> = REGISTRY.lock().unwrap().clone();
    let mut out = Vec::new();
    for rec in recorders {
        let mut ring = rec.ring.lock().unwrap();
        let head = ring.head;
        if ring.events.len() == RING_CAPACITY && head > 0 {
            out.extend_from_slice(&ring.events[head..]);
            out.extend_from_slice(&ring.events[..head]);
        } else {
            out.extend_from_slice(&ring.events);
        }
        ring.events.clear();
        ring.head = 0;
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Collect every retained event carrying `trace`, time-sorted, without
/// draining any ring — the tail-sampling promotion path ([`crate::obs`]
/// exemplar store) snapshots one request's span tree while the rings
/// keep recording. Costs one scan of every ring, so callers should
/// reserve it for rare events (slow/errored requests), not the hot path.
pub fn trace_events(trace: u64) -> Vec<Event> {
    let recorders: Vec<Arc<ThreadRecorder>> = REGISTRY.lock().unwrap().clone();
    let mut out = Vec::new();
    for rec in recorders {
        let ring = rec.ring.lock().unwrap();
        out.extend(ring.events.iter().filter(|e| e.trace == trace).copied());
    }
    out.sort_by_key(|e| e.ts_ns);
    out
}

/// Total events overwritten (ring full) since the process started.
pub fn dropped_events() -> u64 {
    REGISTRY
        .lock()
        .unwrap()
        .iter()
        .map(|r| r.ring.lock().unwrap().dropped)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; every test that records or
    // drains must hold this lock so parallel test threads don't steal
    // each other's events.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap();
        set_enabled(false);
        let _ = take_events();
        span_begin("t.off", 7);
        instant("t.off", 7);
        span_end("t.off", 7);
        {
            let _s = span("t.off.guard", 7);
        }
        assert!(take_events().is_empty());
    }

    #[test]
    fn spans_and_instants_round_trip_with_their_trace_id() {
        let _g = TEST_LOCK.lock().unwrap();
        set_enabled(true);
        let _ = take_events();
        let trace = mint_trace_id();
        span_begin("t.work", trace);
        instant("t.mark", trace);
        span_end("t.work", trace);
        set_enabled(false);
        let events = take_events();
        let mine: Vec<&Event> =
            events.iter().filter(|e| e.trace == trace).collect();
        assert_eq!(mine.len(), 3, "{events:?}");
        assert_eq!(mine[0].kind, EventKind::Begin);
        assert_eq!(mine[1].kind, EventKind::Instant);
        assert_eq!(mine[2].kind, EventKind::End);
        assert!(mine[0].ts_ns <= mine[1].ts_ns && mine[1].ts_ns <= mine[2].ts_ns);
        assert_eq!(mine[0].name, "t.work");
    }

    #[test]
    fn trace_events_scans_without_draining() {
        let _g = TEST_LOCK.lock().unwrap();
        set_enabled(true);
        let _ = take_events();
        let mine = mint_trace_id();
        let other = mint_trace_id();
        span_begin("t.scan", mine);
        instant("t.noise", other);
        span_end("t.scan", mine);
        set_enabled(false);
        let scanned = trace_events(mine);
        assert_eq!(scanned.len(), 2, "{scanned:?}");
        assert!(scanned.iter().all(|e| e.trace == mine));
        assert!(scanned[0].ts_ns <= scanned[1].ts_ns);
        // Non-destructive: a later drain still sees all three events.
        let drained: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.trace == mine || e.trace == other)
            .collect();
        assert_eq!(drained.len(), 3, "scan must not drain the rings");
    }

    #[test]
    fn guard_armed_at_open_does_not_emit_unmatched_end() {
        let _g = TEST_LOCK.lock().unwrap();
        set_enabled(false);
        let _ = take_events();
        let s = span("t.mid", 3);
        set_enabled(true); // flipped on mid-span
        drop(s);
        set_enabled(false);
        assert!(
            take_events().iter().all(|e| e.name != "t.mid"),
            "a span opened while disabled must not close into the ring"
        );
    }

    #[test]
    fn ring_overwrites_oldest_and_stays_bounded() {
        let _g = TEST_LOCK.lock().unwrap();
        set_enabled(true);
        let _ = take_events();
        let before = dropped_events();
        for i in 0..(RING_CAPACITY + 64) {
            instant(if i < 64 { "t.old" } else { "t.new" }, 0);
        }
        set_enabled(false);
        let events: Vec<Event> = take_events()
            .into_iter()
            .filter(|e| e.name == "t.old" || e.name == "t.new")
            .collect();
        assert!(events.len() <= RING_CAPACITY);
        assert!(dropped_events() >= before + 64);
        // The oldest 64 were the ones overwritten.
        assert!(events.iter().all(|e| e.name == "t.new"), "oldest must go first");
        // Chronological order survives the wrap.
        assert!(events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
    }

    #[test]
    fn minted_ids_are_nonzero_and_distinct() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }
}

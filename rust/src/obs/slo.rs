//! SLO objectives and multi-window burn-rate health.
//!
//! An SLO here is two objectives over the serving stack's request
//! stream: a **latency** objective (at least `latency_target` of
//! completions finish under `latency_objective_us`) and an
//! **availability** objective (at least `availability_target` of
//! requests are not shed, quota-refused, or errored). Each is scored
//! per window as a *burn rate*: the fraction of the error budget
//! (`1 - target`) consumed, normalized so `burn = 1.0` means "exactly
//! on budget" and `burn = 14.4` means "burning two weeks of monthly
//! budget per day" — the classic fast-burn alert threshold.
//!
//! Health combines burn rates across the 1s/10s/60s windows the
//! metrics plane keeps (see [`crate::stats::windowed`]): `Critical`
//! requires the fast *pair* of windows to agree (a one-second blip
//! alone cannot page), `Warn` fires on a sustained slow burn, and an
//! idle window burns nothing — a freshly restarted shard reports `Ok`
//! rather than inheriting its predecessor's bad minute.

use std::fmt;

/// Serving objectives evaluated by the telemetry plane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Completions slower than this are "bad" for the latency SLO.
    pub latency_objective_us: f64,
    /// Fraction of completions that must meet the latency objective.
    pub latency_target: f64,
    /// Fraction of requests that must not be shed/refused/errored.
    pub availability_target: f64,
}

impl Default for SloConfig {
    fn default() -> SloConfig {
        SloConfig {
            latency_objective_us: 50_000.0,
            latency_target: 0.99,
            availability_target: 0.999,
        }
    }
}

/// Per-shard health state derived from burn rates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloHealth {
    Ok,
    Warn,
    Critical,
}

impl Default for SloHealth {
    fn default() -> SloHealth {
        SloHealth::Ok
    }
}

impl SloHealth {
    pub fn as_str(self) -> &'static str {
        match self {
            SloHealth::Ok => "ok",
            SloHealth::Warn => "warn",
            SloHealth::Critical => "critical",
        }
    }

    /// Stable numeric code (wire + exposition gauge value).
    pub fn code(self) -> u8 {
        match self {
            SloHealth::Ok => 0,
            SloHealth::Warn => 1,
            SloHealth::Critical => 2,
        }
    }

    /// Inverse of [`SloHealth::code`]; unknown codes clamp to
    /// `Critical` (an undecodable health is not good news).
    pub fn from_code(code: u8) -> SloHealth {
        match code {
            0 => SloHealth::Ok,
            1 => SloHealth::Warn,
            _ => SloHealth::Critical,
        }
    }
}

impl fmt::Display for SloHealth {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Request counts for one evaluation window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WindowCounts {
    /// Requests that completed (fast or slow).
    pub completed: u64,
    /// Requests shed, quota-refused, or errored.
    pub errors: u64,
    /// Completions that exceeded the latency objective.
    pub slow: u64,
}

/// Burn rates per window plus the combined health verdict.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SloReport {
    pub health: SloHealth,
    pub burn_1s: f64,
    pub burn_10s: f64,
    pub burn_60s: f64,
}

/// Burn rate at which the fast window pair escalates to `Critical`.
pub const FAST_BURN: f64 = 14.4;
/// Burn rate at which the sustained (60s) window raises `Warn`.
pub const SLOW_BURN: f64 = 6.0;

/// Burn rate of one window: worst of the latency and availability
/// objectives, `0.0` when the window saw no traffic.
pub fn burn_rate(cfg: &SloConfig, w: &WindowCounts) -> f64 {
    let total = w.completed + w.errors;
    if total == 0 {
        return 0.0;
    }
    let latency_budget = (1.0 - cfg.latency_target).max(1e-9);
    let availability_budget = (1.0 - cfg.availability_target).max(1e-9);
    let slow_frac = w.slow as f64 / total as f64;
    let error_frac = w.errors as f64 / total as f64;
    (slow_frac / latency_budget).max(error_frac / availability_budget)
}

/// Evaluate the three standard windows into a combined report.
///
/// `Critical` needs both fast windows over [`FAST_BURN`] (the 10s
/// window confirms the 1s spike is not a single-request artifact);
/// `Warn` is either the fast burn seen only in one window or a
/// sustained 60s burn over [`SLOW_BURN`].
pub fn evaluate(cfg: &SloConfig, w1: &WindowCounts, w10: &WindowCounts, w60: &WindowCounts) -> SloReport {
    let burn_1s = burn_rate(cfg, w1);
    let burn_10s = burn_rate(cfg, w10);
    let burn_60s = burn_rate(cfg, w60);
    let health = if burn_1s >= FAST_BURN && burn_10s >= FAST_BURN {
        SloHealth::Critical
    } else if burn_1s >= FAST_BURN || burn_10s >= FAST_BURN || burn_60s >= SLOW_BURN {
        SloHealth::Warn
    } else {
        SloHealth::Ok
    };
    SloReport { health, burn_1s, burn_10s, burn_60s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_windows_are_ok_with_zero_burn() {
        let cfg = SloConfig::default();
        let idle = WindowCounts::default();
        let r = evaluate(&cfg, &idle, &idle, &idle);
        assert_eq!(r.health, SloHealth::Ok);
        assert_eq!((r.burn_1s, r.burn_10s, r.burn_60s), (0.0, 0.0, 0.0));
    }

    #[test]
    fn total_failure_in_fast_windows_is_critical() {
        let cfg = SloConfig::default();
        let bad = WindowCounts { completed: 5, errors: 5, slow: 0 };
        let r = evaluate(&cfg, &bad, &bad, &WindowCounts::default());
        // Half the requests failing burns the 0.1% availability budget
        // at 500x — far past the fast-burn bar in both windows.
        assert!(r.burn_1s > FAST_BURN && r.burn_10s > FAST_BURN, "{r:?}");
        assert_eq!(r.health, SloHealth::Critical);
    }

    #[test]
    fn one_second_blip_alone_is_warn_not_critical() {
        let cfg = SloConfig::default();
        let blip = WindowCounts { completed: 1, errors: 1, slow: 0 };
        let calm = WindowCounts { completed: 10_000, errors: 0, slow: 0 };
        let r = evaluate(&cfg, &blip, &calm, &calm);
        assert_eq!(r.health, SloHealth::Warn, "{r:?}");
    }

    #[test]
    fn sustained_slow_requests_warn_via_the_60s_window() {
        let cfg = SloConfig::default();
        let calm = WindowCounts { completed: 100, errors: 0, slow: 0 };
        let sustained = WindowCounts { completed: 100, errors: 0, slow: 8 };
        // 8% slow against a 1% latency budget = burn 8.0 >= SLOW_BURN.
        let r = evaluate(&cfg, &calm, &calm, &sustained);
        assert!(r.burn_60s >= SLOW_BURN, "{r:?}");
        assert_eq!(r.health, SloHealth::Warn);
    }

    #[test]
    fn burn_rate_takes_the_worse_objective() {
        let cfg = SloConfig {
            latency_objective_us: 1_000.0,
            latency_target: 0.9,
            availability_target: 0.99,
        };
        // 20% slow / 10% budget = 2.0; 1% errors / 1% budget = 1.0.
        let w = WindowCounts { completed: 99, errors: 1, slow: 20 };
        let b = burn_rate(&cfg, &w);
        assert!((b - 2.0).abs() < 0.02, "{b}");
    }

    #[test]
    fn health_codes_round_trip_and_unknown_is_critical() {
        for h in [SloHealth::Ok, SloHealth::Warn, SloHealth::Critical] {
            assert_eq!(SloHealth::from_code(h.code()), h);
        }
        assert_eq!(SloHealth::from_code(7), SloHealth::Critical);
        assert_eq!(SloHealth::default(), SloHealth::Ok);
        assert_eq!(format!("{}", SloHealth::Warn), "warn");
    }
}

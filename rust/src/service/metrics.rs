//! Service observability: counters, queue gauges, and per-phase latency
//! histograms with p50/p95/p99.
//!
//! Latencies are recorded into [`Histogram`]s over `log10(1 + µs)` —
//! ~2.3% relative resolution from sub-microsecond to 100 s with a fixed
//! 800-bin footprint and no allocation on the record path (the same
//! fixed-bin substrate the quantizer diagnostics use). Quantiles come
//! from [`Histogram::quantile`] and are exponentiated back to µs.
//!
//! Everything is shared-state-cheap: counters are atomics; the
//! per-phase histograms sit behind one short-critical-section mutex.
//!
//! Alongside the lifetime view, the recorder keeps *windowed* state —
//! per-second rings of the total-phase histogram and rate counters
//! ([`crate::stats::windowed`]) — so a snapshot reports the last
//! 1s/10s/60s rates, tail quantiles, and SLO burn-rate health next to
//! the since-start numbers. Window rotation rides the recording path
//! (no ticker thread) and reuses preallocated buckets, preserving the
//! hot path's zero-steady-state-allocation guarantee. Completions that
//! land above an adaptive window-p99 threshold are promoted into a
//! bounded [`ExemplarStore`] with their span trees (tail-based trace
//! retention; see [`crate::obs::telemetry`]).

use crate::obs::numerics::{NumericsAccum, NumericsHealth, NumericsSnapshot, PlaneNumerics};
use crate::obs::slo::{self, SloConfig, SloReport, WindowCounts};
use crate::obs::telemetry::{
    ExemplarMeta, ExemplarStore, RetainReason, DEFAULT_EXEMPLAR_CAPACITY,
};
use crate::service::request::RequestTiming;
use crate::stats::{Histogram, WindowedCounter, WindowedHistogram};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Most tenants tracked by the per-tenant breakdown. Tenant ids arrive
/// on the wire (client-chosen), so the map must not grow without bound
/// on a long-lived server; past the cap the longest-untouched tenant's
/// counters are evicted — the same bounded-softening policy as the
/// quota map ([`crate::net::quota`]).
const MAX_TENANT_STATS: usize = 4096;

/// One tenant's accumulated counters.
#[derive(Debug, Clone, Default)]
struct TenantCounters {
    /// Frames/requests answered with a result (computed or cache).
    requests: u64,
    /// GAE elements those requests carried.
    elements: u64,
    /// Requests refused by admission control.
    shed: u64,
    /// Frames refused by the tenant's quota bucket.
    quota_shed: u64,
    /// Frames claiming this tenant that failed authentication. The
    /// claimant may be an impostor — the row attributes the *claimed*
    /// identity, which is what an operator investigating abuse wants.
    auth_rejected: u64,
    /// Request payload-section bytes this tenant put on the wire.
    wire_payload_bytes: u64,
    /// What the f32 escape hatch would have used for the same frames —
    /// the lifetime `reduction_vs_f32` numerator.
    wire_f32_bytes: u64,
    /// Quantization-health accumulator, boxed lazily on the tenant's
    /// first quantized plane (the ring preallocates then; the
    /// steady-state record path stays allocation-free).
    numerics: Option<Box<NumericsAccum>>,
    /// Last-touch tick, for LRU eviction at the cap.
    last_touch: u64,
}

#[derive(Debug, Default)]
struct TenantMap {
    map: HashMap<String, TenantCounters>,
    tick: u64,
}

impl TenantMap {
    /// Get-or-insert a tenant's counters, evicting the longest-untouched
    /// tenant when a *new* tenant arrives at the cap (O(n) then, O(1)
    /// otherwise — the quota map's trade-off).
    fn entry(&mut self, tenant: &str) -> &mut TenantCounters {
        self.tick += 1;
        let tick = self.tick;
        if !self.map.contains_key(tenant) {
            if self.map.len() >= MAX_TENANT_STATS {
                if let Some(stalest) = self
                    .map
                    .iter()
                    .min_by_key(|(_, c)| c.last_touch)
                    .map(|(k, _)| k.clone())
                {
                    self.map.remove(&stalest);
                }
            }
            // The only allocating arm: a tenant's first touch. Known
            // tenants take the `get_mut` path below, keeping the
            // steady-state record paths allocation-free.
            self.map.insert(tenant.to_string(), TenantCounters::default());
        }
        let c = self.map.get_mut(tenant).unwrap();
        c.last_touch = tick;
        c
    }
}

/// log10(1+µs) histogram range: 0 .. 10^8 µs (100 s).
const LOG_US_HI: f64 = 8.0;
const LOG_US_BINS: usize = 800;

/// Seconds of per-second window buckets the rings retain — comfortably
/// covers the longest (60s) snapshot view.
const WINDOW_RING_SECS: usize = 64;

/// Margin (log10 domain, ~+20% in µs) added to the 10s-window p99 to
/// form the tail-retention threshold, so requests *at* the p99 are not
/// all promoted — only the ones meaningfully past it.
const RETAIN_MARGIN_LOG: f64 = 0.08;

/// Below this many samples in the 10s window the adaptive threshold is
/// meaningless; fall back to the SLO latency objective.
const MIN_THRESHOLD_SAMPLES: u64 = 32;

fn log_us(d: Duration) -> f64 {
    (1.0 + d.as_secs_f64() * 1e6).log10()
}

fn unlog_us(x: f64) -> f64 {
    10f64.powf(x) - 1.0
}

struct PhaseHists {
    queue_us: Histogram,
    batch_us: Histogram,
    compute_us: Histogram,
    encode_us: Histogram,
    total_us: Histogram,
    /// Per-second ring of the total phase — the windowed-quantile source.
    win_total: WindowedHistogram,
    win_completed: WindowedCounter,
    win_elements: WindowedCounter,
    /// Shed + quota-shed events, for windowed availability burn.
    win_errors: WindowedCounter,
    /// Completions above the SLO latency objective.
    win_slow: WindowedCounter,
    /// Preallocated scratch for the per-second threshold recompute —
    /// keeps the recording path allocation-free.
    scratch: Histogram,
    /// Tail-retention threshold, log10(1+µs) domain.
    retain_threshold_log: f64,
    /// Second the threshold was last recomputed for (`u64::MAX` =
    /// never, so the first record computes it).
    retain_stamp: u64,
    /// Whether the current threshold came from the window p99 (true)
    /// or the objective fallback (false). While on the fallback, the
    /// recompute also fires as soon as the window has enough samples —
    /// not just at the next second boundary.
    threshold_adaptive: bool,
}

impl PhaseHists {
    fn new() -> Self {
        PhaseHists {
            queue_us: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            batch_us: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            compute_us: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            encode_us: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            total_us: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            win_total: WindowedHistogram::new(0.0, LOG_US_HI, LOG_US_BINS, WINDOW_RING_SECS),
            win_completed: WindowedCounter::new(WINDOW_RING_SECS),
            win_elements: WindowedCounter::new(WINDOW_RING_SECS),
            win_errors: WindowedCounter::new(WINDOW_RING_SECS),
            win_slow: WindowedCounter::new(WINDOW_RING_SECS),
            scratch: Histogram::new(0.0, LOG_US_HI, LOG_US_BINS),
            retain_threshold_log: f64::INFINITY,
            retain_stamp: u64::MAX,
            threshold_adaptive: false,
        }
    }
}

/// Live metrics of one [`GaeService`](crate::service::GaeService).
pub struct ServiceMetrics {
    started_at: Instant,
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    elements: AtomicU64,
    batches: AtomicU64,
    batch_lanes: AtomicU64,
    hw_cycles: AtomicU64,
    /// Network-layer counters (the TCP front-end records into the same
    /// snapshot so one view covers the whole stack).
    quota_shed: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// Connections the reactor front-end closed for being slow
    /// consumers (write backlog full past the shed deadline).
    slow_closed: AtomicU64,
    /// Frames rejected by tenant authentication (missing/invalid tag).
    auth_rejected: AtomicU64,
    /// Connections closed after hitting the auth strike limit.
    auth_conns_closed: AtomicU64,
    /// Coalesced groups sent to the scalar loop by size-threshold routing.
    routed_small: AtomicU64,
    /// Tiles computed in place on a resident plane slab (zero gather).
    slab_tiles: AtomicU64,
    /// Tiles that fell back to the packed-tile gather.
    packed_tiles: AtomicU64,
    /// Plane bytes copied into packed tiles (slab tiles gather zero).
    gathered_bytes: AtomicU64,
    hists: Mutex<PhaseHists>,
    /// Per-tenant breakdown for traffic whose tenant is known (the
    /// network front-end and the fabric router attribute their
    /// submissions; anonymous in-process clients are not broken down).
    tenants: Mutex<TenantMap>,
    /// Serving objectives the snapshot evaluates into burn-rate health.
    slo: SloConfig,
    /// The SLO latency objective in the log10(1+µs) domain, precomputed
    /// so the completion path compares without a `log10` call.
    slow_log: f64,
    /// Tail-retained exemplars (slow/errored/shed request traces).
    exemplars: ExemplarStore,
    /// Shard-wide quantization-health accumulator (per-tenant ones live
    /// inside [`TenantCounters`]).
    numerics: Mutex<NumericsAccum>,
    /// Request payload-section bytes received on the wire.
    wire_payload_bytes: AtomicU64,
    /// f32-escape-hatch bytes the same frames would have used.
    wire_f32_bytes: AtomicU64,
    /// Exemplars retained for plane saturation since start.
    saturated_exemplars: AtomicU64,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::with_slo(SloConfig::default())
    }

    /// A recorder evaluating the given objectives.
    pub fn with_slo(slo: SloConfig) -> Self {
        ServiceMetrics {
            started_at: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            elements: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_lanes: AtomicU64::new(0),
            hw_cycles: AtomicU64::new(0),
            quota_shed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            slow_closed: AtomicU64::new(0),
            auth_rejected: AtomicU64::new(0),
            auth_conns_closed: AtomicU64::new(0),
            routed_small: AtomicU64::new(0),
            slab_tiles: AtomicU64::new(0),
            packed_tiles: AtomicU64::new(0),
            gathered_bytes: AtomicU64::new(0),
            hists: Mutex::new(PhaseHists::new()),
            tenants: Mutex::new(TenantMap::default()),
            slo,
            slow_log: (1.0 + slo.latency_objective_us.max(0.0)).log10(),
            exemplars: ExemplarStore::new(DEFAULT_EXEMPLAR_CAPACITY),
            numerics: Mutex::new(NumericsAccum::new(WINDOW_RING_SECS)),
            wire_payload_bytes: AtomicU64::new(0),
            wire_f32_bytes: AtomicU64::new(0),
            saturated_exemplars: AtomicU64::new(0),
        }
    }

    /// The objectives this recorder evaluates.
    pub fn slo_config(&self) -> SloConfig {
        self.slo
    }

    /// The tail-retained exemplar store (exposition + trace RPC read
    /// from here).
    pub fn exemplars(&self) -> &ExemplarStore {
        &self.exemplars
    }

    /// Seconds since the recorder started — the absolute-second clock
    /// every windowed ring is stamped with.
    fn now_sec(&self) -> u64 {
        self.started_at.elapsed().as_secs()
    }

    /// One tenant-attributed request was answered with a result
    /// (computed or served from cache) carrying `elements` GAE elements.
    pub(crate) fn record_tenant_request(&self, tenant: &str, elements: u64) {
        let mut t = self.tenants.lock().unwrap();
        let c = t.entry(tenant);
        c.requests += 1;
        c.elements += elements;
    }

    /// Admission control shed a tenant-attributed request.
    pub(crate) fn record_tenant_shed(&self, tenant: &str) {
        self.tenants.lock().unwrap().entry(tenant).shed += 1;
    }

    /// The tenant's quota bucket refused a frame.
    pub(crate) fn record_tenant_quota_shed(&self, tenant: &str) {
        self.tenants.lock().unwrap().entry(tenant).quota_shed += 1;
    }

    /// One quantized plane's measurements, taken where the f32 and
    /// coded representations coexisted (wire encode/decode). Lands in
    /// the shard-wide and per-tenant windowed accumulators; steady
    /// state this is counter folds only — the tenant's accumulator is
    /// boxed once on its first quantized plane, and
    /// `benches/telemetry_overhead.rs` holds the path to zero
    /// allocations thereafter (which is why the hook is `pub`: the
    /// bench drives it directly).
    ///
    /// A plane saturating past the Critical bar is the one allocation
    /// exception, mirroring slow-tail retention: the plane's metadata
    /// is stamped onto the request's span tree (an instant event) and
    /// the trace is promoted into the exemplar store under
    /// [`RetainReason::Saturated`].
    pub fn record_plane_numerics(&self, tenant: &str, pn: &PlaneNumerics, trace: u64) {
        let now_sec = self.now_sec();
        self.numerics.lock().unwrap().record(now_sec, pn);
        {
            let mut t = self.tenants.lock().unwrap();
            let c = t.entry(tenant);
            c.numerics
                .get_or_insert_with(|| Box::new(NumericsAccum::new(WINDOW_RING_SECS)))
                .record(now_sec, pn);
        }
        if pn.is_critically_saturated() && trace != 0 {
            // The instant event must land in the rings *before* the
            // store snapshots them, or the exemplar body arrives empty.
            crate::obs::trace::instant("numerics.saturated", trace);
            self.saturated_exemplars.fetch_add(1, Ordering::Relaxed);
            self.exemplars.retain(ExemplarMeta {
                trace,
                reason: RetainReason::Saturated,
                total_us: 0.0,
                when_sec: now_sec,
            });
        }
    }

    /// One request frame's transport accounting: payload-section bytes
    /// actually received vs what the f32 escape hatch would have used
    /// for the same geometry — the lifetime `reduction_vs_f32`
    /// aggregate, per shard and per tenant.
    pub(crate) fn record_wire_frame(&self, tenant: &str, payload_bytes: u64, f32_bytes: u64) {
        self.wire_payload_bytes.fetch_add(payload_bytes, Ordering::Relaxed);
        self.wire_f32_bytes.fetch_add(f32_bytes, Ordering::Relaxed);
        let mut t = self.tenants.lock().unwrap();
        let c = t.entry(tenant);
        c.wire_payload_bytes += payload_bytes;
        c.wire_f32_bytes += f32_bytes;
    }

    /// An admission attempt (admitted *or* shed).
    pub(crate) fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Admission control rejected the request. Sheds are availability
    /// "bad events", so they also land in the windowed error ring the
    /// SLO burn rates read.
    pub(crate) fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        let now_sec = self.now_sec();
        self.hists.lock().unwrap().win_errors.add(now_sec, 1);
    }

    /// The network front-end refused a frame on its tenant's quota.
    pub(crate) fn record_quota_shed(&self) {
        self.quota_shed.fetch_add(1, Ordering::Relaxed);
        let now_sec = self.now_sec();
        self.hists.lock().unwrap().win_errors.add(now_sec, 1);
    }

    /// The network front-end answered a frame from the response cache.
    pub(crate) fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// The response cache was consulted and had no entry.
    pub(crate) fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// The reactor front-end shed a connection whose write backlog
    /// stayed full past the slow-consumer deadline.
    pub(crate) fn record_slow_closed(&self) {
        self.slow_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Tenant authentication rejected a frame. Deliberately **not**
    /// ticked into the windowed error ring: auth rejects are hostile or
    /// misconfigured traffic, and an unauthenticated attacker must not
    /// be able to burn the deployment's SLO availability budget by
    /// spraying unsigned frames. The lifetime counter and per-tenant
    /// attribution still make the abuse visible.
    pub(crate) fn record_auth_rejected(&self, claimed_tenant: &str) {
        self.auth_rejected.fetch_add(1, Ordering::Relaxed);
        self.tenants.lock().unwrap().entry(claimed_tenant).auth_rejected += 1;
    }

    /// A front-end closed a connection that hit the auth strike limit.
    pub(crate) fn record_auth_conn_closed(&self) {
        self.auth_conns_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Size-threshold routing sent one coalesced group to the scalar loop.
    pub(crate) fn record_routed_small(&self) {
        self.routed_small.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker flushed one coalesced group of `lanes` trajectories.
    /// The group's backend compute is recorded into the compute
    /// histogram here, **once per group** — every request in the group
    /// rode the same computation, so recording it per request (as the
    /// first generation did) inflated the compute p95/p99 by the group
    /// fan-out.
    pub(crate) fn record_batch(
        &self,
        lanes: usize,
        hw_cycles: Option<u64>,
        compute: Duration,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_lanes.fetch_add(lanes as u64, Ordering::Relaxed);
        if let Some(c) = hw_cycles {
            self.hw_cycles.fetch_add(c, Ordering::Relaxed);
        }
        self.hists.lock().unwrap().compute_us.push(log_us(compute));
    }

    /// Tile-path accounting for one coalesced group: how many tiles ran
    /// the slab fast path vs the packed gather, and the plane bytes the
    /// packed tiles copied.
    pub(crate) fn record_tiles(&self, slab: u64, packed: u64, gathered_bytes: u64) {
        self.slab_tiles.fetch_add(slab, Ordering::Relaxed);
        self.packed_tiles.fetch_add(packed, Ordering::Relaxed);
        self.gathered_bytes.fetch_add(gathered_bytes, Ordering::Relaxed);
    }

    /// One request finished; `elements` = GAE elements it carried. The
    /// compute phase is recorded per *group* in
    /// [`ServiceMetrics::record_batch`], not here; the encode phase per
    /// wire frame in [`ServiceMetrics::record_encode`], since the worker
    /// has already sent the timing by the time a frame is built.
    ///
    /// Besides the lifetime histograms, the completion lands in the
    /// per-second windowed rings, and — when `trace` is nonzero and the
    /// total sits above the adaptive tail threshold (the 10s-window p99
    /// plus [`RETAIN_MARGIN_LOG`], or the SLO latency objective while
    /// the window is thin) — the request's span tree is promoted into
    /// the exemplar store. Everything on the common path reuses
    /// preallocated buckets: no allocation unless a promotion fires —
    /// `benches/telemetry_overhead.rs` holds this path to zero
    /// steady-state allocations (which is why this recorder hook is
    /// `pub`: the worker is its real caller).
    pub fn record_completion(&self, elements: usize, timing: &RequestTiming, trace: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(elements as u64, Ordering::Relaxed);
        let now_sec = self.now_sec();
        let log_total = log_us(timing.total);
        let retain = {
            let mut h = self.hists.lock().unwrap();
            h.queue_us.push(log_us(timing.queue));
            h.batch_us.push(log_us(timing.batch));
            h.total_us.push(log_total);
            h.win_total.record(now_sec, log_total);
            h.win_completed.add(now_sec, 1);
            h.win_elements.add(now_sec, elements as u64);
            if log_total > self.slow_log {
                h.win_slow.add(now_sec, 1);
            }
            let recompute = h.retain_stamp != now_sec
                || (!h.threshold_adaptive
                    && h.win_completed.sum(now_sec, 10) >= MIN_THRESHOLD_SAMPLES);
            if recompute {
                h.retain_stamp = now_sec;
                let inner = &mut *h;
                inner.win_total.merged_into(now_sec, 10, &mut inner.scratch);
                if inner.scratch.count() < MIN_THRESHOLD_SAMPLES {
                    inner.retain_threshold_log = self.slow_log;
                    inner.threshold_adaptive = false;
                } else {
                    inner.retain_threshold_log =
                        inner.scratch.quantile(0.99) + RETAIN_MARGIN_LOG;
                    inner.threshold_adaptive = true;
                }
            }
            trace != 0 && log_total > h.retain_threshold_log
        };
        if retain {
            self.exemplars.retain(ExemplarMeta {
                trace,
                reason: RetainReason::Slow,
                total_us: timing.total.as_secs_f64() * 1e6,
                when_sec: now_sec,
            });
        }
    }

    /// Promote a request's trace for a non-latency reason (errored,
    /// shed, failed over) — called by the front-ends, which know the
    /// outcome and the trace id. Untraced requests have no span tree to
    /// keep and are skipped.
    pub(crate) fn retain_exemplar(&self, trace: u64, reason: RetainReason, total: Duration) {
        if trace == 0 {
            return;
        }
        self.exemplars.retain(ExemplarMeta {
            trace,
            reason,
            total_us: total.as_secs_f64() * 1e6,
            when_sec: self.now_sec(),
        });
    }

    /// The network front-end encoded one response frame in `encode` —
    /// the only phase the worker cannot time itself (the frame is built
    /// after the worker's reply is sent).
    pub(crate) fn record_encode(&self, encode: Duration) {
        self.hists.lock().unwrap().encode_us.push(log_us(encode));
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Point-in-time snapshot; the queue gauges and routing threshold
    /// ride in as [`SnapshotInputs`] (the service owns the queue and the
    /// config, not the recorder).
    pub fn snapshot(&self, inputs: SnapshotInputs) -> MetricsSnapshot {
        let SnapshotInputs { queue_depth, peak_queue_depth, scalar_route_max_elements } =
            inputs;
        let uptime = self.started_at.elapsed();
        let now_sec = uptime.as_secs();
        let mut worst_tenant_health = NumericsHealth::Ok;
        let mut tenants: Vec<TenantSnapshot> = {
            let t = self.tenants.lock().unwrap();
            t.map
                .iter()
                .map(|(tenant, c)| {
                    let (quant_planes, quant_elements, quant_clipped) = c
                        .numerics
                        .as_ref()
                        .map(|n| (n.planes, n.elements, n.clipped))
                        .unwrap_or((0, 0, 0));
                    let (quant_saturation_1s, numerics_health) = c
                        .numerics
                        .as_ref()
                        .map(|n| {
                            (n.window(now_sec, 1).saturation_rate, n.health(now_sec))
                        })
                        .unwrap_or((0.0, NumericsHealth::Ok));
                    worst_tenant_health = worst_tenant_health.max(numerics_health);
                    TenantSnapshot {
                        tenant: tenant.clone(),
                        requests: c.requests,
                        elements: c.elements,
                        shed: c.shed,
                        quota_shed: c.quota_shed,
                        auth_rejected: c.auth_rejected,
                        wire_payload_bytes: c.wire_payload_bytes,
                        wire_f32_bytes: c.wire_f32_bytes,
                        quant_planes,
                        quant_elements,
                        quant_clipped,
                        quant_saturation_1s,
                        numerics_health,
                    }
                })
                .collect()
        };
        // Heaviest tenants first; name breaks ties deterministically.
        tenants.sort_by(|a, b| {
            b.elements.cmp(&a.elements).then_with(|| a.tenant.cmp(&b.tenant))
        });
        let numerics = {
            let n = self.numerics.lock().unwrap();
            let mut snap = n
                .snapshot(now_sec, self.saturated_exemplars.load(Ordering::Relaxed));
            // The shard verdict is the worst of the shard-wide window
            // and every tenant's — one saturating tenant pages even
            // when the blended shard-wide rate stays under threshold.
            snap.health = snap.health.max(worst_tenant_health);
            snap
        };
        let h = self.hists.lock().unwrap();
        let batches = self.batches.load(Ordering::Relaxed);
        let elements = self.elements.load(Ordering::Relaxed);
        // Windowed views: merge the per-second rings over the three
        // standard spans (snapshotting is cold, so allocating the
        // merged histograms here is fine).
        let windows = [1u64, 10, 60].map(|span| {
            let merged = h.win_total.merged(now_sec, span);
            let completed = h.win_completed.sum(now_sec, span);
            let win_elements = h.win_elements.sum(now_sec, span);
            WindowView {
                span_secs: span,
                completed,
                elements: win_elements,
                errors: h.win_errors.sum(now_sec, span),
                slow: h.win_slow.sum(now_sec, span),
                rate_rps: completed as f64 / span as f64,
                elem_per_sec: win_elements as f64 / span as f64,
                total_us: LatencyQuantiles::of(&merged),
            }
        });
        let counts = |w: &WindowView| WindowCounts {
            completed: w.completed,
            errors: w.errors,
            slow: w.slow,
        };
        let slo = slo::evaluate(
            &self.slo,
            &counts(&windows[0]),
            &counts(&windows[1]),
            &counts(&windows[2]),
        );
        let (exemplars_retained, exemplars_evicted) = self.exemplars.counts();
        MetricsSnapshot {
            tenants,
            trace_dropped_events: crate::obs::trace::dropped_events(),
            exemplars_retained,
            exemplars_evicted,
            windows,
            slo,
            recent_exemplars: self.exemplars.metas(8),
            uptime,
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            quota_shed: self.quota_shed.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            slow_closed: self.slow_closed.load(Ordering::Relaxed),
            auth_rejected: self.auth_rejected.load(Ordering::Relaxed),
            auth_conns_closed: self.auth_conns_closed.load(Ordering::Relaxed),
            wire_payload_bytes: self.wire_payload_bytes.load(Ordering::Relaxed),
            wire_f32_bytes: self.wire_f32_bytes.load(Ordering::Relaxed),
            numerics,
            routed_small: self.routed_small.load(Ordering::Relaxed),
            slab_tiles: self.slab_tiles.load(Ordering::Relaxed),
            packed_tiles: self.packed_tiles.load(Ordering::Relaxed),
            gathered_bytes: self.gathered_bytes.load(Ordering::Relaxed),
            scalar_route_max_elements,
            queue_depth,
            peak_queue_depth,
            batches,
            mean_batch_lanes: if batches == 0 {
                0.0
            } else {
                self.batch_lanes.load(Ordering::Relaxed) as f64 / batches as f64
            },
            elements,
            sustained_elem_per_sec: elements as f64 / uptime.as_secs_f64().max(1e-9),
            hw_cycles: self.hw_cycles.load(Ordering::Relaxed),
            queue_us: LatencyQuantiles::of(&h.queue_us),
            batch_us: LatencyQuantiles::of(&h.batch_us),
            compute_us: LatencyQuantiles::of(&h.compute_us),
            encode_us: LatencyQuantiles::of(&h.encode_us),
            total_us: LatencyQuantiles::of(&h.total_us),
        }
    }
}

/// Caller-owned gauges fed into [`ServiceMetrics::snapshot`]: the
/// service owns the queue and the routing config, so their point-in-time
/// values ride in by name instead of as three bare positional `usize`s
/// (which tests used to call as an inscrutable `snapshot(0, 0, 0)`).
#[derive(Debug, Clone, Copy, Default)]
pub struct SnapshotInputs {
    /// Live queue depth.
    pub queue_depth: usize,
    /// High-water queue depth since start.
    pub peak_queue_depth: usize,
    /// The routing threshold in force (0 = routing disabled).
    pub scalar_route_max_elements: usize,
}

/// One tenant's slice of a [`MetricsSnapshot`] — the substrate the
/// fabric's fleet view aggregates across shards.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSnapshot {
    pub tenant: String,
    /// Requests answered with a result (computed or cache).
    pub requests: u64,
    /// GAE elements those requests carried.
    pub elements: u64,
    /// Requests refused by admission control.
    pub shed: u64,
    /// Frames refused by the tenant's quota bucket.
    pub quota_shed: u64,
    /// Frames rejected by tenant authentication. Attributes the
    /// *claimed* identity — an attacker spoofing tenant `a` shows up
    /// under `a`, which is exactly where an operator looks first.
    pub auth_rejected: u64,
    /// Request payload-section bytes this tenant put on the wire.
    pub wire_payload_bytes: u64,
    /// f32-escape-hatch bytes the same frames would have used (the
    /// lifetime per-tenant `reduction_vs_f32` numerator).
    pub wire_f32_bytes: u64,
    /// Quantized planes observed for this tenant.
    pub quant_planes: u64,
    /// Elements those planes carried.
    pub quant_elements: u64,
    /// Elements on the quantizer's end codes (lifetime).
    pub quant_clipped: u64,
    /// Saturation rate over the tenant's last-1s window.
    pub quant_saturation_1s: f64,
    /// The tenant's 1s-window numerics verdict.
    pub numerics_health: NumericsHealth,
}

impl TenantSnapshot {
    /// Lifetime wire-transport reduction vs f32 for this tenant's
    /// request frames (1.0 when nothing was recorded).
    pub fn wire_reduction_vs_f32(&self) -> f64 {
        if self.wire_payload_bytes == 0 {
            1.0
        } else {
            self.wire_f32_bytes as f64 / self.wire_payload_bytes as f64
        }
    }
}

/// p50/p95/p99 of one latency phase, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyQuantiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LatencyQuantiles {
    fn of(h: &Histogram) -> LatencyQuantiles {
        LatencyQuantiles {
            p50: unlog_us(h.quantile(0.50)),
            p95: unlog_us(h.quantile(0.95)),
            p99: unlog_us(h.quantile(0.99)),
        }
    }
}

/// One windowed view of the request stream: the last `span_secs`
/// seconds' rates and total-phase quantiles, merged out of the
/// per-second rings at snapshot time. An idle window reports zeros —
/// stale buckets age out by stamp, so a quiet service never shows a
/// frozen p99 from its last burst.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowView {
    /// Window length in seconds (1, 10, or 60).
    pub span_secs: u64,
    /// Requests completed inside the window.
    pub completed: u64,
    /// GAE elements those completions carried.
    pub elements: u64,
    /// Shed + quota-shed events inside the window.
    pub errors: u64,
    /// Completions above the SLO latency objective.
    pub slow: u64,
    /// `completed / span_secs`.
    pub rate_rps: f64,
    /// `elements / span_secs`.
    pub elem_per_sec: f64,
    /// Total-phase quantiles over the window.
    pub total_us: LatencyQuantiles,
}

/// A frozen view of [`ServiceMetrics`].
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub uptime: Duration,
    /// Admission attempts (admitted + shed).
    pub submitted: u64,
    pub completed: u64,
    /// Requests rejected by admission control.
    pub shed: u64,
    /// Frames refused by the network front-end's per-tenant quotas.
    pub quota_shed: u64,
    /// Frames answered from the network front-end's response cache.
    pub cache_hits: u64,
    /// Cache lookups that missed (cache enabled, no entry).
    pub cache_misses: u64,
    /// Connections the reactor front-end closed for being slow
    /// consumers: write backlog full past the shed deadline, answered
    /// with a typed `Shed` error frame and deregistered.
    pub slow_closed: u64,
    /// Request frames rejected by tenant authentication: missing,
    /// malformed, or mismatched HMAC tag while the server holds an
    /// auth key. Deliberately excluded from the windowed SLO error
    /// rings so unauthenticated traffic cannot burn the availability
    /// budget.
    pub auth_rejected: u64,
    /// Connections closed for exceeding the per-connection auth
    /// strike limit.
    pub auth_conns_closed: u64,
    /// Request payload-section bytes received on the wire (lifetime).
    pub wire_payload_bytes: u64,
    /// f32-escape-hatch bytes the same frames would have used — the
    /// lifetime aggregate behind
    /// [`MetricsSnapshot::wire_reduction_vs_f32`], making the paper's
    /// 4×-memory claim observable per deployment, not just per frame.
    pub wire_f32_bytes: u64,
    /// Quantization-health rows: lifetime reconstruction error and
    /// saturation, the 1/10/60s windowed views, and the 1s verdict
    /// (worst of shard-wide and per-tenant).
    pub numerics: NumericsSnapshot,
    /// Coalesced groups sent to the scalar loop by size-threshold routing.
    pub routed_small: u64,
    /// Tiles computed in place on a resident plane slab (zero gather).
    pub slab_tiles: u64,
    /// Tiles that fell back to the packed-tile gather.
    pub packed_tiles: u64,
    /// Plane bytes copied into packed tiles; the slab fast path
    /// contributes zero here by construction.
    pub gathered_bytes: u64,
    /// The routing threshold in force (0 = routing disabled).
    pub scalar_route_max_elements: usize,
    pub queue_depth: usize,
    pub peak_queue_depth: usize,
    /// Coalesced groups flushed by workers.
    pub batches: u64,
    pub mean_batch_lanes: f64,
    /// GAE elements computed (real, not padding).
    pub elements: u64,
    pub sustained_elem_per_sec: f64,
    /// Accumulated simulated accelerator cycles (hwsim backend).
    pub hw_cycles: u64,
    pub queue_us: LatencyQuantiles,
    /// Batch-assembly wait: pickup → backend compute start.
    pub batch_us: LatencyQuantiles,
    pub compute_us: LatencyQuantiles,
    /// Response-frame wire encode (network front-end only; in-process
    /// submissions move their responses and record nothing here).
    pub encode_us: LatencyQuantiles,
    pub total_us: LatencyQuantiles,
    /// Trace-ring events overwritten before being drained (process
    /// total) — nonzero means span trees are being silently lost.
    pub trace_dropped_events: u64,
    /// Exemplars promoted into the tail-retained store since start.
    pub exemplars_retained: u64,
    /// Exemplars evicted from the bounded store since start.
    pub exemplars_evicted: u64,
    /// Windowed views of the last 1, 10, and 60 seconds, in that order.
    pub windows: [WindowView; 3],
    /// Multi-window SLO burn rates and the combined health verdict.
    pub slo: SloReport,
    /// Up to 8 most recent retained exemplars, newest first (ids only;
    /// full span trees stay in the store / trace RPC).
    pub recent_exemplars: Vec<ExemplarMeta>,
    /// Per-tenant breakdown, heaviest (by elements) first. Covers
    /// tenant-attributed traffic only (network front-end, fabric);
    /// bounded at 4096 tenants with LRU eviction like the quota map.
    pub tenants: Vec<TenantSnapshot>,
}

impl MetricsSnapshot {
    /// The windowed view covering `span_secs` (1, 10, or 60); other
    /// spans fall back to the 1s view.
    pub fn window(&self, span_secs: u64) -> &WindowView {
        self.windows
            .iter()
            .find(|w| w.span_secs == span_secs)
            .unwrap_or(&self.windows[0])
    }

    /// Lifetime wire-transport reduction vs f32 across every request
    /// frame received (1.0 when nothing was recorded).
    pub fn wire_reduction_vs_f32(&self) -> f64 {
        if self.wire_payload_bytes == 0 {
            1.0
        } else {
            self.wire_f32_bytes as f64 / self.wire_payload_bytes as f64
        }
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: {} submitted, {} completed, {} shed (queue depth {} / peak {})",
            self.submitted, self.completed, self.shed, self.queue_depth, self.peak_queue_depth
        )?;
        writeln!(
            f,
            "batches:  {} flushed, {:.1} lanes/batch mean | tiles {} slab / {} packed ({} B gathered)",
            self.batches,
            self.mean_batch_lanes,
            self.slab_tiles,
            self.packed_tiles,
            self.gathered_bytes
        )?;
        writeln!(
            f,
            "net:      cache {} hit / {} miss | quota shed {} | slow-closed {} | auth-rejected {} / {} conns closed | routed-to-scalar {} (threshold {})",
            self.cache_hits,
            self.cache_misses,
            self.quota_shed,
            self.slow_closed,
            self.auth_rejected,
            self.auth_conns_closed,
            self.routed_small,
            self.scalar_route_max_elements
        )?;
        if self.wire_f32_bytes > 0 {
            writeln!(
                f,
                "wire:     {} payload B vs {} f32 B = {:.2}x lifetime reduction",
                self.wire_payload_bytes,
                self.wire_f32_bytes,
                self.wire_reduction_vs_f32()
            )?;
        }
        if !self.tenants.is_empty() {
            write!(f, "tenants:  {} tracked |", self.tenants.len())?;
            for t in self.tenants.iter().take(4) {
                write!(
                    f,
                    " {}: {} req / {} elem ({} shed, {} quota, {} auth)",
                    t.tenant, t.requests, t.elements, t.shed, t.quota_shed, t.auth_rejected
                )?;
                if t.quant_planes > 0 {
                    write!(
                        f,
                        " [quant {} planes, sat(1s) {:.2}%, {:.2}x wire, {}]",
                        t.quant_planes,
                        t.quant_saturation_1s * 100.0,
                        t.wire_reduction_vs_f32(),
                        t.numerics_health.as_str()
                    )?;
                }
            }
            writeln!(f)?;
        }
        writeln!(
            f,
            "latency (µs): total p50 {:.0}  p95 {:.0}  p99 {:.0} | queue p50 {:.0} | batch p50 {:.0} | compute p50 {:.0} | encode p50 {:.0}",
            self.total_us.p50,
            self.total_us.p95,
            self.total_us.p99,
            self.queue_us.p50,
            self.batch_us.p50,
            self.compute_us.p50,
            self.encode_us.p50
        )?;
        for w in &self.windows {
            writeln!(
                f,
                "last {:>3}s: {:.1} req/s, {} elem/s | p50 {:.0}  p95 {:.0}  p99 {:.0} µs | {} errors, {} slow",
                w.span_secs,
                w.rate_rps,
                crate::bench::format_si(w.elem_per_sec),
                w.total_us.p50,
                w.total_us.p95,
                w.total_us.p99,
                w.errors,
                w.slow
            )?;
        }
        writeln!(
            f,
            "slo:      {} (burn 1s {:.1} / 10s {:.1} / 60s {:.1})",
            self.slo.health, self.slo.burn_1s, self.slo.burn_10s, self.slo.burn_60s
        )?;
        if self.numerics.planes > 0 {
            let w1 = self.numerics.window(1);
            writeln!(
                f,
                "numerics: {} | {} planes, sat {:.3}%, mse {:.3e}, max-err {:.3e} | 1s: sat {:.3}%, codes {}/256, σ-drift {:.2} | {} saturated exemplars",
                self.numerics.health.as_str(),
                self.numerics.planes,
                self.numerics.saturation_rate() * 100.0,
                self.numerics.mse(),
                self.numerics.max_abs_err,
                w1.saturation_rate * 100.0,
                w1.codes_used,
                w1.sigma_drift,
                self.numerics.saturated_exemplars
            )?;
        }
        writeln!(
            f,
            "trace:    {} ring-dropped events | exemplars {} retained / {} evicted ({} recent)",
            self.trace_dropped_events,
            self.exemplars_retained,
            self.exemplars_evicted,
            self.recent_exemplars.len()
        )?;
        write!(
            f,
            "work:     {} elements in {:.2}s = {} elem/s sustained",
            self.elements,
            self.uptime.as_secs_f64(),
            crate::bench::format_si(self.sustained_elem_per_sec)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing(queue_us: u64, compute_us: u64) -> RequestTiming {
        RequestTiming {
            queue: Duration::from_micros(queue_us),
            batch: Duration::ZERO,
            compute: Duration::from_micros(compute_us),
            group_compute: Duration::from_micros(compute_us),
            encode: Duration::ZERO,
            total: Duration::from_micros(queue_us + compute_us),
        }
    }

    #[test]
    fn counters_accumulate() {
        let m = ServiceMetrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_shed();
        m.record_quota_shed();
        m.record_cache_hit();
        m.record_cache_miss();
        m.record_cache_miss();
        m.record_slow_closed();
        m.record_routed_small();
        m.record_batch(32, Some(1000), Duration::from_micros(200));
        m.record_batch(16, None, Duration::from_micros(100));
        m.record_tiles(2, 1, 4096);
        m.record_completion(4096, &timing(50, 200), 0);
        let s = m.snapshot(SnapshotInputs {
            queue_depth: 3,
            peak_queue_depth: 7,
            scalar_route_max_elements: 512,
        });
        assert_eq!(s.submitted, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.quota_shed, 1);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 2);
        assert_eq!(s.slow_closed, 1);
        assert_eq!(s.routed_small, 1);
        assert_eq!(s.slab_tiles, 2);
        assert_eq!(s.packed_tiles, 1);
        assert_eq!(s.gathered_bytes, 4096);
        assert_eq!(s.scalar_route_max_elements, 512);
        assert_eq!(s.completed, 1);
        assert_eq!(s.elements, 4096);
        assert_eq!(s.batches, 2);
        assert_eq!(s.hw_cycles, 1000);
        assert!((s.mean_batch_lanes - 24.0).abs() < 1e-12);
        assert_eq!(s.queue_depth, 3);
        assert_eq!(s.peak_queue_depth, 7);
        assert!(s.sustained_elem_per_sec > 0.0);
    }

    #[test]
    fn tenant_breakdown_accumulates_and_sorts_by_elements() {
        let m = ServiceMetrics::new();
        m.record_tenant_request("small", 10);
        m.record_tenant_request("big", 500);
        m.record_tenant_request("big", 500);
        m.record_tenant_shed("small");
        m.record_tenant_quota_shed("hog");
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.tenants.len(), 3);
        assert_eq!(s.tenants[0].tenant, "big");
        assert_eq!(s.tenants[0].requests, 2);
        assert_eq!(s.tenants[0].elements, 1000);
        let small = s.tenants.iter().find(|t| t.tenant == "small").unwrap();
        assert_eq!((small.requests, small.elements, small.shed), (1, 10, 1));
        let hog = s.tenants.iter().find(|t| t.tenant == "hog").unwrap();
        assert_eq!((hog.requests, hog.quota_shed), (0, 1));
        // The breakdown shows up in the human-readable dump.
        let text = s.to_string();
        assert!(text.contains("tenants:") && text.contains("big"), "{text}");
    }

    #[test]
    fn tenant_map_is_bounded_with_lru_eviction() {
        let m = ServiceMetrics::new();
        for i in 0..(MAX_TENANT_STATS + 8) {
            m.record_tenant_request(&format!("t{i}"), 1);
        }
        let s = m.snapshot(SnapshotInputs::default());
        assert!(s.tenants.len() <= MAX_TENANT_STATS, "grew to {}", s.tenants.len());
        // The most recently touched tenant survived.
        let last = format!("t{}", MAX_TENANT_STATS + 7);
        assert!(s.tenants.iter().any(|t| t.tenant == last));
    }

    #[test]
    fn lru_eviction_removes_the_longest_untouched_tenant() {
        let m = ServiceMetrics::new();
        for i in 0..MAX_TENANT_STATS {
            m.record_tenant_request(&format!("t{i}"), 1);
        }
        // Refresh the oldest tenant; "t1" becomes the stalest.
        m.record_tenant_request("t0", 1);
        // A new tenant at the cap evicts the stalest — not the refreshed one.
        m.record_tenant_request("fresh", 1);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.tenants.len(), MAX_TENANT_STATS);
        assert!(s.tenants.iter().any(|t| t.tenant == "t0"), "refreshed must survive");
        assert!(s.tenants.iter().any(|t| t.tenant == "fresh"));
        assert!(
            !s.tenants.iter().any(|t| t.tenant == "t1"),
            "the longest-untouched tenant must be the one evicted"
        );
    }

    #[test]
    fn batch_and_encode_phases_have_their_own_histograms() {
        let m = ServiceMetrics::new();
        let t = RequestTiming {
            queue: Duration::from_micros(10),
            batch: Duration::from_micros(300),
            compute: Duration::from_micros(40),
            group_compute: Duration::from_micros(40),
            encode: Duration::ZERO,
            total: Duration::from_micros(400),
        };
        m.record_completion(1, &t, 0);
        m.record_encode(Duration::from_micros(70));
        let s = m.snapshot(SnapshotInputs::default());
        assert!((250.0..400.0).contains(&s.batch_us.p50), "batch p50 = {}", s.batch_us.p50);
        assert!((55.0..90.0).contains(&s.encode_us.p50), "encode p50 = {}", s.encode_us.p50);
        let text = s.to_string();
        assert!(text.contains("batch p50") && text.contains("encode p50"), "{text}");
    }

    #[test]
    fn log_histogram_quantiles_are_accurate_enough() {
        let m = ServiceMetrics::new();
        // 100 requests at 100µs, 900 at 1000µs total: p50 ~1000.
        for _ in 0..100 {
            m.record_completion(1, &timing(100, 0), 0);
        }
        for _ in 0..900 {
            m.record_completion(1, &timing(1000, 0), 0);
        }
        let s = m.snapshot(SnapshotInputs::default());
        let p50 = s.queue_us.p50;
        assert!((900.0..1150.0).contains(&p50), "p50 = {p50}");
        // Total-phase p99 within the log-bin resolution of 1100µs.
        let p99 = s.total_us.p99;
        assert!((900.0..1300.0).contains(&p99), "p99 = {p99}");
    }

    #[test]
    fn compute_histogram_records_once_per_group() {
        // Ten single-lane requests riding one coalesced group must leave
        // exactly one compute sample (the group's), not ten — the p50 of
        // a one-sample histogram is that sample.
        let m = ServiceMetrics::new();
        m.record_batch(10, None, Duration::from_micros(5000));
        for _ in 0..10 {
            m.record_completion(8, &timing(10, 500), 0);
        }
        let s = m.snapshot(SnapshotInputs::default());
        let p50 = s.compute_us.p50;
        assert!(
            (4000.0..6500.0).contains(&p50),
            "compute p50 must reflect the single group sample, got {p50}"
        );
        assert_eq!(s.completed, 10);
    }

    #[test]
    fn display_mentions_the_headline_numbers() {
        let m = ServiceMetrics::new();
        m.record_submitted();
        m.record_completion(10, &timing(5, 10), 0);
        let text = m
            .snapshot(SnapshotInputs { peak_queue_depth: 1, ..Default::default() })
            .to_string();
        for needle in [
            "p50", "p95", "p99", "shed", "elem/s", "cache", "quota", "slab",
            "last   1s", "slo:", "exemplars",
        ] {
            assert!(text.contains(needle), "missing {needle}: {text}");
        }
    }

    #[test]
    fn windowed_views_report_recent_load_alongside_lifetime() {
        let m = ServiceMetrics::new();
        for _ in 0..40 {
            m.record_completion(16, &timing(500, 0), 0);
        }
        let s = m.snapshot(SnapshotInputs::default());
        // The burst just happened, so every window sees all of it…
        let w1 = s.window(1);
        assert_eq!(w1.span_secs, 1);
        assert_eq!(w1.completed, 40);
        assert_eq!(w1.elements, 640);
        assert!(w1.rate_rps >= 40.0, "{}", w1.rate_rps);
        assert_eq!(s.window(10).completed, 40);
        assert_eq!(s.window(60).completed, 40);
        // …with windowed quantiles near the recorded 500µs totals.
        assert!((400.0..700.0).contains(&w1.total_us.p50), "{}", w1.total_us.p50);
        // Lifetime and window agree while everything is recent.
        assert_eq!(s.completed, 40);
        assert_eq!(w1.errors, 0);
        assert_eq!(w1.slow, 0);
    }

    #[test]
    fn slow_traced_completion_is_retained_as_exemplar() {
        let m = ServiceMetrics::new();
        // Above the 50ms default objective while the 10s window is thin
        // → promoted; same latency untraced → no span tree to keep.
        m.record_completion(8, &timing(200_000, 0), 0xFEED);
        m.record_completion(8, &timing(200_000, 0), 0);
        // A fast traced completion stays unretained.
        m.record_completion(8, &timing(100, 0), 0xBEEF);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.exemplars_retained, 1, "{:?}", s.recent_exemplars);
        assert_eq!(s.exemplars_evicted, 0);
        assert_eq!(s.recent_exemplars.len(), 1);
        assert_eq!(s.recent_exemplars[0].trace, 0xFEED);
        assert_eq!(s.recent_exemplars[0].reason, RetainReason::Slow);
        assert!(s.recent_exemplars[0].total_us > 100_000.0);
        // The slow completions also count against the latency SLO.
        assert_eq!(s.window(1).slow, 2);
    }

    #[test]
    fn shed_heavy_windows_flip_slo_health_to_critical() {
        let m = ServiceMetrics::new();
        let idle = m.snapshot(SnapshotInputs::default());
        assert_eq!(idle.slo.health, crate::obs::SloHealth::Ok);
        assert_eq!(idle.slo.burn_1s, 0.0);
        // Half the traffic shed burns the availability budget at ~500x
        // in both fast windows.
        for _ in 0..10 {
            m.record_completion(1, &timing(100, 0), 0);
            m.record_shed();
        }
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.window(1).errors, 10);
        assert!(s.slo.burn_1s > slo::FAST_BURN, "{:?}", s.slo);
        assert!(s.slo.burn_10s > slo::FAST_BURN, "{:?}", s.slo);
        assert_eq!(s.slo.health, crate::obs::SloHealth::Critical);
    }

    #[test]
    fn retain_exemplar_records_front_end_outcomes() {
        let m = ServiceMetrics::new();
        m.retain_exemplar(0, RetainReason::Shed, Duration::ZERO); // untraced: dropped
        m.retain_exemplar(0xC0FFEE, RetainReason::Shed, Duration::from_millis(3));
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.exemplars_retained, 1);
        assert_eq!(s.recent_exemplars[0].reason, RetainReason::Shed);
        assert_eq!(s.recent_exemplars[0].trace, 0xC0FFEE);
    }

    #[test]
    fn adaptive_threshold_tracks_the_window_p99() {
        let m = ServiceMetrics::new();
        // Fill the 10s window with enough fast samples to arm the
        // adaptive threshold (p99 ≈ 500µs, threshold ≈ +20%).
        for _ in 0..200 {
            m.record_completion(1, &timing(500, 0), 0);
        }
        {
            let h = m.hists.lock().unwrap();
            assert!(
                h.retain_threshold_log.is_finite(),
                "threshold must be armed after {MIN_THRESHOLD_SAMPLES}+ samples"
            );
        }
        // 5ms is ~10x the window p99: well past threshold → retained,
        // even though it is far below the 50ms SLO objective.
        m.record_completion(1, &timing(5_000, 0), 0xAB);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.exemplars_retained, 1, "{:?}", s.recent_exemplars);
        assert_eq!(s.recent_exemplars[0].trace, 0xAB);
    }

    fn clean_plane(elements: u64) -> PlaneNumerics {
        let q = crate::quant::UniformQuantizer::new(8);
        let mut pn = PlaneNumerics::default();
        pn.set_block(0.1, 1.0);
        for i in 0..elements {
            let z = ((i as f32) * 0.37).sin() * 3.0;
            let code = q.quantize(z);
            pn.note_code(code, 8);
            pn.note_err((q.dequantize(code) - z).abs());
        }
        pn
    }

    fn saturated_plane(elements: u64) -> PlaneNumerics {
        let q = crate::quant::UniformQuantizer::new(8);
        let mut pn = PlaneNumerics::default();
        pn.set_block(0.0, 17.0);
        for i in 0..elements {
            let z = if i % 8 == 0 { 50.0 } else { ((i as f32) * 0.37).sin() };
            let code = q.quantize(z);
            pn.note_code(code, 8);
            pn.note_err((q.dequantize(code) - z).abs());
        }
        pn
    }

    #[test]
    fn plane_numerics_land_in_shard_and_tenant_rows() {
        let m = ServiceMetrics::new();
        m.record_plane_numerics("alpha", &clean_plane(256), 0);
        m.record_plane_numerics("alpha", &clean_plane(256), 0);
        m.record_plane_numerics("beta", &clean_plane(256), 0);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.numerics.planes, 3);
        assert_eq!(s.numerics.elements, 768);
        assert_eq!(s.numerics.health, NumericsHealth::Ok);
        assert!(s.numerics.window(1).code_utilization > 0.0);
        let alpha = s.tenants.iter().find(|t| t.tenant == "alpha").unwrap();
        assert_eq!(alpha.quant_planes, 2);
        assert_eq!(alpha.quant_elements, 512);
        assert_eq!(alpha.numerics_health, NumericsHealth::Ok);
        let text = s.to_string();
        assert!(text.contains("numerics:"), "{text}");
    }

    #[test]
    fn one_saturating_tenant_pages_the_shard_verdict() {
        let m = ServiceMetrics::new();
        // Plenty of clean traffic from a big tenant…
        for _ in 0..20 {
            m.record_plane_numerics("clean", &clean_plane(4096), 0);
        }
        // …and one tenant whose planes saturate hard. The *blend* may
        // stay under threshold, but the tenant's own verdict must not.
        m.record_plane_numerics("spiky", &saturated_plane(256), 0);
        let s = m.snapshot(SnapshotInputs::default());
        let spiky = s.tenants.iter().find(|t| t.tenant == "spiky").unwrap();
        assert_eq!(spiky.numerics_health, NumericsHealth::Critical);
        assert!(spiky.quant_saturation_1s >= 0.02, "{}", spiky.quant_saturation_1s);
        assert_eq!(s.numerics.health, NumericsHealth::Critical);
    }

    #[test]
    fn saturated_traced_plane_is_retained_as_exemplar() {
        let m = ServiceMetrics::new();
        m.record_plane_numerics("t", &saturated_plane(256), 0xDEAD);
        // Untraced saturation records the numerics but keeps no exemplar.
        m.record_plane_numerics("t", &saturated_plane(256), 0);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.exemplars_retained, 1);
        assert_eq!(s.recent_exemplars[0].reason, RetainReason::Saturated);
        assert_eq!(s.recent_exemplars[0].trace, 0xDEAD);
        assert_eq!(s.numerics.saturated_exemplars, 1);
    }

    #[test]
    fn wire_frame_bytes_aggregate_into_lifetime_reduction() {
        let m = ServiceMetrics::new();
        // Two quantized frames at ~4x reduction, per tenant and shard.
        m.record_wire_frame("q", 1000, 4000);
        m.record_wire_frame("q", 1000, 4000);
        // One f32 frame from another tenant (reduction 1.0).
        m.record_wire_frame("raw", 4000, 4000);
        let s = m.snapshot(SnapshotInputs::default());
        assert_eq!(s.wire_payload_bytes, 6000);
        assert_eq!(s.wire_f32_bytes, 12000);
        assert!((s.wire_reduction_vs_f32() - 2.0).abs() < 1e-12);
        let q = s.tenants.iter().find(|t| t.tenant == "q").unwrap();
        assert!((q.wire_reduction_vs_f32() - 4.0).abs() < 1e-12);
        let text = s.to_string();
        assert!(text.contains("lifetime reduction"), "{text}");
    }
}

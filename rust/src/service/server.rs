//! The service front-end: configuration, lifecycle, and the
//! `submit` / `submit_many` client API.

use crate::coordinator::gae_stage::GaeBackend;
use crate::gae::{GaeParams, Trajectory};
use crate::hwsim::{GaeHwSim, SimConfig};
use crate::obs::slo::SloConfig;
use crate::service::batcher::{BatcherConfig, DynamicBatcher};
use crate::service::metrics::{MetricsSnapshot, ServiceMetrics, SnapshotInputs};
use crate::service::plane::{Lane, PlaneSet};
use crate::service::queue::{BoundedQueue, PushError};
use crate::service::request::{GaeResponse, ResponseHandle, ServiceError, WorkItem};
use crate::service::worker::{worker_loop, WorkerContext};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Service deployment knobs.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Worker shards; each owns a private backend instance.
    pub workers: usize,
    /// Compute backend (`Scalar`, `Batched`, or `HwSim`; `Hlo` needs a
    /// PJRT runtime and is rejected at start).
    pub backend: GaeBackend,
    /// Admission limit: requests beyond this queue depth are shed.
    pub queue_capacity: usize,
    /// Dynamic-batching policy.
    pub batcher: BatcherConfig,
    /// Systolic rows per worker's private `hwsim` instance.
    pub sim_rows: usize,
    /// Size-threshold backend routing: coalesced groups of at most this
    /// many GAE elements run the scalar loop instead of the configured
    /// backend (small groups don't amortize tile packing or the
    /// simulator's loader pipeline). 0 disables routing.
    pub scalar_route_max_elements: usize,
    /// GAE hyper-parameters applied to every request.
    pub gae: GaeParams,
    /// Serving objectives the telemetry plane scores each window
    /// against (latency objective + availability target).
    pub slo: SloConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            backend: GaeBackend::HwSim,
            queue_capacity: 256,
            batcher: BatcherConfig::default(),
            sim_rows: 64,
            scalar_route_max_elements: 0,
            gae: GaeParams::default(),
            slo: SloConfig::default(),
        }
    }
}

/// A running GAE service: admission-controlled queue in front, sharded
/// worker pool behind. `&self` methods are safe from many client
/// threads. Dropping the service closes the queue, drains accepted
/// requests, and joins the workers.
pub struct GaeService {
    config: ServiceConfig,
    queue: Arc<BoundedQueue<WorkItem>>,
    metrics: Arc<ServiceMetrics>,
    /// `Some` until shutdown; behind a mutex so the service stays `Sync`.
    pool: Mutex<Option<ThreadPool>>,
    next_id: AtomicU64,
}

impl GaeService {
    /// Validate the config and spawn the worker shards.
    pub fn start(config: ServiceConfig) -> anyhow::Result<GaeService> {
        anyhow::ensure!(config.workers >= 1, "service needs at least one worker");
        anyhow::ensure!(config.queue_capacity >= 1, "queue capacity must be >= 1");
        anyhow::ensure!(config.batcher.tile_lanes >= 1, "tile_lanes must be >= 1");
        anyhow::ensure!(
            config.batcher.max_batch_lanes >= 1,
            "max_batch_lanes must be >= 1"
        );
        if config.backend == GaeBackend::Hlo {
            anyhow::bail!(
                "{}",
                ServiceError::UnsupportedBackend(config.backend.label().into())
            );
        }
        let queue = Arc::new(BoundedQueue::new(config.queue_capacity));
        let metrics = Arc::new(ServiceMetrics::with_slo(config.slo));
        let pool = ThreadPool::new(config.workers);
        for index in 0..config.workers {
            let ctx = WorkerContext {
                index,
                backend: config.backend,
                params: config.gae,
                sim: (config.backend == GaeBackend::HwSim).then(|| {
                    GaeHwSim::new(SimConfig {
                        rows: config.sim_rows.max(1),
                        gae: config.gae,
                        ..SimConfig::paper_default()
                    })
                }),
                batcher: DynamicBatcher::new(config.batcher),
                scalar_route_max_elements: config.scalar_route_max_elements,
                queue: Arc::clone(&queue),
                metrics: Arc::clone(&metrics),
            };
            pool.execute(move || worker_loop(ctx));
        }
        Ok(GaeService {
            config,
            queue,
            metrics,
            pool: Mutex::new(Some(pool)),
            next_id: AtomicU64::new(0),
        })
    }

    /// Convenience: default config at a given worker count / backend.
    pub fn with_workers(workers: usize, backend: GaeBackend) -> anyhow::Result<GaeService> {
        Self::start(ServiceConfig { workers, backend, ..ServiceConfig::default() })
    }

    fn make_item(
        &self,
        lanes: Vec<Lane>,
        trace: u64,
    ) -> Result<(WorkItem, mpsc::Receiver<GaeResponse>), ServiceError> {
        if lanes.is_empty() || lanes.iter().any(|l| l.is_empty()) {
            return Err(ServiceError::EmptyRequest);
        }
        self.metrics.record_submitted();
        crate::obs::instant("service.enqueue", trace);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let lane_count = lanes.len();
        let item =
            WorkItem { id, lanes, lane_count, enqueued_at: Instant::now(), trace, tx };
        Ok((item, rx))
    }

    /// Fail-fast admission of a prepared lane set (shared by the public
    /// trajectory path and the plane-column path).
    fn enqueue_lanes(
        &self,
        lanes: Vec<Lane>,
        trace: u64,
    ) -> Result<ResponseHandle, ServiceError> {
        let (item, rx) = self.make_item(lanes, trace)?;
        let id = item.id;
        match self.queue.try_push(item) {
            Ok(()) => Ok(ResponseHandle { id, rx }),
            Err(PushError::Full(_)) => {
                self.metrics.record_shed();
                // Depth at decision time is by definition the capacity;
                // re-reading len() here could race a concurrent pop and
                // report a self-contradictory "depth 0 at limit N".
                Err(ServiceError::Overloaded {
                    depth: self.queue.capacity(),
                    limit: self.queue.capacity(),
                })
            }
            Err(PushError::Closed(_)) => Err(ServiceError::ShuttingDown),
        }
    }

    /// Backpressured admission of a prepared lane set.
    fn enqueue_lanes_blocking(
        &self,
        lanes: Vec<Lane>,
        trace: u64,
    ) -> Result<ResponseHandle, ServiceError> {
        let (item, rx) = self.make_item(lanes, trace)?;
        let id = item.id;
        match self.queue.push(item) {
            Ok(()) => Ok(ResponseHandle { id, rx }),
            // push never reports Full; keep the match total and honest.
            Err(PushError::Full(_)) | Err(PushError::Closed(_)) => {
                Err(ServiceError::ShuttingDown)
            }
        }
    }

    /// Admit a request without waiting for its result. Admission control
    /// sheds with [`ServiceError::Overloaded`] when the queue is at its
    /// depth limit — the open-loop / fail-fast path.
    pub fn enqueue(
        &self,
        trajectories: Vec<Trajectory>,
    ) -> Result<ResponseHandle, ServiceError> {
        self.enqueue_lanes(
            trajectories.into_iter().map(Lane::Owned).collect(),
            auto_trace(),
        )
    }

    /// Admit with **backpressure**: block until a queue slot frees
    /// instead of shedding — the closed-loop client path. Fails only
    /// when the request is empty or the service is shutting down.
    pub fn enqueue_blocking(
        &self,
        trajectories: Vec<Trajectory>,
    ) -> Result<ResponseHandle, ServiceError> {
        self.enqueue_lanes_blocking(
            trajectories.into_iter().map(Lane::Owned).collect(),
            auto_trace(),
        )
    }

    /// Synchronous fail-fast request: admit (or shed), wait, return.
    pub fn submit(
        &self,
        trajectories: Vec<Trajectory>,
    ) -> Result<GaeResponse, ServiceError> {
        self.enqueue(trajectories)?.wait()
    }

    /// Synchronous backpressured request: wait for admission, then for
    /// the result.
    pub fn submit_blocking(
        &self,
        trajectories: Vec<Trajectory>,
    ) -> Result<GaeResponse, ServiceError> {
        self.enqueue_blocking(trajectories)?.wait()
    }

    /// Pipelined batch submit: admit everything first (so the requests
    /// coalesce across the worker shards), then collect in order. Each
    /// slot fails independently — under overload some slots come back
    /// [`ServiceError::Overloaded`] while the rest complete.
    pub fn submit_many(
        &self,
        requests: Vec<Vec<Trajectory>>,
    ) -> Vec<Result<GaeResponse, ServiceError>> {
        let handles: Vec<Result<ResponseHandle, ServiceError>> =
            requests.into_iter().map(|r| self.enqueue(r)).collect();
        handles
            .into_iter()
            .map(|h| h.and_then(|h| h.wait()))
            .collect()
    }

    /// The pipelined trainer's in-process seam: submit one iteration's
    /// timestep-major `(rewards [T·B], values [(T+1)·B], done-mask
    /// [T·B])` planes and get a [`PlanesPending`] to await while other
    /// work overlaps the GAE compute.
    ///
    /// Each env column becomes one single-lane request (the dynamic
    /// batcher then coalesces columns into its leak-free padded tiles
    /// across the worker shards), and column results scatter back into
    /// `[T, B]` planes on [`PlanesPending::wait`]. Admission is
    /// backpressured, never shed — trainer iterations must all complete.
    ///
    /// **Zero-copy**: the borrowed planes are copied once into a shared
    /// [`PlaneSet`] and every column rides as a strided
    /// [`Lane::Column`] view — no per-column gather on the submitting
    /// thread. Callers that own their planes skip even that single copy
    /// via [`GaeService::submit_plane_set`].
    ///
    /// The per-column math is bit-identical to the inline
    /// [`crate::coordinator::gae_stage::run_gae_stage`] on the same
    /// backend: scalar/hwsim mask or split at dones exactly as the
    /// trainer's column splitter does, and the batcher's padding is a
    /// fixed point of the recurrence.
    pub fn submit_planes(
        &self,
        t_len: usize,
        batch: usize,
        rewards: &[f32],
        values: &[f32],
        done_mask: &[f32],
    ) -> Result<PlanesPending, ServiceError> {
        let planes = PlaneSet::new(
            t_len,
            batch,
            rewards.to_vec(),
            values.to_vec(),
            done_mask.to_vec(),
        )?;
        self.submit_plane_set(planes)
    }

    /// Zero-copy plane submission: take ownership of a validated
    /// [`PlaneSet`] (no plane copies at all — the network front-end's
    /// decode buffers land here by move) and enqueue one borrowed-column
    /// lane per env column, backpressured.
    pub fn submit_plane_set(
        &self,
        planes: PlaneSet,
    ) -> Result<PlanesPending, ServiceError> {
        self.submit_plane_set_inner(planes, true, auto_trace())
    }

    /// [`GaeService::submit_plane_set`] under a caller-supplied trace id
    /// (`0` = untraced): every column's queue → worker journey records
    /// into that request's timeline. The network front-end and the
    /// fabric use this so one id spans the whole wire-to-worker path
    /// (and survives fabric failover retries).
    pub fn submit_plane_set_traced(
        &self,
        planes: PlaneSet,
        trace: u64,
    ) -> Result<PlanesPending, ServiceError> {
        self.submit_plane_set_inner(planes, true, trace)
    }

    /// Fail-fast variant of [`GaeService::submit_plane_set`]: sheds with
    /// [`ServiceError::Overloaded`] the moment admission control refuses
    /// a column. Columns admitted before the refusal are abandoned —
    /// exactly the dropped-[`ResponseHandle`] semantics, so their
    /// results are computed and discarded (overload-path waste only).
    pub fn try_submit_plane_set(
        &self,
        planes: PlaneSet,
    ) -> Result<PlanesPending, ServiceError> {
        self.submit_plane_set_inner(planes, false, auto_trace())
    }

    /// Fail-fast plane submission under a caller-supplied trace id —
    /// the traced twin of [`GaeService::try_submit_plane_set`].
    pub fn try_submit_plane_set_traced(
        &self,
        planes: PlaneSet,
        trace: u64,
    ) -> Result<PlanesPending, ServiceError> {
        self.submit_plane_set_inner(planes, false, trace)
    }

    fn submit_plane_set_inner(
        &self,
        planes: PlaneSet,
        blocking: bool,
        trace: u64,
    ) -> Result<PlanesPending, ServiceError> {
        let (t_len, batch) = (planes.t_len, planes.batch);
        let planes = Arc::new(planes);
        let mut handles = Vec::with_capacity(batch);
        for col in 0..batch {
            let lane = Lane::Column { planes: Arc::clone(&planes), col };
            let handle = if blocking {
                self.enqueue_lanes_blocking(vec![lane], trace)?
            } else {
                self.enqueue_lanes(vec![lane], trace)?
            };
            handles.push(handle);
        }
        Ok(PlanesPending { t_len, batch, handles })
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    /// Live queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Frozen metrics view (counters, shed, latency quantiles, elem/s).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot(SnapshotInputs {
            queue_depth: self.queue.len(),
            peak_queue_depth: self.queue.peak_depth(),
            scalar_route_max_elements: self.config.scalar_route_max_elements,
        })
    }

    /// The live metrics recorder — the network front-end records its
    /// cache/quota events here so one snapshot covers the whole stack.
    pub(crate) fn metrics_handle(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Stop admitting new work **without consuming the service**: the
    /// queue closes, already-accepted requests drain through the workers
    /// (their handles still complete), and every later submission fails
    /// with [`ServiceError::ShuttingDown`]. The worker threads are
    /// joined later, on drop/[`GaeService::shutdown`]. This is the
    /// "kill one shard mid-load" seam the fabric's failover tests lean
    /// on: an `Arc`-shared service can be taken out of rotation while
    /// other shards keep serving.
    pub fn begin_shutdown(&self) {
        self.queue.close();
    }

    /// Stop admitting, drain accepted work, join the workers.
    pub fn shutdown(self) -> MetricsSnapshot {
        // Drop runs shutdown_inner; take the snapshot after the drain so
        // it includes every accepted request.
        self.shutdown_inner();
        self.metrics()
    }

    fn shutdown_inner(&self) {
        self.queue.close();
        let pool = self.pool.lock().unwrap().take();
        drop(pool); // joins the worker threads (drains the queue first)
    }
}

impl Drop for GaeService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Trace id for submissions whose caller did not supply one: mint a
/// fresh id while tracing is on (each in-process request gets its own
/// timeline), `0` (untraced) otherwise — so the disabled path stays one
/// relaxed load.
fn auto_trace() -> u64 {
    if crate::obs::enabled() {
        crate::obs::mint_trace_id()
    } else {
        0
    }
}

/// In-flight plane-shaped request set returned by
/// [`GaeService::submit_planes`]: one [`ResponseHandle`] per env column.
#[derive(Debug)]
pub struct PlanesPending {
    t_len: usize,
    batch: usize,
    handles: Vec<ResponseHandle>,
}

/// Reassembled `[T, B]` GAE planes for one trainer iteration.
#[derive(Debug, Clone)]
pub struct PlaneGae {
    /// `[T * B]` advantages, timestep-major.
    pub advantages: Vec<f32>,
    /// `[T * B]` rewards-to-go, timestep-major.
    pub rewards_to_go: Vec<f32>,
    /// Simulated cycles summed over the *distinct* coalesced batches the
    /// columns rode in (hwsim backend only): columns sharing a batch
    /// share its cycle count, so each `(worker, batch_seq)` is counted
    /// once. An aggregate work gauge, not the single-batch figure the
    /// inline stage reports.
    pub hw_cycles: Option<u64>,
}

impl From<PlaneGae> for crate::coordinator::gae_stage::GaeResult {
    /// The plane seam's results are exactly a GAE-stage result — the
    /// single conversion point the trainer, benches, and equivalence
    /// tests all share.
    fn from(p: PlaneGae) -> Self {
        crate::coordinator::gae_stage::GaeResult {
            advantages: p.advantages,
            rewards_to_go: p.rewards_to_go,
            hw_cycles: p.hw_cycles,
        }
    }
}

impl PlanesPending {
    /// Await every column and scatter the per-column outputs back into
    /// timestep-major `[T, B]` planes.
    pub fn wait(self) -> Result<PlaneGae, ServiceError> {
        let (t_len, batch) = (self.t_len, self.batch);
        let mut advantages = vec![0.0f32; t_len * batch];
        let mut rewards_to_go = vec![0.0f32; t_len * batch];
        let mut hw_cycles: Option<u64> = None;
        let mut counted: std::collections::HashSet<(usize, u64)> =
            std::collections::HashSet::new();
        for (i, handle) in self.handles.into_iter().enumerate() {
            let resp = handle.wait()?;
            let out = &resp.outputs[0];
            debug_assert_eq!(out.advantages.len(), t_len);
            for (t, (&a, &r)) in
                out.advantages.iter().zip(&out.rewards_to_go).enumerate()
            {
                advantages[t * batch + i] = a;
                rewards_to_go[t * batch + i] = r;
            }
            if let Some(c) = resp.hw_cycles {
                if counted.insert((resp.worker, resp.batch_seq)) {
                    hw_cycles = Some(hw_cycles.unwrap_or(0) + c);
                }
            }
            // The per-column vectors are dead after the scatter — this
            // is the give-back half of the response-vector recycling
            // loop (the worker's unpack holds the take half).
            for out in resp.outputs {
                crate::service::vecpool::give(out.advantages);
                crate::service::vecpool::give(out.rewards_to_go);
            }
        }
        Ok(PlaneGae { advantages, rewards_to_go, hw_cycles })
    }

    pub fn columns(&self) -> usize {
        self.handles.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::testing::Gen;

    fn request(g: &mut Gen, n: usize, t: usize) -> Vec<Trajectory> {
        crate::testing::ragged_trajectories(g.rng(), n, 1, t, 0.08)
    }

    #[test]
    fn submit_roundtrip_matches_reference() {
        let svc = GaeService::with_workers(2, GaeBackend::Batched).unwrap();
        let mut g = Gen::new(1);
        let trajs = request(&mut g, 5, 40);
        let resp = svc.submit(trajs.clone()).unwrap();
        assert_eq!(resp.outputs.len(), 5);
        for (traj, got) in trajs.iter().zip(&resp.outputs) {
            let want = gae_trajectory(&GaeParams::default(), traj);
            for t in 0..traj.len() {
                assert!((got.advantages[t] - want.advantages[t]).abs() < 1e-4);
            }
        }
        assert!(resp.elements() > 0);
        assert!(resp.timing.total >= resp.timing.queue);
    }

    #[test]
    fn empty_requests_are_rejected() {
        let svc = GaeService::with_workers(1, GaeBackend::Scalar).unwrap();
        assert_eq!(svc.submit(vec![]).unwrap_err(), ServiceError::EmptyRequest);
        let zero_len = Trajectory::without_dones(vec![], vec![0.0]);
        assert_eq!(
            svc.submit(vec![zero_len]).unwrap_err(),
            ServiceError::EmptyRequest
        );
        assert_eq!(svc.metrics().completed, 0);
    }

    #[test]
    fn blocking_submit_backpressures_instead_of_shedding() {
        // Capacity-1 queue + more concurrent blocking clients than slots:
        // everything completes, nothing sheds.
        let svc = GaeService::start(ServiceConfig {
            workers: 1,
            backend: GaeBackend::Scalar,
            queue_capacity: 1,
            ..ServiceConfig::default()
        })
        .unwrap();
        let svc_ref = &svc;
        std::thread::scope(|s| {
            for client in 0..4u64 {
                s.spawn(move || {
                    let mut g = Gen::new(50 + client);
                    for _ in 0..5 {
                        svc_ref
                            .submit_blocking(request(&mut g, 2, 12))
                            .unwrap();
                    }
                });
            }
        });
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 20);
        assert_eq!(snap.shed, 0);
        assert!(snap.peak_queue_depth <= 1);
    }

    #[test]
    fn hlo_backend_is_rejected_at_start() {
        let err = GaeService::with_workers(1, GaeBackend::Hlo).unwrap_err();
        assert!(err.to_string().contains("hwsim"), "{err}");
    }

    #[test]
    fn shutdown_drains_accepted_requests() {
        let svc = GaeService::with_workers(2, GaeBackend::Scalar).unwrap();
        let mut g = Gen::new(3);
        let handles: Vec<_> = (0..16)
            .map(|_| svc.enqueue(request(&mut g, 2, 16)).unwrap())
            .collect();
        let snap = svc.shutdown();
        assert_eq!(snap.completed, 16);
        for h in handles {
            h.wait().unwrap();
        }
    }

    #[test]
    fn submit_planes_matches_per_column_reference_bitwise() {
        // The trainer seam's contract: plane results are bit-identical
        // to the inline stage's per-column computation (masking at dones
        // equals splitting at dones, multiplications by exact 0.0/1.0).
        for backend in [GaeBackend::Scalar, GaeBackend::Batched] {
            let svc = GaeService::with_workers(3, backend).unwrap();
            let mut g = Gen::new(21);
            let (t_len, batch) = (40, 6);
            let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
            let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
            let done_mask: Vec<f32> = (0..t_len * batch)
                .map(|_| if g.bool_p(0.07) { 1.0 } else { 0.0 })
                .collect();
            let pending = svc
                .submit_planes(t_len, batch, &rewards, &values, &done_mask)
                .unwrap();
            assert_eq!(pending.columns(), batch);
            let got = pending.wait().unwrap();
            for i in 0..batch {
                let column = Trajectory::new(
                    (0..t_len).map(|t| rewards[t * batch + i]).collect(),
                    (0..=t_len).map(|t| values[t * batch + i]).collect(),
                    (0..t_len).map(|t| done_mask[t * batch + i] == 1.0).collect(),
                );
                let want = gae_trajectory(&GaeParams::default(), &column);
                for t in 0..t_len {
                    assert_eq!(
                        got.advantages[t * batch + i].to_bits(),
                        want.advantages[t].to_bits(),
                        "{backend:?} col {i} t {t}"
                    );
                    assert_eq!(
                        got.rewards_to_go[t * batch + i].to_bits(),
                        want.rewards_to_go[t].to_bits(),
                        "{backend:?} rtg col {i} t {t}"
                    );
                }
            }
        }
    }

    #[test]
    fn submit_planes_rejects_bad_shapes() {
        let svc = GaeService::with_workers(1, GaeBackend::Scalar).unwrap();
        assert!(matches!(
            svc.submit_planes(4, 2, &[0.0; 7], &[0.0; 10], &[0.0; 8]),
            Err(ServiceError::ShapeMismatch { plane: "rewards", got: 7, want: 8 })
        ));
        assert!(matches!(
            svc.submit_planes(4, 2, &[0.0; 8], &[0.0; 9], &[0.0; 8]),
            Err(ServiceError::ShapeMismatch { plane: "values", .. })
        ));
        assert!(matches!(
            svc.submit_planes(4, 2, &[0.0; 8], &[0.0; 10], &[0.0; 7]),
            Err(ServiceError::ShapeMismatch { plane: "done_mask", .. })
        ));
        assert_eq!(
            svc.submit_planes(0, 0, &[], &[], &[]).unwrap_err(),
            ServiceError::EmptyRequest
        );
    }

    #[test]
    fn planes_wait_counts_each_coalesced_batch_once() {
        use crate::gae::GaeOutput;
        use crate::service::request::RequestTiming;
        use std::time::Duration;
        // Three columns: two rode the same worker batch (cycles 100),
        // one rode its own (cycles 40). Total must be 140, not 240.
        let t_len = 2;
        let mut handles = Vec::new();
        for (worker, batch_seq, cycles) in [(0, 7, 100), (0, 7, 100), (1, 0, 40)] {
            let (tx, rx) = std::sync::mpsc::channel();
            tx.send(GaeResponse {
                id: 0,
                outputs: vec![GaeOutput {
                    advantages: vec![0.0; t_len],
                    rewards_to_go: vec![0.0; t_len],
                }],
                hw_cycles: Some(cycles),
                worker,
                batch_seq,
                timing: RequestTiming {
                    queue: Duration::ZERO,
                    batch: Duration::ZERO,
                    compute: Duration::ZERO,
                    group_compute: Duration::ZERO,
                    encode: Duration::ZERO,
                    total: Duration::ZERO,
                },
            })
            .unwrap();
            handles.push(crate::service::request::ResponseHandle { id: 0, rx });
        }
        let pending = PlanesPending { t_len, batch: 3, handles };
        assert_eq!(pending.wait().unwrap().hw_cycles, Some(140));
    }

    #[test]
    fn submit_planes_hwsim_reports_cycles() {
        let svc = GaeService::with_workers(2, GaeBackend::HwSim).unwrap();
        let t_len = 16;
        let batch = 4;
        let mut g = Gen::new(5);
        let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
        let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
        let done_mask = vec![0.0; t_len * batch];
        let got = svc
            .submit_planes(t_len, batch, &rewards, &values, &done_mask)
            .unwrap()
            .wait()
            .unwrap();
        assert!(got.hw_cycles.unwrap() > 0);
        assert_eq!(got.advantages.len(), t_len * batch);
    }

    #[test]
    fn submit_after_shutdown_reports_shutting_down() {
        let svc = GaeService::with_workers(1, GaeBackend::Scalar).unwrap();
        svc.begin_shutdown();
        let mut g = Gen::new(4);
        assert_eq!(
            svc.submit(request(&mut g, 1, 4)).unwrap_err(),
            ServiceError::ShuttingDown
        );
    }
}

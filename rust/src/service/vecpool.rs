//! Size-classed recycling pool for per-lane response vectors — the last
//! per-group allocation on the warmed worker compute path.
//!
//! Every other buffer in the group pipeline is arena-reused
//! ([`WorkerScratch`](crate::service::batcher::WorkerScratch)), but the
//! per-lane `advantages` / `rewards_to_go` vectors are the response
//! payload: they *leave* the worker inside [`GaeOutput`]s, so a scratch
//! arena cannot hold them. They come back, though — the plane seam
//! ([`PlanesPending::wait`](crate::service::PlanesPending::wait))
//! scatters each column's output into the `[T, B]` planes and then owns
//! two dead vectors per column. This pool closes that loop:
//!
//! - workers [`take`] capacity-classed vectors instead of
//!   `Vec::with_capacity` (a warmed class pops without touching the
//!   allocator),
//! - the plane seam [`give`]s the scattered-out vectors back.
//!
//! Classes are powers of two: `take(len)` draws from the class that
//! guarantees capacity ≥ `len`, `give` files by the class its capacity
//! still guarantees, so a recycled vector never reallocates when pushed
//! to its stated length. Each class is bounded ([`MAX_PER_CLASS`]) and
//! vectors above [`MAX_POOLED_CAPACITY`] are dropped, so traffic that
//! never returns vectors (trajectory clients keep their responses) or
//! one burst of giant lanes cannot pin unbounded memory — the pool
//! degrades to plain allocation, never grows past its cap.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Largest pooled capacity: 2^24 f32s (64 MiB), the wire layer's
/// [`MAX_PLANE_ELEMENTS`](crate::net::wire::MAX_PLANE_ELEMENTS) — no
/// legitimate lane is longer.
const MAX_POOLED_CAPACITY: usize = 1 << 24;
/// Class count: capacities 2^0 ..= 2^24.
const CLASSES: usize = 25;
/// Vectors kept per class; beyond this a returned vector is dropped.
/// 64 vectors × 2 planes covers a 32-lane group per class with no
/// steady-state misses, while capping worst-case pool memory.
const MAX_PER_CLASS: usize = 64;

/// Pool hit/miss counters, for tests and capacity planning.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served from a warmed class (no allocation).
    pub hits: u64,
    /// `take` calls that fell back to a fresh allocation.
    pub misses: u64,
    /// `give`n vectors dropped (class full or over the capacity cap).
    pub dropped: u64,
}

struct VecPool {
    classes: [Mutex<Vec<Vec<f32>>>; CLASSES],
    hits: AtomicU64,
    misses: AtomicU64,
    dropped: AtomicU64,
}

static POOL: VecPool = VecPool {
    classes: [const { Mutex::new(Vec::new()) }; CLASSES],
    hits: AtomicU64::new(0),
    misses: AtomicU64::new(0),
    dropped: AtomicU64::new(0),
};

/// Smallest class whose capacity (2^class) is ≥ `len`.
fn class_for_take(len: usize) -> Option<usize> {
    if len > MAX_POOLED_CAPACITY {
        return None;
    }
    Some(len.next_power_of_two().trailing_zeros() as usize)
}

/// Largest class whose capacity (2^class) the vector still guarantees.
fn class_for_give(capacity: usize) -> Option<usize> {
    if capacity == 0 {
        return None;
    }
    let class = usize::BITS as usize - 1 - capacity.leading_zeros() as usize;
    Some(class.min(CLASSES - 1))
}

/// An empty vector with capacity ≥ `len`, recycled when the class is
/// warm. Lengths above the pooled cap fall through to a plain
/// allocation.
pub fn take(len: usize) -> Vec<f32> {
    if let Some(class) = class_for_take(len) {
        if let Some(mut v) = POOL.classes[class].lock().unwrap().pop() {
            POOL.hits.fetch_add(1, Ordering::Relaxed);
            debug_assert!(v.capacity() >= len);
            v.clear();
            return v;
        }
    }
    POOL.misses.fetch_add(1, Ordering::Relaxed);
    Vec::with_capacity(len)
}

/// [`take`] resized to `len` zeros — for callers that scatter into the
/// vector by index instead of pushing.
pub fn take_zeroed(len: usize) -> Vec<f32> {
    let mut v = take(len);
    v.resize(len, 0.0);
    v
}

/// Return a dead vector to its capacity class. Oversized and
/// over-quota vectors are dropped — the pool is a bounded cache, not a
/// leak.
pub fn give(v: Vec<f32>) {
    if let Some(class) = class_for_give(v.capacity()) {
        if v.capacity() <= MAX_POOLED_CAPACITY {
            let mut slot = POOL.classes[class].lock().unwrap();
            if slot.len() < MAX_PER_CLASS {
                slot.push(v);
                return;
            }
        }
    }
    POOL.dropped.fetch_add(1, Ordering::Relaxed);
}

/// Point-in-time counters (cumulative since process start).
pub fn stats() -> PoolStats {
    PoolStats {
        hits: POOL.hits.load(Ordering::Relaxed),
        misses: POOL.misses.load(Ordering::Relaxed),
        dropped: POOL.dropped.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_capacity_always_covers_len() {
        for len in [0, 1, 2, 3, 7, 8, 9, 100, 1000, 4097] {
            let v = take(len);
            assert!(v.capacity() >= len, "len {len} got cap {}", v.capacity());
            assert!(v.is_empty());
            give(v);
        }
    }

    #[test]
    fn recycled_vector_never_reallocates_at_its_class_length() {
        // A vector given back with capacity C must serve take(len) for
        // any len ≤ the class it was filed under.
        let v = Vec::with_capacity(100); // filed under class 64
        give(v);
        let mut v = take(60); // class 64 → the 100-cap vector qualifies
        let cap = v.capacity();
        assert!(cap >= 60);
        v.resize(60, 1.0);
        assert_eq!(v.capacity(), cap, "resize within class must not reallocate");
        give(v);
    }

    #[test]
    fn zero_length_vectors_are_not_pooled() {
        let before = stats();
        give(Vec::new());
        assert_eq!(stats().dropped, before.dropped + 1);
    }

    #[test]
    fn take_zeroed_is_full_of_zeros() {
        let mut warm = take(16);
        warm.extend_from_slice(&[7.0; 16]);
        give(warm);
        let v = take_zeroed(16);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|&x| x == 0.0), "recycled contents must be cleared");
    }
}

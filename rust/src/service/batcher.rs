//! Dynamic batching: coalesce variable-length trajectories from many
//! concurrent clients into fixed `[T, B]` tiles for the backend.
//!
//! Two halves:
//!
//! - **Grouping** ([`DynamicBatcher::next_group`]): a worker blocks for
//!   the first queued request, drains whatever else is already queued,
//!   and lingers up to `max_wait` for stragglers only when that drain
//!   found concurrent traffic — small batches with zero added latency
//!   under light load, full `max_batch_lanes` groups under heavy load.
//! - **Tiling** ([`PaddedTile`]): a set of ragged trajectories becomes a
//!   timestep-major `[T, B]` tile (`T` = longest lane) with a segment
//!   mask, shaped exactly like the paper's memory-block layout so it can
//!   feed [`gae_batched`] unchanged.
//!
//! ## Padding that cannot leak
//!
//! GAE runs *backward*, so naive zero-padding at the tail of a short
//! lane would inject a spurious `-γ·V_boot` delta into the real region.
//! The pad scheme makes every pad row a fixed point of the recurrence:
//! for a lane of true length `L < T`,
//!
//! - `values[L]` keeps the lane's real bootstrap `V(s_L)` (row `L-1`'s
//!   delta needs it); rows `L+1..=T` are zero;
//! - pad rewards equal the pad-row value (`rewards[L] = V(s_L)`, zero
//!   after), so every pad delta is `r - v = 0`;
//! - the pad region is marked done (`done_mask = 1`), so no carry flows
//!   across it in either direction.
//!
//! Pad advantages are therefore exactly zero and real rows match the
//! unpadded recurrence bit-for-bit; [`PaddedTile::unpack`] then trims
//! each lane back to its true length.
//!
//! ## The `WorkerScratch` lifecycle
//!
//! Every worker shard owns one [`WorkerScratch`] for the lifetime of its
//! thread — created before the first group, never dropped until the
//! queue closes. It is the arena behind the zero-allocation steady
//! state of the compute path:
//!
//! 1. **Group intake** — the group's lanes are *moved* out of the work
//!    items into `flat` (capacity reused; the per-item `lane_count`
//!    stays behind for the response split).
//! 2. **Compute** — the batched path either runs the **slab fast path**
//!    ([`slab_of`](crate::service::plane::slab_of)) straight on the
//!    shared plane set, or repacks the ragged fallback into `tile` via
//!    [`PaddedTile::pack_lane_views`] (plane buffers cleared + resized
//!    in place). Either way the kernel writes into the `out_adv` /
//!    `out_rtg` planes; the hwsim path refills `segments` from the
//!    recycled `seg_pool` trajectory buffers.
//! 3. **Unpack** — [`unpack_lanes_into`] appends per-lane outputs onto
//!    `outs`. The per-lane vectors are the *response payload* (they
//!    leave with the reply), so no arena can hold them; instead they
//!    are drawn from the size-classed recycling pool
//!    ([`crate::service::vecpool`]) and given back by the plane seam
//!    after its `[T, B]` scatter — so a warmed worker serving
//!    plane-shaped traffic allocates nothing per group at all.
//! 4. **Reset** — `flat`, `outs`, `segments`, `lens` are cleared (not
//!    shrunk) and the next group reuses their capacity. After one
//!    maximum-shape group, per-group heap traffic on the compute path
//!    is zero.

use crate::gae::batched::GaeBatch;
use crate::gae::{GaeOutput, Trajectory};
use crate::service::plane::Lane;
use crate::service::queue::BoundedQueue;
use crate::service::request::WorkItem;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Coalescing budget: stop collecting once this many trajectory
    /// lanes are on hand (they are then cut into tiles).
    pub max_batch_lanes: usize,
    /// Lane width `B` of one `[T, B]` tile — sized for the backend
    /// (64 = the paper's row count).
    pub tile_lanes: usize,
    /// How long a worker lingers for more requests after the first.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch_lanes: 256,
            tile_lanes: 64,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// The size-or-timeout grouping policy.
#[derive(Debug, Clone, Copy)]
pub struct DynamicBatcher {
    pub config: BatcherConfig,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> Self {
        DynamicBatcher { config }
    }

    /// Block for the next request, then coalesce. `None` once the queue
    /// is closed and drained (worker shutdown).
    ///
    /// Policy: drain whatever is already queued for free, and *linger*
    /// (up to `max_wait`) only when that drain found company — i.e.
    /// traffic is demonstrably concurrent. A lone request on an idle
    /// service flushes immediately, so light load never pays the linger
    /// as a latency floor.
    pub(crate) fn next_group(&self, queue: &BoundedQueue<WorkItem>) -> Option<Vec<WorkItem>> {
        let first = queue.pop()?;
        let mut lanes = first.lane_count;
        let mut group = vec![first];
        // Free drain: everything that queued up while we were busy.
        while lanes < self.config.max_batch_lanes {
            match queue.try_pop() {
                Some(item) => {
                    lanes += item.lane_count;
                    group.push(item);
                }
                None => break,
            }
        }
        // Linger for stragglers only under concurrent traffic.
        if group.len() > 1 {
            let deadline = Instant::now() + self.config.max_wait;
            while lanes < self.config.max_batch_lanes {
                match queue.pop_deadline(deadline) {
                    Some(item) => {
                        lanes += item.lane_count;
                        group.push(item);
                    }
                    None => break,
                }
            }
        }
        Some(group)
    }
}

/// A fixed `[T, B]` tile of padded trajectories.
#[derive(Debug, Clone)]
pub struct PaddedTile {
    /// Padded timestep count `T` (the longest lane).
    pub t_len: usize,
    /// Lane count `B`.
    pub lanes: usize,
    /// `[T * B]` timestep-major rewards (pad scheme above).
    pub rewards: Vec<f32>,
    /// `[(T+1) * B]` values; row `L` of each lane keeps its bootstrap.
    pub values: Vec<f32>,
    /// `[T * B]` done mask; the pad region reads 1.0.
    pub done_mask: Vec<f32>,
    /// True (unpadded) length of each lane — the compact encoding of the
    /// segment mask (see [`PaddedTile::segment_mask`]).
    pub lens: Vec<usize>,
}

impl PaddedTile {
    /// An empty tile shell — the scratch form. Repack it per group with
    /// [`PaddedTile::pack_lane_views`]; the plane buffers keep their
    /// capacity across repacks.
    pub fn empty() -> PaddedTile {
        PaddedTile {
            t_len: 0,
            lanes: 0,
            rewards: Vec::new(),
            values: Vec::new(),
            done_mask: Vec::new(),
            lens: Vec::new(),
        }
    }

    /// Tile up a set of ragged lanes (at least one, each of length ≥ 0).
    pub fn from_lanes(trajs: &[&Trajectory]) -> PaddedTile {
        Self::build(
            trajs.len(),
            |i| trajs[i].len(),
            |i, t| trajs[i].rewards[t],
            |i, t| trajs[i].values[t],
            |i, t| trajs[i].dones[t],
        )
    }

    /// The same tiling over service [`Lane`]s (owned trajectories or
    /// borrowed plane columns), allocating a fresh tile per call — the
    /// seed-shaped gather the scratch path ([`PaddedTile::pack_lane_views`])
    /// exists to retire; kept as the baseline the `worker_hotpath`
    /// bench measures against.
    pub fn from_lane_views(lanes: &[Lane]) -> PaddedTile {
        let mut tile = PaddedTile::empty();
        tile.pack_lane_views(lanes);
        tile
    }

    /// Scratch-path tiling: repack `lanes` into `self` in place, reusing
    /// the plane buffers' capacity — zero allocations once warm. This is
    /// the worker's ragged fallback when [`slab_of`](crate::service::plane::slab_of)
    /// finds no resident slab.
    pub fn pack_lane_views(&mut self, lanes: &[Lane]) {
        self.rebuild(
            lanes.len(),
            |i| lanes[i].len(),
            |i, t| lanes[i].reward(t),
            |i, t| lanes[i].value(t),
            |i, t| lanes[i].done(t),
        );
    }

    /// Shared tile construction over indexed accessors: lane `i` has
    /// `len_of(i)` steps, `reward(i, t)` / `done(i, t)` for `t < len`,
    /// `value(i, t)` for `t <= len`.
    fn build(
        n: usize,
        len_of: impl Fn(usize) -> usize,
        reward: impl Fn(usize, usize) -> f32,
        value: impl Fn(usize, usize) -> f32,
        done: impl Fn(usize, usize) -> bool,
    ) -> PaddedTile {
        let mut tile = PaddedTile::empty();
        tile.rebuild(n, len_of, reward, value, done);
        tile
    }

    /// In-place form of [`PaddedTile::build`]: clears and resizes the
    /// plane buffers (capacity reused), then fills exactly as the
    /// allocating path does — the two are bit-identical by construction.
    fn rebuild(
        &mut self,
        n: usize,
        len_of: impl Fn(usize) -> usize,
        reward: impl Fn(usize, usize) -> f32,
        value: impl Fn(usize, usize) -> f32,
        done: impl Fn(usize, usize) -> bool,
    ) {
        assert!(n > 0, "a tile needs at least one lane");
        let lanes = n;
        let t_len = (0..n).map(&len_of).max().unwrap();
        self.t_len = t_len;
        self.lanes = lanes;
        self.rewards.clear();
        self.rewards.resize(t_len * lanes, 0.0);
        self.values.clear();
        self.values.resize((t_len + 1) * lanes, 0.0);
        self.done_mask.clear();
        self.done_mask.resize(t_len * lanes, 0.0);
        self.lens.clear();
        let (rewards, values, done_mask) =
            (&mut self.rewards, &mut self.values, &mut self.done_mask);
        for i in 0..n {
            let len = len_of(i);
            self.lens.push(len);
            for t in 0..len {
                rewards[t * lanes + i] = reward(i, t);
                done_mask[t * lanes + i] = if done(i, t) { 1.0 } else { 0.0 };
            }
            for t in 0..=len {
                values[t * lanes + i] = value(i, t);
            }
            // Pad region: done everywhere; the first pad row repeats the
            // bootstrap as its reward so its delta is exactly zero.
            if len < t_len {
                rewards[len * lanes + i] = value(i, len);
                for t in len..t_len {
                    done_mask[t * lanes + i] = 1.0;
                }
            }
        }
    }

    /// Materialize the `[T * B]` segment mask (1.0 = real element, 0.0 =
    /// padding). `lens` encodes it compactly; the full plane is only
    /// built on demand (diagnostics, masked consumers) — never on the
    /// serving hot path.
    pub fn segment_mask(&self) -> Vec<f32> {
        let mut mask = vec![0.0f32; self.t_len * self.lanes];
        for (i, &len) in self.lens.iter().enumerate() {
            for t in 0..len {
                mask[t * self.lanes + i] = 1.0;
            }
        }
        mask
    }

    /// Borrow-and-copy view as the batched backend's input type (tests
    /// and callers that keep the tile; the hot path uses
    /// [`PaddedTile::into_parts`]).
    pub fn to_gae_batch(&self) -> GaeBatch {
        GaeBatch {
            t_len: self.t_len,
            batch: self.lanes,
            rewards: self.rewards.clone(),
            values: self.values.clone(),
            done_mask: self.done_mask.clone(),
        }
    }

    /// Consume the tile into the batched backend's input plus the
    /// per-lane lengths needed to trim its output — zero plane copies.
    pub fn into_parts(self) -> (GaeBatch, Vec<usize>) {
        (
            GaeBatch {
                t_len: self.t_len,
                batch: self.lanes,
                rewards: self.rewards,
                values: self.values,
                done_mask: self.done_mask,
            },
            self.lens,
        )
    }

    /// Trim a `[T, B]` batched output back to per-lane outputs of the
    /// original lengths (input order).
    pub fn unpack(&self, out: &GaeOutput) -> Vec<GaeOutput> {
        unpack_lanes(&self.lens, self.lanes, out)
    }

    /// Real (unpadded) element count.
    pub fn real_elements(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Tile element count including padding.
    pub fn padded_elements(&self) -> usize {
        self.t_len * self.lanes
    }

    /// Fraction of the tile that is padding (a batcher efficiency gauge).
    pub fn pad_fraction(&self) -> f64 {
        let padded = self.padded_elements();
        if padded == 0 {
            0.0
        } else {
            1.0 - self.real_elements() as f64 / padded as f64
        }
    }
}

/// Trim a `[T, B]` batched output (`lanes` = B) back to per-lane
/// outputs of the given true lengths, input order.
pub fn unpack_lanes(lens: &[usize], lanes: usize, out: &GaeOutput) -> Vec<GaeOutput> {
    let mut outs = Vec::with_capacity(lens.len());
    unpack_lanes_into(lens, lanes, &out.advantages, &out.rewards_to_go, &mut outs);
    outs
}

/// Scratch-path unpack: append per-lane outputs (trimmed to their true
/// lengths, input order) onto `outs` from dense `[T, B]` advantage /
/// rewards-to-go planes. The per-lane vectors are the response payload
/// and leave with the reply, so they come from the size-classed
/// recycling pool ([`crate::service::vecpool`]): warm classes serve
/// them without touching the allocator, and the plane seam returns
/// them after scattering.
pub fn unpack_lanes_into(
    lens: &[usize],
    lanes: usize,
    adv: &[f32],
    rtg: &[f32],
    outs: &mut Vec<GaeOutput>,
) {
    for (i, &len) in lens.iter().enumerate() {
        let mut advantages = crate::service::vecpool::take(len);
        let mut rewards_to_go = crate::service::vecpool::take(len);
        for t in 0..len {
            advantages.push(adv[t * lanes + i]);
            rewards_to_go.push(rtg[t * lanes + i]);
        }
        outs.push(GaeOutput { advantages, rewards_to_go });
    }
}

/// Reusable per-worker arena for the group compute path — see the
/// module docs for the full lifecycle. Public so the `worker_hotpath`
/// bench can drive the exact buffers the worker reuses.
pub struct WorkerScratch {
    /// The group's lanes, moved out of the work items (flattened, group
    /// order) so the tile chunking sees one contiguous slice.
    pub(crate) flat: Vec<Lane>,
    /// Packed-tile planes for the ragged fallback path.
    pub tile: PaddedTile,
    /// Dense `[T, W]` advantage plane the batched kernel writes into.
    pub out_adv: Vec<f32>,
    /// Dense `[T, W]` rewards-to-go plane.
    pub out_rtg: Vec<f32>,
    /// Per-lane true lengths handed to the unpack (slab path: all equal).
    pub(crate) lens: Vec<usize>,
    /// Per-lane outputs of one group, drained into the responses.
    pub(crate) outs: Vec<GaeOutput>,
    /// hwsim episode segments of the current group.
    pub(crate) segments: Vec<Trajectory>,
    /// `(lane, start, len)` of each segment, for stitching results back.
    pub(crate) seg_index: Vec<(usize, usize, usize)>,
    /// Recycled trajectory buffers behind `segments` — refilled by the
    /// splitter, drained back after each simulate call.
    pub(crate) seg_pool: Vec<Trajectory>,
}

impl WorkerScratch {
    pub fn new() -> WorkerScratch {
        WorkerScratch {
            flat: Vec::new(),
            tile: PaddedTile::empty(),
            out_adv: Vec::new(),
            out_rtg: Vec::new(),
            lens: Vec::new(),
            outs: Vec::new(),
            segments: Vec::new(),
            seg_index: Vec::new(),
            seg_pool: Vec::new(),
        }
    }
}

impl Default for WorkerScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::GaeParams;
    use crate::gae::batched::gae_batched;
    use crate::testing::{check, Gen};

    fn ragged_lanes(g: &mut Gen, n: usize, max_t: usize) -> Vec<Trajectory> {
        crate::testing::ragged_trajectories(g.rng(), n, 1, max_t, 0.1)
    }

    #[test]
    fn padding_never_leaks_into_real_rows() {
        check("padded tile == per-trajectory reference", 30, |g| {
            let trajs = ragged_lanes(g, g.usize_in(1, 12), 32);
            let refs: Vec<&Trajectory> = trajs.iter().collect();
            // The worker's exact hot path: consume the tile, no copies.
            let tile = PaddedTile::from_lanes(&refs);
            let (batch, lens) = tile.into_parts();
            let out = gae_batched(&GaeParams::default(), &batch);
            let per_lane = unpack_lanes(&lens, batch.batch, &out);
            for (traj, got) in trajs.iter().zip(&per_lane) {
                let want = gae_trajectory(&GaeParams::default(), traj);
                assert_eq!(got.advantages.len(), traj.len());
                for t in 0..traj.len() {
                    assert!(
                        (got.advantages[t] - want.advantages[t]).abs() < 1e-4,
                        "adv t={t}: {} vs {}",
                        got.advantages[t],
                        want.advantages[t]
                    );
                    assert!(
                        (got.rewards_to_go[t] - want.rewards_to_go[t]).abs() < 1e-4
                    );
                }
            }
        });
    }

    #[test]
    fn pad_rows_compute_to_exactly_zero_advantage() {
        let short = Trajectory::without_dones(vec![1.0, -2.0], vec![0.5, 1.5, 7.0]);
        let long = Trajectory::without_dones(
            vec![0.1; 6],
            vec![0.2; 7],
        );
        let tile = PaddedTile::from_lanes(&[&short, &long]);
        assert_eq!(tile.t_len, 6);
        let out = gae_batched(&GaeParams::default(), &tile.to_gae_batch());
        // Lane 0 pad region: rows 2..6 must be exactly zero.
        for t in 2..6 {
            assert_eq!(out.advantages[t * 2], 0.0, "pad row {t} leaked");
        }
        // The bootstrap row is preserved where the real recurrence reads it.
        assert_eq!(tile.values[2 * 2], 7.0);
    }

    #[test]
    fn mask_and_lens_agree() {
        let a = Trajectory::without_dones(vec![0.0; 3], vec![0.0; 4]);
        let b = Trajectory::without_dones(vec![0.0; 5], vec![0.0; 6]);
        let tile = PaddedTile::from_lanes(&[&a, &b]);
        assert_eq!(tile.lens, vec![3, 5]);
        assert_eq!(tile.real_elements(), 8);
        assert_eq!(tile.padded_elements(), 10);
        assert!((tile.pad_fraction() - 0.2).abs() < 1e-12);
        let mask = tile.segment_mask();
        let mask_sum: f32 = mask.iter().sum();
        assert_eq!(mask_sum as usize, 8);
        assert_eq!(mask[2 * 2], 1.0); // row 2, lane 0: last real element
        assert_eq!(mask[3 * 2], 0.0); // row 3, lane 0: padding
        // Pad region is marked done so credit cannot flow across it.
        assert_eq!(tile.done_mask[3 * 2], 1.0);
        assert_eq!(tile.done_mask[4 * 2], 1.0);
        assert_eq!(tile.done_mask[4 * 2 + 1], 0.0);
    }

    #[test]
    fn equal_length_lanes_have_no_padding() {
        let a = Trajectory::without_dones(vec![1.0; 4], vec![0.0; 5]);
        let b = Trajectory::without_dones(vec![2.0; 4], vec![0.0; 5]);
        let tile = PaddedTile::from_lanes(&[&a, &b]);
        assert_eq!(tile.pad_fraction(), 0.0);
        assert!(tile.segment_mask().iter().all(|&m| m == 1.0));
    }

    #[test]
    fn pack_lane_views_matches_the_allocating_build_after_reuse() {
        check("repacked tile == fresh tile (bitwise)", 20, |g| {
            let trajs = ragged_lanes(g, g.usize_in(1, 10), 24);
            let refs: Vec<&Trajectory> = trajs.iter().collect();
            let want = PaddedTile::from_lanes(&refs);
            let owned: Vec<Lane> = trajs.iter().cloned().map(Lane::Owned).collect();
            // Warm the scratch tile with a differently-shaped group
            // first: the repack must fully overwrite stale state.
            let warm = ragged_lanes(g, 3, 40);
            let warm_lanes: Vec<Lane> =
                warm.iter().cloned().map(Lane::Owned).collect();
            let mut tile = PaddedTile::empty();
            tile.pack_lane_views(&warm_lanes);
            tile.pack_lane_views(&owned);
            assert_eq!((tile.t_len, tile.lanes), (want.t_len, want.lanes));
            assert_eq!(tile.lens, want.lens);
            for (planes, want_planes) in [
                (&tile.rewards, &want.rewards),
                (&tile.values, &want.values),
                (&tile.done_mask, &want.done_mask),
            ] {
                assert_eq!(planes.len(), want_planes.len());
                for (a, b) in planes.iter().zip(want_planes) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        });
    }

    #[test]
    fn unpack_lanes_into_appends_exactly_what_unpack_returns() {
        let mut g = Gen::new(5);
        let trajs = ragged_lanes(&mut g, 6, 20);
        let refs: Vec<&Trajectory> = trajs.iter().collect();
        let tile = PaddedTile::from_lanes(&refs);
        let out = gae_batched(&GaeParams::default(), &tile.to_gae_batch());
        let want = tile.unpack(&out);
        let mut outs = Vec::new();
        unpack_lanes_into(
            &tile.lens,
            tile.lanes,
            &out.advantages,
            &out.rewards_to_go,
            &mut outs,
        );
        assert_eq!(outs, want);
    }

}

//! GAE-as-a-service: a production serving subsystem with dynamic
//! batching, sharded workers, and admission control.
//!
//! The paper's single-SoC design exists to kill communication latency in
//! the GAE stage; this module is the deployment story around it — the
//! "multiple custom hardware components on one SoC" usage of §I, grown
//! into a multi-tenant service that many concurrent clients drive with
//! variable-length trajectory batches.
//!
//! ## Architecture
//!
//! ```text
//!  clients ──► GaeService::submit / submit_many / enqueue (fail-fast)
//!              GaeService::submit_blocking / enqueue_blocking (backpressure)
//!                 │   admission control: shed when depth == limit
//!                 ▼
//!          BoundedQueue<WorkItem>           (queue.rs — MPMC, bounded,
//!                 │                          backpressure or fail-fast)
//!      ┌──────────┼──────────┐
//!      ▼          ▼          ▼
//!   worker 0   worker 1 …  worker N-1       (worker.rs — each shard owns
//!      │          │          │               a private backend instance:
//!      │  DynamicBatcher per shard           scalar | batched | GaeHwSim)
//!      │  size-or-timeout coalescing
//!      ▼          ▼          ▼
//!   PaddedTile [T, B] tiles + segment masks (batcher.rs — leak-free
//!      │          │          │               padding, reuses the
//!      ▼          ▼          ▼               gae_stage split logic)
//!   GaeResponse per request ──► ResponseHandle / blocking wait
//!
//!   ServiceMetrics (metrics.rs): counters, shed count, queue gauges,
//!   log-binned latency histograms → p50/p95/p99, sustained elem/s.
//! ```
//!
//! Design rules:
//!
//! - **Admission control beats collapse** — a bounded queue sheds
//!   ([`ServiceError::Overloaded`]) instead of growing an unbounded
//!   backlog; clients see the overload immediately and can back off.
//! - **Batching is where throughput lives** — workers coalesce requests
//!   (size-or-timeout) and cut them into fixed `[T, B]` tiles shaped
//!   like the paper's memory-block layout, so the batched engine and the
//!   simulated row array stay fed under ragged real-world traffic.
//! - **Shards share nothing on the compute path** — each worker owns its
//!   backend (its own [`GaeHwSim`](crate::hwsim::GaeHwSim) row array for
//!   `hwsim`), so N workers scale like N accelerator instances.
//! - **Plane submissions are zero-copy** — `[T, B]` plane sets ride as
//!   one shared [`PlaneSet`] and per-column [`Lane::Column`] strided
//!   views (plane.rs), never gathered on the submitting thread; the
//!   network front-end ([`crate::net`]) moves its decode buffers
//!   straight into this path.
//! - **The compute path is plane-resident and allocation-free in steady
//!   state** — tiles whose lanes are consecutive columns of one shared
//!   plane set take the **slab fast path** ([`slab_of`]): the batched
//!   recurrence runs directly on the resident strided planes, zero bytes
//!   gathered. Ragged tiles repack into the worker's long-lived
//!   [`WorkerScratch`] arena (batcher.rs), so after warm-up neither path
//!   allocates plane-sized buffers per group; the split is observable as
//!   `slab_tiles` / `packed_tiles` / `gathered_bytes` in the snapshot.
//! - **Small groups route to the scalar loop** — see
//!   [`ServiceConfig::scalar_route_max_elements`].
//!
//! Entry points: [`GaeService::start`] with a [`ServiceConfig`], then
//! [`GaeService::submit`] (sync, fail-fast), [`GaeService::submit_blocking`]
//! (sync, backpressured), [`GaeService::submit_many`] (pipelined), or
//! [`GaeService::enqueue`] / [`GaeService::enqueue_blocking`] (async
//! handle). The load
//! generator in `examples/serve_gae.rs` and the
//! `benches/service_throughput.rs` sweep drive exactly this API.

pub mod batcher;
pub mod metrics;
pub mod plane;
pub mod queue;
pub mod request;
pub mod server;
pub mod vecpool;
pub mod worker;

pub use batcher::{BatcherConfig, DynamicBatcher, PaddedTile, WorkerScratch};
pub use metrics::{
    LatencyQuantiles, MetricsSnapshot, ServiceMetrics, SnapshotInputs, TenantSnapshot,
    WindowView,
};
pub use plane::{slab_of, Lane, PlaneSet, Slab};
pub use queue::{BoundedQueue, PushError};
pub use request::{GaeResponse, RequestTiming, ResponseHandle, ServiceError};
pub use server::{GaeService, PlaneGae, PlanesPending, ServiceConfig};

//! Request/response types of the GAE serving subsystem.
//!
//! A request is a set of (variable-length) trajectories from one
//! client; the response carries one [`GaeOutput`] per input trajectory,
//! in input order, plus per-phase timing and — on the `hwsim` backend —
//! the simulated accelerator cycles of the coalesced batch the request
//! rode in.

use crate::gae::GaeOutput;
use crate::service::plane::Lane;
use std::fmt;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Per-phase timing of one request's trip through the service: the
/// full queue → batch → compute → encode breakdown that
/// [`MetricsSnapshot`](crate::service::metrics::MetricsSnapshot)
/// histograms per phase.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// Enqueue → picked up by a worker (queueing delay).
    pub queue: Duration,
    /// Pickup → backend compute start: the batch-assembly wait, i.e.
    /// the time this request's coalesced group spent being gathered
    /// into tiles before the backend ran.
    pub batch: Duration,
    /// This request's share of the coalesced group's backend compute,
    /// pro-rated by element count. The whole group computes at once, so
    /// attributing [`RequestTiming::group_compute`] to every member
    /// would multiply-count the same wall time in any aggregate.
    pub compute: Duration,
    /// Backend compute of the entire coalesced group this request rode
    /// in — identical for every member of the group. The service-level
    /// compute histogram records this once per group, not per request.
    pub group_compute: Duration,
    /// Response-encode time. Zero for in-process submissions (the
    /// response is moved, not encoded); the network front-end measures
    /// its wire encode separately and records it into the encode
    /// histogram, since the worker has already sent this struct by the
    /// time the frame is built.
    pub encode: Duration,
    /// Enqueue → response sent.
    pub total: Duration,
}

/// A completed GAE request.
#[derive(Debug, Clone)]
pub struct GaeResponse {
    /// Service-assigned request id (monotonic per service).
    pub id: u64,
    /// One output per input trajectory, input order.
    pub outputs: Vec<GaeOutput>,
    /// Simulated accelerator cycles of the batch (hwsim backend only).
    pub hw_cycles: Option<u64>,
    /// Index of the worker shard that served the request.
    pub worker: usize,
    /// Sequence number of the coalesced batch within that worker —
    /// `(worker, batch_seq)` uniquely identifies the batch this request
    /// rode in, so aggregators can count shared-batch figures (like
    /// `hw_cycles`) exactly once.
    pub batch_seq: u64,
    pub timing: RequestTiming,
}

impl GaeResponse {
    /// Total GAE elements computed for this request.
    pub fn elements(&self) -> usize {
        self.outputs.iter().map(|o| o.advantages.len()).sum()
    }
}

/// Client-visible service failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// Admission control shed the request: queue depth was at the limit.
    Overloaded { depth: usize, limit: usize },
    /// The request held no trajectories, or a zero-length trajectory.
    EmptyRequest,
    /// The service is shutting down (or died before replying).
    ShuttingDown,
    /// Deadline passed while waiting on a [`ResponseHandle`].
    Timeout,
    /// The configured backend cannot run inside the service.
    UnsupportedBackend(String),
    /// A plane-shaped submission's buffer length disagrees with its
    /// declared `[T, B]` geometry.
    ShapeMismatch { plane: &'static str, got: usize, want: usize },
    /// A plane-shaped submission's done mask holds a value other than
    /// exactly 0.0 / 1.0 at `index`. The mask feeds the branch-free
    /// kernels as `1 - mask`, so anything non-binary would silently
    /// leak fractional bootstrap credit.
    NonBinaryDoneMask { index: usize },
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::Overloaded { depth, limit } => write!(
                f,
                "service overloaded: queue depth {depth} at limit {limit}; request shed"
            ),
            ServiceError::EmptyRequest => {
                f.write_str("request must hold at least one non-empty trajectory")
            }
            ServiceError::ShuttingDown => f.write_str("service is shutting down"),
            ServiceError::Timeout => f.write_str("timed out waiting for a response"),
            ServiceError::UnsupportedBackend(b) => {
                write!(f, "backend {b:?} is not servable (use scalar, batched, or hwsim)")
            }
            ServiceError::ShapeMismatch { plane, got, want } => write!(
                f,
                "plane {plane:?} holds {got} elements, geometry implies {want}"
            ),
            ServiceError::NonBinaryDoneMask { index } => write!(
                f,
                "done_mask[{index}] is not exactly 0.0 or 1.0; plane submissions \
                 require a strict binary mask"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Handle to a pending response (returned by `GaeService::enqueue`).
/// Dropping it abandons the request; the worker's send is ignored.
#[derive(Debug)]
pub struct ResponseHandle {
    pub id: u64,
    pub(crate) rx: mpsc::Receiver<GaeResponse>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<GaeResponse, ServiceError> {
        self.rx.recv().map_err(|_| ServiceError::ShuttingDown)
    }

    /// Block up to `timeout`.
    pub fn wait_timeout(self, timeout: Duration) -> Result<GaeResponse, ServiceError> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => ServiceError::Timeout,
            mpsc::RecvTimeoutError::Disconnected => ServiceError::ShuttingDown,
        })
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<GaeResponse> {
        self.rx.try_recv().ok()
    }
}

/// Internal queue entry: the request's lanes (owned trajectories or
/// borrowed plane columns) plus its reply channel.
pub(crate) struct WorkItem {
    pub id: u64,
    pub lanes: Vec<Lane>,
    /// Cached `lanes.len()` — the batcher's lane budget unit.
    pub lane_count: usize,
    pub enqueued_at: Instant,
    /// Request-scoped trace id ([`crate::obs`]); `0` = untraced. Rides
    /// the item through queue → batcher → worker so worker-side spans
    /// join the submitting request's timeline.
    pub trace: u64,
    pub tx: mpsc::Sender<GaeResponse>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_messages_are_actionable() {
        let e = ServiceError::Overloaded { depth: 128, limit: 128 };
        let s = e.to_string();
        assert!(s.contains("128") && s.contains("shed"), "{s}");
        assert!(ServiceError::UnsupportedBackend("hlo".into())
            .to_string()
            .contains("hwsim"));
        let s = ServiceError::ShapeMismatch { plane: "values", got: 9, want: 10 }
            .to_string();
        assert!(s.contains("values") && s.contains('9') && s.contains("10"), "{s}");
    }

    #[test]
    fn handle_reports_disconnect_as_shutdown() {
        let (tx, rx) = mpsc::channel::<GaeResponse>();
        drop(tx);
        let h = ResponseHandle { id: 1, rx };
        assert_eq!(h.wait().unwrap_err(), ServiceError::ShuttingDown);
    }

    #[test]
    fn handle_delivers_buffered_response_after_sender_drop() {
        let (tx, rx) = mpsc::channel::<GaeResponse>();
        tx.send(GaeResponse {
            id: 9,
            outputs: vec![],
            hw_cycles: None,
            worker: 0,
            batch_seq: 0,
            timing: RequestTiming {
                queue: Duration::ZERO,
                batch: Duration::ZERO,
                compute: Duration::ZERO,
                group_compute: Duration::ZERO,
                encode: Duration::ZERO,
                total: Duration::ZERO,
            },
        })
        .unwrap();
        drop(tx);
        let h = ResponseHandle { id: 9, rx };
        assert_eq!(h.wait().unwrap().id, 9);
    }
}

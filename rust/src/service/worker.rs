//! Sharded workers: each owns a private backend instance and competes
//! for requests on the shared queue (work-stealing MPMC, the software
//! analogue of the paper's round-robin row dispatch — a free shard takes
//! the next request the moment it drains).
//!
//! A worker's loop: block for a request, linger-coalesce into a group
//! ([`DynamicBatcher`]), compute the whole group on its own backend, and
//! answer every request in the group. Because each worker owns its
//! backend — a scalar loop, a batched-CPU engine, or a private
//! [`GaeHwSim`] instance — N workers model N independent accelerator
//! row-arrays on one SoC, with zero shared state on the compute path.
//!
//! Lanes arrive as [`Lane`]s — owned trajectories or borrowed columns
//! of a shared plane set (the zero-copy submission path) — and are read
//! through the lane accessors, so neither representation is gathered
//! until (and unless) a backend needs a contiguous layout.
//!
//! **Slab fast path**: when a tile's lanes are consecutive columns of
//! one shared [`PlaneSet`](crate::service::plane::PlaneSet) (detected by
//! [`slab_of`]) — the shape `submit_plane_set` and the net server's
//! decode buffers arrive in — the batched backward recurrence runs
//! *directly on the resident strided planes*: zero plane bytes gathered,
//! zero allocations. Ragged or mixed tiles fall back to the packed
//! [`PaddedTile`](crate::service::batcher::PaddedTile), repacked into
//! the worker's [`WorkerScratch`] so even the fallback allocates nothing
//! once warm. Both paths are bit-identical to the scalar reference (the
//! per-lane float expressions are the same); the split is counted in the
//! metrics (`slab_tiles` / `packed_tiles` / `gathered_bytes`).
//!
//! **Size-threshold routing**: when
//! [`ServiceConfig::scalar_route_max_elements`](crate::service::ServiceConfig)
//! is nonzero, coalesced groups at or below that many GAE elements run
//! the scalar loop instead of the configured backend — small groups
//! don't amortize tile packing or the simulator's loader pipeline, so
//! routing them to the plain loop is strictly cheaper. Routed groups
//! are counted in the metrics (`routed_small`) and report no `hw_cycles`.

use crate::coordinator::gae_stage::{split_at_dones_with, GaeBackend};
use crate::gae::batched::gae_batched_strided_into;
use crate::gae::reference::gae_indexed_into;
use crate::gae::{GaeOutput, GaeParams};
use crate::hwsim::GaeHwSim;
use crate::service::batcher::{unpack_lanes_into, DynamicBatcher, WorkerScratch};
use crate::service::metrics::ServiceMetrics;
use crate::service::plane::{slab_of, Lane};
use crate::service::queue::BoundedQueue;
use crate::service::request::{GaeResponse, RequestTiming, WorkItem};
use std::sync::Arc;
use std::time::Instant;

/// Everything one worker shard needs (moved into its thread).
pub(crate) struct WorkerContext {
    pub index: usize,
    pub backend: GaeBackend,
    pub params: GaeParams,
    /// Private accelerator model (hwsim backend only).
    pub sim: Option<GaeHwSim>,
    pub batcher: DynamicBatcher,
    /// Size-threshold routing: groups of at most this many elements run
    /// the scalar loop (0 disables routing).
    pub scalar_route_max_elements: usize,
    pub queue: Arc<BoundedQueue<WorkItem>>,
    pub metrics: Arc<ServiceMetrics>,
}

/// Run until the queue is closed and drained. The scratch arena lives
/// for the whole loop: after one maximum-shape group its buffers stop
/// growing and per-group heap traffic on the compute path is zero.
pub(crate) fn worker_loop(ctx: WorkerContext) {
    let mut scratch = WorkerScratch::new();
    let mut batch_seq = 0u64;
    while let Some(group) = ctx.batcher.next_group(&ctx.queue) {
        process_group(&ctx, &mut scratch, group, batch_seq);
        batch_seq += 1;
    }
}

fn process_group(
    ctx: &WorkerContext,
    scratch: &mut WorkerScratch,
    mut group: Vec<WorkItem>,
    batch_seq: u64,
) {
    let picked_at = Instant::now();
    // One span per coalesced group, on the first traced member's
    // timeline (groups mix requests; the batch itself has no id of its
    // own). Per-member attribution rides the `worker.compute` instants.
    let group_trace = group.iter().map(|i| i.trace).find(|&t| t != 0).unwrap_or(0);
    let _batch_span = crate::obs::span("worker.batch", group_trace);
    // Move (not gather) every item's lanes into the reusable flat list;
    // `lane_count` stays behind on the item for the response split.
    let mut flat = std::mem::take(&mut scratch.flat);
    debug_assert!(flat.is_empty());
    for item in &mut group {
        flat.append(&mut item.lanes);
    }
    let total_lanes = flat.len();
    let group_elements: usize = flat.iter().map(|l| l.len()).sum();

    scratch.outs.clear();
    let compute_start = Instant::now();
    let hw_cycles = compute_lanes(ctx, scratch, &flat);
    let compute = compute_start.elapsed();
    // Dropping the lanes releases the clients' plane references; the
    // flat list itself keeps its capacity for the next group.
    flat.clear();
    scratch.flat = flat;

    // The group's compute is recorded once here; per-item timings below
    // carry their pro-rata share (see RequestTiming::compute).
    ctx.metrics.record_batch(total_lanes, hw_cycles, compute);

    let mut outputs = std::mem::take(&mut scratch.outs);
    debug_assert_eq!(outputs.len(), total_lanes);
    // Hand each request its slice of the lane outputs, input order.
    for item in group {
        let item_outputs: Vec<GaeOutput> = outputs.drain(..item.lane_count).collect();
        let elements: usize = item_outputs.iter().map(|o| o.advantages.len()).sum();
        let share = if group_elements == 0 {
            0.0
        } else {
            elements as f64 / group_elements as f64
        };
        if item.trace != 0 {
            crate::obs::instant("worker.compute", item.trace);
        }
        let timing = RequestTiming {
            queue: picked_at.duration_since(item.enqueued_at),
            batch: compute_start.duration_since(picked_at),
            compute: compute.mul_f64(share),
            group_compute: compute,
            // The worker never encodes; the net front-end records its
            // wire encode into the histogram directly.
            encode: std::time::Duration::ZERO,
            total: item.enqueued_at.elapsed(),
        };
        ctx.metrics.record_completion(elements, &timing, item.trace);
        // The client may have dropped its handle; a failed send is fine.
        let _ = item.tx.send(GaeResponse {
            id: item.id,
            outputs: item_outputs,
            hw_cycles,
            worker: ctx.index,
            batch_seq,
            timing,
        });
    }
    debug_assert!(outputs.is_empty(), "every lane output must be consumed");
    scratch.outs = outputs;
}

/// The scalar loop over one lane (owned or strided column) — delegates
/// to the shared indexed kernel, so the bits match [`gae_trajectory`]
/// (crate::gae::reference::gae_trajectory) on the gathered equivalent.
fn gae_lane(params: &GaeParams, lane: &Lane) -> GaeOutput {
    // Output vectors come from the recycling pool, like the batched
    // path's unpack — the scalar route is the small-group fast path and
    // must not reintroduce per-lane allocator traffic.
    let mut out = GaeOutput {
        advantages: crate::service::vecpool::take(lane.len()),
        rewards_to_go: crate::service::vecpool::take(lane.len()),
    };
    gae_indexed_into(
        params,
        lane.len(),
        |t| lane.reward(t),
        |t| lane.value(t),
        |t| lane.done(t),
        &mut out.advantages,
        &mut out.rewards_to_go,
    );
    out
}

/// Pick the backend for one coalesced group: the configured one, unless
/// size-threshold routing sends a small group to the scalar loop.
fn route_backend(ctx: &WorkerContext, lanes: &[Lane]) -> GaeBackend {
    if ctx.scalar_route_max_elements > 0 && ctx.backend != GaeBackend::Scalar {
        let elements: usize = lanes.iter().map(|l| l.len()).sum();
        if elements <= ctx.scalar_route_max_elements {
            ctx.metrics.record_routed_small();
            return GaeBackend::Scalar;
        }
    }
    ctx.backend
}

/// Compute GAE for a flat list of lanes on this worker's backend,
/// appending one output per lane (input order) onto `scratch.outs`.
/// Returns the simulated cycle count of the coalesced batch (hwsim
/// backend only) and records the slab/packed tile split in the metrics.
fn compute_lanes(
    ctx: &WorkerContext,
    scratch: &mut WorkerScratch,
    lanes: &[Lane],
) -> Option<u64> {
    match route_backend(ctx, lanes) {
        GaeBackend::Scalar => {
            // The per-trajectory CPU loop — the baseline shape.
            for lane in lanes {
                scratch.outs.push(gae_lane(&ctx.params, lane));
            }
            None
        }
        GaeBackend::Batched | GaeBackend::Hlo => {
            // Fixed [T, B] tiles through the timestep-major engine. (Hlo
            // is rejected at service start; the arm keeps the match total.)
            let width = ctx.batcher.config.tile_lanes.max(1);
            let (mut slab_tiles, mut packed_tiles, mut gathered_bytes) = (0u64, 0u64, 0u64);
            let WorkerScratch { tile, out_adv, out_rtg, lens, outs, .. } = scratch;
            for tile_set in lanes.chunks(width) {
                if let Some(slab) = slab_of(tile_set) {
                    // Slab fast path: the recurrence runs directly on the
                    // shared plane set's strided columns — nothing copied.
                    let t_len = slab.planes.t_len;
                    gae_batched_strided_into(
                        &ctx.params,
                        t_len,
                        slab.width,
                        slab.planes.batch,
                        slab.rewards(),
                        slab.values(),
                        slab.done_mask(),
                        out_adv,
                        out_rtg,
                    );
                    lens.clear();
                    lens.resize(slab.width, t_len);
                    slab_tiles += 1;
                } else {
                    // Ragged fallback: gather into the scratch tile
                    // (leak-free padding), then the same kernel.
                    tile.pack_lane_views(tile_set);
                    gae_batched_strided_into(
                        &ctx.params,
                        tile.t_len,
                        tile.lanes,
                        tile.lanes,
                        &tile.rewards,
                        &tile.values,
                        &tile.done_mask,
                        out_adv,
                        out_rtg,
                    );
                    lens.clear();
                    lens.extend_from_slice(&tile.lens);
                    packed_tiles += 1;
                    gathered_bytes += 4
                        * (2 * tile.padded_elements()
                            + (tile.t_len + 1) * tile.lanes)
                            as u64;
                }
                unpack_lanes_into(lens, lens.len(), out_adv, out_rtg, outs);
            }
            ctx.metrics.record_tiles(slab_tiles, packed_tiles, gathered_bytes);
            None
        }
        GaeBackend::HwSim => {
            let sim = ctx.sim.as_ref().expect("hwsim worker owns a sim");
            // Rows take single-episode vectors: split each lane at its
            // dones (same preprocessing as the trainer's GAE stage),
            // refilling recycled trajectory buffers from the pool.
            let WorkerScratch { segments, seg_index, seg_pool, outs, .. } = scratch;
            debug_assert!(segments.is_empty());
            seg_index.clear();
            for (lane_idx, lane) in lanes.iter().enumerate() {
                split_at_dones_with(
                    |t| lane.reward(t),
                    |t| lane.value(t),
                    |t| lane.done(t),
                    lane.len(),
                    seg_pool,
                    |start, seg| {
                        seg_index.push((lane_idx, start, seg.len()));
                        segments.push(seg);
                    },
                );
            }
            let rep = sim.simulate(segments);
            // Stitch segments back into per-lane outputs.
            let base = outs.len();
            for lane in lanes {
                outs.push(GaeOutput {
                    advantages: crate::service::vecpool::take_zeroed(lane.len()),
                    rewards_to_go: crate::service::vecpool::take_zeroed(lane.len()),
                });
            }
            for (&(lane_idx, start, len), seg_out) in
                seg_index.iter().zip(rep.outputs)
            {
                outs[base + lane_idx].advantages[start..start + len]
                    .copy_from_slice(&seg_out.advantages);
                outs[base + lane_idx].rewards_to_go[start..start + len]
                    .copy_from_slice(&seg_out.rewards_to_go);
            }
            // Return the segment buffers to the pool for the next group.
            seg_pool.extend(segments.drain(..));
            Some(rep.cycles)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::Trajectory;
    use crate::hwsim::SimConfig;
    use crate::service::batcher::BatcherConfig;
    use crate::service::metrics::SnapshotInputs;
    use crate::service::plane::PlaneSet;
    use crate::testing::{check, Gen};

    fn ctx(backend: GaeBackend) -> WorkerContext {
        let params = GaeParams::default();
        WorkerContext {
            index: 0,
            backend,
            params,
            sim: (backend == GaeBackend::HwSim).then(|| {
                GaeHwSim::new(SimConfig { gae: params, ..SimConfig::paper_default() })
            }),
            batcher: DynamicBatcher::new(BatcherConfig {
                tile_lanes: 4,
                ..BatcherConfig::default()
            }),
            scalar_route_max_elements: 0,
            queue: Arc::new(BoundedQueue::new(1)),
            metrics: Arc::new(ServiceMetrics::new()),
        }
    }

    /// Test shim over the worker's exact compute path: fresh scratch,
    /// outputs handed back.
    fn run(ctx: &WorkerContext, lanes: &[Lane]) -> (Vec<GaeOutput>, Option<u64>) {
        let mut scratch = WorkerScratch::new();
        let cycles = compute_lanes(ctx, &mut scratch, lanes);
        (std::mem::take(&mut scratch.outs), cycles)
    }

    fn random_lanes(g: &mut Gen) -> Vec<Trajectory> {
        (0..g.usize_in(1, 10))
            .map(|_| {
                let t_len = g.usize_in(1, 24);
                Trajectory::new(
                    g.vec_normal_f32(t_len, 0.0, 1.0),
                    g.vec_normal_f32(t_len + 1, 0.0, 1.0),
                    (0..t_len).map(|_| g.bool_p(0.1)).collect(),
                )
            })
            .collect()
    }

    fn random_plane_set(g: &mut Gen, t_len: usize, batch: usize) -> PlaneSet {
        PlaneSet::new(
            t_len,
            batch,
            g.vec_normal_f32(t_len * batch, 0.0, 1.0),
            g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0),
            (0..t_len * batch)
                .map(|_| if g.bool_p(0.1) { 1.0 } else { 0.0 })
                .collect(),
        )
        .unwrap()
    }

    fn column_reference(planes: &PlaneSet, col: usize) -> GaeOutput {
        let (t_len, batch) = (planes.t_len, planes.batch);
        let gathered = Trajectory::new(
            (0..t_len).map(|t| planes.rewards[t * batch + col]).collect(),
            (0..=t_len).map(|t| planes.values[t * batch + col]).collect(),
            (0..t_len)
                .map(|t| planes.done_mask[t * batch + col] == 1.0)
                .collect(),
        );
        gae_trajectory(&GaeParams::default(), &gathered)
    }

    #[test]
    fn every_backend_matches_the_scalar_reference() {
        check("service backends == reference", 15, |g| {
            let trajs = random_lanes(g);
            let owned: Vec<Lane> =
                trajs.iter().cloned().map(Lane::Owned).collect();
            for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
                let c = ctx(backend);
                let (outs, cycles) = run(&c, &owned);
                assert_eq!(outs.len(), trajs.len());
                if backend == GaeBackend::HwSim {
                    assert!(cycles.unwrap() > 0);
                }
                for (traj, got) in trajs.iter().zip(&outs) {
                    let want = gae_trajectory(&GaeParams::default(), traj);
                    for t in 0..traj.len() {
                        assert!(
                            (got.advantages[t] - want.advantages[t]).abs() < 1e-3,
                            "{backend:?} adv t={t}"
                        );
                        assert!(
                            (got.rewards_to_go[t] - want.rewards_to_go[t]).abs() < 1e-3,
                            "{backend:?} rtg t={t}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn column_lanes_match_owned_lanes_bitwise() {
        // The zero-copy contract: a borrowed plane column computes the
        // exact bits of its gathered per-column trajectory, per backend.
        // On the batched backend this pits the slab fast path (columns)
        // against the packed-tile path (owned) directly.
        check("column lanes == owned lanes (bitwise)", 8, |g| {
            let (t_len, batch) = (g.usize_in(2, 24), g.usize_in(1, 5));
            let planes = Arc::new(random_plane_set(g, t_len, batch));
            let columns: Vec<Lane> = (0..batch)
                .map(|col| Lane::Column { planes: Arc::clone(&planes), col })
                .collect();
            let gathered: Vec<Lane> = (0..batch)
                .map(|i| {
                    Lane::Owned(Trajectory::new(
                        (0..t_len).map(|t| planes.rewards[t * batch + i]).collect(),
                        (0..=t_len).map(|t| planes.values[t * batch + i]).collect(),
                        (0..t_len)
                            .map(|t| planes.done_mask[t * batch + i] == 1.0)
                            .collect(),
                    ))
                })
                .collect();
            for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
                let c = ctx(backend);
                let (col_out, _) = run(&c, &columns);
                let (own_out, _) = run(&c, &gathered);
                for (a, b) in col_out.iter().zip(&own_out) {
                    for t in 0..a.advantages.len() {
                        assert_eq!(
                            a.advantages[t].to_bits(),
                            b.advantages[t].to_bits(),
                            "{backend:?} t={t}"
                        );
                        assert_eq!(
                            a.rewards_to_go[t].to_bits(),
                            b.rewards_to_go[t].to_bits(),
                            "{backend:?} rtg t={t}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn slab_fast_path_engages_for_aligned_groups_and_gathers_nothing() {
        let mut g = Gen::new(31);
        let (t_len, batch) = (12, 6);
        let planes = Arc::new(random_plane_set(&mut g, t_len, batch));
        let columns: Vec<Lane> = (0..batch)
            .map(|col| Lane::Column { planes: Arc::clone(&planes), col })
            .collect();
        let c = ctx(GaeBackend::Batched); // tile_lanes = 4 → tiles [4, 2]
        let (outs, _) = run(&c, &columns);
        let snap = c.metrics.snapshot(SnapshotInputs::default());
        assert_eq!(snap.slab_tiles, 2, "both tiles must take the slab path");
        assert_eq!(snap.packed_tiles, 0);
        assert_eq!(snap.gathered_bytes, 0, "slab path must gather zero bytes");
        for (col, got) in outs.iter().enumerate() {
            let want = column_reference(&planes, col);
            for t in 0..t_len {
                assert_eq!(got.advantages[t].to_bits(), want.advantages[t].to_bits());
            }
        }
    }

    #[test]
    fn shuffled_columns_fall_back_to_the_packed_tile_with_identical_bits() {
        // Reversed column order defeats the contiguity check, so the
        // same data must flow through the packed gather — and come out
        // bit-identical to the slab path's answer.
        let mut g = Gen::new(32);
        let (t_len, batch) = (9, 4);
        let planes = Arc::new(random_plane_set(&mut g, t_len, batch));
        let reversed: Vec<Lane> = (0..batch)
            .rev()
            .map(|col| Lane::Column { planes: Arc::clone(&planes), col })
            .collect();
        let c = ctx(GaeBackend::Batched);
        let (outs, _) = run(&c, &reversed);
        let snap = c.metrics.snapshot(SnapshotInputs::default());
        assert_eq!(snap.slab_tiles, 0);
        assert_eq!(snap.packed_tiles, 1);
        assert!(snap.gathered_bytes > 0);
        for (i, got) in outs.iter().enumerate() {
            let want = column_reference(&planes, batch - 1 - i);
            for t in 0..t_len {
                assert_eq!(got.advantages[t].to_bits(), want.advantages[t].to_bits());
                assert_eq!(
                    got.rewards_to_go[t].to_bits(),
                    want.rewards_to_go[t].to_bits()
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_across_groups_stays_bit_exact() {
        // One long-lived scratch over alternating slab / ragged / hwsim
        // groups — exactly the worker loop's life — must never let a
        // previous group's state leak into the next result.
        check("scratch reuse == fresh scratch", 6, |g| {
            let c = ctx(GaeBackend::Batched);
            let mut scratch = WorkerScratch::new();
            for _ in 0..4 {
                let lanes: Vec<Lane> = if g.bool_p(0.5) {
                    let (t_len, batch) = (g.usize_in(1, 20), g.usize_in(1, 6));
                    let planes = Arc::new(random_plane_set(g, t_len, batch));
                    (0..batch)
                        .map(|col| Lane::Column { planes: Arc::clone(&planes), col })
                        .collect()
                } else {
                    random_lanes(g).into_iter().map(Lane::Owned).collect()
                };
                scratch.outs.clear();
                compute_lanes(&c, &mut scratch, &lanes);
                let reused = std::mem::take(&mut scratch.outs);
                let (fresh, _) = run(&c, &lanes);
                assert_eq!(reused.len(), fresh.len());
                for (a, b) in reused.iter().zip(&fresh) {
                    for t in 0..a.advantages.len() {
                        assert_eq!(a.advantages[t].to_bits(), b.advantages[t].to_bits());
                        assert_eq!(
                            a.rewards_to_go[t].to_bits(),
                            b.rewards_to_go[t].to_bits()
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn per_item_compute_is_a_share_of_group_compute() {
        use std::sync::mpsc;
        let c = ctx(GaeBackend::Scalar);
        let mut scratch = WorkerScratch::new();
        let mut g = Gen::new(41);
        // Two items of very different sizes riding one group.
        let sizes = [60usize, 12];
        let mut rxs = Vec::new();
        let mut group = Vec::new();
        for (id, &t_len) in sizes.iter().enumerate() {
            let traj = Trajectory::new(
                g.vec_normal_f32(t_len, 0.0, 1.0),
                g.vec_normal_f32(t_len + 1, 0.0, 1.0),
                vec![false; t_len],
            );
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            group.push(WorkItem {
                id: id as u64,
                lanes: vec![Lane::Owned(traj)],
                lane_count: 1,
                enqueued_at: Instant::now(),
                trace: 0,
                tx,
            });
        }
        process_group(&c, &mut scratch, group, 0);
        let big = rxs[0].recv().unwrap();
        let small = rxs[1].recv().unwrap();
        // Same group → same group_compute; shares are proportional and
        // sum back to (at most) the whole.
        assert_eq!(big.timing.group_compute, small.timing.group_compute);
        assert!(big.timing.compute <= big.timing.group_compute);
        assert!(small.timing.compute <= small.timing.group_compute);
        assert!(
            big.timing.compute >= small.timing.compute,
            "the larger item must carry the larger share"
        );
        let sum = big.timing.compute + small.timing.compute;
        let whole = big.timing.group_compute;
        assert!(
            sum <= whole + std::time::Duration::from_nanos(2),
            "shares must not exceed the group compute: {sum:?} vs {whole:?}"
        );
    }

    #[test]
    fn small_groups_route_to_scalar_and_are_counted() {
        let mut g = Gen::new(9);
        let trajs = random_lanes(&mut g);
        let owned: Vec<Lane> = trajs.iter().cloned().map(Lane::Owned).collect();
        let elements: usize = trajs.iter().map(|t| t.len()).sum();

        // Threshold above the group size: routed (no cycles reported).
        let mut c = ctx(GaeBackend::HwSim);
        c.scalar_route_max_elements = elements;
        let (outs, cycles) = run(&c, &owned);
        assert!(cycles.is_none(), "routed group must not report hw cycles");
        let snap = c.metrics.snapshot(SnapshotInputs {
            scalar_route_max_elements: c.scalar_route_max_elements,
            ..Default::default()
        });
        assert_eq!(snap.routed_small, 1);
        for (traj, got) in trajs.iter().zip(&outs) {
            let want = gae_trajectory(&GaeParams::default(), traj);
            for t in 0..traj.len() {
                assert_eq!(got.advantages[t].to_bits(), want.advantages[t].to_bits());
            }
        }

        // Threshold below the group size (or 0 = disabled): not routed.
        let mut c = ctx(GaeBackend::HwSim);
        c.scalar_route_max_elements = elements - 1;
        let (_, cycles) = run(&c, &owned);
        assert!(cycles.unwrap() > 0);
        assert_eq!(c.metrics.snapshot(SnapshotInputs::default()).routed_small, 0);
    }
}

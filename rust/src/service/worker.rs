//! Sharded workers: each owns a private backend instance and competes
//! for requests on the shared queue (work-stealing MPMC, the software
//! analogue of the paper's round-robin row dispatch — a free shard takes
//! the next request the moment it drains).
//!
//! A worker's loop: block for a request, linger-coalesce into a group
//! ([`DynamicBatcher`]), compute the whole group on its own backend, and
//! answer every request in the group. Because each worker owns its
//! backend — a scalar loop, a batched-CPU engine, or a private
//! [`GaeHwSim`] instance — N workers model N independent accelerator
//! row-arrays on one SoC, with zero shared state on the compute path.
//!
//! Lanes arrive as [`Lane`]s — owned trajectories or borrowed columns
//! of a shared plane set (the zero-copy submission path) — and are read
//! through the lane accessors, so neither representation is gathered
//! until (and unless) a backend needs a contiguous layout.
//!
//! **Size-threshold routing**: when
//! [`ServiceConfig::scalar_route_max_elements`](crate::service::ServiceConfig)
//! is nonzero, coalesced groups at or below that many GAE elements run
//! the scalar loop instead of the configured backend — small groups
//! don't amortize tile packing or the simulator's loader pipeline, so
//! routing them to the plain loop is strictly cheaper. Routed groups
//! are counted in the metrics (`routed_small`) and report no `hw_cycles`.

use crate::coordinator::gae_stage::{split_at_dones, GaeBackend};
use crate::gae::batched::gae_batched;
use crate::gae::reference::gae_indexed;
use crate::gae::{GaeOutput, GaeParams, Trajectory};
use crate::hwsim::GaeHwSim;
use crate::service::batcher::{tile_lanes, unpack_lanes, DynamicBatcher, PaddedTile};
use crate::service::metrics::ServiceMetrics;
use crate::service::plane::Lane;
use crate::service::queue::BoundedQueue;
use crate::service::request::{GaeResponse, RequestTiming, WorkItem};
use std::sync::Arc;
use std::time::Instant;

/// Everything one worker shard needs (moved into its thread).
pub(crate) struct WorkerContext {
    pub index: usize,
    pub backend: GaeBackend,
    pub params: GaeParams,
    /// Private accelerator model (hwsim backend only).
    pub sim: Option<GaeHwSim>,
    pub batcher: DynamicBatcher,
    /// Size-threshold routing: groups of at most this many elements run
    /// the scalar loop (0 disables routing).
    pub scalar_route_max_elements: usize,
    pub queue: Arc<BoundedQueue<WorkItem>>,
    pub metrics: Arc<ServiceMetrics>,
}

/// Run until the queue is closed and drained.
pub(crate) fn worker_loop(ctx: WorkerContext) {
    let mut batch_seq = 0u64;
    while let Some(group) = ctx.batcher.next_group(&ctx.queue) {
        process_group(&ctx, group, batch_seq);
        batch_seq += 1;
    }
}

fn process_group(ctx: &WorkerContext, group: Vec<WorkItem>, batch_seq: u64) {
    let picked_at = Instant::now();
    let lanes: Vec<&Lane> =
        group.iter().flat_map(|item| item.lanes.iter()).collect();
    let total_lanes = lanes.len();

    let compute_start = Instant::now();
    let (mut outputs, hw_cycles) = compute_lanes(ctx, &lanes);
    let compute = compute_start.elapsed();

    ctx.metrics.record_batch(total_lanes, hw_cycles);

    // Hand each request its slice of the lane outputs, input order.
    for item in group {
        let rest = outputs.split_off(item.lane_count);
        let item_outputs = std::mem::replace(&mut outputs, rest);
        let elements: usize = item_outputs.iter().map(|o| o.advantages.len()).sum();
        let timing = RequestTiming {
            queue: picked_at.duration_since(item.enqueued_at),
            compute,
            total: item.enqueued_at.elapsed(),
        };
        ctx.metrics.record_completion(elements, &timing);
        // The client may have dropped its handle; a failed send is fine.
        let _ = item.tx.send(GaeResponse {
            id: item.id,
            outputs: item_outputs,
            hw_cycles,
            worker: ctx.index,
            batch_seq,
            timing,
        });
    }
    debug_assert!(outputs.is_empty(), "every lane output must be consumed");
}

/// The scalar loop over one lane (owned or strided column) — delegates
/// to the shared indexed kernel, so the bits match [`gae_trajectory`]
/// (crate::gae::reference::gae_trajectory) on the gathered equivalent.
fn gae_lane(params: &GaeParams, lane: &Lane) -> GaeOutput {
    gae_indexed(
        params,
        lane.len(),
        |t| lane.reward(t),
        |t| lane.value(t),
        |t| lane.done(t),
    )
}

/// Pick the backend for one coalesced group: the configured one, unless
/// size-threshold routing sends a small group to the scalar loop.
fn route_backend(ctx: &WorkerContext, lanes: &[&Lane]) -> GaeBackend {
    if ctx.scalar_route_max_elements > 0 && ctx.backend != GaeBackend::Scalar {
        let elements: usize = lanes.iter().map(|l| l.len()).sum();
        if elements <= ctx.scalar_route_max_elements {
            ctx.metrics.record_routed_small();
            return GaeBackend::Scalar;
        }
    }
    ctx.backend
}

/// Compute GAE for a flat list of lanes on this worker's backend.
/// Returns per-lane outputs (input order) and, for hwsim, the simulated
/// cycle count of the coalesced batch.
fn compute_lanes(
    ctx: &WorkerContext,
    lanes: &[&Lane],
) -> (Vec<GaeOutput>, Option<u64>) {
    match route_backend(ctx, lanes) {
        GaeBackend::Scalar => {
            // The per-trajectory CPU loop — the baseline shape.
            let outs = lanes.iter().map(|lane| gae_lane(&ctx.params, lane)).collect();
            (outs, None)
        }
        GaeBackend::Batched | GaeBackend::Hlo => {
            // Fixed [T, B] tiles through the timestep-major engine. (Hlo
            // is rejected at service start; the arm keeps the match total.)
            let mut outs = Vec::with_capacity(lanes.len());
            for tile_set in tile_lanes(lanes, ctx.batcher.config.tile_lanes) {
                let (batch, lens) = PaddedTile::from_lane_views(&tile_set).into_parts();
                let out = gae_batched(&ctx.params, &batch);
                outs.extend(unpack_lanes(&lens, batch.batch, &out));
            }
            (outs, None)
        }
        GaeBackend::HwSim => {
            let sim = ctx.sim.as_ref().expect("hwsim worker owns a sim");
            // Rows take single-episode vectors: split each lane at its
            // dones (same preprocessing as the trainer's GAE stage).
            let mut segments: Vec<Trajectory> = Vec::new();
            let mut index: Vec<(usize, usize, usize)> = Vec::new(); // (lane, start, len)
            for (lane_idx, lane) in lanes.iter().enumerate() {
                for (start, seg) in split_at_dones(
                    |t| lane.reward(t),
                    |t| lane.value(t),
                    |t| lane.done(t),
                    lane.len(),
                ) {
                    index.push((lane_idx, start, seg.len()));
                    segments.push(seg);
                }
            }
            let rep = sim.simulate(&segments);
            // Stitch segments back into per-lane outputs.
            let mut outs: Vec<GaeOutput> = lanes
                .iter()
                .map(|lane| GaeOutput {
                    advantages: vec![0.0; lane.len()],
                    rewards_to_go: vec![0.0; lane.len()],
                })
                .collect();
            for ((lane_idx, start, len), seg_out) in
                index.into_iter().zip(rep.outputs)
            {
                outs[lane_idx].advantages[start..start + len]
                    .copy_from_slice(&seg_out.advantages);
                outs[lane_idx].rewards_to_go[start..start + len]
                    .copy_from_slice(&seg_out.rewards_to_go);
            }
            (outs, Some(rep.cycles))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::hwsim::SimConfig;
    use crate::service::batcher::BatcherConfig;
    use crate::service::plane::PlaneSet;
    use crate::testing::{check, Gen};

    fn ctx(backend: GaeBackend) -> WorkerContext {
        let params = GaeParams::default();
        WorkerContext {
            index: 0,
            backend,
            params,
            sim: (backend == GaeBackend::HwSim).then(|| {
                GaeHwSim::new(SimConfig { gae: params, ..SimConfig::paper_default() })
            }),
            batcher: DynamicBatcher::new(BatcherConfig {
                tile_lanes: 4,
                ..BatcherConfig::default()
            }),
            scalar_route_max_elements: 0,
            queue: Arc::new(BoundedQueue::new(1)),
            metrics: Arc::new(ServiceMetrics::new()),
        }
    }

    fn random_lanes(g: &mut Gen) -> Vec<Trajectory> {
        (0..g.usize_in(1, 10))
            .map(|_| {
                let t_len = g.usize_in(1, 24);
                Trajectory::new(
                    g.vec_normal_f32(t_len, 0.0, 1.0),
                    g.vec_normal_f32(t_len + 1, 0.0, 1.0),
                    (0..t_len).map(|_| g.bool_p(0.1)).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn every_backend_matches_the_scalar_reference() {
        check("service backends == reference", 15, |g| {
            let trajs = random_lanes(g);
            let owned: Vec<Lane> =
                trajs.iter().cloned().map(Lane::Owned).collect();
            let lanes: Vec<&Lane> = owned.iter().collect();
            for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
                let c = ctx(backend);
                let (outs, cycles) = compute_lanes(&c, &lanes);
                assert_eq!(outs.len(), trajs.len());
                if backend == GaeBackend::HwSim {
                    assert!(cycles.unwrap() > 0);
                }
                for (traj, got) in trajs.iter().zip(&outs) {
                    let want = gae_trajectory(&GaeParams::default(), traj);
                    for t in 0..traj.len() {
                        assert!(
                            (got.advantages[t] - want.advantages[t]).abs() < 1e-3,
                            "{backend:?} adv t={t}"
                        );
                        assert!(
                            (got.rewards_to_go[t] - want.rewards_to_go[t]).abs() < 1e-3,
                            "{backend:?} rtg t={t}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn column_lanes_match_owned_lanes_bitwise() {
        // The zero-copy contract: a borrowed plane column computes the
        // exact bits of its gathered per-column trajectory, per backend.
        check("column lanes == owned lanes (bitwise)", 8, |g| {
            let (t_len, batch) = (g.usize_in(2, 24), g.usize_in(1, 5));
            let planes = Arc::new(
                PlaneSet::new(
                    t_len,
                    batch,
                    g.vec_normal_f32(t_len * batch, 0.0, 1.0),
                    g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0),
                    (0..t_len * batch)
                        .map(|_| if g.bool_p(0.1) { 1.0 } else { 0.0 })
                        .collect(),
                )
                .unwrap(),
            );
            let columns: Vec<Lane> = (0..batch)
                .map(|col| Lane::Column { planes: Arc::clone(&planes), col })
                .collect();
            let gathered: Vec<Lane> = (0..batch)
                .map(|i| {
                    Lane::Owned(Trajectory::new(
                        (0..t_len).map(|t| planes.rewards[t * batch + i]).collect(),
                        (0..=t_len).map(|t| planes.values[t * batch + i]).collect(),
                        (0..t_len)
                            .map(|t| planes.done_mask[t * batch + i] == 1.0)
                            .collect(),
                    ))
                })
                .collect();
            for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
                let c = ctx(backend);
                let col_refs: Vec<&Lane> = columns.iter().collect();
                let own_refs: Vec<&Lane> = gathered.iter().collect();
                let (col_out, _) = compute_lanes(&c, &col_refs);
                let (own_out, _) = compute_lanes(&c, &own_refs);
                for (a, b) in col_out.iter().zip(&own_out) {
                    for t in 0..a.advantages.len() {
                        assert_eq!(
                            a.advantages[t].to_bits(),
                            b.advantages[t].to_bits(),
                            "{backend:?} t={t}"
                        );
                        assert_eq!(
                            a.rewards_to_go[t].to_bits(),
                            b.rewards_to_go[t].to_bits(),
                            "{backend:?} rtg t={t}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn small_groups_route_to_scalar_and_are_counted() {
        let mut g = Gen::new(9);
        let trajs = random_lanes(&mut g);
        let owned: Vec<Lane> = trajs.iter().cloned().map(Lane::Owned).collect();
        let lanes: Vec<&Lane> = owned.iter().collect();
        let elements: usize = trajs.iter().map(|t| t.len()).sum();

        // Threshold above the group size: routed (no cycles reported).
        let mut c = ctx(GaeBackend::HwSim);
        c.scalar_route_max_elements = elements;
        let (outs, cycles) = compute_lanes(&c, &lanes);
        assert!(cycles.is_none(), "routed group must not report hw cycles");
        assert_eq!(c.metrics.snapshot(0, 0, c.scalar_route_max_elements).routed_small, 1);
        for (traj, got) in trajs.iter().zip(&outs) {
            let want = gae_trajectory(&GaeParams::default(), traj);
            for t in 0..traj.len() {
                assert_eq!(got.advantages[t].to_bits(), want.advantages[t].to_bits());
            }
        }

        // Threshold below the group size (or 0 = disabled): not routed.
        let mut c = ctx(GaeBackend::HwSim);
        c.scalar_route_max_elements = elements - 1;
        let (_, cycles) = compute_lanes(&c, &lanes);
        assert!(cycles.unwrap() > 0);
        assert_eq!(c.metrics.snapshot(0, 0, 0).routed_small, 0);
    }
}

//! Zero-copy plane submission: shared `[T, B]` plane buffers and the
//! borrowed column views the workers compute on.
//!
//! The pipelined trainer (and the network front-end) hands the service
//! one iteration's timestep-major planes — `rewards [T·B]`, `values
//! [(T+1)·B]`, `done_mask [T·B]`. The first-generation seam gathered
//! each env column into its own [`Trajectory`] on the *submitting*
//! thread: `B × 3` allocations plus `B` strided gather passes on the
//! trainer's critical path. This module removes that copy entirely:
//!
//! - [`PlaneSet`] — the three planes, moved (not copied) into one
//!   `Arc` at submission time;
//! - [`Lane`] — the unit the queue carries: either an owned
//!   [`Trajectory`] (the classic client path) or a **borrowed column**
//!   of a shared `PlaneSet` (`planes[t * batch + col]` strided reads).
//!
//! Workers read lanes through the [`Lane::reward`]/[`Lane::value`]/
//! [`Lane::done`] accessors, so the gather either disappears (the
//! scalar backend streams the strides directly through
//! [`gae_indexed`](crate::gae::reference::gae_indexed)) or happens once
//! inside the worker where it is paid in parallel (tile packing,
//! episode splitting). Results are bit-identical to the owned path: the
//! accessors return the very same `f32` values the per-column gather
//! would have copied.

use crate::gae::Trajectory;
use crate::service::request::ServiceError;
use std::sync::Arc;

/// A timestep-major `[T, B]` set of GAE input planes, shared (via `Arc`)
/// by the per-column work items of one plane-shaped submission.
#[derive(Debug, Clone)]
pub struct PlaneSet {
    /// Timesteps `T`.
    pub t_len: usize,
    /// Env columns `B`.
    pub batch: usize,
    /// `[T * B]` rewards.
    pub rewards: Vec<f32>,
    /// `[(T+1) * B]` values; row `T` bootstraps every column.
    pub values: Vec<f32>,
    /// `[T * B]` terminal mask (1.0 = done at that step).
    pub done_mask: Vec<f32>,
}

impl PlaneSet {
    /// Validate the geometry and take ownership of the plane buffers.
    /// Shape errors mirror [`ServiceError::ShapeMismatch`]; a zero-area
    /// plane set is an [`ServiceError::EmptyRequest`].
    pub fn new(
        t_len: usize,
        batch: usize,
        rewards: Vec<f32>,
        values: Vec<f32>,
        done_mask: Vec<f32>,
    ) -> Result<PlaneSet, ServiceError> {
        let check = |plane: &'static str, got: usize, want: usize| {
            if got != want {
                Err(ServiceError::ShapeMismatch { plane, got, want })
            } else {
                Ok(())
            }
        };
        check("rewards", rewards.len(), t_len * batch)?;
        check("values", values.len(), (t_len + 1) * batch)?;
        check("done_mask", done_mask.len(), t_len * batch)?;
        if t_len == 0 || batch == 0 {
            return Err(ServiceError::EmptyRequest);
        }
        // The mask must be strictly binary: the slab fast path feeds it
        // into the branch-free kernel as `not_done = 1.0 - mask`, while
        // the lane accessors test `== 1.0` — any other value would make
        // the two (bit-identical by contract) paths diverge, so it is
        // rejected at the single entry point instead.
        if let Some(index) = done_mask.iter().position(|&d| d != 0.0 && d != 1.0) {
            return Err(ServiceError::NonBinaryDoneMask { index });
        }
        Ok(PlaneSet { t_len, batch, rewards, values, done_mask })
    }

    /// GAE elements per column × columns — the admission/quota cost unit.
    pub fn elements(&self) -> usize {
        self.t_len * self.batch
    }
}

/// One lane of GAE input as the queue carries it: an owned trajectory or
/// a borrowed column of a shared [`PlaneSet`].
#[derive(Debug, Clone)]
pub enum Lane {
    /// A client-supplied trajectory, moved into the work item.
    Owned(Trajectory),
    /// Column `col` of a shared plane set — strided, never copied.
    Column {
        planes: Arc<PlaneSet>,
        col: usize,
    },
}

impl Lane {
    /// Timesteps in this lane.
    #[inline]
    pub fn len(&self) -> usize {
        match self {
            Lane::Owned(t) => t.len(),
            Lane::Column { planes, .. } => planes.t_len,
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Reward at step `t` (`t < len`).
    #[inline]
    pub fn reward(&self, t: usize) -> f32 {
        match self {
            Lane::Owned(traj) => traj.rewards[t],
            Lane::Column { planes, col } => planes.rewards[t * planes.batch + col],
        }
    }

    /// Value at step `t` (`t <= len`; `t == len` is the bootstrap).
    #[inline]
    pub fn value(&self, t: usize) -> f32 {
        match self {
            Lane::Owned(traj) => traj.values[t],
            Lane::Column { planes, col } => planes.values[t * planes.batch + col],
        }
    }

    /// Terminal flag at step `t` (`t < len`).
    #[inline]
    pub fn done(&self, t: usize) -> bool {
        match self {
            Lane::Owned(traj) => traj.dones[t],
            Lane::Column { planes, col } => {
                planes.done_mask[t * planes.batch + col] == 1.0
            }
        }
    }
}

/// A contiguous column window of one shared [`PlaneSet`]: lanes
/// `col0 .. col0 + width` of the same resident `[T, B]` planes, detected
/// by [`slab_of`]. The worker's **slab fast path** runs the batched
/// backward recurrence directly on these strided planes
/// ([`gae_batched_strided_into`](crate::gae::batched::gae_batched_strided_into)
/// with `stride = batch`), so the common coalesced group — equal-length
/// columns of one `submit_plane_set` submission — computes with zero
/// plane bytes gathered and zero allocations.
#[derive(Debug, Clone, Copy)]
pub struct Slab<'a> {
    /// The shared plane set every lane in the window borrows.
    pub planes: &'a PlaneSet,
    /// First column of the window.
    pub col0: usize,
    /// Columns in the window.
    pub width: usize,
}

impl<'a> Slab<'a> {
    /// Rewards plane sliced to the window's first column: rows of
    /// `width` live lanes every [`PlaneSet::batch`] elements.
    pub fn rewards(&self) -> &'a [f32] {
        &self.planes.rewards[self.col0..]
    }

    /// Values plane sliced likewise (`t_len + 1` rows; the last
    /// bootstraps every lane).
    pub fn values(&self) -> &'a [f32] {
        &self.planes.values[self.col0..]
    }

    /// Done-mask plane sliced likewise.
    pub fn done_mask(&self) -> &'a [f32] {
        &self.planes.done_mask[self.col0..]
    }
}

/// Detect the slab fast path: every lane is a borrowed column of the
/// *same* plane set (pointer-equal `Arc`) and the columns form one
/// contiguous ascending run. This is the shape `submit_plane_set`
/// traffic arrives in — columns enqueued `0..B` in order and drained
/// FIFO — so the common case computes in place on the resident planes;
/// anything else (owned lanes, mixed sets, shuffled or gapped columns)
/// returns `None` and falls back to the packed tile.
pub fn slab_of(lanes: &[Lane]) -> Option<Slab<'_>> {
    let (first, col0) = match lanes.first()? {
        Lane::Column { planes, col } => (planes, *col),
        Lane::Owned(_) => return None,
    };
    let mut next = col0 + 1;
    for lane in &lanes[1..] {
        match lane {
            Lane::Column { planes, col }
                if Arc::ptr_eq(planes, first) && *col == next =>
            {
                next += 1;
            }
            _ => return None,
        }
    }
    Some(Slab { planes: first, col0, width: lanes.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::Gen;

    fn plane_set(g: &mut Gen, t_len: usize, batch: usize) -> PlaneSet {
        PlaneSet::new(
            t_len,
            batch,
            g.vec_normal_f32(t_len * batch, 0.0, 1.0),
            g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0),
            (0..t_len * batch)
                .map(|_| if g.bool_p(0.1) { 1.0 } else { 0.0 })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn column_lane_reads_the_same_values_as_a_gathered_trajectory() {
        let mut g = Gen::new(3);
        let (t_len, batch) = (17, 5);
        let planes = Arc::new(plane_set(&mut g, t_len, batch));
        for col in 0..batch {
            let gathered = Trajectory::new(
                (0..t_len).map(|t| planes.rewards[t * batch + col]).collect(),
                (0..=t_len).map(|t| planes.values[t * batch + col]).collect(),
                (0..t_len)
                    .map(|t| planes.done_mask[t * batch + col] == 1.0)
                    .collect(),
            );
            let lane = Lane::Column { planes: Arc::clone(&planes), col };
            assert_eq!(lane.len(), t_len);
            for t in 0..t_len {
                assert_eq!(lane.reward(t).to_bits(), gathered.rewards[t].to_bits());
                assert_eq!(lane.done(t), gathered.dones[t]);
            }
            for t in 0..=t_len {
                assert_eq!(lane.value(t).to_bits(), gathered.values[t].to_bits());
            }
        }
    }

    #[test]
    fn plane_set_validates_geometry() {
        assert!(matches!(
            PlaneSet::new(4, 2, vec![0.0; 7], vec![0.0; 10], vec![0.0; 8]),
            Err(ServiceError::ShapeMismatch { plane: "rewards", got: 7, want: 8 })
        ));
        assert!(matches!(
            PlaneSet::new(4, 2, vec![0.0; 8], vec![0.0; 9], vec![0.0; 8]),
            Err(ServiceError::ShapeMismatch { plane: "values", .. })
        ));
        assert!(matches!(
            PlaneSet::new(4, 2, vec![0.0; 8], vec![0.0; 10], vec![0.0; 7]),
            Err(ServiceError::ShapeMismatch { plane: "done_mask", .. })
        ));
        assert_eq!(
            PlaneSet::new(0, 0, vec![], vec![], vec![]).unwrap_err(),
            ServiceError::EmptyRequest
        );
        let ok = PlaneSet::new(2, 3, vec![0.0; 6], vec![0.0; 9], vec![0.0; 6]).unwrap();
        assert_eq!(ok.elements(), 6);
    }

    #[test]
    fn non_binary_done_masks_are_rejected_at_the_entry_point() {
        // The slab kernel consumes the mask as `1 - mask` while the lane
        // accessors test `== 1.0`; a fractional value would make the two
        // bit-identical-by-contract paths diverge, so it never gets in.
        for bad in [0.5f32, -1.0, 2.0, f32::NAN] {
            let mut mask = vec![0.0f32; 6];
            mask[4] = bad;
            let err = PlaneSet::new(2, 3, vec![0.0; 6], vec![0.0; 9], mask).unwrap_err();
            assert_eq!(err, ServiceError::NonBinaryDoneMask { index: 4 }, "{bad}");
            assert!(err.to_string().contains("done_mask[4]"), "{err}");
        }
        // Exact 0.0 / 1.0 everywhere is fine.
        PlaneSet::new(2, 3, vec![0.0; 6], vec![0.0; 9], vec![1.0; 6]).unwrap();
    }

    #[test]
    fn owned_lane_passes_through() {
        let traj = Trajectory::new(
            vec![1.0, 2.0],
            vec![0.5, 1.5, 2.5],
            vec![false, true],
        );
        let lane = Lane::Owned(traj);
        assert_eq!(lane.len(), 2);
        assert!(!lane.is_empty());
        assert_eq!(lane.reward(1), 2.0);
        assert_eq!(lane.value(2), 2.5);
        assert!(lane.done(1));
        assert!(!lane.done(0));
    }

    fn columns(planes: &Arc<PlaneSet>, cols: &[usize]) -> Vec<Lane> {
        cols.iter()
            .map(|&col| Lane::Column { planes: Arc::clone(planes), col })
            .collect()
    }

    #[test]
    fn slab_detects_contiguous_columns_of_one_set() {
        let mut g = Gen::new(7);
        let planes = Arc::new(plane_set(&mut g, 9, 6));
        // Full run, interior window, and a single column all qualify.
        for (cols, col0, width) in [
            (vec![0, 1, 2, 3, 4, 5], 0, 6),
            (vec![2, 3, 4], 2, 3),
            (vec![5], 5, 1),
        ] {
            let lanes = columns(&planes, &cols);
            let slab = slab_of(&lanes).expect("contiguous columns form a slab");
            assert_eq!((slab.col0, slab.width), (col0, width));
            assert_eq!(slab.planes.t_len, 9);
            // The sliced planes index the right elements: row t of the
            // window starts at t * batch within the slice.
            assert_eq!(
                slab.rewards()[2 * 6].to_bits(),
                planes.rewards[2 * 6 + col0].to_bits()
            );
            assert_eq!(
                slab.values()[9 * 6].to_bits(),
                planes.values[9 * 6 + col0].to_bits()
            );
        }
    }

    #[test]
    fn slab_rejects_everything_else() {
        let mut g = Gen::new(8);
        let planes = Arc::new(plane_set(&mut g, 5, 4));
        let other = Arc::new(plane_set(&mut g, 5, 4));
        // Gapped, descending, and duplicated columns.
        for cols in [vec![0, 2], vec![3, 2], vec![1, 1]] {
            assert!(slab_of(&columns(&planes, &cols)).is_none(), "{cols:?}");
        }
        // Two different plane sets, even with consecutive column ids.
        let mut mixed = columns(&planes, &[0]);
        mixed.extend(columns(&other, &[1]));
        assert!(slab_of(&mixed).is_none());
        // Any owned lane poisons the group.
        let owned = Lane::Owned(Trajectory::new(
            vec![1.0; 5],
            vec![0.0; 6],
            vec![false; 5],
        ));
        let mut with_owned = columns(&planes, &[0, 1]);
        with_owned.push(owned);
        assert!(slab_of(&with_owned).is_none());
        // The empty group is no slab.
        assert!(slab_of(&[]).is_none());
    }
}

//! Bounded MPMC queue — the admission-controlled front door of the
//! serving subsystem.
//!
//! A `Mutex<VecDeque>` + two condvars: simple, fair-enough, and with no
//! allocation on the hot path beyond the ring itself. Producers choose
//! their overload behavior per call:
//!
//! - [`BoundedQueue::try_push`] — *admission control*: fail fast with
//!   [`PushError::Full`] when depth is at the limit (the service sheds
//!   the request and tells the client, instead of queueing unbounded
//!   work it cannot serve in time);
//! - [`BoundedQueue::push`] — *backpressure*: block the producer until
//!   a consumer drains a slot (closed-loop clients).
//!
//! Consumers ([`crate::service::worker`]) use blocking [`pop`] for the
//! first item of a batch and deadline-bounded [`pop_deadline`] while
//! coalescing. [`close`] wakes everyone; a closed queue still drains
//! remaining items so accepted requests are never dropped silently.
//!
//! [`pop`]: BoundedQueue::pop
//! [`pop_deadline`]: BoundedQueue::pop_deadline
//! [`close`]: BoundedQueue::close

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a push did not enqueue; the item is handed back to the caller.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Depth is at capacity (only from [`BoundedQueue::try_push`]).
    Full(T),
    /// The queue was closed.
    Closed(T),
}

impl<T> PushError<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        match self {
            PushError::Full(item) | PushError::Closed(item) => item,
        }
    }
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// High-water mark of the depth (a metrics gauge).
    peak: usize,
}

/// A bounded multi-producer multi-consumer FIFO.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `capacity` items (min 1).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
                peak: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current depth.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Highest depth ever observed.
    pub fn peak_depth(&self) -> usize {
        self.inner.lock().unwrap().peak
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Close the queue: producers fail, consumers drain what remains and
    /// then observe `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Non-blocking push — the admission-control path.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        inner.items.push_back(item);
        inner.peak = inner.peak.max(inner.items.len());
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push — the backpressure path. Waits for a free slot;
    /// fails only when the queue is closed.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(PushError::Closed(item));
            }
            if inner.items.len() < self.capacity {
                inner.items.push_back(item);
                inner.peak = inner.peak.max(inner.items.len());
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front();
        if item.is_some() {
            drop(inner);
            self.not_full.notify_one();
        }
        item
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Pop with a deadline (the batcher's linger): `None` on timeout or
    /// on closed-and-drained.
    pub fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
            if timeout.timed_out() && inner.items.is_empty() {
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn try_push_sheds_at_capacity() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak_depth(), 2);
        q.try_pop().unwrap();
        q.try_push(3).unwrap(); // slot freed
    }

    #[test]
    fn blocking_push_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(1));
        q.try_push(0u64).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            // Blocks until the consumer drains the slot.
            q2.push(1).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked, not queued");
        assert_eq!(q.pop(), Some(0));
        producer.join().unwrap();
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_wakes_blocked_parties() {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || qc.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
        assert_eq!(q.try_push(9), Err(PushError::Closed(9)));
        assert_eq!(q.push(9), Err(PushError::Closed(9)));
    }

    #[test]
    fn closed_queue_still_drains() {
        let q = BoundedQueue::new(4);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_deadline_times_out() {
        let q = BoundedQueue::<u32>::new(1);
        let t0 = Instant::now();
        let got = q.pop_deadline(Instant::now() + Duration::from_millis(15));
        assert_eq!(got, None);
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn pop_deadline_returns_item_when_available() {
        let q = BoundedQueue::new(1);
        q.try_push(7).unwrap();
        let got = q.pop_deadline(Instant::now() + Duration::from_millis(50));
        assert_eq!(got, Some(7));
    }

    #[test]
    fn mpmc_under_contention_delivers_every_item_once() {
        let q = Arc::new(BoundedQueue::new(8));
        let n_producers = 4;
        let per_producer = 250u64;
        let mut consumers = Vec::new();
        let delivered = Arc::new(Mutex::new(Vec::new()));
        for _ in 0..3 {
            let q = Arc::clone(&q);
            let delivered = Arc::clone(&delivered);
            consumers.push(std::thread::spawn(move || {
                while let Some(v) = q.pop() {
                    delivered.lock().unwrap().push(v);
                }
            }));
        }
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        let mut seen = delivered.lock().unwrap().clone();
        seen.sort_unstable();
        let mut want: Vec<u64> = (0..n_producers)
            .flat_map(|p| (0..per_producer).map(move |i| p * 1000 + i))
            .collect();
        want.sort_unstable();
        assert_eq!(seen, want);
    }
}

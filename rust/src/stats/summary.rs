//! Batch summary statistics for reporting (mean/std/min/max/percentiles).

/// Summary of a batch of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute from a slice (O(n log n) for the percentiles).
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: xs.len(),
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    pub fn of_f32(xs: &[f32]) -> Summary {
        let xs64: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
        Summary::of(&xs64)
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = idx - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_batch() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - 1.25f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_batch() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.95) - 95.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.5) - 50.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
    }
}

//! Fixed-window rolling mean — Fig. 10 of the paper plots the "Rolling
//! Average of 1000 Readings" of episode reward.

use std::collections::VecDeque;

/// Rolling mean over the last `window` observations, O(1) per push.
#[derive(Debug, Clone)]
pub struct RollingMean {
    window: usize,
    buf: VecDeque<f64>,
    sum: f64,
}

impl RollingMean {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "window must be positive");
        RollingMean { window, buf: VecDeque::with_capacity(window), sum: 0.0 }
    }

    /// Push an observation and return the current rolling mean.
    pub fn push(&mut self, x: f64) -> f64 {
        self.buf.push_back(x);
        self.sum += x;
        if self.buf.len() > self.window {
            self.sum -= self.buf.pop_front().unwrap();
        }
        self.mean()
    }

    /// Current mean over the (possibly not yet full) window.
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.sum / self.buf.len() as f64
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.buf.len() == self.window
    }
}

/// Smooth a whole series with a rolling window (used when emitting the
/// Fig. 7–10 CSV curves).
pub fn rolling_mean_series(xs: &[f64], window: usize) -> Vec<f64> {
    let mut rm = RollingMean::new(window);
    xs.iter().map(|&x| rm.push(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_window_means() {
        let mut rm = RollingMean::new(3);
        assert_eq!(rm.push(3.0), 3.0);
        assert_eq!(rm.push(5.0), 4.0);
        assert_eq!(rm.push(7.0), 5.0);
        assert!(rm.is_full());
    }

    #[test]
    fn window_evicts_oldest() {
        let mut rm = RollingMean::new(2);
        rm.push(1.0);
        rm.push(2.0);
        assert_eq!(rm.push(4.0), 3.0); // window = [2,4]
        assert_eq!(rm.len(), 2);
    }

    #[test]
    fn series_matches_naive() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let w = 7;
        let got = rolling_mean_series(&xs, w);
        for i in 0..xs.len() {
            let lo = i.saturating_sub(w - 1);
            let naive: f64 =
                xs[lo..=i].iter().sum::<f64>() / (i - lo + 1) as f64;
            assert!((got[i] - naive).abs() < 1e-12, "i={i}");
        }
    }

    #[test]
    #[should_panic(expected = "window must be positive")]
    fn zero_window_panics() {
        RollingMean::new(0);
    }
}

//! Welford's online mean/variance — the arithmetic behind the paper's
//! *dynamic standardization* (Section II-A, Eq. 6–9).
//!
//! The paper maintains, across the **whole training run**, a running mean
//! `M_n` and running cumulative `S_n` updated once per reward:
//!
//! ```text
//! M_n = M_{n-1} + (r_n - M_{n-1}) / n            (7)
//! S_n = S_{n-1} + (r_n - M_{n-1})(r_n - M_n)     (8)
//! std_n = sqrt(S_n / n)                          (9)  — population std
//! ```
//!
//! Note Eq. (9) divides by `n` (population), not `n-1`; we follow the
//! paper exactly ([`Welford::std_population`]) and also expose the sample
//! version for the test oracle.

/// Online mean/variance accumulator (numerically stable).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    s: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    /// Update with one observation — Eq. (7) and (8).
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        let delta2 = x - self.mean;
        self.s += delta * delta2;
    }

    /// Update with a slice of observations.
    ///
    /// §Perf: computes the batch's own (mean, S) with two vectorizable
    /// passes (no loop-carried dependency, unlike per-element
    /// [`Welford::push`]) and folds it in via the Chan merge — identical
    /// statistics, ~4× faster on large reward blocks.
    pub fn push_all(&mut self, xs: &[f32]) {
        if xs.is_empty() {
            return;
        }
        let n = xs.len() as f64;
        let sum: f64 = xs.iter().map(|&x| x as f64).sum();
        let mean = sum / n;
        let s: f64 = xs
            .iter()
            .map(|&x| {
                let d = x as f64 - mean;
                d * d
            })
            .sum();
        self.merge(&Welford { n: xs.len() as u64, mean, s });
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean `M_n`.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance `S_n / n` (the paper's Eq. 9 squared).
    pub fn variance_population(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.s / self.n as f64
        }
    }

    /// Sample variance `S_n / (n-1)`.
    pub fn variance_sample(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.s / (self.n - 1) as f64
        }
    }

    /// The paper's running standard deviation (Eq. 9).
    pub fn std_population(&self) -> f64 {
        self.variance_population().sqrt()
    }

    pub fn std_sample(&self) -> f64 {
        self.variance_sample().sqrt()
    }

    /// Merge another accumulator (Chan et al. parallel combination) —
    /// used when per-worker reward streams are folded into the global
    /// standardizer.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.s += other.s + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_stats(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn matches_naive_two_pass() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (0..10_000).map(|_| rng.normal_with(3.0, 2.5)).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let (mean, var) = naive_stats(&xs);
        assert!((w.mean() - mean).abs() < 1e-9);
        assert!((w.variance_population() - var).abs() < 1e-9);
    }

    #[test]
    fn paper_equation_nine_is_population() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        // population var of [1,2,3,4] = 1.25
        assert!((w.variance_population() - 1.25).abs() < 1e-12);
        assert!((w.variance_sample() - 5.0 / 3.0).abs() < 1e-12);
        assert!((w.std_population() - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        let mut w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.std_population(), 0.0);
        w.push(7.0);
        assert_eq!(w.mean(), 7.0);
        assert_eq!(w.variance_population(), 0.0);
        assert_eq!(w.variance_sample(), 0.0);
    }

    #[test]
    fn numerically_stable_for_large_offsets() {
        // Classic catastrophic-cancellation case for the naive sum-of-
        // squares method; Welford must survive it.
        let offset = 1e9;
        let mut w = Welford::new();
        for x in [offset + 4.0, offset + 7.0, offset + 13.0, offset + 16.0] {
            w.push(x);
        }
        assert!((w.mean() - (offset + 10.0)).abs() < 1e-3);
        assert!((w.variance_population() - 22.5).abs() < 1e-3);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal_with(-1.0, 0.7)).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..1234] {
            a.push(x);
        }
        for &x in &xs[1234..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance_population() - whole.variance_population()).abs() < 1e-9);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = Welford::new();
        a.push(1.0);
        a.push(2.0);
        let before = (a.count(), a.mean(), a.s);
        a.merge(&Welford::new());
        assert_eq!((a.count(), a.mean(), a.s), before);

        let mut e = Welford::new();
        e.merge(&a);
        assert_eq!(e.count(), 2);
        assert!((e.mean() - 1.5).abs() < 1e-12);
    }
}

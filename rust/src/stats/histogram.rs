//! Fixed-bin histograms — used to reproduce Fig. 2 ("Distribution of
//! Value Across Collected Trajectories") and to sanity-check quantizer
//! codeword usage.

/// A histogram over `[lo, hi)` with uniform bins plus under/overflow.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0, "bad histogram range/bins");
        Histogram { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0, count: 0 }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let f = (x - self.lo) / (self.hi - self.lo);
            let i = ((f * self.bins.len() as f64) as usize).min(self.bins.len() - 1);
            self.bins[i] += 1;
        }
    }

    pub fn push_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Normalized densities (sums to the in-range fraction).
    pub fn densities(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.bins.iter().map(|&c| c as f64 / n).collect()
    }

    /// Approximate quantile `q in [0,1]` with linear interpolation inside
    /// the covering bin. Underflow mass sits at `lo`, overflow at `hi`, so
    /// the estimate is clamped to the histogram range — callers wanting
    /// exact tails must keep raw samples ([`crate::stats::Summary`]).
    /// Used by the serving subsystem's latency metrics.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = q * self.count as f64;
        let mut cum = self.underflow as f64;
        if target <= cum {
            return self.lo;
        }
        let width = (self.hi - self.lo) / self.bins.len() as f64;
        for (i, &c) in self.bins.iter().enumerate() {
            let next = cum + c as f64;
            if target <= next && c > 0 {
                let frac = (target - cum) / c as f64;
                return self.lo + width * (i as f64 + frac);
            }
            cum = next;
        }
        self.hi
    }

    /// Clear all counts in place, keeping the bin storage — the
    /// windowed-metrics ring reuses one allocation per window forever.
    pub fn reset(&mut self) {
        for b in &mut self.bins {
            *b = 0;
        }
        self.underflow = 0;
        self.overflow = 0;
        self.count = 0;
    }

    /// Fold another histogram's counts into this one. Panics unless the
    /// two share an identical `[lo, hi)` range and bin count — merging
    /// is bin-wise addition, which is only meaningful over the same
    /// partition. This is what makes fixed-bin histograms *mergeable*:
    /// per-second windows sum into a multi-second view, and per-shard
    /// windows sum into a fleet view, with quantiles of the merge equal
    /// to quantiles of the union of samples (up to bin resolution).
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            self.lo == other.lo && self.hi == other.hi && self.bins.len() == other.bins.len(),
            "histogram merge requires identical ranges and bin counts"
        );
        for (a, b) in self.bins.iter_mut().zip(other.bins.iter()) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.count += other.count;
    }

    /// Fraction of mass outside `[lo, hi)` — the quantizer clipping rate.
    pub fn clipped_fraction(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            (self.underflow + self.overflow) as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bin_assignment() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(0.0);
        h.push(0.99);
        h.push(9.99);
        h.push(-1.0);
        h.push(10.0);
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.count(), 5);
        assert!((h.clipped_fraction() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn standard_normal_mass_within_3_sigma() {
        let mut rng = Rng::new(4);
        let mut h = Histogram::new(-3.0, 3.0, 60);
        for _ in 0..50_000 {
            h.push(rng.normal());
        }
        assert!(h.clipped_fraction() < 0.01);
        // Mode near zero.
        let peak = h.bins().iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
        assert!((h.bin_center(peak)).abs() < 0.5);
    }

    #[test]
    fn quantiles_of_uniform_ramp() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..100 {
            h.push(i as f64 + 0.5);
        }
        assert!((h.quantile(0.5) - 50.0).abs() < 1.5, "{}", h.quantile(0.5));
        assert!((h.quantile(0.95) - 95.0).abs() < 1.5);
        assert!((h.quantile(0.99) - 99.0).abs() < 1.5);
        assert_eq!(h.quantile(0.0), 0.0);
        assert!(h.quantile(1.0) <= 100.0);
        assert_eq!(Histogram::new(0.0, 1.0, 4).quantile(0.5), 0.0);
    }

    #[test]
    fn quantile_clamps_to_range_under_overflow() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(5.0);
        h.push(50.0);
        assert_eq!(h.quantile(0.01), 0.0); // underflow mass sits at lo
        assert_eq!(h.quantile(1.0), 10.0); // overflow mass sits at hi
    }

    #[test]
    fn quantile_of_empty_histogram_is_zero() {
        let h = Histogram::new(0.0, 100.0, 10);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.0, "q={q}");
        }
    }

    #[test]
    fn quantile_of_single_sample_lands_in_its_bin() {
        let mut h = Histogram::new(0.0, 100.0, 10);
        h.push(37.0);
        // Every quantile of a one-sample histogram must fall inside the
        // covering bin [30, 40) — interpolation cannot escape it.
        for q in [0.01, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!((30.0..=40.0).contains(&v), "q={q} gave {v}");
        }
        // q=0 short-circuits through the underflow check to lo.
        assert_eq!(h.quantile(0.0), 0.0);
    }

    #[test]
    fn quantile_with_saturated_top_bucket_stays_clamped() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        // All mass in the last in-range bin plus heavy overflow: the
        // estimate must never exceed hi, and high quantiles must not
        // fall below the saturated bin's lower edge.
        for _ in 0..100 {
            h.push(9.5);
        }
        for _ in 0..900 {
            h.push(1e9);
        }
        for q in [0.5, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v <= 10.0, "q={q} escaped the range: {v}");
            assert!(v >= 9.0, "q={q} fell below the top bucket: {v}");
        }
        // Mass entirely past the top edge: everything clamps to hi.
        let mut all_over = Histogram::new(0.0, 10.0, 10);
        for _ in 0..10 {
            all_over.push(100.0);
        }
        assert_eq!(all_over.quantile(0.5), 10.0);
        assert_eq!(all_over.quantile(1.0), 10.0);
    }

    #[test]
    fn merge_matches_union_of_samples_and_reset_clears() {
        let mut a = Histogram::new(0.0, 100.0, 100);
        let mut b = Histogram::new(0.0, 100.0, 100);
        let mut union = Histogram::new(0.0, 100.0, 100);
        for i in 0..50 {
            let x = i as f64 + 0.5;
            a.push(x);
            union.push(x);
        }
        for i in 50..100 {
            let x = i as f64 + 0.5;
            b.push(x);
            union.push(x);
        }
        a.push(-1.0);
        union.push(-1.0);
        b.push(1e9);
        union.push(1e9);
        a.merge(&b);
        assert_eq!(a.count(), union.count());
        assert_eq!(a.bins(), union.bins());
        assert_eq!((a.underflow, a.overflow), (union.underflow, union.overflow));
        for q in [0.25, 0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), union.quantile(q), "q={q}");
        }
        a.reset();
        assert_eq!(a.count(), 0);
        assert!(a.bins().iter().all(|&c| c == 0));
        assert_eq!(a.quantile(0.99), 0.0);
    }

    #[test]
    #[should_panic(expected = "identical ranges")]
    fn merge_rejects_mismatched_ranges() {
        let mut a = Histogram::new(0.0, 1.0, 10);
        let b = Histogram::new(0.0, 2.0, 10);
        a.merge(&b);
    }

    #[test]
    fn bin_centers() {
        let h = Histogram::new(0.0, 1.0, 4);
        assert!((h.bin_center(0) - 0.125).abs() < 1e-12);
        assert!((h.bin_center(3) - 0.875).abs() < 1e-12);
    }
}

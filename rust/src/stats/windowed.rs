//! Windowed metrics substrate: fixed rings of per-second buckets.
//!
//! The serving stack's lifetime histograms answer "how has this process
//! done since start" — useless for spotting a p99 regression mid-run,
//! because an hour of healthy traffic dilutes a bad minute below the
//! noise floor. The windowed substrate keeps a fixed ring of per-second
//! buckets ([`Histogram`]s or plain counters) and answers "how did the
//! last 1/10/60 seconds look" instead.
//!
//! Two properties drive the design:
//!
//! - **Rotation rides the recording path.** Each bucket is stamped with
//!   the absolute second it holds data for; a record into a second the
//!   slot does not yet represent resets the slot first. No ticker
//!   thread, no timer wheel — an idle service does zero work, and a
//!   busy one pays one stamp compare per record plus one O(bins) reset
//!   per histogram per second.
//! - **Zero allocation after warm-up.** Every bucket's storage is
//!   allocated once at construction; rotation resets counts in place
//!   and views merge into caller-provided scratch
//!   ([`WindowedHistogram::merged_into`]). The telemetry-overhead bench
//!   holds the recording path to 0 steady-state allocations.
//!
//! Stale buckets age out *by stamp*, not by rotation: a view over the
//! last N seconds only admits buckets whose stamp falls inside the
//! span, so a service idle for a minute reports empty windows rather
//! than a frozen p99 from its last burst. Because the ring maps second
//! `s` to slot `s % len`, a stamp can never alias a prior lap — slot
//! reuse re-stamps.

use crate::stats::Histogram;

/// Stamp meaning "this slot has never held data".
const EMPTY: u64 = u64::MAX;

/// A ring of per-second [`Histogram`] buckets over a shared range.
#[derive(Debug, Clone)]
pub struct WindowedHistogram {
    /// Slot `i` holds the data of every second `s` with `s % len == i`
    /// — but only the most recent such second (the stamp says which).
    stamps: Vec<u64>,
    hists: Vec<Histogram>,
}

impl WindowedHistogram {
    /// `ring_secs` is the longest lookback the ring can answer; views
    /// over longer spans silently see at most `ring_secs` seconds.
    pub fn new(lo: f64, hi: f64, n_bins: usize, ring_secs: usize) -> WindowedHistogram {
        assert!(ring_secs > 0, "ring must hold at least one second");
        WindowedHistogram {
            stamps: vec![EMPTY; ring_secs],
            hists: (0..ring_secs).map(|_| Histogram::new(lo, hi, n_bins)).collect(),
        }
    }

    /// Seconds of lookback the ring covers.
    pub fn ring_secs(&self) -> usize {
        self.stamps.len()
    }

    /// Record `value` into the bucket for absolute second `now_sec`
    /// (whatever monotonic second counter the caller keeps). Rotation
    /// happens here: a stale slot is reset and re-stamped in place.
    #[inline]
    pub fn record(&mut self, now_sec: u64, value: f64) {
        let i = (now_sec % self.stamps.len() as u64) as usize;
        if self.stamps[i] != now_sec {
            self.hists[i].reset();
            self.stamps[i] = now_sec;
        }
        self.hists[i].push(value);
    }

    /// Merge the buckets of the last `span_secs` seconds (the current
    /// partial second included) into `out`, which is reset first. `out`
    /// must share the ring's range/bins; scratch-reuse keeps the
    /// periodic threshold recompute allocation-free.
    pub fn merged_into(&self, now_sec: u64, span_secs: u64, out: &mut Histogram) {
        out.reset();
        let span = span_secs.min(self.stamps.len() as u64).max(1);
        let first = now_sec.saturating_sub(span - 1);
        for sec in first..=now_sec {
            let i = (sec % self.stamps.len() as u64) as usize;
            if self.stamps[i] == sec {
                out.merge(&self.hists[i]);
            }
        }
    }

    /// Allocating convenience for snapshot paths: the merged view of
    /// the last `span_secs` seconds as a fresh [`Histogram`].
    pub fn merged(&self, now_sec: u64, span_secs: u64) -> Histogram {
        let mut out = self.hists[0].clone();
        self.merged_into(now_sec, span_secs, &mut out);
        out
    }

    /// Samples recorded in the last `span_secs` seconds.
    pub fn count(&self, now_sec: u64, span_secs: u64) -> u64 {
        let span = span_secs.min(self.stamps.len() as u64).max(1);
        let first = now_sec.saturating_sub(span - 1);
        (first..=now_sec)
            .filter_map(|sec| {
                let i = (sec % self.stamps.len() as u64) as usize;
                (self.stamps[i] == sec).then(|| self.hists[i].count())
            })
            .sum()
    }
}

/// A ring of per-second `u64` counters — the counting counterpart of
/// [`WindowedHistogram`], for rates and SLO good/bad event counts.
#[derive(Debug, Clone)]
pub struct WindowedCounter {
    stamps: Vec<u64>,
    counts: Vec<u64>,
}

impl WindowedCounter {
    pub fn new(ring_secs: usize) -> WindowedCounter {
        assert!(ring_secs > 0, "ring must hold at least one second");
        WindowedCounter { stamps: vec![EMPTY; ring_secs], counts: vec![0; ring_secs] }
    }

    /// Add `n` to the bucket for absolute second `now_sec`.
    #[inline]
    pub fn add(&mut self, now_sec: u64, n: u64) {
        let i = (now_sec % self.stamps.len() as u64) as usize;
        if self.stamps[i] != now_sec {
            self.counts[i] = 0;
            self.stamps[i] = now_sec;
        }
        self.counts[i] += n;
    }

    /// Sum over the last `span_secs` seconds (current second included).
    pub fn sum(&self, now_sec: u64, span_secs: u64) -> u64 {
        let span = span_secs.min(self.stamps.len() as u64).max(1);
        let first = now_sec.saturating_sub(span - 1);
        (first..=now_sec)
            .filter_map(|sec| {
                let i = (sec % self.stamps.len() as u64) as usize;
                (self.stamps[i] == sec).then_some(self.counts[i])
            })
            .sum()
    }
}

/// A bucket type that can live in a [`WindowedSlots`] ring: resettable
/// in place (rotation) and mergeable into scratch (views). Both
/// operations must be allocation-free for warmed buckets — that is the
/// whole point of the ring.
pub trait RingSlot {
    /// Return the slot to its empty state without releasing storage.
    fn reset(&mut self);
    /// Fold this slot's contents into `out`.
    fn merge_into(&self, out: &mut Self);
}

/// A ring of per-second buckets of any [`RingSlot`] type — the generic
/// form of [`WindowedHistogram`] / [`WindowedCounter`], for composite
/// buckets (e.g. the numerics plane's per-second accumulators) that
/// would otherwise need a fistful of parallel rings and pay one stamp
/// compare each.
#[derive(Debug, Clone)]
pub struct WindowedSlots<S> {
    stamps: Vec<u64>,
    slots: Vec<S>,
}

impl<S: RingSlot + Default> WindowedSlots<S> {
    pub fn new(ring_secs: usize) -> WindowedSlots<S> {
        assert!(ring_secs > 0, "ring must hold at least one second");
        WindowedSlots {
            stamps: vec![EMPTY; ring_secs],
            slots: (0..ring_secs).map(|_| S::default()).collect(),
        }
    }

    /// The bucket for absolute second `now_sec`, rotated in place if the
    /// slot still holds a stale second.
    #[inline]
    pub fn slot_mut(&mut self, now_sec: u64) -> &mut S {
        let i = (now_sec % self.stamps.len() as u64) as usize;
        if self.stamps[i] != now_sec {
            self.slots[i].reset();
            self.stamps[i] = now_sec;
        }
        &mut self.slots[i]
    }

    /// Merge the buckets of the last `span_secs` seconds (current
    /// partial second included) into `out`, which is reset first.
    pub fn merged_into(&self, now_sec: u64, span_secs: u64, out: &mut S) {
        out.reset();
        let span = span_secs.min(self.stamps.len() as u64).max(1);
        let first = now_sec.saturating_sub(span - 1);
        for sec in first..=now_sec {
            let i = (sec % self.stamps.len() as u64) as usize;
            if self.stamps[i] == sec {
                self.slots[i].merge_into(out);
            }
        }
    }

    /// Allocating convenience: the merged view as a fresh bucket.
    pub fn merged(&self, now_sec: u64, span_secs: u64) -> S {
        let mut out = S::default();
        self.merged_into(now_sec, span_secs, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotation_at_second_boundaries_keeps_buckets_separate() {
        let mut w = WindowedHistogram::new(0.0, 100.0, 100, 8);
        w.record(5, 10.0);
        w.record(5, 10.0);
        w.record(6, 90.0);
        // The 1s view at sec 6 sees only sec 6's samples…
        assert_eq!(w.count(6, 1), 1);
        let h = w.merged(6, 1);
        assert!((89.0..91.5).contains(&h.quantile(0.5)), "{}", h.quantile(0.5));
        // …and the 2s view merges both seconds.
        assert_eq!(w.count(6, 2), 3);
        // Recording again into sec 5 lands in the *same* bucket (no
        // reset at a boundary already stamped).
        w.record(5, 10.0);
        assert_eq!(w.count(6, 2), 4);
    }

    #[test]
    fn merged_window_quantiles_agree_with_single_histogram() {
        // Two "shards" record disjoint sample streams across 3 seconds;
        // the union of their merged windows must match one histogram
        // that saw every sample — the mergeability contract the fleet
        // view relies on.
        let mut shard_a = WindowedHistogram::new(0.0, 1000.0, 200, 16);
        let mut shard_b = WindowedHistogram::new(0.0, 1000.0, 200, 16);
        let mut reference = Histogram::new(0.0, 1000.0, 200);
        for sec in 10..13u64 {
            for i in 0..100 {
                let xa = (i as f64) + (sec as f64);
                let xb = 500.0 + (i as f64) * 2.0 + (sec as f64);
                shard_a.record(sec, xa);
                shard_b.record(sec, xb);
                reference.push(xa);
                reference.push(xb);
            }
        }
        let mut fleet = shard_a.merged(12, 3);
        fleet.merge(&shard_b.merged(12, 3));
        assert_eq!(fleet.count(), reference.count());
        for q in [0.5, 0.9, 0.95, 0.99] {
            assert_eq!(fleet.quantile(q), reference.quantile(q), "q={q}");
        }
    }

    #[test]
    fn stale_windows_age_out_after_idle_gaps() {
        let mut w = WindowedHistogram::new(0.0, 100.0, 10, 8);
        for _ in 0..50 {
            w.record(3, 42.0);
        }
        assert!(w.count(3, 1) == 50);
        // A long idle gap: the view at a much later second must be
        // empty (no frozen p99 from the old burst)…
        assert_eq!(w.count(120, 8), 0);
        assert_eq!(w.merged(120, 8).quantile(0.99), 0.0);
        // …including the aliasing case where the later second maps to
        // the *same slot* as the stale burst (3 % 8 == 83 % 8).
        assert_eq!(w.count(83, 1), 0);
        w.record(83, 7.0);
        assert_eq!(w.count(83, 1), 1, "slot reuse must reset the stale bucket");
        let h = w.merged(83, 1);
        assert!(h.quantile(0.99) < 12.0, "stale samples leaked: {}", h.quantile(0.99));
    }

    #[test]
    fn merged_into_reuses_scratch_without_leaking_prior_state() {
        let mut w = WindowedHistogram::new(0.0, 10.0, 10, 4);
        w.record(0, 1.0);
        let mut scratch = Histogram::new(0.0, 10.0, 10);
        scratch.push(9.0);
        w.merged_into(0, 1, &mut scratch);
        assert_eq!(scratch.count(), 1);
        assert!(scratch.quantile(0.99) < 2.5, "{}", scratch.quantile(0.99));
    }

    #[test]
    fn counter_sums_span_and_ages_out() {
        let mut c = WindowedCounter::new(8);
        c.add(10, 5);
        c.add(11, 7);
        c.add(12, 1);
        assert_eq!(c.sum(12, 1), 1);
        assert_eq!(c.sum(12, 3), 13);
        assert_eq!(c.sum(12, 100), 13, "span clamps to the ring");
        // Idle gap: everything ages out by stamp.
        assert_eq!(c.sum(1000, 8), 0);
        // Slot aliasing after a full lap resets, not accumulates.
        c.add(18, 2); // 18 % 8 == 10 % 8
        assert_eq!(c.sum(18, 1), 2);
    }

    #[test]
    fn generic_slots_rotate_and_age_like_the_counter_ring() {
        #[derive(Debug, Default, Clone)]
        struct SumMax {
            sum: u64,
            max: u64,
        }
        impl RingSlot for SumMax {
            fn reset(&mut self) {
                self.sum = 0;
                self.max = 0;
            }
            fn merge_into(&self, out: &mut Self) {
                out.sum += self.sum;
                out.max = out.max.max(self.max);
            }
        }
        let mut w: WindowedSlots<SumMax> = WindowedSlots::new(8);
        let s = w.slot_mut(10);
        s.sum += 5;
        s.max = s.max.max(5);
        let s = w.slot_mut(11);
        s.sum += 7;
        s.max = s.max.max(7);
        let v = w.merged(11, 2);
        assert_eq!(v.sum, 12);
        assert_eq!(v.max, 7);
        assert_eq!(w.merged(11, 1).sum, 7);
        // Idle gap ages out by stamp; slot aliasing resets in place.
        assert_eq!(w.merged(1000, 8).sum, 0);
        assert_eq!(w.slot_mut(18).sum, 0, "18 % 8 aliases 10 % 8: must reset");
    }

    #[test]
    fn span_longer_than_ring_is_clamped() {
        let mut w = WindowedHistogram::new(0.0, 10.0, 10, 4);
        for sec in 0..10u64 {
            w.record(sec, 5.0);
        }
        // Only the last 4 seconds survive in a 4-slot ring.
        assert_eq!(w.count(9, 60), 4);
    }
}

//! Running statistics used across the standardization pipeline and the
//! experiment reporting.
//!
//! - [`welford`] — the paper's Eq. (6)–(9): running mean / running std via
//!   Welford's algorithm, the arithmetic core of *dynamic standardization*.
//! - [`rolling`] — fixed-window rolling average (Fig. 10 plots a rolling
//!   average over 1000 readings).
//! - [`histogram`] — fixed-bin histograms (Fig. 2 value distributions),
//!   mergeable bin-wise for windowed and cross-shard views.
//! - [`summary`] — batch summary statistics (mean/std/min/max/percentiles).
//! - [`windowed`] — rings of per-second histogram/counter buckets: the
//!   live-telemetry substrate behind `MetricsSnapshot`'s `last_1s/10s/60s`
//!   views (rotation on the recording path, zero steady-state allocation).

pub mod histogram;
pub mod rolling;
pub mod summary;
pub mod welford;
pub mod windowed;

pub use histogram::Histogram;
pub use rolling::RollingMean;
pub use summary::Summary;
pub use welford::Welford;
pub use windowed::{WindowedCounter, WindowedHistogram};

//! The PPO update phase: minibatched PPO-clip/Adam steps through the
//! `train_step` HLO artifact (paper Algorithm 1 lines 6–7; §III-A
//! "Actor-Critic Losses Calculation" + "Back Propagation and Networks
//! Update").

use super::gae_stage::GaeResult;
use super::profiler::{Phase, PhaseProfiler};
use super::rollout::Rollout;
use crate::runtime::{Runtime, Tensor};
use crate::util::Rng;

/// Optimizer + network state held by the coordinator between updates
/// (flat vectors; layer structure lives only inside the artifact).
#[derive(Debug, Clone)]
pub struct NetState {
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub step: f32,
}

impl NetState {
    pub fn fresh(params: Vec<f32>) -> NetState {
        let n = params.len();
        NetState { params, adam_m: vec![0.0; n], adam_v: vec![0.0; n], step: 0.0 }
    }
}

/// Per-update loss diagnostics (means over minibatches).
#[derive(Debug, Clone, Copy, Default)]
pub struct Losses {
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub minibatches: usize,
}

/// Standardize advantages in place (§V-A — used by every modern PPO
/// implementation; Fig. 7 ablates it).
pub fn standardize_advantages(adv: &mut [f32]) {
    if adv.is_empty() {
        return;
    }
    let n = adv.len() as f64;
    let mean = adv.iter().map(|&a| a as f64).sum::<f64>() / n;
    let var = adv.iter().map(|&a| (a as f64 - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-8);
    for a in adv.iter_mut() {
        *a = ((*a as f64 - mean) / std) as f32;
    }
}

/// PPO update hyper-parameters for one call.
#[derive(Debug, Clone, Copy)]
pub struct UpdateParams {
    pub epochs: usize,
    pub lr: f32,
    pub clip_eps: f32,
    pub ent_coef: f32,
    pub standardize_advantages: bool,
}

/// One planned minibatch: the source rows, plus — when pre-gathered —
/// the tensors that do not depend on the GAE result.
#[derive(Debug, Clone)]
pub struct MinibatchPlan {
    pub rows: Vec<usize>,
    /// Pre-gathered planes (empty when the plan was built without
    /// pre-gathering; [`execute_update`] gathers on demand then).
    pub obs: Vec<f32>,
    pub actions: Vec<f32>,
    pub old_logp: Vec<f32>,
}

/// The advantage-independent half of a PPO update, prepared up front.
///
/// In the pipelined trainer this is built *while the GAE service is
/// computing*: the epoch permutations (consuming the shared RNG stream
/// in exactly the order the sequential path does — the stream does not
/// depend on execution results) and, with `pregather`, the
/// obs/action/log-prob gathers need only the rollout.
/// [`execute_update`] then gathers the advantage and return columns and
/// runs the `train_step` artifact.
#[derive(Debug, Clone)]
pub struct UpdatePlan {
    pub minibatch: usize,
    pub discrete: bool,
    pub act_dim: usize,
    pub pregathered: bool,
    pub batches: Vec<MinibatchPlan>,
}

fn gather_rows(rollout: &Rollout, rows: &[usize]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let obs_dim = rollout.obs_dim;
    let aw = rollout.act_width;
    let mut obs = Vec::with_capacity(rows.len() * obs_dim);
    let mut actions = Vec::with_capacity(rows.len() * aw);
    let mut old_logp = Vec::with_capacity(rows.len());
    for &row in rows {
        obs.extend_from_slice(&rollout.obs[row * obs_dim..(row + 1) * obs_dim]);
        actions.extend_from_slice(&rollout.actions[row * aw..(row + 1) * aw]);
        old_logp.push(rollout.logp[row]);
    }
    (obs, actions, old_logp)
}

/// Draw the epoch permutations (and, with `pregather`, the
/// advantage-independent minibatch tensors — pre-gathering holds
/// `epochs` gathered copies of the rollout resident at once, so only
/// the overlapped schedule, which hides that work under the GAE wait,
/// asks for it). Leftover rows that do not fill a final minibatch are
/// dropped that epoch (they reappear under the next shuffle — standard
/// practice).
pub fn prepare_update(
    runtime: &Runtime,
    artifact: &str,
    rollout: &Rollout,
    epochs: usize,
    rng: &mut Rng,
    pregather: bool,
) -> anyhow::Result<UpdatePlan> {
    let exe = runtime.load(artifact)?;
    let minibatch = exe.spec.meta_usize("minibatch")?;
    let discrete = exe.spec.meta_bool("discrete")?;
    let act_dim = exe.spec.meta_usize("act_dim")?;
    let n = rollout.transitions();
    anyhow::ensure!(
        n >= minibatch,
        "rollout of {n} rows cannot fill a {minibatch}-row minibatch"
    );
    let mut batches = Vec::with_capacity(epochs * (n / minibatch));
    for _epoch in 0..epochs {
        let perm = rng.permutation(n);
        for chunk in perm.chunks_exact(minibatch) {
            let (obs, actions, old_logp) = if pregather {
                gather_rows(rollout, chunk)
            } else {
                (Vec::new(), Vec::new(), Vec::new())
            };
            batches.push(MinibatchPlan { rows: chunk.to_vec(), obs, actions, old_logp });
        }
    }
    Ok(UpdatePlan { minibatch, discrete, act_dim, pregathered: pregather, batches })
}

/// Run the planned minibatches through the `train_step` artifact.
/// Consumes the plan so pre-gathered planes move straight into the
/// input tensors.
#[allow(clippy::too_many_arguments)]
pub fn execute_update(
    runtime: &Runtime,
    artifact: &str,
    state: &mut NetState,
    rollout: &Rollout,
    gae: &GaeResult,
    plan: UpdatePlan,
    up: &UpdateParams,
    profiler: &mut PhaseProfiler,
) -> anyhow::Result<Losses> {
    let exe = runtime.load(artifact)?;
    let minibatch = plan.minibatch;
    let obs_dim = rollout.obs_dim;
    let (discrete, act_dim, pregathered) = (plan.discrete, plan.act_dim, plan.pregathered);

    let mut advantages = gae.advantages.clone();
    if up.standardize_advantages {
        standardize_advantages(&mut advantages);
    }

    let mut losses = Losses::default();
    for mb in plan.batches {
        let (obs, actions, old_logp) = if pregathered {
            (mb.obs, mb.actions, mb.old_logp)
        } else {
            gather_rows(rollout, &mb.rows)
        };
        let mut adv = Vec::with_capacity(minibatch);
        let mut ret = Vec::with_capacity(minibatch);
        for &row in &mb.rows {
            adv.push(advantages[row]);
            ret.push(gae.rewards_to_go[row]);
        }
        let act_shape = if discrete {
            vec![minibatch]
        } else {
            vec![minibatch, act_dim]
        };
        let inputs = vec![
            Tensor::vec1(state.params.clone()),
            Tensor::vec1(state.adam_m.clone()),
            Tensor::vec1(state.adam_v.clone()),
            Tensor::scalar(state.step),
            Tensor::new(obs, vec![minibatch, obs_dim]),
            Tensor::new(actions, act_shape),
            Tensor::vec1(old_logp),
            Tensor::vec1(adv),
            Tensor::vec1(ret),
            Tensor::scalar(up.lr),
            Tensor::scalar(up.clip_eps),
            Tensor::scalar(up.ent_coef),
        ];
        let out = profiler.time(Phase::NetworkUpdate, || exe.call(&inputs))?;
        state.params = out[0].data.clone();
        state.adam_m = out[1].data.clone();
        state.adam_v = out[2].data.clone();
        state.step = out[3].data[0];
        losses.pi_loss += out[4].data[0];
        losses.v_loss += out[4].data[1];
        losses.entropy += out[4].data[2];
        losses.minibatches += 1;
    }
    if losses.minibatches > 0 {
        let k = losses.minibatches as f32;
        losses.pi_loss /= k;
        losses.v_loss /= k;
        losses.entropy /= k;
    }
    Ok(losses)
}

/// Run the PPO update: `epochs` passes of shuffled minibatches
/// ([`prepare_update`] + [`execute_update`] back to back — the
/// sequential trainer's path; the pipelined trainer splits the halves
/// around the GAE service wait).
#[allow(clippy::too_many_arguments)]
pub fn update(
    runtime: &Runtime,
    artifact: &str,
    state: &mut NetState,
    rollout: &Rollout,
    gae: &GaeResult,
    up: &UpdateParams,
    rng: &mut Rng,
    profiler: &mut PhaseProfiler,
) -> anyhow::Result<Losses> {
    // No pre-gathering on the sequential path: there is no wait to hide
    // the gathers under, so they happen per minibatch as executed.
    let plan = prepare_update(runtime, artifact, rollout, up.epochs, rng, false)?;
    execute_update(runtime, artifact, state, rollout, gae, plan, up, profiler)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_advantages_moments() {
        let mut adv: Vec<f32> = (0..1000).map(|i| (i as f32) * 0.01 + 5.0).collect();
        standardize_advantages(&mut adv);
        let mean: f64 = adv.iter().map(|&a| a as f64).sum::<f64>() / 1000.0;
        let var: f64 =
            adv.iter().map(|&a| (a as f64 - mean).powi(2)).sum::<f64>() / 1000.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn standardize_handles_degenerate() {
        let mut adv = vec![3.0f32; 8];
        standardize_advantages(&mut adv);
        assert!(adv.iter().all(|a| a.is_finite()));
        let mut empty: Vec<f32> = vec![];
        standardize_advantages(&mut empty);
    }
}

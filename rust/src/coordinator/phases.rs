//! The PS↔PL phase machine (paper §III-A "Data Flow, Processing, and
//! Efficiency") and its pipelined extension.
//!
//! All subsystems communicate through BRAM; the PS raises an *initiate*
//! control signal into the PL clock domain and waits for *done* — each
//! crossing costs a synchronizer latency ([`crate::hwsim::clock`]). A
//! single [`PhaseMachine`] enforces the legal ordering for one
//! in-flight iteration:
//!
//! ```text
//! Idle → TrajectoryCollection → DataPrep → GaeCompute → LossAndUpdate → Idle/…
//! ```
//!
//! The pipelined trainer keeps *several* iterations in flight at once
//! (iteration *i+1* collects while iteration *i* runs GAE/update).
//! [`PipelineLanes`] models that: one `PhaseMachine` lane per in-flight
//! iteration, each still bound to the sequential ordering above, plus a
//! cross-lane occupancy rule — no two lanes may hold the same non-idle
//! phase, because each phase owns a single hardware resource (the env
//! cores, the GAE row array, the update engine). Handshake overhead is
//! accounted per lane and summed for reporting.

use crate::hwsim::clock::handshake_overhead;
use std::time::Duration;

/// SoC pipeline phases (one PPO iteration traverses all of them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SocPhase {
    Idle,
    /// Env stepping + DNN inference + pushing quantized (r, v) rows.
    TrajectoryCollection,
    /// PS finalizes block statistics, arms the accelerator.
    DataPrep,
    /// PL computes advantages/RTGs in the BRAM stack.
    GaeCompute,
    /// PS computes losses, PL applies backprop/update.
    LossAndUpdate,
}

impl SocPhase {
    /// Legal successors.
    pub fn can_transition_to(self, next: SocPhase) -> bool {
        use SocPhase::*;
        matches!(
            (self, next),
            (Idle, TrajectoryCollection)
                | (TrajectoryCollection, DataPrep)
                | (DataPrep, GaeCompute)
                | (GaeCompute, LossAndUpdate)
                | (LossAndUpdate, Idle)
                | (LossAndUpdate, TrajectoryCollection)
        )
    }

    /// Does entering this phase cross the PS/PL boundary (costing a
    /// handshake)?
    pub fn crosses_domain(self) -> bool {
        matches!(self, SocPhase::GaeCompute | SocPhase::LossAndUpdate)
    }
}

/// Error for illegal phase transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseError {
    pub from: SocPhase,
    pub to: SocPhase,
}

impl std::fmt::Display for PhaseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "illegal SoC phase transition {:?} -> {:?}",
            self.from, self.to
        )
    }
}

impl std::error::Error for PhaseError {}

/// The sequencer.
#[derive(Debug)]
pub struct PhaseMachine {
    current: SocPhase,
    handshakes: u64,
    overhead: Duration,
    transitions: u64,
}

impl Default for PhaseMachine {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseMachine {
    pub fn new() -> Self {
        PhaseMachine {
            current: SocPhase::Idle,
            handshakes: 0,
            overhead: Duration::ZERO,
            transitions: 0,
        }
    }

    pub fn current(&self) -> SocPhase {
        self.current
    }

    /// Transition, accounting handshake overhead on domain crossings.
    pub fn transition(&mut self, next: SocPhase) -> Result<(), PhaseError> {
        if !self.current.can_transition_to(next) {
            return Err(PhaseError { from: self.current, to: next });
        }
        if next.crosses_domain() {
            self.handshakes += 1;
            self.overhead += handshake_overhead();
        }
        self.current = next;
        self.transitions += 1;
        Ok(())
    }

    /// PS→PL round trips performed.
    pub fn handshakes(&self) -> u64 {
        self.handshakes
    }

    /// Accumulated synchronizer overhead (nanoseconds-scale; the §III-A
    /// claim is that this is negligible next to DRAM round trips).
    pub fn overhead(&self) -> Duration {
        self.overhead
    }

    pub fn transitions(&self) -> u64 {
        self.transitions
    }
}

/// Error from a [`PipelineLanes`] transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneError {
    /// The lane's own machine rejected the ordering.
    Transition { lane: usize, err: PhaseError },
    /// Another lane currently occupies the target phase (each phase is a
    /// single hardware resource).
    Occupied { lane: usize, phase: SocPhase, by: usize },
    /// No such lane.
    NoSuchLane { lane: usize, lanes: usize },
}

impl std::fmt::Display for LaneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaneError::Transition { lane, err } => {
                write!(f, "lane {lane}: {err}")
            }
            LaneError::Occupied { lane, phase, by } => write!(
                f,
                "lane {lane}: phase {phase:?} is occupied by lane {by}"
            ),
            LaneError::NoSuchLane { lane, lanes } => {
                write!(f, "lane {lane} out of range ({lanes} lanes)")
            }
        }
    }
}

impl std::error::Error for LaneError {}

/// The overlapped phase model: one [`PhaseMachine`] per in-flight
/// iteration. Every lane still rejects illegal orderings; additionally a
/// non-idle phase may be held by at most one lane at a time.
#[derive(Debug)]
pub struct PipelineLanes {
    lanes: Vec<PhaseMachine>,
}

impl PipelineLanes {
    /// `lanes` = maximum iterations in flight (1 = strictly sequential).
    pub fn new(lanes: usize) -> Self {
        assert!(lanes >= 1, "need at least one lane");
        PipelineLanes {
            lanes: (0..lanes).map(|_| PhaseMachine::new()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// Borrow one lane's machine (read-only; transitions go through
    /// [`PipelineLanes::transition`] so occupancy stays enforced).
    pub fn lane(&self, lane: usize) -> &PhaseMachine {
        &self.lanes[lane]
    }

    pub fn current(&self, lane: usize) -> SocPhase {
        self.lanes[lane].current()
    }

    /// Which lane holds `phase`, if any.
    pub fn occupant(&self, phase: SocPhase) -> Option<usize> {
        self.lanes.iter().position(|m| m.current() == phase)
    }

    /// Advance one lane, enforcing both the lane-local ordering and the
    /// cross-lane occupancy rule.
    pub fn transition(&mut self, lane: usize, next: SocPhase) -> Result<(), LaneError> {
        if lane >= self.lanes.len() {
            return Err(LaneError::NoSuchLane { lane, lanes: self.lanes.len() });
        }
        if next != SocPhase::Idle {
            if let Some(by) = self.occupant(next) {
                if by != lane {
                    return Err(LaneError::Occupied { lane, phase: next, by });
                }
            }
        }
        self.lanes[lane]
            .transition(next)
            .map_err(|err| LaneError::Transition { lane, err })
    }

    /// PS→PL round trips summed over every lane.
    pub fn handshakes(&self) -> u64 {
        self.lanes.iter().map(|m| m.handshakes()).sum()
    }

    /// Synchronizer overhead summed over every lane.
    pub fn overhead(&self) -> Duration {
        self.lanes.iter().map(|m| m.overhead()).sum()
    }

    /// Transitions summed over every lane.
    pub fn transitions(&self) -> u64 {
        self.lanes.iter().map(|m| m.transitions()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use SocPhase::*;

    #[test]
    fn full_iteration_cycle() {
        let mut m = PhaseMachine::new();
        for p in [TrajectoryCollection, DataPrep, GaeCompute, LossAndUpdate] {
            m.transition(p).unwrap();
        }
        // Loop straight into the next iteration.
        m.transition(TrajectoryCollection).unwrap();
        assert_eq!(m.transitions(), 5);
        assert_eq!(m.handshakes(), 2); // GaeCompute + LossAndUpdate
    }

    #[test]
    fn illegal_jumps_rejected() {
        let mut m = PhaseMachine::new();
        assert_eq!(
            m.transition(GaeCompute),
            Err(PhaseError { from: Idle, to: GaeCompute })
        );
        m.transition(TrajectoryCollection).unwrap();
        assert!(m.transition(LossAndUpdate).is_err());
        assert_eq!(m.current(), TrajectoryCollection);
    }

    #[test]
    fn overhead_is_nanoseconds_per_iteration() {
        let mut m = PhaseMachine::new();
        for _ in 0..1000 {
            m.transition(TrajectoryCollection).unwrap();
            m.transition(DataPrep).unwrap();
            m.transition(GaeCompute).unwrap();
            m.transition(LossAndUpdate).unwrap();
            m.transition(Idle).unwrap();
        }
        // 2 handshakes × ~8 ns × 1000 iterations « 1 ms.
        assert!(m.overhead() < Duration::from_millis(1));
        assert_eq!(m.handshakes(), 2000);
    }

    #[test]
    fn overlapped_lanes_interleave_legally() {
        // The steady-state pipeline schedule: lane 1 collects while lane
        // 0 runs GAE + update.
        let mut p = PipelineLanes::new(2);
        p.transition(0, TrajectoryCollection).unwrap();
        p.transition(0, DataPrep).unwrap();
        p.transition(1, TrajectoryCollection).unwrap(); // overlap begins
        p.transition(0, GaeCompute).unwrap();
        p.transition(0, LossAndUpdate).unwrap();
        p.transition(0, Idle).unwrap();
        p.transition(1, DataPrep).unwrap();
        p.transition(0, TrajectoryCollection).unwrap(); // lane 0 re-enters
        p.transition(1, GaeCompute).unwrap();
        // Both iterations crossed into the PL twice each so far minus
        // lane 1's pending LossAndUpdate.
        assert_eq!(p.handshakes(), 3);
        assert!(p.overhead() > Duration::ZERO);
    }

    #[test]
    fn overlapped_lanes_still_reject_illegal_orderings() {
        let mut p = PipelineLanes::new(2);
        // A lane cannot skip phases even when the pipeline is idle.
        assert_eq!(
            p.transition(1, GaeCompute),
            Err(LaneError::Transition {
                lane: 1,
                err: PhaseError { from: Idle, to: GaeCompute },
            })
        );
        p.transition(0, TrajectoryCollection).unwrap();
        assert!(matches!(
            p.transition(0, LossAndUpdate),
            Err(LaneError::Transition { lane: 0, .. })
        ));
        // The failed transition must not advance the lane.
        assert_eq!(p.current(0), TrajectoryCollection);
    }

    #[test]
    fn phase_occupancy_is_exclusive_across_lanes() {
        let mut p = PipelineLanes::new(2);
        p.transition(0, TrajectoryCollection).unwrap();
        // Lane 1 cannot also collect: the env cores are one resource.
        assert_eq!(
            p.transition(1, TrajectoryCollection),
            Err(LaneError::Occupied {
                lane: 1,
                phase: TrajectoryCollection,
                by: 0
            })
        );
        // Once lane 0 moves on, lane 1 may enter.
        p.transition(0, DataPrep).unwrap();
        p.transition(1, TrajectoryCollection).unwrap();
        assert_eq!(p.occupant(TrajectoryCollection), Some(1));
        // Both lanes may be Idle at once (Idle is not a resource).
        let mut q = PipelineLanes::new(3);
        q.transition(1, TrajectoryCollection).unwrap();
        for ph in [DataPrep, GaeCompute, LossAndUpdate, Idle] {
            q.transition(1, ph).unwrap();
        }
        assert_eq!(q.occupant(Idle), Some(0)); // first of the idle lanes
    }

    #[test]
    fn lane_bounds_checked() {
        let mut p = PipelineLanes::new(1);
        assert_eq!(
            p.transition(3, TrajectoryCollection),
            Err(LaneError::NoSuchLane { lane: 3, lanes: 1 })
        );
        assert_eq!(p.len(), 1);
    }

    #[test]
    fn single_lane_matches_plain_machine() {
        // PipelineLanes::new(1) must behave exactly like PhaseMachine.
        let mut p = PipelineLanes::new(1);
        let mut m = PhaseMachine::new();
        for ph in [TrajectoryCollection, DataPrep, GaeCompute, LossAndUpdate, Idle] {
            p.transition(0, ph).unwrap();
            m.transition(ph).unwrap();
        }
        assert_eq!(p.handshakes(), m.handshakes());
        assert_eq!(p.transitions(), m.transitions());
        assert_eq!(p.overhead(), m.overhead());
    }

    #[test]
    fn lane_error_messages_are_descriptive() {
        let e = LaneError::Occupied { lane: 1, phase: GaeCompute, by: 0 };
        assert!(e.to_string().contains("occupied by lane 0"), "{e}");
        let e = LaneError::NoSuchLane { lane: 9, lanes: 2 };
        assert!(e.to_string().contains("out of range"), "{e}");
    }
}

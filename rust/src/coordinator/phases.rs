//! The PS↔PL phase machine (paper §III-A "Data Flow, Processing, and
//! Efficiency").
//!
//! All subsystems operate sequentially and communicate through BRAM; the
//! PS raises an *initiate* control signal into the PL clock domain and
//! waits for *done* — each crossing costs a synchronizer latency
//! ([`crate::hwsim::clock`]). The machine enforces the legal ordering:
//!
//! ```text
//! Idle → TrajectoryCollection → DataPrep → GaeCompute → LossAndUpdate → Idle/…
//! ```

use crate::hwsim::clock::handshake_overhead;
use std::time::Duration;

/// SoC pipeline phases (one PPO iteration traverses all of them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SocPhase {
    Idle,
    /// Env stepping + DNN inference + pushing quantized (r, v) rows.
    TrajectoryCollection,
    /// PS finalizes block statistics, arms the accelerator.
    DataPrep,
    /// PL computes advantages/RTGs in the BRAM stack.
    GaeCompute,
    /// PS computes losses, PL applies backprop/update.
    LossAndUpdate,
}

impl SocPhase {
    /// Legal successors.
    pub fn can_transition_to(self, next: SocPhase) -> bool {
        use SocPhase::*;
        matches!(
            (self, next),
            (Idle, TrajectoryCollection)
                | (TrajectoryCollection, DataPrep)
                | (DataPrep, GaeCompute)
                | (GaeCompute, LossAndUpdate)
                | (LossAndUpdate, Idle)
                | (LossAndUpdate, TrajectoryCollection)
        )
    }

    /// Does entering this phase cross the PS/PL boundary (costing a
    /// handshake)?
    pub fn crosses_domain(self) -> bool {
        matches!(self, SocPhase::GaeCompute | SocPhase::LossAndUpdate)
    }
}

/// Error for illegal phase transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseError {
    pub from: SocPhase,
    pub to: SocPhase,
}

impl std::fmt::Display for PhaseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "illegal SoC phase transition {:?} -> {:?}",
            self.from, self.to
        )
    }
}

impl std::error::Error for PhaseError {}

/// The sequencer.
#[derive(Debug)]
pub struct PhaseMachine {
    current: SocPhase,
    handshakes: u64,
    overhead: Duration,
    transitions: u64,
}

impl Default for PhaseMachine {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseMachine {
    pub fn new() -> Self {
        PhaseMachine {
            current: SocPhase::Idle,
            handshakes: 0,
            overhead: Duration::ZERO,
            transitions: 0,
        }
    }

    pub fn current(&self) -> SocPhase {
        self.current
    }

    /// Transition, accounting handshake overhead on domain crossings.
    pub fn transition(&mut self, next: SocPhase) -> Result<(), PhaseError> {
        if !self.current.can_transition_to(next) {
            return Err(PhaseError { from: self.current, to: next });
        }
        if next.crosses_domain() {
            self.handshakes += 1;
            self.overhead += handshake_overhead();
        }
        self.current = next;
        self.transitions += 1;
        Ok(())
    }

    /// PS→PL round trips performed.
    pub fn handshakes(&self) -> u64 {
        self.handshakes
    }

    /// Accumulated synchronizer overhead (nanoseconds-scale; the §III-A
    /// claim is that this is negligible next to DRAM round trips).
    pub fn overhead(&self) -> Duration {
        self.overhead
    }

    pub fn transitions(&self) -> u64 {
        self.transitions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use SocPhase::*;

    #[test]
    fn full_iteration_cycle() {
        let mut m = PhaseMachine::new();
        for p in [TrajectoryCollection, DataPrep, GaeCompute, LossAndUpdate] {
            m.transition(p).unwrap();
        }
        // Loop straight into the next iteration.
        m.transition(TrajectoryCollection).unwrap();
        assert_eq!(m.transitions(), 5);
        assert_eq!(m.handshakes(), 2); // GaeCompute + LossAndUpdate
    }

    #[test]
    fn illegal_jumps_rejected() {
        let mut m = PhaseMachine::new();
        assert_eq!(
            m.transition(GaeCompute),
            Err(PhaseError { from: Idle, to: GaeCompute })
        );
        m.transition(TrajectoryCollection).unwrap();
        assert!(m.transition(LossAndUpdate).is_err());
        assert_eq!(m.current(), TrajectoryCollection);
    }

    #[test]
    fn overhead_is_nanoseconds_per_iteration() {
        let mut m = PhaseMachine::new();
        for _ in 0..1000 {
            m.transition(TrajectoryCollection).unwrap();
            m.transition(DataPrep).unwrap();
            m.transition(GaeCompute).unwrap();
            m.transition(LossAndUpdate).unwrap();
            m.transition(Idle).unwrap();
        }
        // 2 handshakes × ~8 ns × 1000 iterations « 1 ms.
        assert!(m.overhead() < Duration::from_millis(1));
        assert_eq!(m.handshakes(), 2000);
    }
}

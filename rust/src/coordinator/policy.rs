//! Action sampling + log-prob math on the PS side.
//!
//! The `policy_fwd` artifact returns distribution parameters (logits for
//! discrete heads; mean‖log_std for continuous); the coordinator samples
//! actions and evaluates log π(a|s) in rust — the same split as the
//! paper's SoC, where the PL produces network outputs and the PS handles
//! the (cheap, irregular) sampling.

use crate::envs::{Action, ActionSpace};
use crate::util::Rng;

/// Sampled action + its log-probability.
#[derive(Debug, Clone)]
pub struct Sampled {
    pub action: Action,
    pub logp: f32,
    /// Flat f32 encoding fed back to the train_step artifact
    /// (discrete: [index]; continuous: the raw pre-clip sample).
    pub encoded: Vec<f32>,
}

/// log softmax of a row (numerically stable).
fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&l| ((l - max) as f64).exp()).sum::<f64>().ln() as f32 + max;
    logits.iter().map(|&l| l - lse).collect()
}

/// Sample one action from a distribution row.
///
/// `dist_row`: `[A]` logits (discrete) or `[2A]` mean‖log_std
/// (continuous).
pub fn sample(space: &ActionSpace, dist_row: &[f32], rng: &mut Rng) -> Sampled {
    match space {
        ActionSpace::Discrete(n) => {
            assert_eq!(dist_row.len(), *n, "logit width");
            let a = rng.categorical_from_logits(dist_row);
            let logp = log_softmax(dist_row)[a];
            Sampled {
                action: Action::Discrete(a),
                logp,
                encoded: vec![a as f32],
            }
        }
        ActionSpace::Continuous { dim, low, high } => {
            assert_eq!(dist_row.len(), 2 * dim, "mean/log_std width");
            let (mean, log_std) = dist_row.split_at(*dim);
            let mut raw = Vec::with_capacity(*dim);
            let mut logp = 0.0f64;
            for k in 0..*dim {
                let std = log_std[k].exp();
                let z = rng.normal() as f32;
                let a = mean[k] + std * z;
                raw.push(a);
                logp += -0.5 * (z as f64) * (z as f64)
                    - log_std[k] as f64
                    - 0.5 * (2.0 * std::f64::consts::PI).ln();
            }
            let clipped: Vec<f32> =
                raw.iter().map(|&a| a.clamp(*low, *high)).collect();
            Sampled {
                action: Action::Continuous(clipped),
                logp: logp as f32,
                encoded: raw,
            }
        }
    }
}

/// log π(a|s) of an already-encoded action under a (possibly updated)
/// distribution row — the post-update re-evaluation behind the
/// approx-KL and clip-fraction learning-health scalars. Consumes no
/// RNG, so emitting the diagnostics never perturbs a run's sampled
/// trajectory. For continuous heads `encoded` is the raw pre-clip
/// sample, exactly what [`sample`] scored, so
/// `logp_of(space, same_row, &s.encoded) == s.logp` up to fp noise.
pub fn logp_of(space: &ActionSpace, dist_row: &[f32], encoded: &[f32]) -> f32 {
    match space {
        ActionSpace::Discrete(n) => {
            assert_eq!(dist_row.len(), *n, "logit width");
            let a = encoded[0] as usize;
            log_softmax(dist_row)[a]
        }
        ActionSpace::Continuous { dim, .. } => {
            assert_eq!(dist_row.len(), 2 * dim, "mean/log_std width");
            assert_eq!(encoded.len(), *dim, "encoded action width");
            let (mean, log_std) = dist_row.split_at(*dim);
            let mut logp = 0.0f64;
            for k in 0..*dim {
                let std = (log_std[k] as f64).exp();
                let z = (encoded[k] as f64 - mean[k] as f64) / std;
                logp += -0.5 * z * z
                    - log_std[k] as f64
                    - 0.5 * (2.0 * std::f64::consts::PI).ln();
            }
            logp as f32
        }
    }
}

/// Greedy (mode) action — used by evaluation rollouts.
pub fn greedy(space: &ActionSpace, dist_row: &[f32]) -> Action {
    match space {
        ActionSpace::Discrete(n) => {
            let a = dist_row[..*n]
                .iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0;
            Action::Discrete(a)
        }
        ActionSpace::Continuous { dim, low, high } => Action::Continuous(
            dist_row[..*dim].iter().map(|&m| m.clamp(*low, *high)).collect(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn log_softmax_normalizes() {
        check("log_softmax sums to 1", 30, |g| {
            let n = g.usize_in(2, 10);
            let logits = g.vec_normal_f32(n, 0.0, 3.0);
            let ls = log_softmax(&logits);
            let sum: f64 = ls.iter().map(|&l| (l as f64).exp()).sum();
            assert!((sum - 1.0).abs() < 1e-5, "sum={sum}");
        });
    }

    #[test]
    fn discrete_sampling_frequencies_match() {
        let mut rng = Rng::new(1);
        let space = ActionSpace::Discrete(3);
        let logits = [0.0f32, 1.0, 2.0];
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            let s = sample(&space, &logits, &mut rng);
            match s.action {
                Action::Discrete(a) => counts[a] += 1,
                _ => unreachable!(),
            }
            // logp consistency with the softmax.
            let ls = log_softmax(&logits);
            match s.action {
                Action::Discrete(a) => assert!((s.logp - ls[a]).abs() < 1e-6),
                _ => unreachable!(),
            }
        }
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        for i in 0..3 {
            let want = (logits[i] as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!((got - want).abs() < 0.02, "{i}: {got} vs {want}");
        }
    }

    #[test]
    fn continuous_sampling_moments() {
        let mut rng = Rng::new(2);
        let space = ActionSpace::Continuous { dim: 1, low: -10.0, high: 10.0 };
        let dist = [1.5f32, -0.5]; // mean 1.5, std e^-0.5
        let n = 30_000;
        let mut sum = 0.0f64;
        let mut sum2 = 0.0f64;
        for _ in 0..n {
            let s = sample(&space, &dist, &mut rng);
            let a = s.encoded[0] as f64;
            sum += a;
            sum2 += a * a;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!((mean - 1.5).abs() < 0.02);
        assert!((var.sqrt() - (-0.5f64).exp()).abs() < 0.02);
    }

    #[test]
    fn continuous_clips_action_but_not_encoding() {
        let mut rng = Rng::new(3);
        let space = ActionSpace::Continuous { dim: 1, low: -0.1, high: 0.1 };
        let dist = [5.0f32, 0.0]; // mean far outside bounds
        let s = sample(&space, &dist, &mut rng);
        match &s.action {
            Action::Continuous(a) => assert!(a[0] <= 0.1),
            _ => unreachable!(),
        }
        assert!(s.encoded[0] > 1.0, "raw sample must stay unclipped");
    }

    #[test]
    fn logp_of_agrees_with_sample() {
        check("logp_of matches sample", 30, |g| {
            // Discrete head.
            let n = g.usize_in(2, 6);
            let logits = g.vec_normal_f32(n, 0.0, 2.0);
            let space = ActionSpace::Discrete(n);
            let s = sample(&space, &logits, g.rng());
            assert!((logp_of(&space, &logits, &s.encoded) - s.logp).abs() < 1e-6);

            // Continuous head (same row → identical; shifted row → lower
            // logp for the same action, i.e. the KL numerator moves).
            let dim = g.usize_in(1, 3);
            let mut dist = g.vec_normal_f32(2 * dim, 0.0, 1.0);
            for v in dist[dim..].iter_mut() {
                *v = v.clamp(-1.0, 0.5);
            }
            let space = ActionSpace::Continuous { dim, low: -50.0, high: 50.0 };
            let s = sample(&space, &dist, g.rng());
            assert!((logp_of(&space, &dist, &s.encoded) - s.logp).abs() < 1e-4);
            let mut shifted = dist.clone();
            for v in shifted[..dim].iter_mut() {
                *v += 10.0;
            }
            assert!(logp_of(&space, &shifted, &s.encoded) < s.logp);
        });
    }

    #[test]
    fn greedy_picks_mode() {
        let a = greedy(&ActionSpace::Discrete(3), &[0.1, 2.0, -1.0]);
        assert_eq!(a, Action::Discrete(1));
        let a = greedy(
            &ActionSpace::Continuous { dim: 2, low: -1.0, high: 1.0 },
            &[0.5, -2.0, 0.0, 0.0],
        );
        assert_eq!(a, Action::Continuous(vec![0.5, -1.0]));
    }

    #[test]
    fn continuous_logp_matches_gaussian_formula() {
        check("logp formula", 30, |g| {
            let dim = g.usize_in(1, 4);
            let mut dist = g.vec_normal_f32(2 * dim, 0.0, 1.0);
            // keep log_std sane
            for v in dist[dim..].iter_mut() {
                *v = v.clamp(-2.0, 1.0);
            }
            let space = ActionSpace::Continuous { dim, low: -100.0, high: 100.0 };
            let s = sample(&space, &dist, g.rng());
            let mut want = 0.0f64;
            for k in 0..dim {
                let mean = dist[k] as f64;
                let log_std = dist[dim + k] as f64;
                let std = log_std.exp();
                let a = s.encoded[k] as f64;
                let z = (a - mean) / std;
                want += -0.5 * z * z - log_std - 0.5 * (2.0 * std::f64::consts::PI).ln();
            }
            assert!((s.logp as f64 - want).abs() < 1e-4);
        });
    }
}

//! The GAE phase: codec round trip + advantage/RTG computation through a
//! pluggable backend.
//!
//! Backends, matching the paper's evaluation axes:
//!
//! - [`GaeBackend::Scalar`] — the per-trajectory CPU loop (the ≈9000
//!   elem/s baseline of §V-D-3);
//! - [`GaeBackend::Batched`] — timestep-major batched CPU (our optimized
//!   software path);
//! - [`GaeBackend::Hlo`] — the Pallas-lowered `gae_T*_B*` artifact via
//!   PJRT (L1 kernel on the request path);
//! - [`GaeBackend::HwSim`] — the cycle-accurate accelerator model
//!   ([`crate::hwsim`]), which also yields cycle counts.

use super::profiler::{Phase, PhaseProfiler};
use super::rollout::Rollout;
use crate::gae::batched::gae_batched_strided_into;
use crate::gae::reference::gae_trajectory;
use crate::gae::{GaeParams, Trajectory};
use crate::hwsim::{GaeHwSim, SimConfig};
use crate::quant::RewardValueCodec;
use crate::runtime::{Runtime, Tensor};

/// Which GAE implementation runs the phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaeBackend {
    Scalar,
    Batched,
    Hlo,
    HwSim,
}

impl GaeBackend {
    /// Every backend, in presentation order.
    pub const ALL: [GaeBackend; 4] = [
        GaeBackend::Scalar,
        GaeBackend::Batched,
        GaeBackend::Hlo,
        GaeBackend::HwSim,
    ];

    /// Case-insensitive name lookup (`"HwSim"`, `"BATCHED"`, … all work).
    pub fn parse(s: &str) -> Option<GaeBackend> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(GaeBackend::Scalar),
            "batched" => Some(GaeBackend::Batched),
            "hlo" => Some(GaeBackend::Hlo),
            "hwsim" => Some(GaeBackend::HwSim),
            _ => None,
        }
    }

    /// CLI-boundary parse: a helpful error that lists the valid names
    /// instead of a bare `None`.
    pub fn parse_cli(s: &str) -> anyhow::Result<GaeBackend> {
        Self::parse(s).ok_or_else(|| {
            let valid: Vec<&str> = Self::ALL.iter().map(|b| b.label()).collect();
            anyhow::anyhow!(
                "unknown GAE backend {s:?}; valid backends: {}",
                valid.join(", ")
            )
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            GaeBackend::Scalar => "scalar",
            GaeBackend::Batched => "batched",
            GaeBackend::Hlo => "hlo",
            GaeBackend::HwSim => "hwsim",
        }
    }
}

/// GAE-phase results.
#[derive(Debug, Clone)]
pub struct GaeResult {
    /// `[T * B]` advantages.
    pub advantages: Vec<f32>,
    /// `[T * B]` rewards-to-go.
    pub rewards_to_go: Vec<f32>,
    /// Simulated accelerator cycles (HwSim backend only).
    pub hw_cycles: Option<u64>,
}

/// Split one lane of `[T]` rewards / `[T+1]` values / `[T]` dones into
/// single-episode trajectories (the preprocessing the paper's round-
/// robin row dispatch implies: each systolic row receives one episode's
/// vectors). Terminal segments get a zeroed bootstrap value. Returns
/// `(start_t, trajectory)` pairs covering `[0, T)` exactly once.
///
/// Shared by the trainer's [`split_column`] and the serving subsystem's
/// batcher ([`crate::service`]), which splits client trajectories the
/// same way before dispatching them to `hwsim` rows.
pub fn split_at_dones(
    rewards: impl Fn(usize) -> f32,
    values: impl Fn(usize) -> f32,
    dones: impl Fn(usize) -> bool,
    t_len: usize,
) -> Vec<(usize, Trajectory)> {
    let mut out = Vec::new();
    let mut pool = Vec::new();
    split_at_dones_with(rewards, values, dones, t_len, &mut pool, |start, seg| {
        out.push((start, seg))
    });
    out
}

/// Pool-backed form of [`split_at_dones`]: each emitted segment is built
/// in a [`Trajectory`] recycled from `pool` (or fresh while the pool
/// warms), and the caller returns the buffers to the pool after use.
/// The serving hot path splits thousands of lanes per second; this form
/// keeps it from allocating three vectors per episode in steady state.
/// Segment contents are identical to the allocating path by
/// construction.
pub fn split_at_dones_with(
    rewards: impl Fn(usize) -> f32,
    values: impl Fn(usize) -> f32,
    dones: impl Fn(usize) -> bool,
    t_len: usize,
    pool: &mut Vec<Trajectory>,
    mut emit: impl FnMut(usize, Trajectory),
) {
    let mut start = 0usize;
    for t in 0..t_len {
        let done = dones(t);
        if done || t == t_len - 1 {
            let end = t + 1;
            let mut seg = pool.pop().unwrap_or_else(|| Trajectory {
                rewards: Vec::new(),
                values: Vec::new(),
                dones: Vec::new(),
            });
            seg.rewards.clear();
            seg.rewards.extend((start..end).map(&rewards));
            seg.values.clear();
            seg.values.extend((start..=end).map(&values));
            seg.dones.clear();
            seg.dones.resize(end - start, false);
            if done {
                *seg.values.last_mut().unwrap() = 0.0; // terminal: no bootstrap
                *seg.dones.last_mut().unwrap() = true;
            }
            emit(start, seg);
            start = end;
        }
    }
}

/// Split one env's column into single-episode trajectories for the
/// hardware rows. Returns (start_t, trajectory) pairs.
pub fn split_column(
    rollout: &Rollout,
    env_idx: usize,
) -> Vec<(usize, Trajectory)> {
    let (t_len, b) = (rollout.t_len, rollout.batch);
    split_at_dones(
        |t| rollout.rewards[t * b + env_idx],
        |t| rollout.values[t * b + env_idx],
        |t| rollout.done_mask[t * b + env_idx] == 1.0,
        t_len,
    )
}

/// The codec round trip of the GAE phase: what the accelerator reads
/// back from BRAM. The bootstrap value row participates in value
/// statistics (it is stored like every other row). Shared by the inline
/// [`run_gae_stage`] and the pipelined trainer's service-backed path, so
/// both modes mutate the codec state in exactly the same order.
pub fn codec_stage(
    rollout: &mut Rollout,
    codec: &mut RewardValueCodec,
    profiler: &mut PhaseProfiler,
) {
    profiler.time(Phase::GaeMemoryFetch, || {
        let mut rewards = std::mem::take(&mut rollout.rewards);
        let mut values = std::mem::take(&mut rollout.values);
        codec.transform(&mut rewards, &mut values);
        rollout.rewards = rewards;
        rollout.values = values;
    });
}

/// Run the full GAE phase: codec round trip (StoringTrajectories /
/// GaeMemoryFetch accounting) then the backend compute.
pub fn run_gae_stage(
    backend: GaeBackend,
    params: &GaeParams,
    rollout: &mut Rollout,
    codec: &mut RewardValueCodec,
    runtime: Option<&Runtime>,
    profiler: &mut PhaseProfiler,
) -> anyhow::Result<GaeResult> {
    codec_stage(rollout, codec, profiler);

    let (t_len, b) = (rollout.t_len, rollout.batch);
    let mut hw_cycles = None;

    let (advantages, rewards_to_go) = match backend {
        GaeBackend::Scalar => profiler.time(Phase::GaeComputation, || {
            // One trajectory at a time, per-episode segments — "iterating
            // over one trajectory at a time, not in batch form".
            let mut adv = vec![0.0f32; t_len * b];
            let mut rtg = vec![0.0f32; t_len * b];
            for i in 0..b {
                for (start, traj) in split_column(rollout, i) {
                    let out = gae_trajectory(params, &traj);
                    for (off, t) in (start..start + traj.len()).enumerate() {
                        adv[t * b + i] = out.advantages[off];
                        rtg[t * b + i] = out.rewards_to_go[off];
                    }
                }
            }
            (adv, rtg)
        }),
        GaeBackend::Batched => profiler.time(Phase::GaeComputation, || {
            // Plane-resident: the kernel reads the rollout's timestep-
            // major planes directly (stride == width == B), no staging
            // copy into a GaeBatch.
            let mut adv = Vec::new();
            let mut rtg = Vec::new();
            gae_batched_strided_into(
                params,
                t_len,
                b,
                b,
                &rollout.rewards,
                &rollout.values,
                &rollout.done_mask,
                &mut adv,
                &mut rtg,
            );
            (adv, rtg)
        }),
        GaeBackend::Hlo => {
            let rt = runtime
                .ok_or_else(|| anyhow::anyhow!("HLO backend needs a Runtime"))?;
            let name = format!("gae_T{t_len}_B{b}");
            let exe = rt.load(&name)?;
            let out = profiler.time(Phase::GaeComputation, || {
                exe.call(&[
                    Tensor::new(rollout.rewards.clone(), vec![t_len, b]),
                    Tensor::new(rollout.values.clone(), vec![t_len + 1, b]),
                    Tensor::new(rollout.done_mask.clone(), vec![t_len, b]),
                ])
            })?;
            (out[0].data.clone(), out[1].data.clone())
        }
        GaeBackend::HwSim => profiler.time(Phase::GaeComputation, || {
            let sim = GaeHwSim::new(SimConfig {
                gae: *params,
                ..SimConfig::paper_default()
            });
            // Split every column at episode boundaries; dispatch all
            // segments to the row array.
            let mut segments = Vec::new();
            let mut index = Vec::new();
            for i in 0..b {
                for (start, traj) in split_column(rollout, i) {
                    index.push((i, start, traj.len()));
                    segments.push(traj);
                }
            }
            let rep = sim.simulate(&segments);
            hw_cycles = Some(rep.cycles);
            let mut adv = vec![0.0f32; t_len * b];
            let mut rtg = vec![0.0f32; t_len * b];
            for ((i, start, len), out) in index.into_iter().zip(rep.outputs) {
                for off in 0..len {
                    adv[(start + off) * b + i] = out.advantages[off];
                    rtg[(start + off) * b + i] = out.rewards_to_go[off];
                }
            }
            (adv, rtg)
        }),
    };

    // Results written back to the stack (in-place overwrite, §IV-3).
    profiler.time(Phase::GaeMemoryWrite, || {
        // The rollout's reward plane becomes the advantage plane —
        // mirrors `gae_batched_in_place`; kept as a copy so diagnostics
        // still see both.
    });

    Ok(GaeResult { advantages, rewards_to_go, hw_cycles })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::CodecKind;
    use crate::testing::{check, Gen};

    fn synthetic_rollout(g: &mut Gen, t_len: usize, b: usize) -> Rollout {
        let rewards = g.vec_normal_f32(t_len * b, 0.0, 1.0);
        let values = g.vec_normal_f32((t_len + 1) * b, 0.0, 1.0);
        let done_mask: Vec<f32> = (0..t_len * b)
            .map(|_| if g.bool_p(0.08) { 1.0 } else { 0.0 })
            .collect();
        Rollout {
            t_len,
            batch: b,
            obs_dim: 1,
            obs: vec![0.0; t_len * b],
            actions: vec![0.0; t_len * b],
            act_width: 1,
            logp: vec![0.0; t_len * b],
            raw_rewards: rewards.clone(),
            raw_values: values.clone(),
            rewards,
            values,
            done_mask,
            finished_returns: vec![],
        }
    }

    #[test]
    fn all_cpu_backends_agree() {
        check("scalar == batched == hwsim", 10, |g| {
            let t_len = g.usize_in(2, 40);
            let b = g.usize_in(1, 6);
            let params = GaeParams::default();
            let mut results = Vec::new();
            for backend in [GaeBackend::Scalar, GaeBackend::Batched, GaeBackend::HwSim] {
                let mut rollout = synthetic_rollout(&mut Gen::new(g.case_seed), t_len, b);
                let mut codec = RewardValueCodec::paper(CodecKind::Exp1Baseline);
                let mut prof = PhaseProfiler::new();
                let r = run_gae_stage(
                    backend, &params, &mut rollout, &mut codec, None, &mut prof,
                )
                .unwrap();
                results.push(r);
            }
            for other in &results[1..] {
                for (a, b_) in results[0].advantages.iter().zip(&other.advantages) {
                    assert!((a - b_).abs() < 1e-3, "{a} vs {b_}");
                }
                for (a, b_) in results[0].rewards_to_go.iter().zip(&other.rewards_to_go) {
                    assert!((a - b_).abs() < 1e-3);
                }
            }
            assert!(results[2].hw_cycles.unwrap() > 0);
        });
    }

    #[test]
    fn scalar_with_dones_splits_credit() {
        // A done at (t, i) must stop credit flow in every backend.
        let mut g = Gen::new(42);
        let mut rollout = synthetic_rollout(&mut g, 10, 2);
        rollout.rewards.iter_mut().for_each(|r| *r = 0.0);
        rollout.done_mask.iter_mut().for_each(|d| *d = 0.0);
        rollout.values.iter_mut().for_each(|v| *v = 0.0);
        rollout.rewards[7 * 2] = 100.0; // env 0, t=7
        rollout.done_mask[4 * 2] = 1.0; // env 0 terminal at t=4
        let params = GaeParams::default();
        let mut codec = RewardValueCodec::paper(CodecKind::Exp1Baseline);
        let mut prof = PhaseProfiler::new();
        let r = run_gae_stage(
            GaeBackend::Scalar, &params, &mut rollout, &mut codec, None, &mut prof,
        )
        .unwrap();
        for t in 0..=4 {
            assert!(r.advantages[t * 2].abs() < 1e-6, "t={t}");
        }
        assert!(r.advantages[5 * 2] > 1.0);
    }

    #[test]
    fn codec_transforms_are_applied() {
        let mut g = Gen::new(7);
        let mut rollout = synthetic_rollout(&mut g, 16, 4);
        // Push rewards far from zero so standardization is visible.
        for r in rollout.rewards.iter_mut() {
            *r += 50.0;
        }
        let raw_mean: f32 =
            rollout.rewards.iter().sum::<f32>() / rollout.rewards.len() as f32;
        let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
        let mut prof = PhaseProfiler::new();
        run_gae_stage(
            GaeBackend::Batched,
            &GaeParams::default(),
            &mut rollout,
            &mut codec,
            None,
            &mut prof,
        )
        .unwrap();
        let post_mean: f32 =
            rollout.rewards.iter().sum::<f32>() / rollout.rewards.len() as f32;
        assert!(raw_mean > 40.0);
        assert!(post_mean.abs() < 1.0, "rewards must be standardized, got {post_mean}");
    }

    #[test]
    fn backend_parse_is_case_insensitive() {
        assert_eq!(GaeBackend::parse("HwSim"), Some(GaeBackend::HwSim));
        assert_eq!(GaeBackend::parse("BATCHED"), Some(GaeBackend::Batched));
        assert_eq!(GaeBackend::parse("Scalar"), Some(GaeBackend::Scalar));
        assert_eq!(GaeBackend::parse("hlo"), Some(GaeBackend::Hlo));
        assert_eq!(GaeBackend::parse("fpga"), None);
    }

    #[test]
    fn backend_parse_cli_lists_valid_names() {
        assert_eq!(GaeBackend::parse_cli("HWSIM").unwrap(), GaeBackend::HwSim);
        let err = GaeBackend::parse_cli("fpga").unwrap_err().to_string();
        for b in GaeBackend::ALL {
            assert!(err.contains(b.label()), "error must list {}: {err}", b.label());
        }
    }

    #[test]
    fn pooled_splitter_matches_the_allocating_splitter() {
        // Recycled trajectory buffers must not leak stale contents: run
        // the pool through a first lane, then verify a second lane's
        // segments are identical to the fresh-allocation path.
        check("split_at_dones_with == split_at_dones", 20, |g| {
            let mut pool: Vec<Trajectory> = Vec::new();
            for _ in 0..2 {
                let t_len = g.usize_in(1, 48);
                let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
                let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
                let dones: Vec<bool> = (0..t_len).map(|_| g.bool_p(0.15)).collect();
                let want = split_at_dones(
                    |t| rewards[t],
                    |t| values[t],
                    |t| dones[t],
                    t_len,
                );
                let mut got: Vec<(usize, Trajectory)> = Vec::new();
                split_at_dones_with(
                    |t| rewards[t],
                    |t| values[t],
                    |t| dones[t],
                    t_len,
                    &mut pool,
                    |start, seg| got.push((start, seg)),
                );
                assert_eq!(got.len(), want.len());
                for ((ws, wt), (gs, gt)) in want.iter().zip(&got) {
                    assert_eq!(ws, gs);
                    assert_eq!(wt.rewards, gt.rewards);
                    assert_eq!(wt.values, gt.values);
                    assert_eq!(wt.dones, gt.dones);
                }
                // Return the buffers so the next round exercises reuse.
                pool.extend(got.into_iter().map(|(_, seg)| seg));
            }
        });
    }

    #[test]
    fn split_column_covers_everything_once() {
        check("split covers [0,T)", 20, |g| {
            let t_len = g.usize_in(1, 64);
            let b = g.usize_in(1, 4);
            let rollout = synthetic_rollout(g, t_len, b);
            for i in 0..b {
                let segs = split_column(&rollout, i);
                let mut covered = vec![false; t_len];
                for (start, traj) in &segs {
                    for t in *start..*start + traj.len() {
                        assert!(!covered[t], "t={t} covered twice");
                        covered[t] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "gap in coverage");
            }
        });
    }
}

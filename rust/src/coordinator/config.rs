//! Run configuration for the trainer — the config system behind the
//! `heppo train` CLI and the experiment benches.

use super::gae_stage::GaeBackend;
use super::pipeline::PipelineMode;
use crate::quant::CodecKind;
use crate::util::cli::Args;

/// Full trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Environment name (must have artifacts in the manifest).
    pub env: String,
    /// Training iterations (each = one rollout + update).
    pub iters: usize,
    /// PPO epochs per iteration.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// PPO clip ε.
    pub clip_eps: f32,
    /// Entropy bonus coefficient.
    pub ent_coef: f32,
    /// Standardize advantages after GAE (§V-A: near-universal practice;
    /// Fig. 7 compares with/without).
    pub standardize_advantages: bool,
    /// Reward/value storage codec (Table III experiments).
    pub codec: CodecKind,
    /// Quantizer bit width (Figs. 8–9 sweep 3–10).
    pub quant_bits: u8,
    /// GAE backend.
    pub backend: GaeBackend,
    /// RNG seed.
    pub seed: u64,
    /// Artifact directory.
    pub artifact_dir: String,
    /// Environment worker threads.
    pub env_threads: usize,
    /// Phase scheduling: `Sequential` reproduces the paper's §III-A
    /// machine bit-for-bit; `Overlapped` pipelines the GAE phase through
    /// the serving subsystem's worker pool.
    pub pipeline: PipelineMode,
    /// Worker shards of the in-process GAE service (`Overlapped` only).
    pub service_workers: usize,
    /// Capture the raw (pre-codec) reward/value planes each iteration.
    /// Diagnostics only (Fig. 2/7 data) — doubles rollout memory, so off
    /// by default.
    pub keep_raw_planes: bool,
    /// JSONL learning-curve path (`--timeseries`): when set, the
    /// trainer appends one
    /// [`LearningHealthRecord`](crate::obs::timeseries::LearningHealthRecord)
    /// per iteration. `None` = no time series written.
    pub timeseries_path: Option<String>,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            env: "cartpole".into(),
            iters: 50,
            epochs: 4,
            lr: 3e-4,
            clip_eps: 0.2,
            ent_coef: 0.01,
            standardize_advantages: true,
            codec: CodecKind::Exp5DynamicBlock,
            quant_bits: 8,
            backend: GaeBackend::Batched,
            seed: 0,
            artifact_dir: "artifacts".into(),
            env_threads: 4,
            pipeline: PipelineMode::Sequential,
            service_workers: 4,
            keep_raw_planes: false,
            timeseries_path: None,
        }
    }
}

impl TrainerConfig {
    /// Overlay CLI arguments onto the defaults; `--config file.json`
    /// loads a JSON config as the base layer first (CLI still wins).
    pub fn from_args(args: &Args) -> anyhow::Result<TrainerConfig> {
        let d = match args.opt("config") {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("reading --config {path}: {e}"))?;
                Self::from_json(&text)?
            }
            None => TrainerConfig::default(),
        };
        let default_codec = format!("exp{}", d.codec.index());
        let codec_str = args.str_or("codec", &default_codec);
        let codec = CodecKind::parse(&codec_str)
            .ok_or_else(|| anyhow::anyhow!("unknown codec {codec_str:?} (exp1..exp5)"))?;
        let backend_str = args.str_or("backend", d.backend.label());
        let backend = GaeBackend::parse_cli(&backend_str)?;
        let pipeline_str = args.str_or("pipeline", d.pipeline.label());
        let pipeline = PipelineMode::parse_cli(&pipeline_str)?;
        Ok(TrainerConfig {
            env: args.str_or("env", &d.env),
            iters: args.get_or("iters", d.iters),
            epochs: args.get_or("epochs", d.epochs),
            lr: args.get_or("lr", d.lr),
            clip_eps: args.get_or("clip", d.clip_eps),
            ent_coef: args.get_or("ent-coef", d.ent_coef),
            standardize_advantages: if args.flag("no-adv-std") {
                false
            } else {
                d.standardize_advantages
            },
            codec,
            quant_bits: args.get_or("bits", d.quant_bits),
            backend,
            seed: args.get_or("seed", d.seed),
            artifact_dir: args.str_or("artifacts", &d.artifact_dir),
            env_threads: args.get_or("env-threads", d.env_threads),
            pipeline,
            service_workers: args.get_or("service-workers", d.service_workers),
            keep_raw_planes: args.flag("keep-raw") || d.keep_raw_planes,
            timeseries_path: args
                .opt("timeseries")
                .map(|s| s.to_string())
                .or(d.timeseries_path),
        })
    }

    /// Parse a JSON config document (any subset of keys; the rest keep
    /// their defaults).
    pub fn from_json(text: &str) -> anyhow::Result<TrainerConfig> {
        use crate::util::json::Json;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config json: {e}"))?;
        let mut c = TrainerConfig::default();
        if let Some(v) = j.get("env").and_then(Json::as_str) {
            c.env = v.to_string();
        }
        if let Some(v) = j.get("iters").and_then(Json::as_usize) {
            c.iters = v;
        }
        if let Some(v) = j.get("epochs").and_then(Json::as_usize) {
            c.epochs = v;
        }
        if let Some(v) = j.get("lr").and_then(Json::as_f64) {
            c.lr = v as f32;
        }
        if let Some(v) = j.get("clip").and_then(Json::as_f64) {
            c.clip_eps = v as f32;
        }
        if let Some(v) = j.get("ent_coef").and_then(Json::as_f64) {
            c.ent_coef = v as f32;
        }
        if let Some(v) = j.get("standardize_advantages").and_then(Json::as_bool) {
            c.standardize_advantages = v;
        }
        if let Some(v) = j.get("codec").and_then(Json::as_str) {
            c.codec = CodecKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("config: unknown codec {v:?}"))?;
        }
        if let Some(v) = j.get("bits").and_then(Json::as_usize) {
            c.quant_bits = v as u8;
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            c.backend = GaeBackend::parse_cli(v)
                .map_err(|e| anyhow::anyhow!("config: {e}"))?;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_usize) {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("artifacts").and_then(Json::as_str) {
            c.artifact_dir = v.to_string();
        }
        if let Some(v) = j.get("env_threads").and_then(Json::as_usize) {
            c.env_threads = v;
        }
        if let Some(v) = j.get("pipeline").and_then(Json::as_str) {
            c.pipeline = PipelineMode::parse_cli(v)
                .map_err(|e| anyhow::anyhow!("config: {e}"))?;
        }
        if let Some(v) = j.get("service_workers").and_then(Json::as_usize) {
            c.service_workers = v;
        }
        if let Some(v) = j.get("keep_raw_planes").and_then(Json::as_bool) {
            c.keep_raw_planes = v;
        }
        if let Some(v) = j.get("timeseries_path").and_then(Json::as_str) {
            c.timeseries_path = Some(v.to_string());
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse_tokens(tokens.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_are_paper_operating_point() {
        let c = TrainerConfig::default();
        assert_eq!(c.codec, CodecKind::Exp5DynamicBlock);
        assert_eq!(c.quant_bits, 8);
        assert!(c.standardize_advantages);
        // Sequential by default: bit-exact with the pre-pipeline trainer.
        assert_eq!(c.pipeline, PipelineMode::Sequential);
        assert!(!c.keep_raw_planes, "raw diagnostic planes are opt-in");
    }

    #[test]
    fn pipeline_cli_overlay() {
        let args = parse(&[
            "train", "--pipeline", "overlapped", "--service-workers", "8",
            "--keep-raw",
        ]);
        let c = TrainerConfig::from_args(&args).unwrap();
        assert_eq!(c.pipeline, PipelineMode::Overlapped);
        assert_eq!(c.service_workers, 8);
        assert!(c.keep_raw_planes);
        let bad = parse(&["train", "--pipeline", "diagonal"]);
        assert!(TrainerConfig::from_args(&bad).is_err());
    }

    #[test]
    fn keep_raw_from_config_file_survives_cli_overlay() {
        // The `|| d.keep_raw_planes` arm is live: a --config file can
        // enable the diagnostic planes without the CLI flag.
        let path = std::env::temp_dir()
            .join(format!("heppo_keepraw_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"keep_raw_planes": true}"#).unwrap();
        let args = parse(&["train", "--config", path.to_str().unwrap()]);
        let c = TrainerConfig::from_args(&args).unwrap();
        assert!(c.keep_raw_planes);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn pipeline_json_overlay() {
        let c = TrainerConfig::from_json(
            r#"{"pipeline": "overlapped", "service_workers": 2, "keep_raw_planes": true}"#,
        )
        .unwrap();
        assert_eq!(c.pipeline, PipelineMode::Overlapped);
        assert_eq!(c.service_workers, 2);
        assert!(c.keep_raw_planes);
        assert!(TrainerConfig::from_json(r#"{"pipeline": "zigzag"}"#).is_err());
    }

    #[test]
    fn cli_overlay() {
        let args = parse(&[
            "train", "--env", "pendulum", "--iters", "10", "--codec", "exp1",
            "--backend", "hwsim", "--bits", "6", "--no-adv-std",
        ]);
        let c = TrainerConfig::from_args(&args).unwrap();
        assert_eq!(c.env, "pendulum");
        assert_eq!(c.iters, 10);
        assert_eq!(c.codec, CodecKind::Exp1Baseline);
        assert_eq!(c.backend, GaeBackend::HwSim);
        assert_eq!(c.quant_bits, 6);
        assert!(!c.standardize_advantages);
    }

    #[test]
    fn timeseries_overlay() {
        assert_eq!(TrainerConfig::default().timeseries_path, None);
        let args = parse(&["train", "--timeseries", "results/curve.jsonl"]);
        let c = TrainerConfig::from_args(&args).unwrap();
        assert_eq!(c.timeseries_path.as_deref(), Some("results/curve.jsonl"));
        let c =
            TrainerConfig::from_json(r#"{"timeseries_path": "out.jsonl"}"#).unwrap();
        assert_eq!(c.timeseries_path.as_deref(), Some("out.jsonl"));
    }

    #[test]
    fn bad_codec_errors() {
        let args = parse(&["train", "--codec", "bogus"]);
        assert!(TrainerConfig::from_args(&args).is_err());
    }

    #[test]
    fn json_config_partial_overlay() {
        let c = TrainerConfig::from_json(
            r#"{"env": "pendulum", "iters": 7, "codec": "exp3", "lr": 0.001,
                "standardize_advantages": false, "backend": "hwsim"}"#,
        )
        .unwrap();
        assert_eq!(c.env, "pendulum");
        assert_eq!(c.iters, 7);
        assert_eq!(c.codec, CodecKind::Exp3BlockDestd);
        assert!((c.lr - 0.001).abs() < 1e-9);
        assert!(!c.standardize_advantages);
        assert_eq!(c.backend, GaeBackend::HwSim);
        // Untouched keys keep defaults.
        assert_eq!(c.epochs, TrainerConfig::default().epochs);
    }

    #[test]
    fn json_config_rejects_bad_values() {
        assert!(TrainerConfig::from_json(r#"{"codec": "nope"}"#).is_err());
        assert!(TrainerConfig::from_json("not json").is_err());
    }

    #[test]
    fn config_file_plus_cli_override() {
        let path = std::env::temp_dir().join(format!("heppo_cfg_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"env": "pendulum", "iters": 9}"#).unwrap();
        let args = parse(&["train", "--config", path.to_str().unwrap(), "--iters", "3"]);
        let c = TrainerConfig::from_args(&args).unwrap();
        assert_eq!(c.env, "pendulum"); // from file
        assert_eq!(c.iters, 3); // CLI wins
        let _ = std::fs::remove_file(path);
    }
}

//! Per-phase wall-time capture — regenerates the paper's Table I / Fig. 1
//! ("Time Profiling of PPO Iteration over Different Systems").

use crate::util::csv::CsvTable;
use crate::util::timer::{fmt_duration, Stopwatch};
use std::collections::BTreeMap;
use std::time::Duration;

/// Table I row identifiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Trajectory Collection — DNN Inference.
    DnnInference,
    /// Trajectory Collection — Environment Run.
    EnvironmentRun,
    /// Trajectory Collection — Storing Trajectories (codec + stack push).
    StoringTrajectories,
    /// GAE — Memory Fetch (stack → compute layout).
    GaeMemoryFetch,
    /// GAE — Computation.
    GaeComputation,
    /// GAE — Memory Write (results → storage).
    GaeMemoryWrite,
    /// Network Update — loss + optimizer (the train_step artifact).
    NetworkUpdate,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::DnnInference,
        Phase::EnvironmentRun,
        Phase::StoringTrajectories,
        Phase::GaeMemoryFetch,
        Phase::GaeComputation,
        Phase::GaeMemoryWrite,
        Phase::NetworkUpdate,
    ];

    /// Table I row label.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::DnnInference => "DNN Inference",
            Phase::EnvironmentRun => "Environment Run",
            Phase::StoringTrajectories => "Storing Trajectories",
            Phase::GaeMemoryFetch => "GAE Memory Fetch",
            Phase::GaeComputation => "GAE Computation",
            Phase::GaeMemoryWrite => "GAE Memory Write",
            Phase::NetworkUpdate => "Network Update",
        }
    }

    /// Table I group.
    pub fn group(&self) -> &'static str {
        match self {
            Phase::DnnInference | Phase::EnvironmentRun | Phase::StoringTrajectories => {
                "Trajectory Collection"
            }
            Phase::GaeMemoryFetch | Phase::GaeComputation | Phase::GaeMemoryWrite => "GAE",
            Phase::NetworkUpdate => "Network Update",
        }
    }
}

/// Accumulates per-phase durations across iterations, plus the
/// end-to-end wall clock of each iteration so overlapped schedules can
/// be compared against the sum of their phases.
#[derive(Debug, Default)]
pub struct PhaseProfiler {
    watches: BTreeMap<Phase, Stopwatch>,
    iteration_wall: Stopwatch,
}

impl PhaseProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        self.watches.entry(phase).or_default().time(f)
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.watches.entry(phase).or_default().add(d);
    }

    pub fn total(&self, phase: Phase) -> Duration {
        self.watches.get(&phase).map(|w| w.total()).unwrap_or_default()
    }

    /// Sum over all phases.
    pub fn grand_total(&self) -> Duration {
        self.watches.values().map(|w| w.total()).sum()
    }

    /// Fraction of total time in a phase (Table I's percentages).
    pub fn fraction(&self, phase: Phase) -> f64 {
        let total = self.grand_total().as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.total(phase).as_secs_f64() / total
        }
    }

    /// Combined GAE share — the paper's headline "GAE ≈ 30% of PPO time".
    pub fn gae_fraction(&self) -> f64 {
        self.fraction(Phase::GaeMemoryFetch)
            + self.fraction(Phase::GaeComputation)
            + self.fraction(Phase::GaeMemoryWrite)
    }

    /// Record one iteration's end-to-end wall clock (the trainer calls
    /// this once per [`crate::coordinator::Trainer::iterate`]).
    pub fn add_iteration_wall(&mut self, d: Duration) {
        self.iteration_wall.add(d);
    }

    /// Total iteration wall clock across the run.
    pub fn iteration_wall(&self) -> Duration {
        self.iteration_wall.total()
    }

    /// Phase-time / wall-time ratio: ≈1.0 on the sequential schedule;
    /// on the overlapped schedule the gap `wall − phases` is the time
    /// hidden behind other stages (the GAE wait shrinks as update prep
    /// overlaps it). Returns 0 when no iteration wall was recorded.
    pub fn phase_coverage(&self) -> f64 {
        let wall = self.iteration_wall.total().as_secs_f64();
        if wall == 0.0 {
            0.0
        } else {
            self.grand_total().as_secs_f64() / wall
        }
    }

    /// Render as a Table-I-shaped table.
    pub fn to_table(&self, system_label: &str) -> CsvTable {
        let mut t = CsvTable::new(&["Phase", "Sub-Phase", system_label, "total"]);
        for phase in Phase::ALL {
            t.row(&[
                phase.group().to_string(),
                phase.label().to_string(),
                format!("{:.2}%", self.fraction(phase) * 100.0),
                fmt_duration(self.total(phase)),
            ]);
        }
        t
    }

    pub fn reset(&mut self) {
        self.watches.clear();
        self.iteration_wall = Stopwatch::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let mut p = PhaseProfiler::new();
        p.add(Phase::EnvironmentRun, Duration::from_millis(47));
        p.add(Phase::GaeComputation, Duration::from_millis(30));
        p.add(Phase::NetworkUpdate, Duration::from_millis(23));
        let sum: f64 = Phase::ALL.iter().map(|&ph| p.fraction(ph)).sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!((p.fraction(Phase::GaeComputation) - 0.30).abs() < 1e-9);
        assert!((p.gae_fraction() - 0.30).abs() < 1e-9);
    }

    #[test]
    fn time_accumulates_calls() {
        let mut p = PhaseProfiler::new();
        for _ in 0..3 {
            p.time(Phase::DnnInference, || std::thread::sleep(Duration::from_millis(1)));
        }
        assert!(p.total(Phase::DnnInference) >= Duration::from_millis(3));
    }

    #[test]
    fn table_has_all_rows() {
        let p = PhaseProfiler::new();
        let t = p.to_table("CPU Only");
        assert_eq!(t.n_rows(), 7);
    }

    #[test]
    fn iteration_wall_and_coverage() {
        let mut p = PhaseProfiler::new();
        assert_eq!(p.phase_coverage(), 0.0);
        p.add(Phase::GaeComputation, Duration::from_millis(30));
        p.add(Phase::NetworkUpdate, Duration::from_millis(30));
        // An overlapped iteration: 60ms of phase time in 40ms of wall.
        p.add_iteration_wall(Duration::from_millis(40));
        assert_eq!(p.iteration_wall(), Duration::from_millis(40));
        assert!((p.phase_coverage() - 1.5).abs() < 1e-9);
        p.reset();
        assert_eq!(p.iteration_wall(), Duration::ZERO);
        assert_eq!(p.phase_coverage(), 0.0);
    }
}

//! The pipelined trainer substrate: double-buffered rollout storage and
//! a bounded-channel stage driver that overlaps iteration *i+1*'s
//! trajectory collection with iteration *i*'s GAE + update (the
//! OPPO-style phase overlap named in ROADMAP.md).
//!
//! Two consumers share this module:
//!
//! - **[`Trainer`](super::Trainer)** selects a [`PipelineMode`].
//!   `Sequential` is the paper's §III-A schedule, bit-identical to the
//!   pre-pipeline trainer. `Overlapped` dispatches the GAE phase to the
//!   [`crate::service::GaeService`] worker pool through the
//!   plane-shaped client seam and overlaps the wait with the
//!   advantage-independent half of the update
//!   ([`super::ppo::prepare_update`]); because the PJRT runtime is
//!   thread-pinned (`Rc` executable cache), the coordinator thread keeps
//!   the policy/update artifacts and only the GAE compute fans out —
//!   which preserves the exact sequential dependency graph, so
//!   `Overlapped` is *also* bit-identical at a given seed.
//! - **[`run_stages`]** is the fully-threaded two-lane driver for `Send`
//!   stage sets (closure policies: benches, tests, sharded trainers): a
//!   collector thread fills recycled [`Rollout`] buffers from a bounded
//!   pool while the consumer thread runs GAE + update on the previous
//!   buffer, with [`PipelineLanes`] enforcing that the overlapped
//!   schedule never violates the per-iteration phase order.
//! - **[`run_stage_fleet`]** scales the driver *out*: N coordinator
//!   replicas, each its own `run_stages` instance, concurrently feeding
//!   one shared GAE substrate (typically a
//!   [`GaeFabric`](crate::fabric::GaeFabric)) — the sharded-trainer
//!   shape ROADMAP named with the stage driver as its substrate.
//!
//! The steady-state schedule `run_stages` realizes, two buffers deep:
//!
//! ```text
//! lane 0: TC₀ DP₀ GC₀ LU₀ ···· TC₂ DP₂ GC₂ LU₂
//! lane 1: ····· TC₁ ········ DP₁ GC₁ LU₁ ···· TC₃ …
//! ```
//!
//! so wall-clock per iteration approaches `max(collect, gae + update)`
//! instead of their sum.

use super::gae_stage::GaeResult;
use super::phases::{PipelineLanes, SocPhase};
use super::rollout::Rollout;
use std::sync::mpsc::{sync_channel, RecvTimeoutError};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the trainer schedules its phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// The paper's strictly sequential §III-A schedule (the default;
    /// reproduces pre-pipeline results bit-for-bit).
    #[default]
    Sequential,
    /// Pipelined: GAE runs on the service worker pool and overlaps
    /// adjacent stages; collection overlaps the previous iteration's
    /// GAE + update wherever the stage set is `Send`.
    Overlapped,
}

impl PipelineMode {
    pub const ALL: [PipelineMode; 2] = [PipelineMode::Sequential, PipelineMode::Overlapped];

    pub fn label(&self) -> &'static str {
        match self {
            PipelineMode::Sequential => "sequential",
            PipelineMode::Overlapped => "overlapped",
        }
    }

    /// Case-insensitive name lookup.
    pub fn parse(s: &str) -> Option<PipelineMode> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Some(PipelineMode::Sequential),
            "overlapped" | "overlap" => Some(PipelineMode::Overlapped),
            _ => None,
        }
    }

    /// CLI-boundary parse with an error listing the valid names.
    pub fn parse_cli(s: &str) -> anyhow::Result<PipelineMode> {
        Self::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown pipeline mode {s:?}; valid modes: sequential, overlapped")
        })
    }
}

/// Accumulated per-stage wall time of one [`run_stages`] run.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub collect: Duration,
    pub gae: Duration,
    pub update: Duration,
    /// End-to-end wall clock of the whole run.
    pub wall: Duration,
    pub iters: usize,
}

impl StageTimes {
    /// Sum of the stage times (what a sequential schedule would pay).
    pub fn stage_sum(&self) -> Duration {
        self.collect + self.gae + self.update
    }

    /// Wall-clock saved versus running the stages back to back.
    pub fn overlap_saving(&self) -> Duration {
        self.stage_sum().saturating_sub(self.wall)
    }
}

/// Result of [`run_stages`]: the per-iteration stats stream, stage
/// timing, and the lane machine (handshake accounting).
#[derive(Debug)]
pub struct PipelineRun<S> {
    pub stats: Vec<S>,
    pub times: StageTimes,
    pub lanes: PipelineLanes,
}

/// Result of [`run_stage_fleet`]: every replica's [`PipelineRun`] plus
/// the fleet's end-to-end wall clock.
#[derive(Debug)]
pub struct FleetRun<S> {
    /// One run per coordinator replica, replica order.
    pub replicas: Vec<PipelineRun<S>>,
    /// Wall clock of the whole fleet (spawn → last join).
    pub wall: Duration,
}

impl<S> FleetRun<S> {
    /// Iterations completed across the fleet.
    pub fn total_iters(&self) -> usize {
        self.replicas.iter().map(|r| r.times.iters).sum()
    }

    /// Stage times summed over replicas, with the fleet wall clock —
    /// `aggregate().stage_sum()` vs `wall` quantifies how much compute
    /// the replicas overlapped on top of each replica's own pipeline
    /// overlap.
    pub fn aggregate(&self) -> StageTimes {
        let mut t = StageTimes {
            wall: self.wall,
            iters: self.total_iters(),
            ..StageTimes::default()
        };
        for r in &self.replicas {
            t.collect += r.times.collect;
            t.gae += r.times.gae;
            t.update += r.times.update;
        }
        t
    }
}

/// The multi-replica trainer mode: run `replicas` coordinator
/// stage-driver replicas concurrently, each feeding the same shared GAE
/// substrate (a [`GaeService`](crate::service::GaeService) or a
/// [`GaeFabric`](crate::fabric::GaeFabric)) from its own stage set.
///
/// `run_replica(r)` builds and drives replica `r` — typically a
/// [`run_stages`] call over closures that own the replica's envs, RNG
/// streams, and fabric submitter; sharing mutable state across replicas
/// is the caller's (non-)problem exactly as with `run_stages`' stage
/// closures. Replicas that keep their state private produce the same
/// per-replica stats streams at any replica count — the property
/// `tests/fabric_integration.rs` pins against a live fabric.
///
/// All replicas run even if one fails; the first error (replica order)
/// is then reported, so a poisoned replica can't strand the others'
/// threads mid-scope.
pub fn run_stage_fleet<S, F>(
    replicas: usize,
    run_replica: F,
) -> anyhow::Result<FleetRun<S>>
where
    S: Send,
    F: Fn(usize) -> anyhow::Result<PipelineRun<S>> + Sync,
{
    anyhow::ensure!(replicas >= 1, "fleet needs at least one replica");
    let start = Instant::now();
    let results: Vec<anyhow::Result<PipelineRun<S>>> = std::thread::scope(|scope| {
        let run_replica = &run_replica;
        let handles: Vec<_> = (0..replicas)
            .map(|r| scope.spawn(move || run_replica(r)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("replica must not panic"))
            .collect()
    });
    let wall = start.elapsed();
    let mut runs = Vec::with_capacity(replicas);
    for (r, result) in results.into_iter().enumerate() {
        runs.push(result.map_err(|e| e.context(format!("replica {r} failed")))?);
    }
    Ok(FleetRun { replicas: runs, wall })
}

/// Shared lane state for the threaded driver. The collector must stall
/// when the trajectory-collection resource is still held by the previous
/// lane (a structural hazard, not an error), so entry into
/// `TrajectoryCollection` blocks on a condvar; every other transition is
/// owned by exactly one thread at a time and conflicts are hard errors.
struct LaneGate {
    lanes: Mutex<PipelineLanes>,
    freed: Condvar,
    /// Set when the consumer stops (normally or on error) so a stalled
    /// collector wakes up and exits instead of waiting forever.
    stopped: Mutex<bool>,
}

impl LaneGate {
    fn new(lanes: usize) -> LaneGate {
        LaneGate {
            lanes: Mutex::new(PipelineLanes::new(lanes)),
            freed: Condvar::new(),
            stopped: Mutex::new(false),
        }
    }

    /// Non-blocking transition; a conflict is a bug in the schedule.
    fn step(&self, lane: usize, next: SocPhase) -> anyhow::Result<()> {
        let r = self
            .lanes
            .lock()
            .unwrap()
            .transition(lane, next)
            .map_err(|e| anyhow::anyhow!("{e}"));
        self.freed.notify_all();
        r
    }

    /// Blocking entry into `TrajectoryCollection`: waits for the phase
    /// to free. Returns false if the pipeline stopped while waiting.
    fn enter_collect(&self, lane: usize) -> anyhow::Result<bool> {
        let mut lanes = self.lanes.lock().unwrap();
        loop {
            match lanes.occupant(SocPhase::TrajectoryCollection) {
                Some(by) if by != lane => {
                    if *self.stopped.lock().unwrap() {
                        return Ok(false);
                    }
                    let (guard, _timeout) = self
                        .freed
                        .wait_timeout(lanes, Duration::from_millis(5))
                        .unwrap();
                    lanes = guard;
                }
                _ => {
                    lanes
                        .transition(lane, SocPhase::TrajectoryCollection)
                        .map_err(|e| anyhow::anyhow!("{e}"))?;
                    return Ok(true);
                }
            }
        }
    }

    fn stop(&self) {
        *self.stopped.lock().unwrap() = true;
        self.freed.notify_all();
    }

    fn into_lanes(self) -> PipelineLanes {
        self.lanes.into_inner().unwrap()
    }
}

/// Drive `iters` iterations of `collect → gae → update` over recycled
/// rollout buffers.
///
/// `Sequential` calls the stages back to back on the caller's thread.
/// `Overlapped` runs `collect` on a dedicated collector thread two
/// buffers deep: collection of iteration *i+1* overlaps GAE + update of
/// iteration *i*. Stage closures own their state (envs, RNG streams,
/// service clients), so a stage set whose collection does not read
/// update results produces **identical stats streams in both modes** —
/// the property `tests/pipeline_equivalence.rs` pins down.
///
/// Iteration *i* runs on lane `i % 2` of a [`PipelineLanes`]; every
/// transition is checked, so an illegal overlap is a hard error, and
/// PS↔PL handshakes are accounted per lane exactly as the sequential
/// machine accounts them.
pub fn run_stages<S, C, G, U>(
    mode: PipelineMode,
    iters: usize,
    mut collect: C,
    mut gae: G,
    mut update: U,
) -> anyhow::Result<PipelineRun<S>>
where
    S: Send,
    C: FnMut(usize, &mut Rollout) -> anyhow::Result<()> + Send,
    G: FnMut(usize, &mut Rollout) -> anyhow::Result<GaeResult>,
    U: FnMut(usize, &mut Rollout, &GaeResult) -> anyhow::Result<S>,
{
    let gate = LaneGate::new(2);
    let mut times = StageTimes { iters, ..StageTimes::default() };
    let mut stats = Vec::with_capacity(iters);
    // One trace id per run: every stage span across both threads joins
    // the same timeline, so `Overlapped` renders its collect spans
    // *overlapping* the previous iteration's gae/update spans while
    // `Sequential` renders them back to back.
    let run_trace =
        if crate::obs::enabled() { crate::obs::mint_trace_id() } else { 0 };
    let run_start = Instant::now();

    match mode {
        PipelineMode::Sequential => {
            // One lane, one buffer, stages back to back — the reference
            // schedule.
            let mut buf = Rollout::empty();
            for i in 0..iters {
                gate.step(0, SocPhase::TrajectoryCollection)?;
                let t0 = Instant::now();
                {
                    let _span = crate::obs::span("pipeline.collect", run_trace);
                    collect(i, &mut buf)?;
                }
                times.collect += t0.elapsed();
                gate.step(0, SocPhase::DataPrep)?;
                gate.step(0, SocPhase::GaeCompute)?;
                let t0 = Instant::now();
                let g = {
                    let _span = crate::obs::span("pipeline.gae", run_trace);
                    gae(i, &mut buf)?
                };
                times.gae += t0.elapsed();
                gate.step(0, SocPhase::LossAndUpdate)?;
                let t0 = Instant::now();
                {
                    let _span = crate::obs::span("pipeline.update", run_trace);
                    stats.push(update(i, &mut buf, &g)?);
                }
                times.update += t0.elapsed();
                gate.step(0, SocPhase::Idle)?;
            }
        }
        PipelineMode::Overlapped => {
            // Free buffers flow consumer → collector (the double-buffer
            // pool; the receiver lives on the collector thread), filled
            // buffers flow back through a bounded rendezvous.
            let depth = 2;
            let (free_tx, free_rx) = sync_channel::<Rollout>(depth);
            for _ in 0..depth {
                free_tx.send(Rollout::empty()).expect("pool prefill");
            }
            let (full_tx, full_rx) = sync_channel::<(usize, Rollout)>(1);
            let gate_ref = &gate;
            let collector_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            std::thread::scope(|scope| -> anyhow::Result<()> {
                let collector = scope.spawn({
                    let collector_err = &collector_err;
                    move || -> Duration {
                        let mut total = Duration::ZERO;
                        for i in 0..iters {
                            // recv (not recv_timeout): the consumer drops
                            // free_tx on exit, which unblocks this side.
                            let Ok(mut buf) = free_rx.recv() else { return total };
                            match gate_ref.enter_collect(i % 2) {
                                Ok(true) => {}
                                Ok(false) => return total, // pipeline stopped
                                Err(e) => {
                                    *collector_err.lock().unwrap() = Some(e);
                                    return total;
                                }
                            }
                            let t0 = Instant::now();
                            let span =
                                crate::obs::span("pipeline.collect", run_trace);
                            if let Err(e) = collect(i, &mut buf) {
                                *collector_err.lock().unwrap() = Some(e);
                                return total;
                            }
                            drop(span);
                            total += t0.elapsed();
                            if full_tx.send((i, buf)).is_err() {
                                return total; // consumer bailed; its error wins
                            }
                        }
                        total
                    }
                });
                let mut consume = || -> anyhow::Result<()> {
                    for _ in 0..iters {
                        let (i, mut buf) = loop {
                            match full_rx.recv_timeout(Duration::from_millis(5)) {
                                Ok(x) => break x,
                                Err(RecvTimeoutError::Timeout) => {
                                    if collector_err.lock().unwrap().is_some() {
                                        anyhow::bail!("collector stage failed");
                                    }
                                }
                                Err(RecvTimeoutError::Disconnected) => {
                                    anyhow::bail!("collector stage stopped early")
                                }
                            }
                        };
                        let lane = i % 2;
                        gate.step(lane, SocPhase::DataPrep)?;
                        gate.step(lane, SocPhase::GaeCompute)?;
                        let t0 = Instant::now();
                        let g = {
                            let _span = crate::obs::span("pipeline.gae", run_trace);
                            gae(i, &mut buf)?
                        };
                        times.gae += t0.elapsed();
                        gate.step(lane, SocPhase::LossAndUpdate)?;
                        let t0 = Instant::now();
                        {
                            let _span =
                                crate::obs::span("pipeline.update", run_trace);
                            stats.push(update(i, &mut buf, &g)?);
                        }
                        times.update += t0.elapsed();
                        gate.step(lane, SocPhase::Idle)?;
                        let _ = free_tx.send(buf); // collector may be done
                    }
                    Ok(())
                };
                let result = consume();
                // Unblock a stalled collector and join it before deciding
                // whose error to report.
                gate.stop();
                drop(full_rx);
                drop(free_tx);
                times.collect = collector.join().expect("collector must not panic");
                if let Some(e) = collector_err.lock().unwrap().take() {
                    return Err(e);
                }
                result
            })?;
        }
    }
    times.wall = run_start.elapsed();
    Ok(PipelineRun { stats, times, lanes: gate.into_lanes() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_gae(rollout: &Rollout) -> GaeResult {
        GaeResult {
            advantages: rollout.rewards.clone(),
            rewards_to_go: rollout.rewards.iter().map(|r| r * 2.0).collect(),
            hw_cycles: None,
        }
    }

    /// A deterministic stage set: collect writes iter-dependent rewards,
    /// update folds them into a checksum.
    fn run_mode(mode: PipelineMode, iters: usize) -> Vec<f32> {
        let run = run_stages(
            mode,
            iters,
            |i, buf: &mut Rollout| {
                buf.t_len = 4;
                buf.batch = 2;
                buf.rewards.clear();
                buf.rewards
                    .extend((0..8).map(|k| (i * 100 + k) as f32 * 0.5));
                Ok(())
            },
            |_i, buf| Ok(fake_gae(buf)),
            |_i, _buf, g: &GaeResult| Ok(g.advantages.iter().sum::<f32>()),
        )
        .unwrap();
        assert_eq!(run.stats.len(), iters);
        assert_eq!(run.times.iters, iters);
        // Every iteration crossed the PS↔PL boundary twice.
        assert_eq!(run.lanes.handshakes(), 2 * iters as u64);
        run.stats
    }

    #[test]
    fn both_modes_produce_identical_streams() {
        let seq = run_mode(PipelineMode::Sequential, 7);
        let ovl = run_mode(PipelineMode::Overlapped, 7);
        assert_eq!(seq, ovl);
    }

    #[test]
    fn overlapped_recycles_two_buffers() {
        use std::collections::HashSet;
        let seen = Mutex::new(HashSet::new());
        let run = run_stages(
            PipelineMode::Overlapped,
            6,
            |_i, buf: &mut Rollout| {
                buf.rewards.clear();
                buf.rewards.resize(16, 1.0);
                seen.lock().unwrap().insert(buf.rewards.as_ptr() as usize);
                Ok(())
            },
            |_i, buf| Ok(fake_gae(buf)),
            |_i, _buf, _g| Ok(()),
        )
        .unwrap();
        assert_eq!(run.stats.len(), 6);
        // The pool is 2 deep: after warmup no new allocations appear.
        assert!(
            seen.lock().unwrap().len() <= 2,
            "double buffering must reuse the two pool buffers"
        );
    }

    #[test]
    fn collector_errors_surface() {
        let err = run_stages(
            PipelineMode::Overlapped,
            4,
            |i, _buf: &mut Rollout| {
                anyhow::ensure!(i != 2, "collect failed at iter {i}");
                Ok(())
            },
            |_i, buf| Ok(fake_gae(buf)),
            |_i, _buf, _g| Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("collect failed at iter 2"), "{err}");
    }

    #[test]
    fn consumer_errors_surface_and_join_cleanly() {
        let err = run_stages(
            PipelineMode::Overlapped,
            8,
            |_i, _buf: &mut Rollout| Ok(()),
            |i, buf| {
                anyhow::ensure!(i != 1, "gae exploded");
                Ok(fake_gae(buf))
            },
            |_i, _buf, _g| Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("gae exploded"), "{err}");
    }

    #[test]
    fn stage_fleet_replicas_run_independently_and_in_order() {
        let fleet = run_stage_fleet(3, |replica| {
            run_stages(
                PipelineMode::Sequential,
                4,
                move |i, buf: &mut Rollout| {
                    buf.rewards.clear();
                    buf.rewards
                        .extend((0..4).map(|k| (replica * 1000 + i * 10 + k) as f32));
                    Ok(())
                },
                |_i, buf| Ok(fake_gae(buf)),
                |_i, _buf, g: &GaeResult| Ok(g.advantages.iter().sum::<f32>()),
            )
        })
        .unwrap();
        assert_eq!(fleet.replicas.len(), 3);
        assert_eq!(fleet.total_iters(), 12);
        // Replica order is preserved and each stream matches the same
        // stage set run solo.
        for (replica, run) in fleet.replicas.iter().enumerate() {
            let want: Vec<f32> = (0..4)
                .map(|i| (0..4).map(|k| (replica * 1000 + i * 10 + k) as f32).sum())
                .collect();
            assert_eq!(run.stats, want, "replica {replica}");
        }
        let agg = fleet.aggregate();
        assert_eq!(agg.iters, 12);
        assert_eq!(agg.wall, fleet.wall);
    }

    #[test]
    fn stage_fleet_reports_the_first_failing_replica() {
        let err = run_stage_fleet(3, |replica| {
            run_stages(
                PipelineMode::Sequential,
                2,
                move |_i, _buf: &mut Rollout| {
                    anyhow::ensure!(replica != 1, "replica went down");
                    Ok(())
                },
                |_i, buf| Ok(fake_gae(buf)),
                |_i, _buf, _g| Ok(()),
            )
        })
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("replica 1"), "{msg}");
        assert!(msg.contains("replica went down"), "{msg}");
        assert!(run_stage_fleet::<(), _>(0, |_| unreachable!()).is_err());
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(PipelineMode::parse("Sequential"), Some(PipelineMode::Sequential));
        assert_eq!(PipelineMode::parse("OVERLAP"), Some(PipelineMode::Overlapped));
        assert_eq!(PipelineMode::parse("nope"), None);
        assert_eq!(PipelineMode::default(), PipelineMode::Sequential);
        let err = PipelineMode::parse_cli("bogus").unwrap_err().to_string();
        assert!(err.contains("sequential") && err.contains("overlapped"), "{err}");
    }

    #[test]
    fn stage_times_accounting() {
        let t = StageTimes {
            collect: Duration::from_millis(30),
            gae: Duration::from_millis(20),
            update: Duration::from_millis(10),
            wall: Duration::from_millis(40),
            iters: 1,
        };
        assert_eq!(t.stage_sum(), Duration::from_millis(60));
        assert_eq!(t.overlap_saving(), Duration::from_millis(20));
    }
}

//! Checkpointing: save/restore the full optimizer+network state so
//! training survives process restarts and trained policies can be
//! served by `heppo eval --load`.
//!
//! Format: a small JSON header (versioned, with the env name and vector
//! lengths) followed by the three flat f32 vectors little-endian —
//! readable from any language, diff-friendly header.

use super::ppo::NetState;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &str = "HEPPO-CKPT";
const VERSION: usize = 1;

/// Save a checkpoint.
pub fn save(path: impl AsRef<Path>, env: &str, state: &NetState) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let header = Json::obj(vec![
        ("magic", MAGIC.into()),
        ("version", VERSION.into()),
        ("env", env.into()),
        ("param_count", state.params.len().into()),
        ("step", Json::Num(state.step as f64)),
    ])
    .to_string();
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for vec in [&state.params, &state.adam_m, &state.adam_v] {
        for x in vec {
            f.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a checkpoint; returns `(env_name, state)`.
pub fn load(path: impl AsRef<Path>) -> Result<(String, NetState)> {
    let path = path.as_ref();
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut len4 = [0u8; 4];
    f.read_exact(&mut len4)?;
    let hlen = u32::from_le_bytes(len4) as usize;
    anyhow::ensure!(hlen < 1 << 20, "implausible header length {hlen}");
    let mut hbytes = vec![0u8; hlen];
    f.read_exact(&mut hbytes)?;
    let header = Json::parse(std::str::from_utf8(&hbytes)?)
        .map_err(|e| anyhow!("checkpoint header: {e}"))?;
    anyhow::ensure!(
        header.get("magic").and_then(Json::as_str) == Some(MAGIC),
        "not a heppo checkpoint"
    );
    anyhow::ensure!(
        header.get("version").and_then(Json::as_usize) == Some(VERSION),
        "unsupported checkpoint version"
    );
    let env = header
        .req("env")?
        .as_str()
        .ok_or_else(|| anyhow!("bad env"))?
        .to_string();
    let n = header.req("param_count")?.as_usize().unwrap();
    let step = header.req("step")?.as_f64().unwrap() as f32;

    let mut read_vec = |n: usize| -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let params = read_vec(n)?;
    let adam_m = read_vec(n)?;
    let adam_v = read_vec(n)?;
    Ok((env, NetState { params, adam_m, adam_v, step }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("heppo_ckpt_{name}_{}", std::process::id()))
    }

    fn random_state(n: usize, seed: u64) -> NetState {
        let mut rng = Rng::new(seed);
        let mut s = NetState::fresh(vec![0.0; n]);
        rng.fill_normal_f32(&mut s.params);
        rng.fill_normal_f32(&mut s.adam_m);
        rng.fill_normal_f32(&mut s.adam_v);
        s.step = 42.0;
        s
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let path = tmp("roundtrip");
        let state = random_state(1234, 1);
        save(&path, "pendulum", &state).unwrap();
        let (env, loaded) = load(&path).unwrap();
        assert_eq!(env, "pendulum");
        assert_eq!(loaded.params, state.params);
        assert_eq!(loaded.adam_m, state.adam_m);
        assert_eq!(loaded.adam_v, state.adam_v);
        assert_eq!(loaded.step, 42.0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage");
        std::fs::write(&path, b"\x08\x00\x00\x00notjson!").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic");
        let header = r#"{"magic":"OTHER","version":1,"env":"x","param_count":0,"step":0}"#;
        let mut bytes = (header.len() as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(header.as_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("not a heppo checkpoint"), "{err}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn truncated_body_errors() {
        let path = tmp("trunc");
        let state = random_state(100, 2);
        save(&path, "cartpole", &state).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}

//! The full PPO trainer — ties rollout, GAE stage, and update together
//! under the SoC phase machine, with Table-I phase profiling throughout.

use super::config::TrainerConfig;
use super::gae_stage::{run_gae_stage, GaeResult};
use super::phases::{PhaseMachine, SocPhase};
use super::ppo::{update, Losses, NetState, UpdateParams};
use super::profiler::PhaseProfiler;
use super::rollout::collect;
use crate::envs::vec_env::VecEnv;
use crate::gae::GaeParams;
use crate::quant::RewardValueCodec;
use crate::runtime::{Runtime, Tensor};
use crate::stats::RollingMean;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// Per-iteration statistics.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: usize,
    /// Env steps so far.
    pub steps: usize,
    /// Rolling mean of completed-episode returns.
    pub mean_return: f64,
    /// Episodes completed so far.
    pub episodes: usize,
    pub losses: Losses,
    /// HwSim cycles this iteration, if that backend ran.
    pub hw_cycles: Option<u64>,
}

/// The trainer.
pub struct Trainer {
    pub config: TrainerConfig,
    pub runtime: Runtime,
    envs: VecEnv,
    state: NetState,
    codec: RewardValueCodec,
    gae_params: GaeParams,
    rng: Rng,
    current_obs: Vec<f32>,
    rolling_return: RollingMean,
    episodes: usize,
    steps: usize,
    pub profiler: PhaseProfiler,
    pub phases: PhaseMachine,
    policy_artifact: String,
    train_artifact: String,
}

impl Trainer {
    /// Build a trainer: loads the manifest, the env's artifacts, and the
    /// seeded initial parameters.
    pub fn new(config: TrainerConfig) -> anyhow::Result<Trainer> {
        let runtime = Runtime::new(&config.artifact_dir)?;
        let geo = runtime.manifest.geometry;
        let pool = ThreadPool::new(config.env_threads);
        let envs = VecEnv::new(&config.env, geo.num_envs, config.seed ^ 0xE57, pool)?;
        let params = runtime
            .manifest
            .load_blob_f32(&format!("{}_init_params", config.env))?;
        let mut rng = Rng::new(config.seed);
        let mut envs = envs;
        let current_obs = envs.reset_all();
        let _ = &mut rng;
        Ok(Trainer {
            policy_artifact: format!("{}_policy_fwd", config.env),
            train_artifact: format!("{}_train_step", config.env),
            gae_params: GaeParams::new(geo.gamma, geo.lambda),
            codec: RewardValueCodec::new(config.codec, config.quant_bits),
            state: NetState::fresh(params),
            rolling_return: RollingMean::new(100),
            episodes: 0,
            steps: 0,
            profiler: PhaseProfiler::new(),
            phases: PhaseMachine::new(),
            rng,
            current_obs,
            envs,
            runtime,
            config,
        })
    }

    /// Run one PPO iteration (rollout → GAE → update).
    pub fn iterate(&mut self, iter: usize) -> anyhow::Result<IterStats> {
        let geo = self.runtime.manifest.geometry;

        // --- trajectory collection -----------------------------------
        if self.phases.current() == SocPhase::Idle {
            self.phases.transition(SocPhase::TrajectoryCollection).unwrap();
        } else {
            self.phases.transition(SocPhase::TrajectoryCollection).unwrap();
        }
        let exe = self.runtime.load(&self.policy_artifact)?;
        let num_envs = self.envs.len();
        let obs_dim = self.envs.obs_dim();
        // §Perf: parameters are invariant across the rollout — encode the
        // literal once per iteration instead of once per step.
        let params_lit = Tensor::vec1(self.state.params.clone()).to_literal()?;
        let mut policy = |obs: &[f32]| -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            let obs_lit =
                Tensor::new(obs.to_vec(), vec![num_envs, obs_dim]).to_literal()?;
            let out = exe.call_literals(&[&params_lit, &obs_lit])?;
            Ok((out[0].data.clone(), out[1].data.clone()))
        };
        let mut rollout = collect(
            &mut self.envs,
            &mut policy,
            &mut self.current_obs,
            geo.rollout_t,
            &mut self.rng,
            &mut self.profiler,
        )?;
        for &r in &rollout.finished_returns {
            self.rolling_return.push(r);
            self.episodes += 1;
        }
        self.steps += rollout.transitions();

        // --- GAE phase -------------------------------------------------
        self.phases.transition(SocPhase::DataPrep).unwrap();
        self.phases.transition(SocPhase::GaeCompute).unwrap();
        let gae: GaeResult = run_gae_stage(
            self.config.backend,
            &self.gae_params,
            &mut rollout,
            &mut self.codec,
            Some(&self.runtime),
            &mut self.profiler,
        )?;

        // --- update ----------------------------------------------------
        self.phases.transition(SocPhase::LossAndUpdate).unwrap();
        let up = UpdateParams {
            epochs: self.config.epochs,
            lr: self.config.lr,
            clip_eps: self.config.clip_eps,
            ent_coef: self.config.ent_coef,
            standardize_advantages: self.config.standardize_advantages,
        };
        let losses = update(
            &self.runtime,
            &self.train_artifact,
            &mut self.state,
            &rollout,
            &gae,
            &up,
            &mut self.rng,
            &mut self.profiler,
        )?;

        Ok(IterStats {
            iter,
            steps: self.steps,
            mean_return: self.rolling_return.mean(),
            episodes: self.episodes,
            losses,
            hw_cycles: gae.hw_cycles,
        })
    }

    /// Run `iters` iterations, returning per-iteration stats.
    pub fn run(&mut self) -> anyhow::Result<Vec<IterStats>> {
        let iters = self.config.iters;
        let mut stats = Vec::with_capacity(iters);
        for i in 0..iters {
            let s = self.iterate(i)?;
            crate::log_info!(
                "iter {:>4} steps {:>8} return {:>9.2} pi {:+.4} v {:.4} H {:.3}{}",
                s.iter,
                s.steps,
                s.mean_return,
                s.losses.pi_loss,
                s.losses.v_loss,
                s.losses.entropy,
                s.hw_cycles
                    .map(|c| format!(" hw_cycles {c}"))
                    .unwrap_or_default()
            );
            stats.push(s);
        }
        Ok(stats)
    }

    /// Current network parameters (for evaluation).
    pub fn params(&self) -> &[f32] {
        &self.state.params
    }

    /// Persist the full optimizer+network state.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        super::checkpoint::save(path, &self.config.env, &self.state)
    }

    /// Restore state from a checkpoint (env must match this trainer's).
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let (env, state) = super::checkpoint::load(path)?;
        anyhow::ensure!(
            env == self.config.env,
            "checkpoint is for env {env:?}, trainer is {:?}",
            self.config.env
        );
        anyhow::ensure!(
            state.params.len() == self.state.params.len(),
            "checkpoint param count {} != model {}",
            state.params.len(),
            self.state.params.len()
        );
        self.state = state;
        Ok(())
    }

    /// Mean return of a greedy evaluation rollout (no exploration).
    pub fn evaluate(&mut self, episodes: usize) -> anyhow::Result<f64> {
        let exe = self.runtime.load(&self.policy_artifact)?;
        let num_envs = self.envs.len();
        let obs_dim = self.envs.obs_dim();
        let space = self.envs.action_space().clone();
        let mut done_returns = Vec::new();
        let mut obs = self.envs.reset_all();
        while done_returns.len() < episodes {
            let out = exe.call(&[
                Tensor::vec1(self.state.params.clone()),
                Tensor::new(obs.clone(), vec![num_envs, obs_dim]),
            ])?;
            let width = out[0].data.len() / num_envs;
            let actions: Vec<crate::envs::Action> = (0..num_envs)
                .map(|i| {
                    super::policy::greedy(
                        &space,
                        &out[0].data[i * width..(i + 1) * width],
                    )
                })
                .collect();
            let step = self.envs.step_all(&actions);
            for &(_, ret, _) in &step.finished {
                done_returns.push(ret);
            }
            obs = step.obs;
        }
        // Restore training observation state.
        self.current_obs = self.envs.reset_all();
        Ok(done_returns.iter().sum::<f64>() / done_returns.len() as f64)
    }
}

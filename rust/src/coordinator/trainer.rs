//! The full PPO trainer — ties rollout, GAE stage, and update together
//! under the SoC phase-lane machine, with Table-I phase profiling
//! throughout.
//!
//! [`Trainer::iterate`] is split into three stages —
//! [`Trainer::collect_stage`], [`Trainer::gae_stage`],
//! [`Trainer::update_stage`] — scheduled per
//! [`PipelineMode`]:
//!
//! - **`Sequential`** (default) runs them back to back with the inline
//!   GAE backend: the paper's §III-A schedule, bit-identical to the
//!   pre-pipeline trainer at the same seed.
//! - **`Overlapped`** dispatches the GAE planes to an in-process
//!   [`GaeService`] worker pool and overlaps the wait with the
//!   advantage-independent half of the update (epoch permutations +
//!   minibatch gathers). The PJRT runtime is thread-pinned (its
//!   executable cache is `Rc`), so the policy/update artifacts stay on
//!   this thread and only the GAE compute fans out — which preserves the
//!   sequential dependency graph exactly, so `Overlapped` produces the
//!   same `IterStats` stream bit-for-bit (the service's per-column math
//!   is bit-identical to the inline stage; only `hw_cycles` accounting
//!   differs on the hwsim backend). Rollout storage is a recycled
//!   buffer refilled in place, so the collection path allocates nothing
//!   per iteration (the trainer holds at most one rollout in flight;
//!   true double buffering lives in the threaded driver).
//!
//! The fully-threaded cross-iteration overlap (collection of *i+1*
//! concurrent with GAE+update of *i*) lives in
//! [`super::pipeline::run_stages`] for `Send` stage sets; see
//! `benches/pipeline_overlap.rs` for the wall-clock comparison.

use super::config::TrainerConfig;
use super::gae_stage::{codec_stage, run_gae_stage, GaeBackend, GaeResult};
use super::phases::{PipelineLanes, SocPhase};
use super::pipeline::PipelineMode;
use super::ppo::{
    execute_update, prepare_update, standardize_advantages, update, Losses, NetState,
    UpdateParams,
};
use super::profiler::{Phase, PhaseProfiler};
use super::rollout::{collect_into, CollectBuffers, Rollout};
use crate::envs::vec_env::VecEnv;
use crate::gae::GaeParams;
use crate::obs::timeseries::{explained_variance, JsonlWriter, LearningHealthRecord};
use crate::quant::RewardValueCodec;
use crate::runtime::{Runtime, Tensor};
use crate::service::{GaeService, ServiceConfig};
use crate::stats::RollingMean;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// Per-iteration statistics.
#[derive(Debug, Clone)]
pub struct IterStats {
    pub iter: usize,
    /// Env steps so far.
    pub steps: usize,
    /// Rolling mean of completed-episode returns.
    pub mean_return: f64,
    /// Episodes completed so far.
    pub episodes: usize,
    pub losses: Losses,
    /// HwSim cycles this iteration, if that backend ran (in `Overlapped`
    /// mode: summed over the service batches the columns rode in).
    pub hw_cycles: Option<u64>,
}

/// The trainer.
pub struct Trainer {
    pub config: TrainerConfig,
    pub runtime: Runtime,
    envs: VecEnv,
    state: NetState,
    codec: RewardValueCodec,
    gae_params: GaeParams,
    rng: Rng,
    current_obs: Vec<f32>,
    rolling_return: RollingMean,
    episodes: usize,
    steps: usize,
    pub profiler: PhaseProfiler,
    /// Phase lanes: `Sequential` cycles lane 0; `Overlapped` alternates
    /// lanes so the schedule (and its PS↔PL handshake accounting) is
    /// auditable per in-flight iteration.
    pub phases: PipelineLanes,
    policy_artifact: String,
    train_artifact: String,
    /// Recycled rollout storage (refilled in place every iteration).
    scratch: Rollout,
    collect_bufs: CollectBuffers,
    /// In-process GAE service (`Overlapped` mode only).
    service: Option<GaeService>,
    /// Learning-curve JSONL sink (`--timeseries` only).
    timeseries: Option<JsonlWriter>,
}

impl Trainer {
    /// Build a trainer: loads the manifest, the env's artifacts, and the
    /// seeded initial parameters.
    pub fn new(config: TrainerConfig) -> anyhow::Result<Trainer> {
        let runtime = Runtime::new(&config.artifact_dir)?;
        let geo = runtime.manifest.geometry;
        let pool = ThreadPool::new(config.env_threads);
        let mut envs = VecEnv::new(&config.env, geo.num_envs, config.seed ^ 0xE57, pool)?;
        let params = runtime
            .manifest
            .load_blob_f32(&format!("{}_init_params", config.env))?;
        let current_obs = envs.reset_all();
        let gae_params = GaeParams::new(geo.gamma, geo.lambda);
        let service = match config.pipeline {
            PipelineMode::Sequential => None,
            PipelineMode::Overlapped => {
                anyhow::ensure!(
                    config.backend != GaeBackend::Hlo,
                    "the overlapped pipeline serves GAE through the worker pool, \
                     which cannot host the hlo backend; use scalar/batched/hwsim \
                     or --pipeline sequential"
                );
                Some(GaeService::start(ServiceConfig {
                    workers: config.service_workers.max(1),
                    backend: config.backend,
                    // Backpressured plane submission: capacity just needs
                    // to cover one iteration's columns without shedding.
                    queue_capacity: geo.num_envs.max(256),
                    gae: gae_params,
                    ..ServiceConfig::default()
                })?)
            }
        };
        let timeseries = match &config.timeseries_path {
            Some(path) => Some(JsonlWriter::create(path)?),
            None => None,
        };
        Ok(Trainer {
            policy_artifact: format!("{}_policy_fwd", config.env),
            train_artifact: format!("{}_train_step", config.env),
            gae_params,
            codec: RewardValueCodec::new(config.codec, config.quant_bits),
            state: NetState::fresh(params),
            rolling_return: RollingMean::new(100),
            episodes: 0,
            steps: 0,
            profiler: PhaseProfiler::new(),
            phases: PipelineLanes::new(2),
            rng: Rng::new(config.seed),
            current_obs,
            scratch: Rollout::empty(),
            collect_bufs: CollectBuffers::new(geo.num_envs, geo.rollout_t),
            service,
            timeseries,
            envs,
            runtime,
            config,
        })
    }

    fn lane_step(&mut self, lane: usize, next: SocPhase) -> anyhow::Result<()> {
        self.phases
            .transition(lane, next)
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// The PPO hyper-parameters for one update call — single source for
    /// both schedules (divergence here is exactly what the equivalence
    /// tests exist to prevent).
    fn update_params(&self) -> UpdateParams {
        UpdateParams {
            epochs: self.config.epochs,
            lr: self.config.lr,
            clip_eps: self.config.clip_eps,
            ent_coef: self.config.ent_coef,
            standardize_advantages: self.config.standardize_advantages,
        }
    }

    /// Trajectory-collection stage: fill a recycled rollout buffer with
    /// `rollout_t` steps from the vectorized envs under the current
    /// policy parameters.
    fn collect_stage(&mut self, lane: usize) -> anyhow::Result<Rollout> {
        self.lane_step(lane, SocPhase::TrajectoryCollection)?;
        let geo = self.runtime.manifest.geometry;
        let exe = self.runtime.load(&self.policy_artifact)?;
        let num_envs = self.envs.len();
        let obs_dim = self.envs.obs_dim();
        // §Perf: parameters are invariant across the rollout — encode the
        // literal once per iteration instead of once per step.
        let params_lit = Tensor::vec1(self.state.params.clone()).to_literal()?;
        let mut policy = |obs: &[f32]| -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
            let obs_lit =
                Tensor::new(obs.to_vec(), vec![num_envs, obs_dim]).to_literal()?;
            let out = exe.call_literals(&[&params_lit, &obs_lit])?;
            Ok((out[0].data.clone(), out[1].data.clone()))
        };
        let mut rollout = std::mem::take(&mut self.scratch);
        collect_into(
            &mut self.envs,
            &mut policy,
            &mut self.current_obs,
            geo.rollout_t,
            &mut self.rng,
            &mut self.profiler,
            &mut self.collect_bufs,
            &mut rollout,
            self.config.keep_raw_planes,
        )?;
        for &r in &rollout.finished_returns {
            self.rolling_return.push(r);
            self.episodes += 1;
        }
        self.steps += rollout.transitions();
        Ok(rollout)
    }

    /// Inline GAE stage (sequential schedule).
    fn gae_stage(&mut self, lane: usize, rollout: &mut Rollout) -> anyhow::Result<GaeResult> {
        self.lane_step(lane, SocPhase::DataPrep)?;
        self.lane_step(lane, SocPhase::GaeCompute)?;
        run_gae_stage(
            self.config.backend,
            &self.gae_params,
            rollout,
            &mut self.codec,
            Some(&self.runtime),
            &mut self.profiler,
        )
    }

    /// Loss + update stage.
    fn update_stage(
        &mut self,
        lane: usize,
        rollout: &Rollout,
        gae: &GaeResult,
    ) -> anyhow::Result<Losses> {
        self.lane_step(lane, SocPhase::LossAndUpdate)?;
        let up = self.update_params();
        let losses = update(
            &self.runtime,
            &self.train_artifact,
            &mut self.state,
            rollout,
            gae,
            &up,
            &mut self.rng,
            &mut self.profiler,
        )?;
        self.lane_step(lane, SocPhase::Idle)?;
        Ok(losses)
    }

    /// One iteration on the overlapped schedule: GAE runs on the service
    /// worker pool while this thread prepares the update's
    /// advantage-independent half.
    fn iterate_overlapped(&mut self, lane: usize) -> anyhow::Result<(GaeResult, Losses, Rollout)> {
        let mut rollout = self.collect_stage(lane)?;
        self.lane_step(lane, SocPhase::DataPrep)?;
        codec_stage(&mut rollout, &mut self.codec, &mut self.profiler);
        self.lane_step(lane, SocPhase::GaeCompute)?;
        let service = self.service.as_ref().expect("overlapped mode owns a service");
        let pending = service.submit_planes(
            rollout.t_len,
            rollout.batch,
            &rollout.rewards,
            &rollout.values,
            &rollout.done_mask,
        )?;
        // ---- the overlap: while the worker pool computes advantages,
        // draw the epoch permutations (same RNG stream order as the
        // sequential path — the stream does not depend on GAE results)
        // and gather the advantage-independent minibatch tensors.
        let plan = prepare_update(
            &self.runtime,
            &self.train_artifact,
            &rollout,
            self.config.epochs,
            &mut self.rng,
            true, // pre-gather: this work hides under the service wait
        )?;
        let gae: GaeResult = self
            .profiler
            .time(Phase::GaeComputation, || pending.wait())?
            .into();
        self.lane_step(lane, SocPhase::LossAndUpdate)?;
        let up = self.update_params();
        let losses = execute_update(
            &self.runtime,
            &self.train_artifact,
            &mut self.state,
            &rollout,
            &gae,
            plan,
            &up,
            &mut self.profiler,
        )?;
        self.lane_step(lane, SocPhase::Idle)?;
        Ok((gae, losses, rollout))
    }

    /// Run one PPO iteration (rollout → GAE → update) on the configured
    /// schedule.
    pub fn iterate(&mut self, iter: usize) -> anyhow::Result<IterStats> {
        let wall_start = std::time::Instant::now();
        let (gae, losses) = match self.config.pipeline {
            PipelineMode::Sequential => {
                let lane = 0;
                let mut rollout = self.collect_stage(lane)?;
                let gae = self.gae_stage(lane, &mut rollout)?;
                let losses = self.update_stage(lane, &rollout, &gae)?;
                self.scratch = rollout;
                (gae, losses)
            }
            PipelineMode::Overlapped => {
                let (gae, losses, rollout) = self.iterate_overlapped(iter % 2)?;
                self.scratch = rollout;
                (gae, losses)
            }
        };
        self.profiler.add_iteration_wall(wall_start.elapsed());
        let stats = IterStats {
            iter,
            steps: self.steps,
            mean_return: self.rolling_return.mean(),
            episodes: self.episodes,
            losses,
            hw_cycles: gae.hw_cycles,
        };
        if self.timeseries.is_some() {
            let record = self.learning_health(&stats, &gae)?;
            if let Some(w) = self.timeseries.as_mut() {
                w.write(&record.to_json())?;
            }
        }
        Ok(stats)
    }

    /// Build the per-iteration learning-health row from the rollout just
    /// stored in `scratch` and its GAE result. The approx-KL and
    /// clip-fraction scalars re-evaluate the *updated* policy over the
    /// rollout observations ([`super::policy::logp_of`] consumes no
    /// RNG), so emitting the time series never perturbs the run's
    /// sampled trajectory — sequential/overlapped bit-equivalence
    /// holds with diagnostics on or off.
    fn learning_health(
        &mut self,
        stats: &IterStats,
        gae: &GaeResult,
    ) -> anyhow::Result<LearningHealthRecord> {
        fn moments(xs: &[f32]) -> (f32, f32) {
            if xs.is_empty() {
                return (0.0, 0.0);
            }
            let n = xs.len() as f64;
            let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
            let var =
                xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
            (mean as f32, var.sqrt() as f32)
        }
        let rollout = &self.scratch;
        let n = rollout.transitions();
        let (adv_mean_pre, adv_std_pre) = moments(&gae.advantages);
        let (adv_mean_post, adv_std_post) = if self.config.standardize_advantages {
            let mut post = gae.advantages.clone();
            standardize_advantages(&mut post);
            moments(&post)
        } else {
            (adv_mean_pre, adv_std_pre)
        };
        // The critic's per-transition predictions are the first T rows of
        // the value plane (row T+1 only bootstraps), post-codec — exactly
        // what the update consumed.
        let value_explained_variance =
            explained_variance(&gae.rewards_to_go, &rollout.values[..n]);

        // Post-update policy over the same observations, one forward per
        // timestep (the artifact's batch dimension is the env count).
        let exe = self.runtime.load(&self.policy_artifact)?;
        let space = self.envs.action_space().clone();
        let num_envs = rollout.batch;
        let obs_dim = rollout.obs_dim;
        let aw = rollout.act_width;
        let params_lit = Tensor::vec1(self.state.params.clone()).to_literal()?;
        let mut kl_sum = 0.0f64;
        let mut clipped = 0usize;
        for t in 0..rollout.t_len {
            let obs = &rollout.obs[t * num_envs * obs_dim..(t + 1) * num_envs * obs_dim];
            let obs_lit = Tensor::new(obs.to_vec(), vec![num_envs, obs_dim]).to_literal()?;
            let out = exe.call_literals(&[&params_lit, &obs_lit])?;
            let width = out[0].data.len() / num_envs;
            for b in 0..num_envs {
                let row = t * num_envs + b;
                let dist = &out[0].data[b * width..(b + 1) * width];
                let new_lp = super::policy::logp_of(
                    &space,
                    dist,
                    &rollout.actions[row * aw..(row + 1) * aw],
                );
                let old_lp = rollout.logp[row];
                kl_sum += (old_lp - new_lp) as f64;
                let ratio = ((new_lp - old_lp) as f64).exp();
                if (ratio - 1.0).abs() > self.config.clip_eps as f64 {
                    clipped += 1;
                }
            }
        }
        Ok(LearningHealthRecord {
            iter: stats.iter,
            env_steps: stats.steps as u64,
            episodes: stats.episodes as u64,
            mean_return: stats.mean_return as f32,
            pi_loss: stats.losses.pi_loss,
            v_loss: stats.losses.v_loss,
            entropy: stats.losses.entropy,
            adv_mean_pre,
            adv_std_pre,
            adv_mean_post,
            adv_std_post,
            value_explained_variance,
            approx_kl: (kl_sum / n.max(1) as f64) as f32,
            clip_fraction: clipped as f32 / n.max(1) as f32,
        })
    }

    /// Learning-health rows written so far (`--timeseries` only).
    pub fn timeseries_records(&self) -> u64 {
        self.timeseries.as_ref().map(|w| w.records_written()).unwrap_or(0)
    }

    /// Run `iters` iterations, returning per-iteration stats.
    pub fn run(&mut self) -> anyhow::Result<Vec<IterStats>> {
        let iters = self.config.iters;
        let mut stats = Vec::with_capacity(iters);
        for i in 0..iters {
            let s = self.iterate(i)?;
            crate::log_info!(
                "iter {:>4} steps {:>8} return {:>9.2} pi {:+.4} v {:.4} H {:.3}{}",
                s.iter,
                s.steps,
                s.mean_return,
                s.losses.pi_loss,
                s.losses.v_loss,
                s.losses.entropy,
                s.hw_cycles
                    .map(|c| format!(" hw_cycles {c}"))
                    .unwrap_or_default()
            );
            stats.push(s);
        }
        Ok(stats)
    }

    /// Current network parameters (for evaluation).
    pub fn params(&self) -> &[f32] {
        &self.state.params
    }

    /// Persist the full optimizer+network state.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        super::checkpoint::save(path, &self.config.env, &self.state)
    }

    /// Restore state from a checkpoint (env must match this trainer's).
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let (env, state) = super::checkpoint::load(path)?;
        anyhow::ensure!(
            env == self.config.env,
            "checkpoint is for env {env:?}, trainer is {:?}",
            self.config.env
        );
        anyhow::ensure!(
            state.params.len() == self.state.params.len(),
            "checkpoint param count {} != model {}",
            state.params.len(),
            self.state.params.len()
        );
        self.state = state;
        Ok(())
    }

    /// Mean return of a greedy evaluation rollout (no exploration).
    pub fn evaluate(&mut self, episodes: usize) -> anyhow::Result<f64> {
        let exe = self.runtime.load(&self.policy_artifact)?;
        let num_envs = self.envs.len();
        let obs_dim = self.envs.obs_dim();
        let space = self.envs.action_space().clone();
        let mut done_returns = Vec::new();
        let mut obs = self.envs.reset_all();
        while done_returns.len() < episodes {
            let out = exe.call(&[
                Tensor::vec1(self.state.params.clone()),
                Tensor::new(obs.clone(), vec![num_envs, obs_dim]),
            ])?;
            let width = out[0].data.len() / num_envs;
            let actions: Vec<crate::envs::Action> = (0..num_envs)
                .map(|i| {
                    super::policy::greedy(
                        &space,
                        &out[0].data[i * width..(i + 1) * width],
                    )
                })
                .collect();
            let step = self.envs.step_all(&actions);
            for &(_, ret, _) in &step.finished {
                done_returns.push(ret);
            }
            obs = step.obs;
        }
        // Restore training observation state.
        self.current_obs = self.envs.reset_all();
        Ok(done_returns.iter().sum::<f64>() / done_returns.len() as f64)
    }
}

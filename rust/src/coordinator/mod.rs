//! The L3 coordinator — the pipelined PPO training system around the
//! HEPPO-GAE accelerator.
//!
//! One iteration still traverses the paper's SoC data flow (§III-A):
//!
//! 1. **Trajectory collection** ([`rollout`]) — the vectorized env engine
//!    steps N environments; actions come from the `policy_fwd` HLO
//!    artifact (the PL's DNN systolic array in the paper); rewards and
//!    values pass through the standardization/quantization codec into
//!    FILO stack storage ([`crate::memory::filo`]). The path is
//!    allocation-free across iterations: [`rollout::collect_into`]
//!    refills recycled [`rollout::Rollout`] buffers and
//!    [`rollout::CollectBuffers`] stack planes in place.
//! 2. **GAE phase** ([`gae_stage`]) — advantages/RTGs from a pluggable
//!    backend (scalar baseline, batched CPU, the Pallas-lowered HLO
//!    kernel, or the cycle-accurate [`crate::hwsim`]), either inline or
//!    dispatched to the [`crate::service`] worker pool through its
//!    plane-shaped client seam.
//! 3. **Losses + update** ([`ppo`]) — minibatched PPO-clip/Adam steps via
//!    the `train_step` HLO artifact, split into an
//!    advantage-independent [`ppo::prepare_update`] half and the
//!    artifact-executing [`ppo::execute_update`] half so preparation can
//!    hide under the GAE wait.
//!
//! *How iterations are scheduled* is now a knob
//! ([`pipeline::PipelineMode`], `TrainerConfig::pipeline`):
//!
//! - **`Sequential`** — the paper's strictly ordered phase machine; bit-
//!   identical to the pre-pipeline trainer at the same seed.
//! - **`Overlapped`** — the pipelined trainer: GAE runs on the service
//!   worker shards while the coordinator prepares the update, and — for
//!   `Send` stage sets via [`pipeline::run_stages`] — iteration *i+1*'s
//!   collection runs on a collector thread, double-buffered through
//!   bounded channels, concurrently with iteration *i*'s GAE + update.
//!
//! [`phases::PhaseMachine`] enforces the PS↔PL sequencing of one
//! in-flight iteration and accounts handshake overhead;
//! [`phases::PipelineLanes`] extends that to overlapped schedules (one
//! lane per in-flight iteration, exclusive phase occupancy, per-lane
//! handshake accounting). [`profiler::PhaseProfiler`] captures per-phase
//! wall time to regenerate the paper's Table I, plus per-iteration wall
//! clock so overlap can be quantified
//! ([`profiler::PhaseProfiler::phase_coverage`]);
//! `benches/pipeline_overlap.rs` sweeps sequential vs. overlapped across
//! backends.

pub mod checkpoint;
pub mod config;
pub mod gae_stage;
pub mod phases;
pub mod pipeline;
pub mod policy;
pub mod ppo;
pub mod profiler;
pub mod rollout;
pub mod trainer;

pub use config::TrainerConfig;
pub use gae_stage::GaeBackend;
pub use pipeline::{
    run_stage_fleet, run_stages, FleetRun, PipelineMode, PipelineRun, StageTimes,
};
pub use profiler::{Phase, PhaseProfiler};
pub use trainer::{IterStats, Trainer};

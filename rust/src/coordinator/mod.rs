//! The L3 coordinator — the PPO training system around the HEPPO-GAE
//! accelerator.
//!
//! Mirrors the paper's SoC data flow (§III-A):
//!
//! 1. **Trajectory collection** ([`rollout`]) — the vectorized env engine
//!    steps N environments; actions come from the `policy_fwd` HLO
//!    artifact (the PL's DNN systolic array in the paper); rewards and
//!    values pass through the standardization/quantization codec into
//!    FILO stack storage ([`crate::memory::filo`]).
//! 2. **GAE phase** ([`gae_stage`]) — the PS signals the accelerator;
//!    advantages/RTGs are computed by a pluggable backend (scalar
//!    baseline, batched CPU, the Pallas-lowered HLO kernel, or the
//!    cycle-accurate [`crate::hwsim`]).
//! 3. **Losses + update** ([`ppo`]) — minibatched PPO-clip/Adam steps via
//!    the `train_step` HLO artifact.
//!
//! [`phases::PhaseMachine`] enforces the PS↔PL sequencing and accounts
//! handshake overhead; [`profiler::PhaseProfiler`] captures per-phase
//! wall time to regenerate the paper's Table I.

pub mod checkpoint;
pub mod config;
pub mod gae_stage;
pub mod phases;
pub mod policy;
pub mod ppo;
pub mod profiler;
pub mod rollout;
pub mod trainer;

pub use config::TrainerConfig;
pub use gae_stage::GaeBackend;
pub use profiler::{Phase, PhaseProfiler};
pub use trainer::{IterStats, Trainer};

//! Trajectory collection — the paper's "Trajectory Collection" phase.
//!
//! Steps the vectorized envs for `T` timesteps with actions from the
//! `policy_fwd` artifact, storing everything in timestep-major layout
//! (the Fig. 6 memory-block layout): rewards and values are pushed
//! row-by-row into FILO stacks through the standardization/quantization
//! codec, exactly as the SoC stores them in BRAM. Observations, encoded
//! actions and log-probs stay on the PS side for the update phase.
//!
//! The collection path is allocation-free across iterations: the caller
//! owns a [`Rollout`] and a [`CollectBuffers`] (the FILO stack planes)
//! and [`collect_into`] refills them in place, so the pipelined trainer
//! recycles the same storage every iteration and `vec_env` rows flow
//! into the GAE service batcher without per-iteration reallocation. The
//! raw (pre-codec) diagnostic planes double rollout memory, so they are
//! only captured when `keep_raw` is set (Fig. 2/7 benches want them; the
//! training loop does not).

use super::policy::{sample, Sampled};
use super::profiler::{Phase, PhaseProfiler};
use crate::envs::vec_env::{VecEnv, VecStep};
use crate::memory::FiloStack;
use crate::util::Rng;

/// One iteration's collected data, timestep-major.
#[derive(Debug, Clone, Default)]
pub struct Rollout {
    pub t_len: usize,
    pub batch: usize,
    pub obs_dim: usize,
    /// `[T * B * obs_dim]` observations (pre-step).
    pub obs: Vec<f32>,
    /// `[T * B * act_width]` encoded actions.
    pub actions: Vec<f32>,
    pub act_width: usize,
    /// `[T * B]` behavior log-probs.
    pub logp: Vec<f32>,
    /// `[T * B]` rewards *after* the storage codec (what GAE reads back).
    pub rewards: Vec<f32>,
    /// `[(T+1) * B]` values after the codec; last row bootstraps.
    pub values: Vec<f32>,
    /// `[T * B]` done mask (1.0 = episode ended at t).
    pub done_mask: Vec<f32>,
    /// Episode returns completed during collection.
    pub finished_returns: Vec<f64>,
    /// Raw (pre-codec) rewards for diagnostics (Fig. 2/7 data); empty
    /// unless collected with `keep_raw`.
    pub raw_rewards: Vec<f32>,
    /// Raw (pre-codec) values; empty unless collected with `keep_raw`.
    pub raw_values: Vec<f32>,
}

impl Rollout {
    /// An empty, shape-less buffer for a reuse pool ([`collect_into`]
    /// sets the shape on every fill).
    pub fn empty() -> Rollout {
        Rollout::default()
    }

    pub fn transitions(&self) -> usize {
        self.t_len * self.batch
    }

    /// Reset for refill: set the shape, clear every plane but keep the
    /// allocations.
    fn clear_for(&mut self, t_len: usize, batch: usize, obs_dim: usize, act_width: usize) {
        self.t_len = t_len;
        self.batch = batch;
        self.obs_dim = obs_dim;
        self.act_width = act_width;
        self.obs.clear();
        self.actions.clear();
        self.logp.clear();
        self.rewards.clear();
        self.values.clear();
        self.done_mask.clear();
        self.finished_returns.clear();
        self.raw_rewards.clear();
        self.raw_values.clear();
    }
}

/// Reusable FILO stack planes for the (reward, value) rows — the BRAM
/// stack of Fig. 6 (raw f32 here; the codec pass quantizes at the
/// iteration level, matching the paper's block-statistics timing). Owned
/// by the trainer so the planes persist across iterations.
#[derive(Debug)]
pub struct CollectBuffers {
    reward_stack: FiloStack<f32>,
    value_stack: FiloStack<f32>,
    /// Reused env-step output buffers (obs/rewards/dones planes).
    step: VecStep,
    batch: usize,
    t_len: usize,
}

impl CollectBuffers {
    pub fn new(batch: usize, t_len: usize) -> CollectBuffers {
        CollectBuffers {
            reward_stack: FiloStack::new(batch, t_len),
            value_stack: FiloStack::new(batch, t_len + 1),
            step: VecStep::default(),
            batch,
            t_len,
        }
    }

    /// Reset the stacks (re-allocating only if the shape changed).
    fn reset_for(&mut self, batch: usize, t_len: usize) {
        if self.batch != batch || self.t_len != t_len {
            self.reward_stack = FiloStack::new(batch, t_len);
            self.value_stack = FiloStack::new(batch, t_len + 1);
            self.batch = batch;
            self.t_len = t_len;
        } else {
            self.reward_stack.reset();
            self.value_stack.reset();
        }
    }
}

/// A policy-forward oracle: obs `[B * obs_dim]` → (dist `[B * W]`, values
/// `[B]`). Implemented by the trainer over the HLO artifact; tests use
/// closures.
pub trait PolicyFn {
    fn forward(&mut self, obs: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;
}

impl<F> PolicyFn for F
where
    F: FnMut(&[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>,
{
    fn forward(&mut self, obs: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self(obs)
    }
}

/// Collect `t_len` steps from `envs` with `policy` into a caller-owned
/// [`Rollout`], reusing `bufs` for the stack planes — no per-iteration
/// allocation once the buffers are warm.
///
/// `current_obs` carries the env state across iterations (from
/// `reset_all` initially, then the tail of the previous rollout).
/// The profiler attributes time to `DnnInference` / `EnvironmentRun` /
/// `StoringTrajectories` as in Table I. The raw (pre-codec) planes are
/// captured only when `keep_raw` is set.
#[allow(clippy::too_many_arguments)]
pub fn collect_into(
    envs: &mut VecEnv,
    policy: &mut dyn PolicyFn,
    current_obs: &mut Vec<f32>,
    t_len: usize,
    rng: &mut Rng,
    profiler: &mut PhaseProfiler,
    bufs: &mut CollectBuffers,
    out: &mut Rollout,
    keep_raw: bool,
) -> anyhow::Result<()> {
    let batch = envs.len();
    let obs_dim = envs.obs_dim();
    let space = envs.action_space().clone();
    let act_width = match &space {
        crate::envs::ActionSpace::Discrete(_) => 1,
        crate::envs::ActionSpace::Continuous { dim, .. } => *dim,
    };

    bufs.reset_for(batch, t_len);
    out.clear_for(t_len, batch, obs_dim, act_width);
    out.obs.reserve(t_len * batch * obs_dim);
    out.actions.reserve(t_len * batch * act_width);
    out.logp.reserve(t_len * batch);
    out.done_mask.reserve(t_len * batch);

    let mut acts: Vec<crate::envs::Action> = Vec::with_capacity(batch);
    for _t in 0..t_len {
        // DNN inference on the PL (the policy_fwd artifact).
        let (dist, values_row) =
            profiler.time(Phase::DnnInference, || policy.forward(current_obs))?;
        let width = dist.len() / batch;

        // PS samples actions (cheap, irregular).
        let sampled: Vec<Sampled> = (0..batch)
            .map(|i| sample(&space, &dist[i * width..(i + 1) * width], rng))
            .collect();

        out.obs.extend_from_slice(current_obs);
        for s in &sampled {
            out.actions.extend_from_slice(&s.encoded);
            out.logp.push(s.logp);
        }

        // Environment step on the PS cores (into the reused step planes).
        acts.clear();
        acts.extend(sampled.iter().map(|s| s.action.clone()));
        profiler.time(Phase::EnvironmentRun, || {
            envs.step_all_into(&acts, &mut bufs.step)
        });

        // Store the (reward, value) rows into the stacks.
        profiler.time(Phase::StoringTrajectories, || {
            bufs.reward_stack
                .push_row(&bufs.step.rewards)
                .expect("stack sized for T");
            bufs.value_stack
                .push_row(&values_row)
                .expect("stack sized for T+1");
        });

        for d in &bufs.step.dones {
            out.done_mask.push(if *d { 1.0 } else { 0.0 });
        }
        for &(_, ret, _) in &bufs.step.finished {
            out.finished_returns.push(ret);
        }
        current_obs.clear();
        current_obs.extend_from_slice(&bufs.step.obs);
    }

    // Bootstrap value of the final state.
    let (_, boot_values) =
        profiler.time(Phase::DnnInference, || policy.forward(current_obs))?;
    profiler.time(Phase::StoringTrajectories, || {
        bufs.value_stack.push_row(&boot_values).expect("bootstrap row");
    });

    // Drain the stacks into contiguous timestep-major planes.
    out.rewards.resize(t_len * batch, 0.0);
    out.values.resize((t_len + 1) * batch, 0.0);
    for t in 0..t_len {
        out.rewards[t * batch..(t + 1) * batch]
            .copy_from_slice(bufs.reward_stack.row(t).unwrap());
    }
    for t in 0..=t_len {
        out.values[t * batch..(t + 1) * batch]
            .copy_from_slice(bufs.value_stack.row(t).unwrap());
    }
    if keep_raw {
        out.raw_rewards.extend_from_slice(&out.rewards);
        out.raw_values.extend_from_slice(&out.values);
    }
    Ok(())
}

/// Allocate-and-collect convenience (tests, diagnostics benches): fresh
/// buffers every call, raw planes kept. The training loop uses
/// [`collect_into`] with recycled storage instead.
pub fn collect(
    envs: &mut VecEnv,
    policy: &mut dyn PolicyFn,
    current_obs: &mut Vec<f32>,
    t_len: usize,
    rng: &mut Rng,
    profiler: &mut PhaseProfiler,
) -> anyhow::Result<Rollout> {
    let mut bufs = CollectBuffers::new(envs.len(), t_len);
    let mut out = Rollout::empty();
    collect_into(
        envs, policy, current_obs, t_len, rng, profiler, &mut bufs, &mut out, true,
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;

    /// A uniform-random "policy" with zero values.
    fn uniform_policy(act_width: usize, batch: usize) -> impl PolicyFn {
        move |_obs: &[f32]| Ok((vec![0.0f32; batch * act_width], vec![0.0f32; batch]))
    }

    #[test]
    fn shapes_and_layout() {
        let mut envs = VecEnv::new("cartpole", 4, 1, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(0);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 4);
        let r = collect(&mut envs, &mut pol, &mut obs, 16, &mut rng, &mut prof).unwrap();
        assert_eq!(r.t_len, 16);
        assert_eq!(r.batch, 4);
        assert_eq!(r.obs.len(), 16 * 4 * 4);
        assert_eq!(r.actions.len(), 16 * 4);
        assert_eq!(r.logp.len(), 64);
        assert_eq!(r.rewards.len(), 64);
        assert_eq!(r.values.len(), 17 * 4);
        assert_eq!(r.done_mask.len(), 64);
        // CartPole: every reward is 1.0 pre-codec.
        assert!(r.rewards.iter().all(|&x| x == 1.0));
        // The convenience wrapper keeps the raw diagnostic planes.
        assert_eq!(r.raw_rewards, r.rewards);
        assert_eq!(r.raw_values, r.values);
        // Profiler saw all three collection phases.
        assert!(prof.total(Phase::DnnInference) > std::time::Duration::ZERO);
        assert!(prof.total(Phase::EnvironmentRun) > std::time::Duration::ZERO);
    }

    #[test]
    fn carries_obs_across_calls() {
        let mut envs = VecEnv::new("pendulum", 2, 3, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(0);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 2); // mean+log_std for dim=1
        let r1 = collect(&mut envs, &mut pol, &mut obs, 8, &mut rng, &mut prof).unwrap();
        let carried = obs.clone();
        // The first obs row of the next rollout must equal the carried obs
        // (rollout.obs stores pre-step observations).
        let r2 = collect(&mut envs, &mut pol, &mut obs, 8, &mut rng, &mut prof).unwrap();
        assert_ne!(r1.obs[..6], r2.obs[..6]);
        assert_eq!(&r2.obs[..6], &carried[..]);
    }

    #[test]
    fn done_mask_marks_episode_ends() {
        let mut envs = VecEnv::new("cartpole", 2, 5, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(1);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 2);
        let r = collect(&mut envs, &mut pol, &mut obs, 256, &mut rng, &mut prof).unwrap();
        let dones = r.done_mask.iter().filter(|&&d| d == 1.0).count();
        assert!(dones > 0, "random cartpole must fail within 256 steps");
        assert_eq!(r.finished_returns.len(), dones);
    }

    #[test]
    fn collect_into_reuses_allocations_and_matches_collect() {
        // Same seeds through the reuse path and the allocating wrapper
        // must agree bit-for-bit; the second refill must not reallocate.
        let fresh = {
            let mut envs = VecEnv::new("cartpole", 4, 9, ThreadPool::new(2)).unwrap();
            let mut obs = envs.reset_all();
            let mut rng = Rng::new(7);
            let mut prof = PhaseProfiler::new();
            let mut pol = uniform_policy(2, 4);
            let a = collect(&mut envs, &mut pol, &mut obs, 32, &mut rng, &mut prof)
                .unwrap();
            let b = collect(&mut envs, &mut pol, &mut obs, 32, &mut rng, &mut prof)
                .unwrap();
            (a, b)
        };
        let mut envs = VecEnv::new("cartpole", 4, 9, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(7);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 4);
        let mut bufs = CollectBuffers::new(4, 32);
        let mut out = Rollout::empty();
        collect_into(
            &mut envs, &mut pol, &mut obs, 32, &mut rng, &mut prof, &mut bufs,
            &mut out, false,
        )
        .unwrap();
        assert_eq!(out.rewards, fresh.0.rewards);
        assert_eq!(out.obs, fresh.0.obs);
        assert!(out.raw_rewards.is_empty(), "raw planes are gated off");
        let ptrs = (out.obs.as_ptr(), out.rewards.as_ptr(), out.values.as_ptr());
        collect_into(
            &mut envs, &mut pol, &mut obs, 32, &mut rng, &mut prof, &mut bufs,
            &mut out, false,
        )
        .unwrap();
        assert_eq!(out.rewards, fresh.1.rewards);
        assert_eq!(out.obs, fresh.1.obs);
        assert_eq!(
            ptrs,
            (out.obs.as_ptr(), out.rewards.as_ptr(), out.values.as_ptr()),
            "warm refill must not reallocate the rollout planes"
        );
    }
}

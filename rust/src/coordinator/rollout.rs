//! Trajectory collection — the paper's "Trajectory Collection" phase.
//!
//! Steps the vectorized envs for `T` timesteps with actions from the
//! `policy_fwd` artifact, storing everything in timestep-major layout
//! (the Fig. 6 memory-block layout): rewards and values are pushed
//! row-by-row into FILO stacks through the standardization/quantization
//! codec, exactly as the SoC stores them in BRAM. Observations, encoded
//! actions and log-probs stay on the PS side for the update phase.

use super::policy::{sample, Sampled};
use super::profiler::{Phase, PhaseProfiler};
use crate::envs::vec_env::VecEnv;
use crate::memory::FiloStack;
use crate::util::Rng;

/// One iteration's collected data, timestep-major.
#[derive(Debug, Clone)]
pub struct Rollout {
    pub t_len: usize,
    pub batch: usize,
    pub obs_dim: usize,
    /// `[T * B * obs_dim]` observations (pre-step).
    pub obs: Vec<f32>,
    /// `[T * B * act_width]` encoded actions.
    pub actions: Vec<f32>,
    pub act_width: usize,
    /// `[T * B]` behavior log-probs.
    pub logp: Vec<f32>,
    /// `[T * B]` rewards *after* the storage codec (what GAE reads back).
    pub rewards: Vec<f32>,
    /// `[(T+1) * B]` values after the codec; last row bootstraps.
    pub values: Vec<f32>,
    /// `[T * B]` done mask (1.0 = episode ended at t).
    pub done_mask: Vec<f32>,
    /// Episode returns completed during collection.
    pub finished_returns: Vec<f64>,
    /// Raw (pre-codec) rewards, kept for diagnostics (Fig. 2/7 data).
    pub raw_rewards: Vec<f32>,
    pub raw_values: Vec<f32>,
}

impl Rollout {
    pub fn transitions(&self) -> usize {
        self.t_len * self.batch
    }
}

/// A policy-forward oracle: obs `[B * obs_dim]` → (dist `[B * W]`, values
/// `[B]`). Implemented by the trainer over the HLO artifact; tests use
/// closures.
pub trait PolicyFn {
    fn forward(&mut self, obs: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;
}

impl<F> PolicyFn for F
where
    F: FnMut(&[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)>,
{
    fn forward(&mut self, obs: &[f32]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self(obs)
    }
}

/// Collect `t_len` steps from `envs` with `policy`.
///
/// `current_obs` carries the env state across iterations (from
/// `reset_all` initially, then the tail of the previous rollout).
/// The profiler attributes time to `DnnInference` / `EnvironmentRun` /
/// `StoringTrajectories` as in Table I.
#[allow(clippy::too_many_arguments)]
pub fn collect(
    envs: &mut VecEnv,
    policy: &mut dyn PolicyFn,
    current_obs: &mut Vec<f32>,
    t_len: usize,
    rng: &mut Rng,
    profiler: &mut PhaseProfiler,
) -> anyhow::Result<Rollout> {
    let batch = envs.len();
    let obs_dim = envs.obs_dim();
    let space = envs.action_space().clone();
    let act_width = match &space {
        crate::envs::ActionSpace::Discrete(_) => 1,
        crate::envs::ActionSpace::Continuous { dim, .. } => *dim,
    };

    // FILO stacks for the (reward, value) planes — the BRAM stack of
    // Fig. 6 (raw f32 here; the codec pass quantizes at the iteration
    // level, matching the paper's block-statistics timing).
    let mut reward_stack: FiloStack<f32> = FiloStack::new(batch, t_len);
    let mut value_stack: FiloStack<f32> = FiloStack::new(batch, t_len + 1);

    let mut obs_out = Vec::with_capacity(t_len * batch * obs_dim);
    let mut actions = Vec::with_capacity(t_len * batch * act_width);
    let mut logp = Vec::with_capacity(t_len * batch);
    let mut done_mask = Vec::with_capacity(t_len * batch);
    let mut finished_returns = Vec::new();

    for _t in 0..t_len {
        // DNN inference on the PL (the policy_fwd artifact).
        let (dist, values_row) =
            profiler.time(Phase::DnnInference, || policy.forward(current_obs))?;
        let width = dist.len() / batch;

        // PS samples actions (cheap, irregular).
        let sampled: Vec<Sampled> = (0..batch)
            .map(|i| sample(&space, &dist[i * width..(i + 1) * width], rng))
            .collect();

        obs_out.extend_from_slice(current_obs);
        for s in &sampled {
            actions.extend_from_slice(&s.encoded);
            logp.push(s.logp);
        }

        // Environment step on the PS cores.
        let acts: Vec<crate::envs::Action> =
            sampled.iter().map(|s| s.action.clone()).collect();
        let step = profiler.time(Phase::EnvironmentRun, || envs.step_all(&acts));

        // Store the (reward, value) rows into the stacks.
        profiler.time(Phase::StoringTrajectories, || {
            reward_stack.push_row(&step.rewards).expect("stack sized for T");
            value_stack.push_row(&values_row).expect("stack sized for T+1");
        });

        for d in &step.dones {
            done_mask.push(if *d { 1.0 } else { 0.0 });
        }
        for &(_, ret, _) in &step.finished {
            finished_returns.push(ret);
        }
        *current_obs = step.obs;
    }

    // Bootstrap value of the final state.
    let (_, boot_values) =
        profiler.time(Phase::DnnInference, || policy.forward(current_obs))?;
    profiler.time(Phase::StoringTrajectories, || {
        value_stack.push_row(&boot_values).expect("bootstrap row");
    });

    // Drain the stacks into contiguous timestep-major planes.
    let mut rewards = vec![0.0f32; t_len * batch];
    let mut values = vec![0.0f32; (t_len + 1) * batch];
    for t in 0..t_len {
        rewards[t * batch..(t + 1) * batch]
            .copy_from_slice(reward_stack.row(t).unwrap());
    }
    for t in 0..=t_len {
        values[t * batch..(t + 1) * batch]
            .copy_from_slice(value_stack.row(t).unwrap());
    }

    Ok(Rollout {
        t_len,
        batch,
        obs_dim,
        obs: obs_out,
        actions,
        act_width,
        logp,
        raw_rewards: rewards.clone(),
        raw_values: values.clone(),
        rewards,
        values,
        done_mask,
        finished_returns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::ThreadPool;

    /// A uniform-random "policy" with zero values.
    fn uniform_policy(act_width: usize, batch: usize) -> impl PolicyFn {
        move |_obs: &[f32]| Ok((vec![0.0f32; batch * act_width], vec![0.0f32; batch]))
    }

    #[test]
    fn shapes_and_layout() {
        let mut envs = VecEnv::new("cartpole", 4, 1, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(0);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 4);
        let r = collect(&mut envs, &mut pol, &mut obs, 16, &mut rng, &mut prof).unwrap();
        assert_eq!(r.t_len, 16);
        assert_eq!(r.batch, 4);
        assert_eq!(r.obs.len(), 16 * 4 * 4);
        assert_eq!(r.actions.len(), 16 * 4);
        assert_eq!(r.logp.len(), 64);
        assert_eq!(r.rewards.len(), 64);
        assert_eq!(r.values.len(), 17 * 4);
        assert_eq!(r.done_mask.len(), 64);
        // CartPole: every reward is 1.0 pre-codec.
        assert!(r.rewards.iter().all(|&x| x == 1.0));
        // Profiler saw all three collection phases.
        assert!(prof.total(Phase::DnnInference) > std::time::Duration::ZERO);
        assert!(prof.total(Phase::EnvironmentRun) > std::time::Duration::ZERO);
    }

    #[test]
    fn carries_obs_across_calls() {
        let mut envs = VecEnv::new("pendulum", 2, 3, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(0);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 2); // mean+log_std for dim=1
        let r1 = collect(&mut envs, &mut pol, &mut obs, 8, &mut rng, &mut prof).unwrap();
        let carried = obs.clone();
        // The first obs row of the next rollout must equal the carried obs
        // (rollout.obs stores pre-step observations).
        let r2 = collect(&mut envs, &mut pol, &mut obs, 8, &mut rng, &mut prof).unwrap();
        assert_ne!(r1.obs[..6], r2.obs[..6]);
        assert_eq!(&r2.obs[..6], &carried[..]);
    }

    #[test]
    fn done_mask_marks_episode_ends() {
        let mut envs = VecEnv::new("cartpole", 2, 5, ThreadPool::new(2)).unwrap();
        let mut obs = envs.reset_all();
        let mut rng = Rng::new(1);
        let mut prof = PhaseProfiler::new();
        let mut pol = uniform_policy(2, 2);
        let r = collect(&mut envs, &mut pol, &mut obs, 256, &mut rng, &mut prof).unwrap();
        let dones = r.done_mask.iter().filter(|&&d| d == 1.0).count();
        assert!(dones > 0, "random cartpole must fail within 256 steps");
        assert_eq!(r.finished_returns.len(), dones);
    }
}

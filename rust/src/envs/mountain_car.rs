//! MountainCarContinuous-v0 (Gymnasium): drive an underpowered car up a
//! hill by building momentum.
//!
//! Continuous force in [-1, 1]; reward = +100 at the goal minus 0.1·u²
//! per step; 999-step truncation.

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

const MIN_POS: f32 = -1.2;
const MAX_POS: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POS: f32 = 0.45;
const POWER: f32 = 0.0015;
const MAX_STEPS: usize = 999;

/// Mountain-car environment state.
#[derive(Debug, Clone)]
pub struct MountainCarContinuous {
    pos: f32,
    vel: f32,
    steps: usize,
}

impl MountainCarContinuous {
    pub fn new() -> Self {
        MountainCarContinuous { pos: 0.0, vel: 0.0, steps: 0 }
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.pos, self.vel]
    }
}

impl Default for MountainCarContinuous {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for MountainCarContinuous {
    fn name(&self) -> &'static str {
        "mountain_car"
    }

    fn obs_dim(&self) -> usize {
        2
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Continuous { dim: 1, low: -1.0, high: 1.0 }
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.pos = rng.uniform_f32(-0.6, -0.4);
        self.vel = 0.0;
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Step {
        let force = match action {
            Action::Continuous(a) => a[0].clamp(-1.0, 1.0),
            Action::Discrete(_) => panic!("mountain_car takes continuous actions"),
        };
        self.vel += force * POWER - 0.0025 * (3.0 * self.pos).cos();
        self.vel = self.vel.clamp(-MAX_SPEED, MAX_SPEED);
        self.pos = (self.pos + self.vel).clamp(MIN_POS, MAX_POS);
        if self.pos <= MIN_POS && self.vel < 0.0 {
            self.vel = 0.0;
        }
        self.steps += 1;

        let at_goal = self.pos >= GOAL_POS;
        let mut reward = -0.1 * force * force;
        if at_goal {
            reward += 100.0;
        }
        Step {
            obs: self.obs(),
            reward,
            done: at_goal || self.steps >= MAX_STEPS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(MountainCarContinuous::new()), MAX_STEPS);
    }

    #[test]
    fn full_throttle_alone_cannot_climb() {
        // The defining property of the task: constant +1 force from the
        // valley cannot reach the goal directly.
        let mut env = MountainCarContinuous::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        env.pos = -0.5;
        env.vel = 0.0;
        for _ in 0..200 {
            let s = env.step(&Action::Continuous(vec![1.0]), &mut rng);
            if s.done && env.pos >= GOAL_POS {
                panic!("car should not climb directly");
            }
        }
        assert!(env.pos < GOAL_POS);
    }

    #[test]
    fn bang_bang_momentum_policy_reaches_goal() {
        // Push in the direction of motion — the classic solution.
        let mut env = MountainCarContinuous::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let mut reached = false;
        for _ in 0..MAX_STEPS {
            let u = if env.vel >= 0.0 { 1.0 } else { -1.0 };
            let s = env.step(&Action::Continuous(vec![u]), &mut rng);
            if s.done {
                reached = env.pos >= GOAL_POS;
                break;
            }
        }
        assert!(reached, "momentum policy must reach the goal");
    }
}

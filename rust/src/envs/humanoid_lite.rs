//! HumanoidLite — a synthetic high-dimensional continuous-control task
//! with MuJoCo-Humanoid-like tensor shapes (376 obs / 17 act).
//!
//! The paper profiles PPO on Gymnasium Humanoid (Table I); MuJoCo is
//! unavailable here, so this environment substitutes a dynamical system
//! that exercises the same code paths and shapes:
//!
//! - 376-dim observation = a linear-plus-nonlinear latent state;
//! - 17-dim bounded action driving the latent through a fixed random
//!   projection;
//! - a locomotion-shaped reward: forward-velocity term + alive bonus −
//!   control cost (the Humanoid reward structure);
//! - early termination when the "torso height" coordinate leaves a band
//!   (Humanoid's fall detection) plus a 1000-step truncation.
//!
//! Dynamics parameters are generated from a fixed seed so every process
//! sees the same MDP. The task is genuinely learnable: pushing the
//! velocity coordinate up through the action projection earns reward,
//! but uniformly large actions destabilize the height coordinate.

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

pub const OBS_DIM: usize = 376;
pub const ACT_DIM: usize = 17;
const LATENT: usize = 32;
const MAX_STEPS: usize = 1000;
const HEIGHT_MIN: f32 = -2.0;
const HEIGHT_MAX: f32 = 2.0;

/// Fixed random MDP parameters (shared by all instances).
struct Mdp {
    /// Latent transition [LATENT, LATENT], spectral-normalized-ish.
    a: Vec<f32>,
    /// Action projection [ACT_DIM, LATENT].
    b: Vec<f32>,
    /// Observation lift [LATENT, OBS_DIM].
    c: Vec<f32>,
}

fn mdp() -> &'static Mdp {
    use std::sync::OnceLock;
    static MDP: OnceLock<Mdp> = OnceLock::new();
    MDP.get_or_init(|| {
        let mut rng = Rng::new(0x48554D41); // "HUMA"
        let mut a = vec![0.0f32; LATENT * LATENT];
        // Stable transition: 0.95 on the diagonal + weak coupling (the
        // coupling scale keeps the spectral radius < 1 so the passive
        // system is stable, like a standing Humanoid with small noise).
        for i in 0..LATENT {
            for j in 0..LATENT {
                a[i * LATENT + j] = if i == j {
                    0.95
                } else {
                    0.03 * rng.normal() as f32 / (LATENT as f32).sqrt()
                };
            }
        }
        let mut b = vec![0.0f32; ACT_DIM * LATENT];
        rng.fill_normal_f32(&mut b);
        for x in b.iter_mut() {
            *x *= 0.3;
        }
        let mut c = vec![0.0f32; LATENT * OBS_DIM];
        rng.fill_normal_f32(&mut c);
        for x in c.iter_mut() {
            *x /= (LATENT as f32).sqrt();
        }
        Mdp { a, b, c }
    })
}

/// HumanoidLite environment state.
pub struct HumanoidLite {
    z: Vec<f32>,
    steps: usize,
}

impl HumanoidLite {
    pub fn new() -> Self {
        HumanoidLite { z: vec![0.0; LATENT], steps: 0 }
    }

    /// Latent coordinates 0/1 play the roles of forward velocity and
    /// torso height.
    fn velocity(&self) -> f32 {
        self.z[0]
    }

    fn height(&self) -> f32 {
        self.z[1]
    }

    fn obs(&self) -> Vec<f32> {
        let m = mdp();
        let mut obs = vec![0.0f32; OBS_DIM];
        for i in 0..LATENT {
            let zi = self.z[i];
            if zi != 0.0 {
                let row = &m.c[i * OBS_DIM..(i + 1) * OBS_DIM];
                for (o, &cij) in obs.iter_mut().zip(row) {
                    *o += zi * cij;
                }
            }
        }
        // tanh keeps observations bounded like normalized MuJoCo states.
        for o in obs.iter_mut() {
            *o = o.tanh();
        }
        obs
    }
}

impl Default for HumanoidLite {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for HumanoidLite {
    fn name(&self) -> &'static str {
        "humanoid_lite"
    }

    fn obs_dim(&self) -> usize {
        OBS_DIM
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Continuous { dim: ACT_DIM, low: -1.0, high: 1.0 }
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        for z in self.z.iter_mut() {
            *z = rng.uniform_f32(-0.1, 0.1);
        }
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, rng: &mut Rng) -> Step {
        let act = match action {
            Action::Continuous(a) => a,
            Action::Discrete(_) => panic!("humanoid_lite takes continuous actions"),
        };
        assert_eq!(act.len(), ACT_DIM);
        let m = mdp();
        let mut z_new = vec![0.0f32; LATENT];
        for i in 0..LATENT {
            let row = &m.a[i * LATENT..(i + 1) * LATENT];
            let mut acc = 0.0f32;
            for (zj, aij) in self.z.iter().zip(row) {
                acc += zj * aij;
            }
            z_new[i] = acc;
        }
        let mut ctrl_cost = 0.0f32;
        for (k, &u) in act.iter().enumerate() {
            let u = u.clamp(-1.0, 1.0);
            ctrl_cost += u * u;
            let row = &m.b[k * LATENT..(k + 1) * LATENT];
            for (zn, &bkj) in z_new.iter_mut().zip(row) {
                *zn += u * bkj;
            }
        }
        // Process noise (the stochasticity MuJoCo gets from contacts).
        for zn in z_new.iter_mut() {
            *zn += 0.01 * rng.normal() as f32;
        }
        self.z = z_new;
        self.steps += 1;

        let fell = !(HEIGHT_MIN..=HEIGHT_MAX).contains(&self.height());
        let truncated = self.steps >= MAX_STEPS;
        // Humanoid-shaped reward: forward velocity + alive bonus - control.
        let reward = 1.25 * self.velocity() + 5.0 - 0.1 * ctrl_cost
            - if fell { 5.0 } else { 0.0 };
        Step { obs: self.obs(), reward, done: fell || truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(HumanoidLite::new()), MAX_STEPS);
    }

    #[test]
    fn shapes_match_mujoco_humanoid() {
        let env = HumanoidLite::new();
        assert_eq!(env.obs_dim(), 376);
        assert_eq!(env.action_space().dim(), 17);
    }

    #[test]
    fn zero_action_survives_many_steps() {
        let mut env = HumanoidLite::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut n = 0;
        for _ in 0..300 {
            let s = env.step(&Action::Continuous(vec![0.0; ACT_DIM]), &mut rng);
            n += 1;
            if s.done {
                break;
            }
        }
        assert!(n >= 100, "passive policy should not fall instantly, n={n}");
    }

    #[test]
    fn velocity_direction_controls_reward() {
        // An action aligned with +velocity projection earns more than the
        // opposite action: the task has learnable signal.
        let m = mdp();
        // Build the action that maximally increases z[0].
        let mut best = vec![0.0f32; ACT_DIM];
        for k in 0..ACT_DIM {
            best[k] = m.b[k * LATENT].signum(); // b[k][0]
        }
        let run = |act: Vec<f32>| {
            let mut env = HumanoidLite::new();
            let mut rng = Rng::new(2);
            env.reset(&mut rng);
            let mut total = 0.0;
            for _ in 0..50 {
                let s = env.step(&Action::Continuous(act.clone()), &mut rng);
                total += s.reward;
                if s.done {
                    break;
                }
            }
            total
        };
        let fwd = run(best.clone());
        let back = run(best.iter().map(|x| -x).collect());
        assert!(
            fwd > back + 1.0,
            "forward-aligned actions must out-earn backward: {fwd} vs {back}"
        );
    }

    #[test]
    fn mdp_is_process_stable() {
        // Same seed ⇒ same dynamics ⇒ same rollout.
        let roll = || {
            let mut env = HumanoidLite::new();
            let mut rng = Rng::new(3);
            env.reset(&mut rng);
            let s = env.step(&Action::Continuous(vec![0.5; ACT_DIM]), &mut rng);
            s.obs[0..8].to_vec()
        };
        assert_eq!(roll(), roll());
    }
}

//! Vectorized environment execution — the EnvPool-style engine.
//!
//! Steps `N` environment instances in parallel on the shared thread
//! pool, with per-env RNG streams and automatic reset on episode end
//! (the next observation after `done` is the fresh episode's first
//! observation, as in Gymnasium's AsyncVectorEnv autoreset semantics).
//!
//! Environment execution dominates PPO wall time (47–61% in the paper's
//! Table I); this engine is what makes the "Environment Run" phase of
//! our Table I reproduction representative.

use super::{Action, ActionSpace, Env};
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;
use std::sync::Mutex;

/// Result of stepping all environments once.
#[derive(Debug, Clone, Default)]
pub struct VecStep {
    /// `[N * obs_dim]` row-major observations (post-autoreset).
    pub obs: Vec<f32>,
    /// `[N]` rewards.
    pub rewards: Vec<f32>,
    /// `[N]` episode-end flags.
    pub dones: Vec<bool>,
    /// Completed-episode returns recorded this step (env index, return,
    /// length).
    pub finished: Vec<(usize, f64, usize)>,
}

struct Slot {
    env: Box<dyn Env>,
    rng: Rng,
    episode_return: f64,
    episode_len: usize,
}

/// N parallel environments with autoreset.
pub struct VecEnv {
    slots: Vec<Mutex<Slot>>,
    pool: ThreadPool,
    obs_dim: usize,
    action_space: ActionSpace,
    name: &'static str,
}

impl VecEnv {
    /// Build `n` instances of `env_name`, seeded from `seed`.
    pub fn new(env_name: &str, n: usize, seed: u64, pool: ThreadPool) -> anyhow::Result<VecEnv> {
        anyhow::ensure!(n > 0, "need at least one env");
        let mut root = Rng::new(seed);
        let mut slots = Vec::with_capacity(n);
        let probe = super::make_env(env_name)?;
        let obs_dim = probe.obs_dim();
        let action_space = probe.action_space();
        let name = probe.name();
        for _ in 0..n {
            slots.push(Mutex::new(Slot {
                env: super::make_env(env_name)?,
                rng: root.split(),
                episode_return: 0.0,
                episode_len: 0,
            }));
        }
        Ok(VecEnv { slots, pool, obs_dim, action_space, name })
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn action_space(&self) -> &ActionSpace {
        &self.action_space
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Reset every environment, returning `[N * obs_dim]` observations.
    pub fn reset_all(&mut self) -> Vec<f32> {
        let n = self.slots.len();
        let obs = Mutex::new(vec![0.0f32; n * self.obs_dim]);
        let d = self.obs_dim;
        self.pool.scoped_for(n, |i| {
            let mut guard = self.slots[i].lock().unwrap();
            let slot = &mut *guard;
            let o = slot.env.reset(&mut slot.rng);
            slot.episode_return = 0.0;
            slot.episode_len = 0;
            obs.lock().unwrap()[i * d..(i + 1) * d].copy_from_slice(&o);
        });
        obs.into_inner().unwrap()
    }

    /// Step every environment with its action; autoresets finished ones.
    pub fn step_all(&mut self, actions: &[Action]) -> VecStep {
        let mut out = VecStep::default();
        self.step_all_into(actions, &mut out);
        out
    }

    /// [`VecEnv::step_all`] into caller-owned output planes — the
    /// zero-allocation path the pipelined trainer steps through every
    /// timestep (the planes are recycled across the whole run).
    pub fn step_all_into(&mut self, actions: &[Action], out: &mut VecStep) {
        let n = self.slots.len();
        assert_eq!(actions.len(), n, "need one action per env");
        let d = self.obs_dim;
        // resize without clear: a warm buffer of the right length is
        // left as-is (every slot is overwritten below), so the hot path
        // pays no per-step memset.
        out.obs.resize(n * d, 0.0);
        out.rewards.resize(n, 0.0);
        out.dones.resize(n, false);
        out.finished.clear();
        let obs = Mutex::new(std::mem::take(&mut out.obs));
        let rewards = Mutex::new(std::mem::take(&mut out.rewards));
        let dones = Mutex::new(std::mem::take(&mut out.dones));
        let finished = Mutex::new(std::mem::take(&mut out.finished));
        self.pool.scoped_for(n, |i| {
            let mut guard = self.slots[i].lock().unwrap();
            let slot = &mut *guard;
            let step = slot.env.step(&actions[i], &mut slot.rng);
            slot.episode_return += step.reward as f64;
            slot.episode_len += 1;
            rewards.lock().unwrap()[i] = step.reward;
            dones.lock().unwrap()[i] = step.done;
            let next_obs = if step.done {
                finished.lock().unwrap().push((
                    i,
                    slot.episode_return,
                    slot.episode_len,
                ));
                slot.episode_return = 0.0;
                slot.episode_len = 0;
                slot.env.reset(&mut slot.rng)
            } else {
                step.obs
            };
            obs.lock().unwrap()[i * d..(i + 1) * d].copy_from_slice(&next_obs);
        });
        out.obs = obs.into_inner().unwrap();
        out.rewards = rewards.into_inner().unwrap();
        out.dones = dones.into_inner().unwrap();
        out.finished = finished.into_inner().unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> ThreadPool {
        ThreadPool::new(4)
    }

    #[test]
    fn reset_shapes() {
        let mut v = VecEnv::new("cartpole", 8, 1, pool()).unwrap();
        let obs = v.reset_all();
        assert_eq!(obs.len(), 8 * 4);
        assert!(obs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn step_and_autoreset() {
        let mut v = VecEnv::new("cartpole", 4, 2, pool()).unwrap();
        v.reset_all();
        let mut total_finished = 0;
        for _ in 0..400 {
            let actions: Vec<Action> =
                (0..4).map(|i| Action::Discrete(i % 2)).collect();
            let s = v.step_all(&actions);
            assert_eq!(s.obs.len(), 16);
            assert_eq!(s.rewards.len(), 4);
            total_finished += s.finished.len();
            for &(_, ret, len) in &s.finished {
                assert!(ret > 0.0 && len > 0);
            }
        }
        assert!(total_finished > 0, "episodes must finish under constant actions");
    }

    #[test]
    fn per_env_streams_are_deterministic() {
        let run = || {
            let mut v = VecEnv::new("pendulum", 3, 7, pool()).unwrap();
            let o0 = v.reset_all();
            let a: Vec<Action> =
                (0..3).map(|_| Action::Continuous(vec![0.5])).collect();
            let s = v.step_all(&a);
            (o0, s.obs, s.rewards)
        };
        let (a0, a1, a2) = run();
        let (b0, b1, b2) = run();
        assert_eq!(a0, b0);
        assert_eq!(a1, b1);
        assert_eq!(a2, b2);
    }

    #[test]
    fn distinct_envs_diverge() {
        let mut v = VecEnv::new("pendulum", 2, 9, pool()).unwrap();
        let obs = v.reset_all();
        // Different RNG streams ⇒ different initial states.
        assert_ne!(&obs[0..3], &obs[3..6]);
    }

    #[test]
    fn step_all_into_reuses_buffers() {
        let mut v = VecEnv::new("cartpole", 4, 3, pool()).unwrap();
        v.reset_all();
        let actions: Vec<Action> = (0..4).map(|i| Action::Discrete(i % 2)).collect();
        let mut out = VecStep::default();
        v.step_all_into(&actions, &mut out);
        assert_eq!(out.obs.len(), 16);
        let ptr = out.obs.as_ptr();
        v.step_all_into(&actions, &mut out);
        assert_eq!(ptr, out.obs.as_ptr(), "warm step must not reallocate");
        // And the into-variant agrees with the allocating one.
        let mut a = VecEnv::new("cartpole", 2, 5, pool()).unwrap();
        let mut b = VecEnv::new("cartpole", 2, 5, pool()).unwrap();
        a.reset_all();
        b.reset_all();
        let acts: Vec<Action> = (0..2).map(|_| Action::Discrete(0)).collect();
        let want = a.step_all(&acts);
        let mut got = VecStep::default();
        b.step_all_into(&acts, &mut got);
        assert_eq!(want.obs, got.obs);
        assert_eq!(want.rewards, got.rewards);
        assert_eq!(want.dones, got.dones);
    }

    #[test]
    fn humanoid_lite_vectorized() {
        let mut v = VecEnv::new("humanoid_lite", 4, 11, pool()).unwrap();
        let obs = v.reset_all();
        assert_eq!(obs.len(), 4 * 376);
        let acts: Vec<Action> = (0..4)
            .map(|_| Action::Continuous(vec![0.1; 17]))
            .collect();
        let s = v.step_all(&acts);
        assert_eq!(s.obs.len(), 4 * 376);
    }
}

//! Pendulum-v1 (Gymnasium): swing a pendulum upright with bounded torque.
//!
//! Continuous action in [-2, 2]; reward = -(θ² + 0.1·θ̇² + 0.001·u²);
//! fixed 200-step episodes (pure truncation).

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

const MAX_SPEED: f32 = 8.0;
const MAX_TORQUE: f32 = 2.0;
const DT: f32 = 0.05;
const G: f32 = 10.0;
const M: f32 = 1.0;
const L: f32 = 1.0;
const MAX_STEPS: usize = 200;

/// Pendulum environment state.
#[derive(Debug, Clone)]
pub struct Pendulum {
    theta: f32,
    theta_dot: f32,
    steps: usize,
}

fn angle_normalize(x: f32) -> f32 {
    let two_pi = 2.0 * std::f32::consts::PI;
    ((x + std::f32::consts::PI).rem_euclid(two_pi)) - std::f32::consts::PI
}

impl Pendulum {
    pub fn new() -> Self {
        Pendulum { theta: 0.0, theta_dot: 0.0, steps: 0 }
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.theta.cos(), self.theta.sin(), self.theta_dot]
    }
}

impl Default for Pendulum {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for Pendulum {
    fn name(&self) -> &'static str {
        "pendulum"
    }

    fn obs_dim(&self) -> usize {
        3
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Continuous { dim: 1, low: -MAX_TORQUE, high: MAX_TORQUE }
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.theta = rng.uniform_f32(-std::f32::consts::PI, std::f32::consts::PI);
        self.theta_dot = rng.uniform_f32(-1.0, 1.0);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Step {
        let u = match action {
            Action::Continuous(a) => a[0].clamp(-MAX_TORQUE, MAX_TORQUE),
            Action::Discrete(_) => panic!("pendulum takes continuous actions"),
        };
        let th = angle_normalize(self.theta);
        let cost = th * th + 0.1 * self.theta_dot * self.theta_dot + 0.001 * u * u;

        let new_thdot = (self.theta_dot
            + (3.0 * G / (2.0 * L) * self.theta.sin() + 3.0 / (M * L * L) * u) * DT)
            .clamp(-MAX_SPEED, MAX_SPEED);
        self.theta += new_thdot * DT;
        self.theta_dot = new_thdot;
        self.steps += 1;

        Step { obs: self.obs(), reward: -cost, done: self.steps >= MAX_STEPS }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(Pendulum::new()), MAX_STEPS);
    }

    #[test]
    fn reward_is_nonpositive_and_bounded() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        for _ in 0..MAX_STEPS {
            let a = Action::Continuous(vec![rng.uniform_f32(-2.0, 2.0)]);
            let s = env.step(&a, &mut rng);
            assert!(s.reward <= 0.0);
            // max cost: pi^2 + 0.1*64 + 0.001*4 ≈ 16.28
            assert!(s.reward >= -17.0);
            if s.done {
                break;
            }
        }
    }

    #[test]
    fn fixed_episode_length() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let mut n = 0;
        loop {
            n += 1;
            if env.step(&Action::Continuous(vec![0.0]), &mut rng).done {
                break;
            }
        }
        assert_eq!(n, MAX_STEPS);
    }

    #[test]
    fn upright_no_torque_is_near_zero_cost() {
        let mut env = Pendulum::new();
        let mut rng = Rng::new(3);
        env.reset(&mut rng);
        env.theta = 0.0;
        env.theta_dot = 0.0;
        let s = env.step(&Action::Continuous(vec![0.0]), &mut rng);
        assert!(s.reward > -1e-3, "upright cost should be ~0, got {}", s.reward);
    }

    #[test]
    fn angle_normalize_wraps() {
        assert!((angle_normalize(2.0 * std::f32::consts::PI)).abs() < 1e-6);
        assert!(
            (angle_normalize(3.0 * std::f32::consts::PI)
                - (-std::f32::consts::PI))
                .abs()
                < 1e-5
        );
    }
}

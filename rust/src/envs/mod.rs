//! Rust-native RL environments + the vectorized execution engine.
//!
//! The paper trains on Gymnasium MuJoCo/Atari environments, which are
//! unavailable here (hardware/data gate — see DESIGN.md §2); this module
//! provides the substitution: faithful Rust ports of the classic-control
//! suite (CartPole, Pendulum, Acrobot, MountainCarContinuous) plus
//! `HumanoidLite`, a synthetic high-dimensional continuous-control task
//! with MuJoCo-Humanoid-like tensor shapes (376 obs / 17 act) for
//! profiling parity with the paper's Table I workload.
//!
//! [`vec_env::VecEnv`] executes N environment instances on the
//! [`crate::util::threadpool`] — the EnvPool-style engine the paper cites
//! as related work for the "Environment Run" phase.

pub mod acrobot;
pub mod cartpole;
pub mod humanoid_lite;
pub mod lunar_lander;
pub mod mountain_car;
pub mod pendulum;
pub mod vec_env;

use crate::util::Rng;

/// Action space description.
#[derive(Debug, Clone, PartialEq)]
pub enum ActionSpace {
    /// `n` discrete actions.
    Discrete(usize),
    /// Box action of `dim` dims, bounded per-dim to `[low, high]`.
    Continuous { dim: usize, low: f32, high: f32 },
}

impl ActionSpace {
    pub fn dim(&self) -> usize {
        match self {
            ActionSpace::Discrete(_) => 1,
            ActionSpace::Continuous { dim, .. } => *dim,
        }
    }

    pub fn is_discrete(&self) -> bool {
        matches!(self, ActionSpace::Discrete(_))
    }
}

/// An agent action.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    Discrete(usize),
    Continuous(Vec<f32>),
}

/// One transition.
#[derive(Debug, Clone)]
pub struct Step {
    pub obs: Vec<f32>,
    pub reward: f32,
    /// Episode ended (terminal or truncation — both end bootstrap here,
    /// matching the common single-flag PPO implementations the paper
    /// builds on).
    pub done: bool,
}

/// An episodic RL environment.
pub trait Env: Send {
    /// Environment id (matches the model spec names in the manifest).
    fn name(&self) -> &'static str;
    fn obs_dim(&self) -> usize;
    fn action_space(&self) -> ActionSpace;
    /// Reset to a fresh episode, returning the initial observation.
    fn reset(&mut self, rng: &mut Rng) -> Vec<f32>;
    /// Advance one step.
    fn step(&mut self, action: &Action, rng: &mut Rng) -> Step;
}

/// Construct an environment by name.
pub fn make_env(name: &str) -> anyhow::Result<Box<dyn Env>> {
    Ok(match name {
        "cartpole" => Box::new(cartpole::CartPole::new()),
        "pendulum" => Box::new(pendulum::Pendulum::new()),
        "acrobot" => Box::new(acrobot::Acrobot::new()),
        "mountain_car" => Box::new(mountain_car::MountainCarContinuous::new()),
        "lunar_lander" => Box::new(lunar_lander::LunarLander::new()),
        "humanoid_lite" => Box::new(humanoid_lite::HumanoidLite::new()),
        other => anyhow::bail!("unknown env {other:?}"),
    })
}

/// Names of all bundled environments.
pub const ALL_ENVS: &[&str] = &[
    "cartpole",
    "pendulum",
    "acrobot",
    "mountain_car",
    "lunar_lander",
    "humanoid_lite",
];

#[cfg(test)]
pub(crate) mod conformance {
    //! Shared conformance checks run by each environment's test module.
    use super::*;

    /// Random-policy rollout checks: obs dims stable, rewards finite,
    /// episodes terminate within `max_steps`.
    pub fn check_env(mut env: Box<dyn Env>, max_steps: usize) {
        let mut rng = Rng::new(0xC0FFEE);
        let space = env.action_space();
        for episode in 0..3 {
            let obs = env.reset(&mut rng);
            assert_eq!(obs.len(), env.obs_dim(), "reset obs dim");
            assert!(obs.iter().all(|x| x.is_finite()));
            let mut steps = 0;
            loop {
                let action = match &space {
                    ActionSpace::Discrete(n) => {
                        Action::Discrete(rng.below(*n as u64) as usize)
                    }
                    ActionSpace::Continuous { dim, low, high } => Action::Continuous(
                        (0..*dim).map(|_| rng.uniform_f32(*low, *high)).collect(),
                    ),
                };
                let step = env.step(&action, &mut rng);
                assert_eq!(step.obs.len(), env.obs_dim());
                assert!(step.reward.is_finite(), "episode {episode} reward");
                assert!(step.obs.iter().all(|x| x.is_finite()));
                steps += 1;
                if step.done {
                    break;
                }
                assert!(
                    steps <= max_steps,
                    "episode {episode} ran past {max_steps} steps"
                );
            }
        }
    }
}

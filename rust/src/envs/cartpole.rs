//! CartPole-v1 (Barto, Sutton & Anderson 1983; Gymnasium port).
//!
//! Discrete(2) actions push the cart left/right; +1 reward per step;
//! episode ends when |x| > 2.4, |θ| > 12°, or after 500 steps.

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

const GRAVITY: f32 = 9.8;
const MASS_CART: f32 = 1.0;
const MASS_POLE: f32 = 0.1;
const TOTAL_MASS: f32 = MASS_CART + MASS_POLE;
const LENGTH: f32 = 0.5; // half pole length
const POLE_MASS_LENGTH: f32 = MASS_POLE * LENGTH;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_LIMIT: f32 = 2.4;
const MAX_STEPS: usize = 500;

/// CartPole environment state.
#[derive(Debug, Clone)]
pub struct CartPole {
    x: f32,
    x_dot: f32,
    theta: f32,
    theta_dot: f32,
    steps: usize,
}

impl CartPole {
    pub fn new() -> Self {
        CartPole { x: 0.0, x_dot: 0.0, theta: 0.0, theta_dot: 0.0, steps: 0 }
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.x, self.x_dot, self.theta, self.theta_dot]
    }
}

impl Default for CartPole {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for CartPole {
    fn name(&self) -> &'static str {
        "cartpole"
    }

    fn obs_dim(&self) -> usize {
        4
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Discrete(2)
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.x = rng.uniform_f32(-0.05, 0.05);
        self.x_dot = rng.uniform_f32(-0.05, 0.05);
        self.theta = rng.uniform_f32(-0.05, 0.05);
        self.theta_dot = rng.uniform_f32(-0.05, 0.05);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Step {
        let force = match action {
            Action::Discrete(1) => FORCE_MAG,
            Action::Discrete(_) => -FORCE_MAG,
            Action::Continuous(_) => panic!("cartpole takes discrete actions"),
        };
        let cos_t = self.theta.cos();
        let sin_t = self.theta.sin();
        let temp =
            (force + POLE_MASS_LENGTH * self.theta_dot * self.theta_dot * sin_t)
                / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos_t / TOTAL_MASS;

        // Euler integration (matches Gymnasium's default).
        self.x += TAU * self.x_dot;
        self.x_dot += TAU * x_acc;
        self.theta += TAU * self.theta_dot;
        self.theta_dot += TAU * theta_acc;
        self.steps += 1;

        let fell = self.x.abs() > X_LIMIT || self.theta.abs() > THETA_LIMIT;
        let truncated = self.steps >= MAX_STEPS;
        Step { obs: self.obs(), reward: 1.0, done: fell || truncated }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(CartPole::new()), MAX_STEPS);
    }

    #[test]
    fn random_policy_fails_fast() {
        // A random policy should not survive anywhere near MAX_STEPS.
        let mut env = CartPole::new();
        let mut rng = Rng::new(1);
        let mut lengths = Vec::new();
        for _ in 0..20 {
            env.reset(&mut rng);
            let mut n = 0;
            loop {
                let a = Action::Discrete(rng.below(2) as usize);
                n += 1;
                if env.step(&a, &mut rng).done {
                    break;
                }
            }
            lengths.push(n);
        }
        let mean = lengths.iter().sum::<usize>() as f64 / lengths.len() as f64;
        assert!(mean < 100.0, "random policy mean length {mean}");
    }

    #[test]
    fn constant_push_tips_the_pole() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let mut done_at = None;
        for i in 0..200 {
            let s = env.step(&Action::Discrete(1), &mut rng);
            if s.done {
                done_at = Some(i);
                break;
            }
        }
        assert!(done_at.is_some(), "constant force must topple the pole");
    }

    #[test]
    fn physics_is_deterministic() {
        let run = || {
            let mut env = CartPole::new();
            let mut rng = Rng::new(3);
            env.reset(&mut rng);
            let mut acc = Vec::new();
            for i in 0..50 {
                let s = env.step(&Action::Discrete(i % 2), &mut rng);
                acc.extend(s.obs);
                if s.done {
                    break;
                }
            }
            acc
        };
        assert_eq!(run(), run());
    }
}

//! Acrobot-v1 (Sutton 1996; Gymnasium port): swing the tip of a
//! two-link underactuated pendulum above the bar.
//!
//! Discrete(3) torque {-1, 0, +1} on the second joint; -1 reward per
//! step until the goal; 500-step truncation.

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

const DT: f32 = 0.2;
const L1: f32 = 1.0;
const LC1: f32 = 0.5;
const LC2: f32 = 0.5;
const M1: f32 = 1.0;
const M2: f32 = 1.0;
const I1: f32 = 1.0;
const I2: f32 = 1.0;
const G: f32 = 9.8;
const MAX_VEL1: f32 = 4.0 * std::f32::consts::PI;
const MAX_VEL2: f32 = 9.0 * std::f32::consts::PI;
const MAX_STEPS: usize = 500;

/// Acrobot environment state.
#[derive(Debug, Clone)]
pub struct Acrobot {
    th1: f32,
    th2: f32,
    dth1: f32,
    dth2: f32,
    steps: usize,
}

impl Acrobot {
    pub fn new() -> Self {
        Acrobot { th1: 0.0, th2: 0.0, dth1: 0.0, dth2: 0.0, steps: 0 }
    }

    fn obs(&self) -> Vec<f32> {
        vec![
            self.th1.cos(),
            self.th1.sin(),
            self.th2.cos(),
            self.th2.sin(),
            self.dth1,
            self.dth2,
        ]
    }

    fn dynamics(&self, torque: f32) -> (f32, f32) {
        // Standard acrobot equations (Sutton & Barto, "book" convention
        // used by Gymnasium).
        let (th1, th2, dth1, dth2) = (self.th1, self.th2, self.dth1, self.dth2);
        let d1 = M1 * LC1 * LC1
            + M2 * (L1 * L1 + LC2 * LC2 + 2.0 * L1 * LC2 * th2.cos())
            + I1
            + I2;
        let d2 = M2 * (LC2 * LC2 + L1 * LC2 * th2.cos()) + I2;
        let phi2 =
            M2 * LC2 * G * (th1 + th2 - std::f32::consts::FRAC_PI_2).cos();
        let phi1 = -M2 * L1 * LC2 * dth2 * dth2 * th2.sin()
            - 2.0 * M2 * L1 * LC2 * dth2 * dth1 * th2.sin()
            + (M1 * LC1 + M2 * L1) * G * (th1 - std::f32::consts::FRAC_PI_2).cos()
            + phi2;
        let ddth2 = (torque + d2 / d1 * phi1
            - M2 * L1 * LC2 * dth1 * dth1 * th2.sin()
            - phi2)
            / (M2 * LC2 * LC2 + I2 - d2 * d2 / d1);
        let ddth1 = -(d2 * ddth2 + phi1) / d1;
        (ddth1, ddth2)
    }
}

impl Default for Acrobot {
    fn default() -> Self {
        Self::new()
    }
}

fn wrap(x: f32) -> f32 {
    let two_pi = 2.0 * std::f32::consts::PI;
    ((x + std::f32::consts::PI).rem_euclid(two_pi)) - std::f32::consts::PI
}

impl Env for Acrobot {
    fn name(&self) -> &'static str {
        "acrobot"
    }

    fn obs_dim(&self) -> usize {
        6
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Discrete(3)
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.th1 = rng.uniform_f32(-0.1, 0.1);
        self.th2 = rng.uniform_f32(-0.1, 0.1);
        self.dth1 = rng.uniform_f32(-0.1, 0.1);
        self.dth2 = rng.uniform_f32(-0.1, 0.1);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Step {
        let torque = match action {
            Action::Discrete(0) => -1.0,
            Action::Discrete(1) => 0.0,
            Action::Discrete(_) => 1.0,
            Action::Continuous(_) => panic!("acrobot takes discrete actions"),
        };
        // 4 substeps of Euler at dt/4 approximates Gymnasium's RK4
        // closely enough for training purposes.
        let sub = 4;
        for _ in 0..sub {
            let (ddth1, ddth2) = self.dynamics(torque);
            let h = DT / sub as f32;
            self.th1 += h * self.dth1;
            self.th2 += h * self.dth2;
            self.dth1 = (self.dth1 + h * ddth1).clamp(-MAX_VEL1, MAX_VEL1);
            self.dth2 = (self.dth2 + h * ddth2).clamp(-MAX_VEL2, MAX_VEL2);
        }
        self.th1 = wrap(self.th1);
        self.th2 = wrap(self.th2);
        self.steps += 1;

        let goal = -self.th1.cos() - (self.th2 + self.th1).cos() > 1.0;
        let truncated = self.steps >= MAX_STEPS;
        Step {
            obs: self.obs(),
            reward: if goal { 0.0 } else { -1.0 },
            done: goal || truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(Acrobot::new()), MAX_STEPS);
    }

    #[test]
    fn hanging_still_with_no_torque_stays_down() {
        let mut env = Acrobot::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        env.th1 = 0.0;
        env.th2 = 0.0;
        env.dth1 = 0.0;
        env.dth2 = 0.0;
        let s = env.step(&Action::Discrete(1), &mut rng);
        assert!(!s.done || env.steps >= MAX_STEPS);
        assert_eq!(s.reward, -1.0);
        // Equilibrium: should barely move.
        assert!(env.th1.abs() < 1e-3 && env.th2.abs() < 1e-3);
    }

    #[test]
    fn energy_grows_under_resonant_torque() {
        // Pumping torque in the direction of dth2 increases total swing.
        let mut env = Acrobot::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        let mut max_height = f32::NEG_INFINITY;
        for _ in 0..400 {
            let a = if env.dth2 >= 0.0 { 2 } else { 0 };
            let s = env.step(&Action::Discrete(a), &mut rng);
            max_height =
                max_height.max(-env.th1.cos() - (env.th2 + env.th1).cos());
            if s.done {
                break;
            }
        }
        assert!(max_height > 0.3, "pumping should raise the tip, got {max_height}");
    }
}

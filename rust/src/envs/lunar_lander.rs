//! LunarLander-v2 (simplified, no Box2D): soft-land a thrust-vectoring
//! module on a pad at the origin.
//!
//! A rigid-body point-mass port of Gymnasium's LunarLander: same
//! Discrete(4) action set {noop, left engine, main engine, right
//! engine}, same 8-dim observation (position, velocity, attitude,
//! angular rate, leg contacts) and the same potential-based shaping
//! reward with fuel costs and ±100 terminal bonus — but the contact
//! dynamics are analytic (flat terrain at `y = 0`) instead of a physics
//! engine, which keeps the env dependency-free and deterministic.

use super::{Action, ActionSpace, Env, Step};
use crate::util::Rng;

const DT: f32 = 0.05;
/// Gravitational acceleration (scaled units, like Gym's viewport scale).
const GRAVITY: f32 = 1.2;
/// Main-engine acceleration along the body's up vector.
const MAIN_THRUST: f32 = 2.4;
/// Side-engine lateral acceleration.
const SIDE_THRUST: f32 = 0.6;
/// Side-engine angular acceleration.
const SIDE_TORQUE: f32 = 3.0;
/// Passive attitude damping (the simplified stand-in for Box2D's
/// angular friction — without it the lander spins up unboundedly).
const ANGULAR_DAMPING: f32 = 0.4;
const MAX_STEPS: usize = 400;
/// Half-width of the landing pad.
const PAD_HALF_WIDTH: f32 = 0.3;

/// Simplified lunar lander state.
#[derive(Debug, Clone)]
pub struct LunarLander {
    x: f32,
    y: f32,
    vx: f32,
    vy: f32,
    th: f32,
    dth: f32,
    steps: usize,
    prev_shaping: Option<f32>,
}

impl LunarLander {
    pub fn new() -> Self {
        LunarLander {
            x: 0.0,
            y: 1.3,
            vx: 0.0,
            vy: 0.0,
            th: 0.0,
            dth: 0.0,
            steps: 0,
            prev_shaping: None,
        }
    }

    fn legs_down(&self) -> bool {
        self.y <= 0.02
    }

    fn obs(&self) -> Vec<f32> {
        let contact = if self.legs_down() { 1.0 } else { 0.0 };
        vec![self.x, self.y, self.vx, self.vy, self.th, self.dth, contact, contact]
    }

    /// Gym's potential: closer / slower / more upright is better, with a
    /// bonus per leg on the ground.
    fn shaping(&self) -> f32 {
        let contact = if self.legs_down() { 1.0 } else { 0.0 };
        -100.0 * (self.x * self.x + self.y * self.y).sqrt()
            - 100.0 * (self.vx * self.vx + self.vy * self.vy).sqrt()
            - 100.0 * self.th.abs()
            + 10.0 * contact
            + 10.0 * contact
    }
}

impl Default for LunarLander {
    fn default() -> Self {
        Self::new()
    }
}

impl Env for LunarLander {
    fn name(&self) -> &'static str {
        "lunar_lander"
    }

    fn obs_dim(&self) -> usize {
        8
    }

    fn action_space(&self) -> ActionSpace {
        ActionSpace::Discrete(4)
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.x = rng.uniform_f32(-0.2, 0.2);
        self.y = 1.3;
        self.vx = rng.uniform_f32(-0.3, 0.3);
        self.vy = rng.uniform_f32(-0.4, 0.0);
        self.th = rng.uniform_f32(-0.1, 0.1);
        self.dth = rng.uniform_f32(-0.1, 0.1);
        self.steps = 0;
        self.prev_shaping = None;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Step {
        let a = match action {
            Action::Discrete(a) => *a,
            Action::Continuous(_) => panic!("lunar_lander takes discrete actions"),
        };
        let mut fuel = 0.0f32;
        let mut ax = 0.0f32;
        let mut ay = -GRAVITY;
        let mut ath = -ANGULAR_DAMPING * self.dth;
        match a {
            1 => {
                // Left engine: pushes the lander rightward, torques CCW.
                ax += SIDE_THRUST * self.th.cos();
                ay += SIDE_THRUST * self.th.sin();
                ath += SIDE_TORQUE;
                fuel = 0.03;
            }
            2 => {
                // Main engine: thrust along the body's up vector.
                ax += -MAIN_THRUST * self.th.sin();
                ay += MAIN_THRUST * self.th.cos();
                fuel = 0.30;
            }
            3 => {
                // Right engine: mirror of the left.
                ax -= SIDE_THRUST * self.th.cos();
                ay -= SIDE_THRUST * self.th.sin();
                ath -= SIDE_TORQUE;
                fuel = 0.03;
            }
            _ => {}
        }
        self.vx = (self.vx + DT * ax).clamp(-5.0, 5.0);
        self.vy = (self.vy + DT * ay).clamp(-5.0, 5.0);
        self.dth = (self.dth + DT * ath).clamp(-5.0, 5.0);
        self.x += DT * self.vx;
        self.y += DT * self.vy;
        self.th += DT * self.dth;
        self.steps += 1;

        let shaping = self.shaping();
        let mut reward =
            self.prev_shaping.map(|p| shaping - p).unwrap_or(0.0) - fuel;
        self.prev_shaping = Some(shaping);

        let mut done = false;
        if self.y <= 0.0 {
            // Touchdown: gentle, upright, and on the pad is a landing;
            // anything else is a crash.
            done = true;
            let gentle = self.vy.abs() < 1.0
                && self.vx.abs() < 0.6
                && self.th.abs() < 0.4
                && self.x.abs() < PAD_HALF_WIDTH;
            reward += if gentle { 100.0 } else { -100.0 };
        } else if self.x.abs() > 1.5 || self.y > 2.5 {
            // Flew off the viewport.
            done = true;
            reward += -100.0;
        } else if self.steps >= MAX_STEPS {
            done = true;
        }
        Step { obs: self.obs(), reward, done }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::conformance::check_env;

    #[test]
    fn conformance() {
        check_env(Box::new(LunarLander::new()), MAX_STEPS);
    }

    #[test]
    fn free_fall_reaches_the_ground() {
        let mut env = LunarLander::new();
        let mut rng = Rng::new(1);
        env.reset(&mut rng);
        let mut last = None;
        for _ in 0..MAX_STEPS {
            let s = env.step(&Action::Discrete(0), &mut rng);
            let done = s.done;
            last = Some(s);
            if done {
                break;
            }
        }
        let last = last.unwrap();
        assert!(last.done, "gravity must end the episode");
        assert!(env.y <= 0.0, "must have hit the ground, y={}", env.y);
        assert!(env.vy < 0.0, "still descending at touchdown");
    }

    #[test]
    fn main_engine_counteracts_gravity() {
        let mut env = LunarLander::new();
        let mut rng = Rng::new(2);
        env.reset(&mut rng);
        env.th = 0.0;
        env.dth = 0.0;
        env.vy = 0.0;
        for _ in 0..20 {
            env.step(&Action::Discrete(2), &mut rng);
        }
        // MAIN_THRUST > GRAVITY, so sustained burn gains upward speed.
        assert!(env.vy > 0.0, "burn must arrest the descent, vy={}", env.vy);
    }

    #[test]
    fn side_engines_torque_in_opposite_directions() {
        let mut rng = Rng::new(3);
        let mut left = LunarLander::new();
        left.reset(&mut rng);
        left.th = 0.0;
        left.dth = 0.0;
        let mut right = left.clone();
        left.step(&Action::Discrete(1), &mut rng);
        right.step(&Action::Discrete(3), &mut rng);
        assert!(left.dth > 0.0, "left engine torques CCW, dth={}", left.dth);
        assert!(right.dth < 0.0, "right engine torques CW, dth={}", right.dth);
    }

    #[test]
    fn gentle_pad_touchdown_scores_the_landing_bonus() {
        let mut env = LunarLander::new();
        let mut rng = Rng::new(4);
        env.reset(&mut rng);
        // Hand-place a perfect final approach.
        env.x = 0.0;
        env.y = 0.01;
        env.vx = 0.0;
        env.vy = -0.3;
        env.th = 0.0;
        env.dth = 0.0;
        env.prev_shaping = Some(env.shaping());
        let s = env.step(&Action::Discrete(0), &mut rng);
        assert!(s.done);
        assert!(s.reward > 50.0, "landing bonus missing, reward={}", s.reward);
    }

    #[test]
    fn hard_crash_scores_the_penalty() {
        let mut env = LunarLander::new();
        let mut rng = Rng::new(5);
        env.reset(&mut rng);
        env.x = 1.0; // far off the pad
        env.y = 0.01;
        env.vy = -4.0;
        env.prev_shaping = Some(env.shaping());
        let s = env.step(&Action::Discrete(0), &mut rng);
        assert!(s.done);
        assert!(s.reward < -50.0, "crash penalty missing, reward={}", s.reward);
    }
}

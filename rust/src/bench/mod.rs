//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with median/mean/min reporting
//! and throughput derivation. Every `cargo bench` target in
//! `rust/benches/` uses this, prints a markdown table, and saves CSV
//! under `results/`.

use crate::util::csv::CsvTable;
use crate::util::timer::fmt_duration;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
    /// Work items per iteration (for throughput), if meaningful.
    pub items_per_iter: Option<u64>,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    pub fn mean(&self) -> Duration {
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    /// Items/second at the median sample.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter
            .map(|n| n as f64 / self.median().as_secs_f64())
    }
}

/// Benchmark runner with fixed warmup/sample counts.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    measurements: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, sample_iters: 10, measurements: Vec::new() }
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Bencher { warmup_iters, sample_iters, measurements: Vec::new() }
    }

    /// Quick-mode bencher honoring `HEPPO_BENCH_FAST=1` (used in CI/tests).
    pub fn from_env() -> Self {
        if std::env::var("HEPPO_BENCH_FAST").as_deref() == Ok("1") {
            Bencher::new(1, 3)
        } else {
            Bencher::default()
        }
    }

    /// Run a benchmark; `f` is one full iteration.
    pub fn bench<T>(&mut self, name: &str, items_per_iter: Option<u64>, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let samples = (0..self.sample_iters)
            .map(|_| {
                let t = Instant::now();
                black_box(f());
                t.elapsed()
            })
            .collect();
        self.measurements.push(Measurement {
            name: name.to_string(),
            samples,
            items_per_iter,
        });
        self.measurements.last().unwrap()
    }

    pub fn measurements(&self) -> &[Measurement] {
        &self.measurements
    }

    /// Render all measurements as a markdown table.
    pub fn to_table(&self) -> CsvTable {
        let mut t = CsvTable::new(&["benchmark", "median", "mean", "min", "throughput/s"]);
        for m in &self.measurements {
            t.row(&[
                m.name.clone(),
                fmt_duration(m.median()),
                fmt_duration(m.mean()),
                fmt_duration(m.min()),
                m.throughput()
                    .map(|t| format_si(t))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// Print the table and save raw samples as CSV.
    pub fn report(&self, csv_path: &str) -> anyhow::Result<()> {
        println!("{}", self.to_table().to_markdown());
        let mut raw = CsvTable::new(&["benchmark", "sample_idx", "seconds", "items_per_iter"]);
        for m in &self.measurements {
            for (i, s) in m.samples.iter().enumerate() {
                raw.row(&[
                    m.name.clone(),
                    i.to_string(),
                    format!("{:.9}", s.as_secs_f64()),
                    m.items_per_iter.map(|n| n.to_string()).unwrap_or_default(),
                ]);
            }
        }
        raw.save(csv_path)?;
        Ok(())
    }
}

/// SI-suffixed number formatting (1.23M, 45.6k ...).
pub fn format_si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::new(1, 5);
        let m = b.bench("noop", Some(100), || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn median_of_odd() {
        let m = Measurement {
            name: "x".into(),
            samples: vec![
                Duration::from_millis(3),
                Duration::from_millis(1),
                Duration::from_millis(2),
            ],
            items_per_iter: None,
        };
        assert_eq!(m.median(), Duration::from_millis(2));
        assert_eq!(m.min(), Duration::from_millis(1));
    }

    #[test]
    fn si_format() {
        assert_eq!(format_si(1234.0), "1.23k");
        assert_eq!(format_si(2.5e6), "2.50M");
        assert_eq!(format_si(3e8), "300.00M");
        assert_eq!(format_si(12.0), "12.00");
        assert_eq!(format_si(4.2e9), "4.20G");
    }

    #[test]
    fn table_has_all_rows() {
        let mut b = Bencher::new(0, 2);
        b.bench("a", None, || 0);
        b.bench("b", Some(10), || 0);
        assert_eq!(b.to_table().n_rows(), 2);
    }
}

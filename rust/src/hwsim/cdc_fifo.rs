//! Clock-domain-crossing synchronization FIFO model — the paper's §VI
//! future-work item ("High-performance clock-domain crossing (CDC)
//! FIFOs can facilitate faster data transfers", citing the authors' own
//! FIFO line of work [20]–[24]).
//!
//! Standard asynchronous FIFO with Gray-coded pointers: each pointer
//! crosses into the other domain through a 2-flop synchronizer, so the
//! *observed* occupancy lags by 2 cycles of the observing clock. The
//! model answers the two questions the SoC design needs:
//!
//! - sustained throughput of a `wr_hz → rd_hz` crossing (min of the two
//!   clocks when the FIFO is deep enough to hide the sync lag);
//! - the minimum depth that sustains full rate (the classic
//!   `2·sync + margin` bound).
//!
//! A functional simulation (cycle-interleaved producer/consumer with
//! lagged pointer views) backs the closed forms in tests.

/// An asynchronous FIFO between two clock domains.
#[derive(Debug, Clone, Copy)]
pub struct CdcFifo {
    pub depth: usize,
    pub wr_hz: f64,
    pub rd_hz: f64,
    /// Synchronizer stages (2-flop standard).
    pub sync_stages: usize,
}

/// Result of a functional throughput simulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FifoRun {
    pub items: u64,
    pub wall_seconds: f64,
    pub items_per_sec: f64,
    /// Fraction of writer cycles stalled on a (lagged-)full view.
    pub writer_stall_frac: f64,
}

impl CdcFifo {
    pub fn new(depth: usize, wr_hz: f64, rd_hz: f64) -> Self {
        assert!(depth >= 2, "FIFO depth must be >= 2");
        CdcFifo { depth, wr_hz, rd_hz, sync_stages: 2 }
    }

    /// Ideal sustained rate: the slower domain's clock.
    pub fn ideal_rate(&self) -> f64 {
        self.wr_hz.min(self.rd_hz)
    }

    /// Minimum depth for full-rate streaming: the round-trip pointer lag
    /// (sync stages in each direction, in the slower domain's cycles,
    /// scaled to the faster side) plus one slot of margin.
    pub fn min_full_rate_depth(&self) -> usize {
        let ratio = (self.wr_hz / self.rd_hz).max(self.rd_hz / self.wr_hz);
        (2.0 * self.sync_stages as f64 * ratio).ceil() as usize + 1
    }

    /// Functional simulation of `items` transfers (event-driven over the
    /// two clock grids).
    pub fn simulate(&self, items: u64) -> FifoRun {
        let wr_period = 1.0 / self.wr_hz;
        let rd_period = 1.0 / self.rd_hz;
        let lag_wr = self.sync_stages as f64 * wr_period; // rd-ptr view lag at writer
        let lag_rd = self.sync_stages as f64 * rd_period; // wr-ptr view lag at reader

        // Timestamps of completed writes/reads.
        let mut write_times: Vec<f64> = Vec::with_capacity(items as usize);
        let mut read_times: Vec<f64> = Vec::with_capacity(items as usize);
        let mut t_wr = 0.0f64;
        let mut t_rd = 0.0f64;
        let mut written = 0u64;
        let mut read = 0u64;
        let mut stalls = 0u64;
        let mut wr_cycles = 0u64;
        // Monotone cursors over the timestamp lists (visibility horizons
        // only move forward, so each list is scanned once overall).
        let mut vis_reads = 0usize;
        let mut vis_writes = 0usize;

        while read < items {
            // Advance whichever domain acts next.
            if written < items && t_wr <= t_rd {
                wr_cycles += 1;
                // Writer sees reads completed before t_wr - lag_wr.
                while vis_reads < read_times.len()
                    && read_times[vis_reads] <= t_wr - lag_wr
                {
                    vis_reads += 1;
                }
                if written - (vis_reads as u64) < self.depth as u64 {
                    write_times.push(t_wr);
                    written += 1;
                } else {
                    stalls += 1;
                }
                t_wr += wr_period;
            } else {
                // Reader sees writes completed before t_rd - lag_rd.
                while vis_writes < write_times.len()
                    && write_times[vis_writes] <= t_rd - lag_rd
                {
                    vis_writes += 1;
                }
                if read < vis_writes as u64 {
                    read_times.push(t_rd);
                    read += 1;
                }
                t_rd += rd_period;
            }
        }
        let wall = read_times.last().copied().unwrap_or(0.0).max(1e-12);
        FifoRun {
            items,
            wall_seconds: wall,
            items_per_sec: items as f64 / wall,
            writer_stall_frac: stalls as f64 / wr_cycles.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deep_fifo_reaches_slower_clock_rate() {
        // GAE (300 MHz) -> DNN (285 MHz) crossing with ample depth.
        let f = CdcFifo::new(32, 300e6, 285e6);
        let run = f.simulate(20_000);
        assert!(
            run.items_per_sec > 0.97 * f.ideal_rate(),
            "rate {:.3e} vs ideal {:.3e}",
            run.items_per_sec,
            f.ideal_rate()
        );
    }

    #[test]
    fn shallow_fifo_throttles() {
        // Depth 2 cannot hide a 2-stage round-trip lag.
        let deep = CdcFifo::new(32, 300e6, 300e6).simulate(10_000);
        let shallow = CdcFifo::new(2, 300e6, 300e6).simulate(10_000);
        assert!(
            shallow.items_per_sec < 0.7 * deep.items_per_sec,
            "shallow {:.3e} vs deep {:.3e}",
            shallow.items_per_sec,
            deep.items_per_sec
        );
        assert!(shallow.writer_stall_frac > 0.2);
    }

    #[test]
    fn min_depth_bound_is_sufficient() {
        for (wr, rd) in [(300e6, 285e6), (285e6, 300e6), (300e6, 100e6)] {
            let f0 = CdcFifo::new(2, wr, rd);
            let depth = f0.min_full_rate_depth();
            let f = CdcFifo::new(depth.max(2), wr, rd);
            let run = f.simulate(20_000);
            assert!(
                run.items_per_sec > 0.95 * f.ideal_rate(),
                "wr={wr:.0} rd={rd:.0} depth={depth}: {:.3e} vs {:.3e}",
                run.items_per_sec,
                f.ideal_rate()
            );
        }
    }

    #[test]
    fn asymmetric_clocks_bound_by_slower() {
        let f = CdcFifo::new(64, 300e6, 100e6);
        let run = f.simulate(10_000);
        assert!(run.items_per_sec <= 100e6 * 1.01);
        assert!(run.items_per_sec > 95e6);
    }

    #[test]
    #[should_panic(expected = "depth must be >= 2")]
    fn depth_one_rejected() {
        CdcFifo::new(1, 1e6, 1e6);
    }
}

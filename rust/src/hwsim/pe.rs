//! Cycle-level Processing Element model (paper §III-B, Fig. 4).
//!
//! The PE datapath splits into:
//!
//! - a **feed-forward** part — compute δ_t = r_t + γ·V(s_{t+1}) − V(s_t)
//!   and the k-term weighted δ-sum — which pipelines arbitrarily; and
//! - the **feedback loop** — Â_t = C^k·Â_{t+k} + (δ-sum) — whose
//!   multiplier result must return to its own input after k issue slots.
//!
//! With a DSP multiplier of latency `mul_latency` cycles, element t can
//! only issue `max(mul_latency − k, 0)` cycles after the naïve 1/cycle
//! schedule — those are the Fig. 4 *bubbles*. k ≥ mul_latency makes the
//! loop bubble-free and the PE streams one element per cycle.
//!
//! The model issues elements in reverse time order (FILO pops) and
//! tracks per-element ready times explicitly; it also computes the real
//! advantage/RTG numerics via the same k-step decomposition the RTL
//! evaluates, cross-checked against [`crate::gae::reference`].

use crate::gae::lookahead::gae_lookahead_no_dones;
use crate::gae::{GaeOutput, GaeParams};

/// PE configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeConfig {
    /// Lookahead depth k (≥ 1).
    pub lookahead: usize,
    /// Pipelined multiplier latency, cycles (DSP48 f32 MAC ≈ 3).
    pub mul_latency: usize,
    /// Front-end (ReL → VaL → δ) pipeline depth, cycles.
    pub frontend_latency: usize,
}

impl Default for PeConfig {
    /// The paper's operating point: 2-step lookahead. (With this
    /// mul_latency=2 model, k=2 is exactly bubble-free — "the 2-step
    /// lookahead transformation is satisfactory … to operate at the
    /// highest frequency", §III-B.)
    fn default() -> Self {
        PeConfig { lookahead: 2, mul_latency: 2, frontend_latency: 4 }
    }
}

/// Result of running one trajectory vector through the PE.
#[derive(Debug, Clone)]
pub struct PeRun {
    /// Total cycles from first fetch to last writeback.
    pub cycles: u64,
    /// Stall cycles injected by the feedback loop (Fig. 4 bubbles).
    pub bubbles: u64,
    /// Elements processed.
    pub elements: usize,
    /// The computed numerics.
    pub output: GaeOutput,
}

impl PeRun {
    /// Sustained throughput in elements/cycle.
    pub fn elements_per_cycle(&self) -> f64 {
        self.elements as f64 / self.cycles as f64
    }
}

/// Per-element bubble count for a config: the feedback loop forces
/// `max(mul_latency - lookahead, 0)` dead cycles between issues.
pub fn bubbles_per_element(cfg: &PeConfig) -> u64 {
    cfg.mul_latency.saturating_sub(cfg.lookahead) as u64
}

/// Run one trajectory (rewards `T`, values `T+1`, no mid-vector
/// terminals — the coordinator splits at episode boundaries before
/// dispatch) through the PE.
pub fn run_pe(cfg: &PeConfig, params: &GaeParams, rewards: &[f32], values: &[f32]) -> PeRun {
    assert!(cfg.lookahead >= 1);
    let t_len = rewards.len();
    if t_len == 0 {
        return PeRun {
            cycles: 0,
            bubbles: 0,
            elements: 0,
            output: GaeOutput { advantages: vec![], rewards_to_go: vec![] },
        };
    }

    // --- timing: explicit issue/ready schedule over reverse order ---
    // issue[j] = cycle the j-th processed element (t = T-1-j) enters the
    // feedback stage; its result is ready at issue[j] + mul_latency.
    // Element j needs the result of element j - k (its Â_{t+k}); with
    // one issue slot per cycle:
    //   issue[j] = max(issue[j-1] + 1, issue[j-k] + mul_latency)
    let k = cfg.lookahead;
    let lat = cfg.mul_latency as u64;
    let mut issue = vec![0u64; t_len];
    let mut bubbles = 0u64;
    for j in 1..t_len {
        let serial = issue[j - 1] + 1;
        let dep = if j >= k { issue[j - k] + lat } else { 0 };
        issue[j] = serial.max(dep);
        bubbles += issue[j] - serial;
    }
    let last_ready = issue[t_len - 1] + lat;
    let cycles = cfg.frontend_latency as u64 + last_ready + 1; // +1 writeback

    // --- numerics: the k-step decomposition the RTL evaluates ---
    let output = gae_lookahead_no_dones(params, rewards, values, k);

    PeRun { cycles, bubbles, elements: t_len, output }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::Trajectory;
    use crate::testing::check;

    #[test]
    fn bubble_free_at_k_ge_latency() {
        // Fig. 4(b): k >= multiplier latency ⇒ 1 element/cycle.
        let cfg = PeConfig { lookahead: 2, mul_latency: 2, frontend_latency: 4 };
        let params = GaeParams::default();
        let r = vec![1.0f32; 1024];
        let v = vec![0.5f32; 1025];
        let run = run_pe(&cfg, &params, &r, &v);
        assert_eq!(run.bubbles, 0);
        // cycles = frontend + (T-1 issues) + latency + writeback
        assert_eq!(run.cycles, 4 + 1023 + 2 + 1);
        assert!(run.elements_per_cycle() > 0.99);
    }

    #[test]
    fn k1_injects_bubbles() {
        // Fig. 4(a): pipelining the loop at k=1 stalls every element.
        let cfg = PeConfig { lookahead: 1, mul_latency: 3, frontend_latency: 4 };
        let params = GaeParams::default();
        let r = vec![1.0f32; 512];
        let v = vec![0.0f32; 513];
        let run = run_pe(&cfg, &params, &r, &v);
        assert_eq!(run.bubbles, (512 - 1) * 2); // (lat-k)=2 per element
        assert!(run.elements_per_cycle() < 0.34);
    }

    #[test]
    fn throughput_monotone_in_k() {
        let params = GaeParams::default();
        let r = vec![0.5f32; 2048];
        let v = vec![0.1f32; 2049];
        let mut last = 0.0;
        for k in 1..=4 {
            let cfg = PeConfig { lookahead: k, mul_latency: 3, frontend_latency: 4 };
            let run = run_pe(&cfg, &params, &r, &v);
            assert!(
                run.elements_per_cycle() >= last,
                "k={k}: {} < {last}",
                run.elements_per_cycle()
            );
            last = run.elements_per_cycle();
        }
        assert!(last > 0.99, "k=4 must be bubble-free");
    }

    #[test]
    fn numerics_match_reference() {
        check("PE numerics == scalar reference", 30, |g| {
            let t_len = g.usize_in(1, 200);
            let k = g.usize_in(1, 4);
            let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
            let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
            let cfg = PeConfig { lookahead: k, mul_latency: 3, frontend_latency: 4 };
            let params = GaeParams::default();
            let run = run_pe(&cfg, &params, &rewards, &values);
            let want = gae_trajectory(
                &params,
                &Trajectory::without_dones(rewards.clone(), values.clone()),
            );
            for t in 0..t_len {
                assert!(
                    (run.output.advantages[t] - want.advantages[t]).abs() < 1e-3,
                    "t={t}"
                );
            }
        });
    }

    #[test]
    fn paper_throughput_claim_300m_per_sec() {
        // §V-D-1: one PE at 300 MHz handles 300 M elements/s — i.e. the
        // sustained rate is 1 element/cycle for long vectors.
        let cfg = PeConfig::default();
        let params = GaeParams::default();
        let r = vec![0.0f32; 100_000];
        let v = vec![0.0f32; 100_001];
        let run = run_pe(&cfg, &params, &r, &v);
        let eps = run.elements_per_cycle() * 300e6;
        assert!(eps > 299e6, "elements/s at 300 MHz = {eps}");
    }

    #[test]
    fn empty_vector() {
        let run = run_pe(&PeConfig::default(), &GaeParams::default(), &[], &[0.0]);
        assert_eq!(run.cycles, 0);
        assert_eq!(run.elements, 0);
    }
}

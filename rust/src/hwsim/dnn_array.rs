//! DNN systolic-array model — the inference/backprop engine the paper
//! adapts from Meng et al. (FCCM 2020) for the PL (§V-D-1: "for the DNN
//! inference within the PL, we adapt the systolic array implementation
//! introduced by Meng et al. Their design achieves a clock frequency of
//! 285 MHz").
//!
//! A weight-stationary `R×C` MAC array computing dense layers: an
//! `M×K · K×N` matmul is tiled into ⌈M/R⌉·⌈N/C⌉ passes of `K`-cycle
//! streams (+ array fill/drain). Enough fidelity to project the SoC-
//! level Table I timing (DNN phases on-chip vs via PJRT host calls);
//! utilization and cycle counts are exact for the tiling model.

/// Systolic array configuration (defaults = the adapted Meng et al.
/// array: 16×16 MACs at 285 MHz).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DnnArraySpec {
    pub rows: usize,
    pub cols: usize,
    pub clock_hz: f64,
    /// Fill+drain latency per tile pass (array diagonal).
    pub fill_drain: usize,
}

impl Default for DnnArraySpec {
    fn default() -> Self {
        DnnArraySpec { rows: 16, cols: 16, clock_hz: 285e6, fill_drain: 31 }
    }
}

/// A dense layer workload: `[batch, in_dim] · [in_dim, out_dim]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerShape {
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
}

impl LayerShape {
    pub fn macs(&self) -> u64 {
        (self.batch * self.in_dim * self.out_dim) as u64
    }
}

/// Cycle/utilization estimate for a workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DnnEstimate {
    pub cycles: u64,
    pub macs: u64,
    /// Achieved MACs / (cycles × array MACs).
    pub utilization: f64,
}

impl DnnArraySpec {
    /// Cycles for one dense layer (weight-stationary tiling).
    pub fn layer_cycles(&self, l: &LayerShape) -> u64 {
        let row_tiles = l.out_dim.div_ceil(self.rows);
        let col_tiles = l.batch.div_ceil(self.cols);
        let per_pass = l.in_dim + self.fill_drain;
        (row_tiles * col_tiles * per_pass) as u64
    }

    /// Estimate for a stack of layers (an MLP forward pass).
    pub fn estimate(&self, layers: &[LayerShape]) -> DnnEstimate {
        let cycles: u64 = layers.iter().map(|l| self.layer_cycles(l)).sum();
        let macs: u64 = layers.iter().map(|l| l.macs()).sum();
        let peak = cycles.max(1) as f64 * (self.rows * self.cols) as f64;
        DnnEstimate { cycles, macs, utilization: macs as f64 / peak }
    }

    /// MLP forward layers for an actor-critic of this repo's shape
    /// (2×(obs→h, h→h, h→out) for actor + critic).
    pub fn actor_critic_layers(
        batch: usize,
        obs_dim: usize,
        hidden: usize,
        act_dim: usize,
    ) -> Vec<LayerShape> {
        vec![
            LayerShape { batch, in_dim: obs_dim, out_dim: hidden },
            LayerShape { batch, in_dim: hidden, out_dim: hidden },
            LayerShape { batch, in_dim: hidden, out_dim: act_dim },
            LayerShape { batch, in_dim: obs_dim, out_dim: hidden },
            LayerShape { batch, in_dim: hidden, out_dim: hidden },
            LayerShape { batch, in_dim: hidden, out_dim: 1 },
        ]
    }

    /// Wall time of an estimate at this array's clock.
    pub fn time(&self, e: &DnnEstimate) -> std::time::Duration {
        std::time::Duration::from_secs_f64(e.cycles as f64 / self.clock_hz)
    }

    /// Backprop ≈ 2× forward MAC volume (dX and dW matmuls) + the
    /// optimizer's elementwise pass (absorbed by the array's idle lanes).
    pub fn backward_estimate(&self, layers: &[LayerShape]) -> DnnEstimate {
        let fwd = self.estimate(layers);
        DnnEstimate {
            cycles: fwd.cycles * 2,
            macs: fwd.macs * 2,
            utilization: fwd.utilization,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_tiled_layer_is_near_peak() {
        // batch=cols, out=rows, long K: utilization → K/(K+fill).
        let a = DnnArraySpec::default();
        let l = LayerShape { batch: 16, in_dim: 1024, out_dim: 16 };
        let e = a.estimate(&[l]);
        assert_eq!(e.cycles, (1024 + 31) as u64);
        assert!((e.utilization - 1024.0 / 1055.0).abs() < 1e-9);
    }

    #[test]
    fn small_layers_waste_the_array() {
        // CartPole-sized layers (4→64) keep most lanes idle — why the
        // paper pairs the array with *Humanoid-scale* networks.
        let a = DnnArraySpec::default();
        let tiny = a.estimate(&DnnArraySpec::actor_critic_layers(16, 4, 64, 2));
        let big = a.estimate(&DnnArraySpec::actor_critic_layers(16, 376, 64, 17));
        assert!(tiny.utilization < big.utilization);
        assert!(big.utilization > 0.2, "util = {}", big.utilization);
    }

    #[test]
    fn cycles_scale_with_tiling() {
        let a = DnnArraySpec::default();
        let one = a.layer_cycles(&LayerShape { batch: 16, in_dim: 64, out_dim: 16 });
        let two_rows = a.layer_cycles(&LayerShape { batch: 16, in_dim: 64, out_dim: 32 });
        assert_eq!(two_rows, 2 * one);
        let two_cols = a.layer_cycles(&LayerShape { batch: 32, in_dim: 64, out_dim: 16 });
        assert_eq!(two_cols, 2 * one);
    }

    #[test]
    fn backward_is_twice_forward() {
        let a = DnnArraySpec::default();
        let layers = DnnArraySpec::actor_critic_layers(256, 376, 64, 17);
        let f = a.estimate(&layers);
        let b = a.backward_estimate(&layers);
        assert_eq!(b.cycles, 2 * f.cycles);
    }

    #[test]
    fn humanoid_inference_is_microseconds() {
        // Sanity for the SoC projection: one rollout-step inference for
        // 16 envs on the 285 MHz array is ~tens of µs.
        let a = DnnArraySpec::default();
        let e = a.estimate(&DnnArraySpec::actor_critic_layers(16, 376, 64, 17));
        let t = a.time(&e).as_secs_f64();
        assert!(t > 1e-6 && t < 1e-3, "t = {t}");
    }
}

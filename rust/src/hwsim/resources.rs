//! Analytic resource + fmax model, calibrated to paper Table IV and
//! Fig. 11.
//!
//! Fig. 11 shows per-PE LUT/FF/DSP usage growing **quadratically** with
//! the lookahead depth n (each extra lookahead step widens the
//! feed-forward δ-combination tree *and* deepens the pipelined
//! multiplier). Table IV pins the absolute numbers at n=2 for 64 PEs:
//! 12864 LUTs, 54336 FFs, 768 DSPs (201/849/12 per PE). We fit
//! `r(n) = a·n² + b·n + c` through those points with coefficient ratios
//! chosen to keep r(1) and r(3) consistent with Fig. 11's visual trend.
//!
//! fmax: the paper reports that n > 1 removes the feedback-loop critical
//! path and lets the design close timing at 300 MHz; n = 1 leaves the
//! combinational multiply-accumulate in the loop (we model 150 MHz, the
//! typical unpipelined DSP48 f32 MAC speed).
//!
//! **Paper erratum noted:** Table IV lists DSP utilization 30.48% while
//! the §V-D-1 text says "the most significant utilization being DSPs at
//! 17.7%". We reproduce the table's arithmetic (768/2520 = 30.48%).

/// Per-PE resource usage at a given lookahead depth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeResources {
    pub luts: usize,
    pub ffs: usize,
    pub dsps: usize,
}

/// FPGA device capacity (defaults: ZCU106 / XCZU7EV, Table IV column
/// "Available").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    pub luts: usize,
    pub ffs: usize,
    pub dsps: usize,
    pub bram36: usize,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec { luts: 274_080, ffs: 548_160, dsps: 2_520, bram36: 312 }
    }
}

/// The calibrated quadratic model.
#[derive(Debug, Clone, Copy)]
pub struct ResourceModel {
    pub device: DeviceSpec,
}

impl Default for ResourceModel {
    fn default() -> Self {
        ResourceModel { device: DeviceSpec::default() }
    }
}

impl ResourceModel {
    /// Per-PE resources at lookahead depth `k` (k >= 1).
    ///
    /// Quadratics fit so that k=2 reproduces Table IV exactly:
    ///   luts(k) = 35k² + 20k + 21   → luts(2) = 201
    ///   ffs(k)  = 150k² + 80k + 89  → ffs(2)  = 849
    ///   dsps(k) = 2k² + k + 2       → dsps(2) = 12
    pub fn per_pe(&self, k: usize) -> PeResources {
        assert!(k >= 1, "lookahead must be >= 1");
        PeResources {
            luts: 35 * k * k + 20 * k + 21,
            ffs: 150 * k * k + 80 * k + 89,
            dsps: 2 * k * k + k + 2,
        }
    }

    /// Totals for `n_pes` PEs.
    pub fn total(&self, k: usize, n_pes: usize) -> PeResources {
        let p = self.per_pe(k);
        PeResources {
            luts: p.luts * n_pes,
            ffs: p.ffs * n_pes,
            dsps: p.dsps * n_pes,
        }
    }

    /// Device utilization fractions `(lut, ff, dsp)` for a config.
    pub fn utilization(&self, k: usize, n_pes: usize) -> (f64, f64, f64) {
        let t = self.total(k, n_pes);
        (
            t.luts as f64 / self.device.luts as f64,
            t.ffs as f64 / self.device.ffs as f64,
            t.dsps as f64 / self.device.dsps as f64,
        )
    }

    /// Does the configuration fit the device?
    pub fn fits(&self, k: usize, n_pes: usize) -> bool {
        let t = self.total(k, n_pes);
        t.luts <= self.device.luts && t.ffs <= self.device.ffs && t.dsps <= self.device.dsps
    }

    /// Largest PE count that fits at lookahead `k` (DSPs bind first).
    pub fn max_pes(&self, k: usize) -> usize {
        let p = self.per_pe(k);
        (self.device.luts / p.luts)
            .min(self.device.ffs / p.ffs)
            .min(self.device.dsps / p.dsps)
    }

    /// Achievable clock, Hz: k=1 leaves the MAC feedback combinational
    /// (≈150 MHz); k>=2 closes at the design target 300 MHz.
    pub fn fmax_hz(&self, k: usize) -> f64 {
        if k >= 2 {
            300e6
        } else {
            150e6
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_exact_at_k2_64pes() {
        let m = ResourceModel::default();
        let t = m.total(2, 64);
        assert_eq!(t.luts, 12_864);
        assert_eq!(t.ffs, 54_336);
        assert_eq!(t.dsps, 768);
        let (ul, uf, ud) = m.utilization(2, 64);
        assert!((ul - 0.0469).abs() < 5e-4, "lut util {ul}");
        assert!((uf - 0.0991).abs() < 5e-4, "ff util {uf}");
        assert!((ud - 0.3048).abs() < 5e-4, "dsp util {ud}");
    }

    #[test]
    fn growth_is_quadratic() {
        // Fig. 11: second difference of r(k) is constant and positive.
        let m = ResourceModel::default();
        let l: Vec<isize> = (1..=5).map(|k| m.per_pe(k).luts as isize).collect();
        let d2: Vec<isize> = (0..3).map(|i| l[i + 2] - 2 * l[i + 1] + l[i]).collect();
        assert!(d2.iter().all(|&x| x == d2[0] && x > 0), "{d2:?}");
    }

    #[test]
    fn fmax_transitions_at_k2() {
        let m = ResourceModel::default();
        assert_eq!(m.fmax_hz(1), 150e6);
        assert_eq!(m.fmax_hz(2), 300e6);
        assert_eq!(m.fmax_hz(4), 300e6);
    }

    #[test]
    fn device_comfortably_fits_64_pes() {
        // §V-D-1: "the ZCU106 can comfortably accommodate our design".
        let m = ResourceModel::default();
        assert!(m.fits(2, 64));
        assert!(m.max_pes(2) >= 64 * 3, "max_pes = {}", m.max_pes(2));
    }

    #[test]
    fn dsps_bind_first() {
        let m = ResourceModel::default();
        let p = m.per_pe(2);
        let by_dsp = m.device.dsps / p.dsps;
        assert_eq!(m.max_pes(2), by_dsp);
    }

    #[test]
    #[should_panic(expected = "lookahead must be >= 1")]
    fn k0_rejected() {
        ResourceModel::default().per_pe(0);
    }
}

//! Crossbar + BRAM port contention model (paper Fig. 5: "a crossbar
//! network ensures robust connections between ReLs, VaLs, and PEs to the
//! BRAM stack memory").
//!
//! Each active row demands 4 byte-lanes per cycle of stack traffic
//! (read R, read V, write Adv, write RTG — in-place via the second
//! port). The BRAM stack provides `blocks × 2 ports × 4 B`. When demand
//! exceeds supply the crossbar arbitrates round-robin and rows stall;
//! we model the steady-state slowdown factor exactly as
//! `min(1, supply/demand)` (round-robin is work-conserving and fair, so
//! the fluid limit is tight for the streaming access pattern).

use crate::memory::BramSpec;

/// Crossbar + stack configuration.
#[derive(Debug, Clone, Copy)]
pub struct CrossbarConfig {
    pub bram: BramSpec,
    /// BRAM blocks allocated to the stack.
    pub blocks: usize,
    /// Bytes per element as stored (1 for 8-bit codewords, 4 for f32).
    pub elem_bytes: usize,
}

impl CrossbarConfig {
    /// Paper configuration: 32 blocks, 8-bit elements.
    pub fn paper_default() -> Self {
        CrossbarConfig { bram: BramSpec::default(), blocks: 32, elem_bytes: 1 }
    }

    /// Bytes/cycle demanded by `rows` active rows (2 reads + 2 writes).
    pub fn demand_bytes_per_cycle(&self, rows: usize) -> usize {
        rows * 4 * self.elem_bytes
    }

    /// Bytes/cycle the stack can supply.
    pub fn supply_bytes_per_cycle(&self) -> usize {
        self.bram.peak_bandwidth(self.blocks)
    }

    /// Steady-state throughput factor for `rows` concurrently active
    /// rows (1.0 = no contention).
    pub fn throughput_factor(&self, rows: usize) -> f64 {
        if rows == 0 {
            return 1.0;
        }
        let demand = self.demand_bytes_per_cycle(rows) as f64;
        let supply = self.supply_bytes_per_cycle() as f64;
        (supply / demand).min(1.0)
    }

    /// Largest row count that streams without stalling.
    pub fn max_unstalled_rows(&self) -> usize {
        self.supply_bytes_per_cycle() / (4 * self.elem_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_feeds_64_rows() {
        // 32 blocks × 2 ports × 4 B = 256 B/cycle; 64 rows × 4 × 1 B =
        // 256 B/cycle — exactly balanced, no stall (§V-D-2).
        let cfg = CrossbarConfig::paper_default();
        assert_eq!(cfg.supply_bytes_per_cycle(), 256);
        assert_eq!(cfg.demand_bytes_per_cycle(64), 256);
        assert_eq!(cfg.throughput_factor(64), 1.0);
        assert_eq!(cfg.max_unstalled_rows(), 64);
    }

    #[test]
    fn f32_elements_quadruple_demand() {
        let cfg = CrossbarConfig {
            bram: BramSpec::default(),
            blocks: 32,
            elem_bytes: 4,
        };
        // Only 16 rows stream stall-free without quantization — the
        // §IV-A argument, on-chip edition.
        assert_eq!(cfg.max_unstalled_rows(), 16);
        assert!((cfg.throughput_factor(64) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn oversubscription_scales_inverse_linearly() {
        let cfg = CrossbarConfig::paper_default();
        assert!((cfg.throughput_factor(128) - 0.5).abs() < 1e-9);
        assert!((cfg.throughput_factor(256) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn zero_rows_no_contention() {
        assert_eq!(CrossbarConfig::paper_default().throughput_factor(0), 1.0);
    }
}

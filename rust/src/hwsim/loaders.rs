//! Rewards Loader (ReL) and Values Loader (VaL) models (paper Fig. 5).
//!
//! Each row's front-end: the ReL pops `R_i` from BRAM₀, forwards
//! `(R_i, i, done)` to the VaL, which fetches the matching `V_i` from
//! BRAM₁ and forwards the pair to the PE. Both are single-cycle
//! pipeline stages; with dual-port BRAM serving one element per port per
//! cycle they sustain one (R, V) pair per cycle per row, plus an
//! optional de-quantization stage when the stack stores 8-bit codewords
//! (paper §III-A "performs de-quantization").

use crate::quant::UniformQuantizer;

/// Loader pipeline configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoaderConfig {
    /// Stack stores n-bit codewords (None = raw f32, no dequant stage).
    pub quant_bits: Option<u8>,
}

impl LoaderConfig {
    /// Pipeline stages contributed to the row front-end:
    /// ReL (1) + VaL (1) + dequant (1 if quantized) + skew register (1).
    pub fn latency_cycles(&self) -> usize {
        2 + usize::from(self.quant_bits.is_some()) + 1
    }

    /// Functional model: decode one stored element to the f32 the PE
    /// consumes.
    pub fn decode(&self, stored: StoredElem) -> f32 {
        match (self.quant_bits, stored) {
            (None, StoredElem::F32(x)) => x,
            (Some(bits), StoredElem::Code(c)) => {
                UniformQuantizer::new(bits).dequantize(c)
            }
            (None, StoredElem::Code(_)) => panic!("raw loader got a codeword"),
            (Some(_), StoredElem::F32(_)) => panic!("quant loader got raw f32"),
        }
    }

    /// Encode for storage (used by the push path of the stack).
    pub fn encode(&self, x: f32) -> StoredElem {
        match self.quant_bits {
            None => StoredElem::F32(x),
            Some(bits) => StoredElem::Code(UniformQuantizer::new(bits).quantize(x)),
        }
    }

    /// Stored bits per element.
    pub fn elem_bits(&self) -> usize {
        self.quant_bits.map(|b| b as usize).unwrap_or(32)
    }
}

/// An element as held in the BRAM stack.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StoredElem {
    F32(f32),
    Code(u16),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounts_for_dequant() {
        assert_eq!(LoaderConfig { quant_bits: None }.latency_cycles(), 3);
        assert_eq!(LoaderConfig { quant_bits: Some(8) }.latency_cycles(), 4);
    }

    #[test]
    fn raw_roundtrip() {
        let lc = LoaderConfig { quant_bits: None };
        assert_eq!(lc.decode(lc.encode(1.25)), 1.25);
        assert_eq!(lc.elem_bits(), 32);
    }

    #[test]
    fn quantized_roundtrip_error_bounded() {
        let lc = LoaderConfig { quant_bits: Some(8) };
        let q = UniformQuantizer::new(8);
        for &x in &[-4.9f32, -1.0, 0.0, 0.37, 4.9] {
            let y = lc.decode(lc.encode(x));
            assert!((y - x).abs() <= q.max_in_range_error() + 1e-6);
        }
        assert_eq!(lc.elem_bits(), 8);
    }

    #[test]
    #[should_panic(expected = "raw loader got a codeword")]
    fn type_confusion_is_caught() {
        LoaderConfig { quant_bits: None }.decode(StoredElem::Code(7));
    }
}

//! Cycle-level simulator of the HEPPO-GAE microarchitecture (paper §III)
//! — the substitution for the Zynq ZCU106 FPGA fabric we do not have
//! (DESIGN.md §2).
//!
//! The simulated design matches Fig. 5: `N` independent rows, each a
//! Rewards Loader (ReL) → Values Loader (VaL) → Processing Element (PE)
//! pipeline, fed from dual-port BRAM stack memory through a crossbar,
//! processing distinct trajectories assigned round-robin. Cycle counts
//! come from an explicit dependence model of the PE's feedback loop
//! (bubbles for k < multiplier latency, bubble-free otherwise — Fig. 4),
//! and device numbers from an analytic resource/fmax model calibrated to
//! the paper's Table IV / Fig. 11.
//!
//! Every simulation also *computes the real GAE numerics*, cross-checked
//! in tests against [`crate::gae::reference`] — the simulator is an
//! executable spec, not a stopwatch.

pub mod cdc_fifo;
pub mod clock;
pub mod crossbar;
pub mod dnn_array;
pub mod loaders;
pub mod pe;
pub mod resources;
pub mod sim;

pub use dnn_array::DnnArraySpec;
pub use pe::{PeConfig, PeRun};
pub use resources::{DeviceSpec, PeResources, ResourceModel};
pub use sim::{GaeHwSim, SimConfig, SimReport};

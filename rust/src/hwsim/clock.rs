//! Clock domains + clock-domain-crossing model (paper §V-D).
//!
//! The SoC runs each subsystem at its own best frequency — the adapted
//! DNN systolic array at 285 MHz (Meng et al. 2020), the GAE array at
//! 300 MHz, the ARM PS at its own clock. "Data synchronization is not
//! required because all subsystems operate sequentially and communicate
//! through BRAMs. However, control signals across domains … still need
//! to be synchronized" — a 2-flop synchronizer per crossing.

use std::time::Duration;

/// One clock domain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClockDomain {
    pub name: &'static str,
    pub hz: f64,
}

impl ClockDomain {
    pub const fn new(name: &'static str, hz: f64) -> Self {
        ClockDomain { name, hz }
    }

    /// Wall time of `cycles` in this domain.
    pub fn time(&self, cycles: u64) -> Duration {
        Duration::from_secs_f64(cycles as f64 / self.hz)
    }

    /// Cycles elapsed in `d` wall time (ceiling).
    pub fn cycles_in(&self, d: Duration) -> u64 {
        (d.as_secs_f64() * self.hz).ceil() as u64
    }
}

/// The paper's three domains.
pub const PS_CLOCK: ClockDomain = ClockDomain::new("ps_arm", 1.2e9);
pub const DNN_CLOCK: ClockDomain = ClockDomain::new("dnn_systolic", 285e6);
pub const GAE_CLOCK: ClockDomain = ClockDomain::new("gae_array", 300e6);

/// A control-signal crossing between two domains (2-flop synchronizer in
/// the destination domain + 1 source launch edge).
#[derive(Debug, Clone, Copy)]
pub struct Crossing {
    pub from: ClockDomain,
    pub to: ClockDomain,
}

impl Crossing {
    /// Worst-case latency for one control pulse.
    pub fn latency(&self) -> Duration {
        let launch = 1.0 / self.from.hz;
        let sync = 2.0 / self.to.hz;
        Duration::from_secs_f64(launch + sync)
    }
}

/// Total handshake overhead of one PS→PL "initiate" + PL→PS "done"
/// round trip (paper §III-A data-flow step 1–2).
pub fn handshake_overhead() -> Duration {
    let start = Crossing { from: PS_CLOCK, to: GAE_CLOCK }.latency();
    let done = Crossing { from: GAE_CLOCK, to: PS_CLOCK }.latency();
    start + done
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_time_roundtrip() {
        let d = GAE_CLOCK.time(300_000_000);
        assert!((d.as_secs_f64() - 1.0).abs() < 1e-9);
        assert_eq!(GAE_CLOCK.cycles_in(Duration::from_secs(1)), 300_000_000);
    }

    #[test]
    fn crossing_latency_is_nanoseconds() {
        let c = Crossing { from: PS_CLOCK, to: GAE_CLOCK };
        let l = c.latency().as_secs_f64();
        // 1/1.2e9 + 2/300e6 ≈ 7.5 ns (Duration quantizes to whole ns).
        assert!((l - 7.5e-9).abs() <= 1e-9, "{l}");
    }

    #[test]
    fn handshake_is_negligible_vs_gae_pass() {
        // The §III-A claim that the handshake is cheap: a full 64×1024
        // GAE pass is ~1024 cycles ≈ 3.4 µs; the handshake is < 1% of it.
        let pass = GAE_CLOCK.time(1024);
        assert!(handshake_overhead().as_secs_f64() < 0.01 * pass.as_secs_f64());
    }
}

//! Top-level HEPPO-GAE simulation: N rows, round-robin trajectory
//! assignment, crossbar contention, cycle accounting, and full numerics.
//!
//! "Rows in the systolic array run concurrently and independently, each
//! processing distinct vectors from different agents assigned by a
//! round-robin fashion. When one row finishes, it gets a new set of
//! vectors." (§III-C)

use super::crossbar::CrossbarConfig;
use super::loaders::LoaderConfig;
use super::pe::{run_pe, PeConfig};
use super::resources::ResourceModel;
use crate::gae::{GaeOutput, GaeParams, Trajectory};
use std::time::Duration;

/// Full simulator configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Number of rows (ReL+VaL+PE) — the paper's 64.
    pub rows: usize,
    pub pe: PeConfig,
    pub loaders: LoaderConfig,
    pub crossbar: CrossbarConfig,
    pub gae: GaeParams,
}

impl SimConfig {
    /// The paper's operating point: 64 rows, 2-step lookahead, 8-bit
    /// quantized stack, 32 BRAM blocks.
    pub fn paper_default() -> Self {
        SimConfig {
            rows: 64,
            pe: PeConfig::default(),
            loaders: LoaderConfig { quant_bits: Some(8) },
            crossbar: CrossbarConfig::paper_default(),
            gae: GaeParams::default(),
        }
    }
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total cycles until the last row drains.
    pub cycles: u64,
    /// Total elements processed.
    pub elements: usize,
    /// Feedback-loop bubbles summed over rows.
    pub bubbles: u64,
    /// Crossbar throughput factor applied (1.0 = no contention).
    pub crossbar_factor: f64,
    /// Mean row occupancy (busy cycles / total cycles).
    pub row_utilization: f64,
    /// Per-trajectory numerics, input order.
    pub outputs: Vec<GaeOutput>,
    /// Clock this design closes at (from the resource model).
    pub clock_hz: f64,
}

impl SimReport {
    pub fn elements_per_cycle(&self) -> f64 {
        self.elements as f64 / self.cycles.max(1) as f64
    }

    /// Projected wall time on the FPGA.
    pub fn wall_time(&self) -> Duration {
        Duration::from_secs_f64(self.cycles as f64 / self.clock_hz)
    }

    /// Projected elements/second on the FPGA.
    pub fn elements_per_sec(&self) -> f64 {
        self.elements as f64 / self.wall_time().as_secs_f64().max(1e-12)
    }
}

/// The simulator.
#[derive(Debug, Clone, Copy)]
pub struct GaeHwSim {
    pub config: SimConfig,
    pub resources: ResourceModel,
}

impl GaeHwSim {
    pub fn new(config: SimConfig) -> Self {
        GaeHwSim { config, resources: ResourceModel::default() }
    }

    pub fn paper_default() -> Self {
        Self::new(SimConfig::paper_default())
    }

    /// Simulate one GAE phase over a set of trajectories (no mid-vector
    /// terminals — the coordinator pre-splits episodes).
    ///
    /// Rows run a greedy round-robin queue: each row picks the next
    /// unprocessed trajectory the moment it drains — exactly the paper's
    /// "when one row finishes, it gets a new set of vectors".
    pub fn simulate(&self, trajs: &[Trajectory]) -> SimReport {
        let cfg = &self.config;
        let rows = cfg.rows.max(1);
        // Extend the PE front-end with the loader stages.
        let pe_cfg = PeConfig {
            frontend_latency: cfg.pe.frontend_latency + cfg.loaders.latency_cycles(),
            ..cfg.pe
        };

        let mut outputs: Vec<Option<GaeOutput>> = vec![None; trajs.len()];
        let mut row_free_at = vec![0u64; rows];
        let mut row_busy = vec![0u64; rows];
        let mut bubbles = 0u64;
        let mut elements = 0usize;
        let mut next = 0usize; // round-robin queue cursor

        while next < trajs.len() {
            // The earliest-free row takes the next trajectory.
            let (row, &free_at) = row_free_at
                .iter()
                .enumerate()
                .min_by_key(|(_, &t)| t)
                .unwrap();
            let traj = &trajs[next];
            debug_assert!(
                traj.dones.iter().take(traj.len().saturating_sub(1)).all(|&d| !d),
                "hwsim rows take single-episode vectors; split at dones first"
            );
            // Zero the bootstrap if the vector ends in a terminal.
            let mut values = traj.values.clone();
            if traj.dones.last().copied().unwrap_or(false) {
                values[traj.len()] = 0.0;
            }
            let run = run_pe(&pe_cfg, &cfg.gae, &traj.rewards, &values);
            outputs[next] = Some(run.output);
            bubbles += run.bubbles;
            elements += run.elements;
            row_busy[row] += run.cycles;
            row_free_at[row] = free_at + run.cycles;
            next += 1;
        }

        let ideal_cycles = *row_free_at.iter().max().unwrap_or(&0);
        // Crossbar contention inflates the streaming phase uniformly.
        let factor = cfg.crossbar.throughput_factor(rows.min(trajs.len()));
        let cycles = (ideal_cycles as f64 / factor).ceil() as u64;
        let busy: u64 = row_busy.iter().sum();
        let row_utilization = if cycles == 0 {
            0.0
        } else {
            busy as f64 / (cycles * rows as u64) as f64 * factor
        };

        SimReport {
            cycles,
            elements,
            bubbles,
            crossbar_factor: factor,
            row_utilization,
            outputs: outputs.into_iter().map(|o| o.unwrap()).collect(),
            clock_hz: self.resources.fmax_hz(cfg.pe.lookahead),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::testing::{check, Gen};

    fn equal_batch(t_len: usize, n: usize, g: &mut Gen) -> Vec<Trajectory> {
        (0..n)
            .map(|_| {
                Trajectory::without_dones(
                    g.vec_normal_f32(t_len, 0.0, 1.0),
                    g.vec_normal_f32(t_len + 1, 0.0, 1.0),
                )
            })
            .collect()
    }

    #[test]
    fn paper_workload_64x1024() {
        // §V-D: 64 trajectories × 1024 steps on 64 rows — every row gets
        // exactly one vector; total cycles ≈ 1024 + pipeline fill; at
        // 300 MHz the array sustains ~64 × 300M elements/s.
        let mut g = Gen::new(1);
        let trajs = equal_batch(1024, 64, &mut g);
        let sim = GaeHwSim::paper_default();
        let rep = sim.simulate(&trajs);
        assert_eq!(rep.elements, 64 * 1024);
        assert_eq!(rep.bubbles, 0, "k=2 must be bubble-free");
        assert_eq!(rep.crossbar_factor, 1.0);
        assert!(rep.cycles < 1024 + 32, "cycles = {}", rep.cycles);
        let eps = rep.elements_per_sec();
        assert!(
            (eps / (64.0 * 300e6) - 1.0).abs() < 0.05,
            "array elements/s = {eps:.3e}"
        );
        assert!(rep.row_utilization > 0.95);
    }

    #[test]
    fn numerics_match_reference_always() {
        check("hwsim numerics == reference", 20, |g| {
            let n = g.usize_in(1, 40);
            let trajs: Vec<Trajectory> = (0..n)
                .map(|_| {
                    let t_len = g.usize_in(1, 64);
                    Trajectory::without_dones(
                        g.vec_normal_f32(t_len, 0.0, 1.0),
                        g.vec_normal_f32(t_len + 1, 0.0, 1.0),
                    )
                })
                .collect();
            let sim = GaeHwSim::paper_default();
            let rep = sim.simulate(&trajs);
            for (traj, out) in trajs.iter().zip(&rep.outputs) {
                let want = gae_trajectory(&GaeParams::default(), traj);
                for t in 0..traj.len() {
                    assert!(
                        (out.advantages[t] - want.advantages[t]).abs() < 1e-3
                    );
                }
            }
        });
    }

    #[test]
    fn round_robin_balances_unequal_lengths() {
        // Many short + few long vectors: rows that finish early must pick
        // up the remaining queue (utilization stays high).
        let mut g = Gen::new(3);
        let mut trajs = Vec::new();
        for i in 0..256 {
            let t_len = if i % 16 == 0 { 512 } else { 64 };
            trajs.push(Trajectory::without_dones(
                g.vec_normal_f32(t_len, 0.0, 1.0),
                g.vec_normal_f32(t_len + 1, 0.0, 1.0),
            ));
        }
        let sim = GaeHwSim::new(SimConfig { rows: 16, ..SimConfig::paper_default() });
        let rep = sim.simulate(&trajs);
        assert!(rep.row_utilization > 0.8, "util = {}", rep.row_utilization);
    }

    #[test]
    fn unquantized_stack_stalls_the_crossbar() {
        // f32 elements quadruple stack traffic: 64 rows on 32 blocks run
        // at 1/4 speed — the on-chip version of the §IV-A argument.
        let mut g = Gen::new(4);
        let trajs = equal_batch(256, 64, &mut g);
        let mut cfg = SimConfig::paper_default();
        cfg.loaders = LoaderConfig { quant_bits: None };
        cfg.crossbar.elem_bytes = 4;
        let rep = GaeHwSim::new(cfg).simulate(&trajs);
        assert!((rep.crossbar_factor - 0.25).abs() < 1e-9);
        let quant = GaeHwSim::paper_default().simulate(&trajs);
        assert!(rep.cycles > 3 * quant.cycles);
    }

    #[test]
    fn k1_design_is_slower_and_lower_clocked() {
        let mut g = Gen::new(5);
        let trajs = equal_batch(512, 64, &mut g);
        let mut cfg = SimConfig::paper_default();
        cfg.pe = PeConfig { lookahead: 1, mul_latency: 2, frontend_latency: 4 };
        let k1 = GaeHwSim::new(cfg).simulate(&trajs);
        let k2 = GaeHwSim::paper_default().simulate(&trajs);
        assert!(k1.bubbles > 0);
        assert_eq!(k1.clock_hz, 150e6);
        assert!(k1.wall_time() > 2 * k2.wall_time());
    }

    #[test]
    fn empty_workload() {
        let rep = GaeHwSim::paper_default().simulate(&[]);
        assert_eq!(rep.cycles, 0);
        assert_eq!(rep.elements, 0);
    }
}

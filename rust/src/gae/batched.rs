//! Timestep-major batched GAE — the software analogue of the 64-PE row
//! array.
//!
//! Data layout matches the paper's memory-block layout (§IV): a
//! `[T, B]` matrix where row `t` holds element `t` of all `B`
//! trajectories ("groups data from different trajectories with the same
//! timestep into memory blocks, enabling simultaneous retrieval and
//! processing"). The backward loop then runs once over `T` with a
//! `B`-wide vectorizable inner loop — exactly the work distribution the
//! systolic rows perform in hardware.

use super::{GaeOutput, GaeParams};

/// A batch of equal-length trajectories in timestep-major layout.
#[derive(Debug, Clone)]
pub struct GaeBatch {
    /// Number of timesteps `T`.
    pub t_len: usize,
    /// Number of trajectories `B`.
    pub batch: usize,
    /// Rewards, `[T, B]` row-major (`rewards[t*batch + i]`).
    pub rewards: Vec<f32>,
    /// Values, `[T+1, B]` row-major; the final row bootstraps.
    pub values: Vec<f32>,
    /// Terminal flags, `[T, B]` row-major, 1.0 = done (f32 mask form so
    /// the inner loop is branch-free, as in the hardware datapath).
    pub done_mask: Vec<f32>,
}

impl GaeBatch {
    pub fn new(t_len: usize, batch: usize) -> Self {
        GaeBatch {
            t_len,
            batch,
            rewards: vec![0.0; t_len * batch],
            values: vec![0.0; (t_len + 1) * batch],
            done_mask: vec![0.0; t_len * batch],
        }
    }

    /// Assemble from per-trajectory vectors (all must share the length).
    pub fn from_trajectories(trajs: &[super::Trajectory]) -> Self {
        assert!(!trajs.is_empty(), "empty batch");
        let t_len = trajs[0].len();
        assert!(
            trajs.iter().all(|t| t.len() == t_len),
            "all trajectories must have equal length in batched layout"
        );
        let batch = trajs.len();
        let mut b = GaeBatch::new(t_len, batch);
        for (i, traj) in trajs.iter().enumerate() {
            for t in 0..t_len {
                b.rewards[t * batch + i] = traj.rewards[t];
                b.done_mask[t * batch + i] = if traj.dones[t] { 1.0 } else { 0.0 };
            }
            for t in 0..=t_len {
                b.values[t * batch + i] = traj.values[t];
            }
        }
        b
    }

    #[inline]
    pub fn idx(&self, t: usize, i: usize) -> usize {
        t * self.batch + i
    }
}

/// Width of one register-blocked lane group: wide enough to fill a
/// 256-bit SIMD row of f32s, small enough that the per-block carry and
/// `v_next` state live entirely in registers across the whole backward
/// sweep — the software shape of the paper's per-PE register pair.
pub const LANE_BLOCK: usize = 8;

/// One lane block's full backward sweep: `bw <= LANE_BLOCK` lanes at
/// column offset `base`, reading rows `t * stride + base` of the input
/// planes and writing rows `t * width + base` of the dense outputs.
/// Carry (`A_{t+1}`) and the original `V(s_{t+1})` row live in
/// fixed-size register arrays for the whole sweep; the caller invokes
/// this with the constant `LANE_BLOCK` for full blocks so LLVM sees a
/// fixed trip count and vectorizes the inner loop.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn lane_block_pass(
    gamma: f32,
    c: f32,
    t_len: usize,
    stride: usize,
    width: usize,
    base: usize,
    bw: usize,
    rewards: &[f32],
    values: &[f32],
    done_mask: &[f32],
    adv: &mut [f32],
    rtg: &mut [f32],
) {
    debug_assert!(bw <= LANE_BLOCK);
    let mut carry = [0.0f32; LANE_BLOCK];
    let mut v_next = [0.0f32; LANE_BLOCK];
    let boot = t_len * stride + base;
    v_next[..bw].copy_from_slice(&values[boot..boot + bw]);
    for t in (0..t_len).rev() {
        let row = t * stride + base;
        let out = t * width + base;
        for j in 0..bw {
            let not_done = 1.0 - done_mask[row + j];
            let v = values[row + j];
            let delta = rewards[row + j] + gamma * v_next[j] * not_done - v;
            let a = delta + c * not_done * carry[j];
            carry[j] = a;
            v_next[j] = v; // register the original value for row t-1
            adv[out + j] = a;
            rtg[out + j] = a + v;
        }
    }
}

/// Backward GAE over a **strided** `[T, W]` slab, written into reusable
/// output planes. Input rows of `width` live lanes sit `stride` elements
/// apart (`stride == width` is the dense tile case; `stride > width` is
/// a column window of a wider resident plane set — the serving worker's
/// slab fast path, which computes directly on a shared `[T, B]`
/// `PlaneSet` with zero gather). Outputs are dense `[T, W]`; `adv` and
/// `rtg` are cleared and resized in place, so a warmed caller performs
/// zero allocations.
///
/// Per-lane float expressions are identical to the scalar reference
/// ([`gae_indexed`](crate::gae::reference::gae_indexed)), so results are
/// bit-identical to gathering each lane and running the scalar loop.
#[allow(clippy::too_many_arguments)]
pub fn gae_batched_strided_into(
    params: &GaeParams,
    t_len: usize,
    width: usize,
    stride: usize,
    rewards: &[f32],
    values: &[f32],
    done_mask: &[f32],
    adv: &mut Vec<f32>,
    rtg: &mut Vec<f32>,
) {
    assert!(stride >= width, "row stride {stride} must cover lane width {width}");
    adv.clear();
    adv.resize(t_len * width, 0.0);
    rtg.clear();
    rtg.resize(t_len * width, 0.0);
    if t_len == 0 || width == 0 {
        return;
    }
    debug_assert!(rewards.len() >= (t_len - 1) * stride + width);
    debug_assert!(values.len() >= t_len * stride + width);
    debug_assert!(done_mask.len() >= (t_len - 1) * stride + width);
    let c = params.c();
    let gamma = params.gamma;
    let mut base = 0usize;
    while base < width {
        let bw = (width - base).min(LANE_BLOCK);
        if bw == LANE_BLOCK {
            // Constant trip count: the vectorized hot case.
            lane_block_pass(
                gamma, c, t_len, stride, width, base, LANE_BLOCK, rewards, values,
                done_mask, adv, rtg,
            );
        } else {
            lane_block_pass(
                gamma, c, t_len, stride, width, base, bw, rewards, values, done_mask,
                adv, rtg,
            );
        }
        base += bw;
    }
}

/// Scratch-reusing form of [`gae_batched`]: outputs land in
/// caller-provided planes (cleared + resized, capacity reused).
pub fn gae_batched_into(
    params: &GaeParams,
    b: &GaeBatch,
    adv: &mut Vec<f32>,
    rtg: &mut Vec<f32>,
) {
    gae_batched_strided_into(
        params, b.t_len, b.batch, b.batch, &b.rewards, &b.values, &b.done_mask, adv,
        rtg,
    );
}

/// Batched GAE: one backward pass over `T`, register-blocked vector work
/// over `B` (see [`gae_batched_strided_into`] for the allocation-free
/// form this wraps).
pub fn gae_batched(params: &GaeParams, b: &GaeBatch) -> GaeOutput {
    let mut advantages = Vec::new();
    let mut rewards_to_go = Vec::new();
    gae_batched_into(params, b, &mut advantages, &mut rewards_to_go);
    GaeOutput { advantages, rewards_to_go }
}

/// In-place variant modelling the paper's dual-port overwrite (§IV-3):
/// advantages overwrite the rewards array and rewards-to-go overwrite
/// values rows `0..T`, halving working memory.
///
/// Note the hazard Algorithm 2 sidesteps by writing to row `t+1`: by the
/// time row `t` is processed, row `t+1` of the value plane has already
/// been overwritten with RTGs. Like the hardware PE, we keep the
/// original `V(s_{t+1})` row in registers (`v_next`) across iterations.
pub fn gae_batched_in_place(params: &GaeParams, b: &mut GaeBatch) {
    let (t_len, batch) = (b.t_len, b.batch);
    let mut carry = vec![0.0f32; batch];
    // Original values of row t+1 (starts as the bootstrap row, which is
    // never overwritten).
    let mut v_next: Vec<f32> = b.values[t_len * batch..(t_len + 1) * batch].to_vec();
    let c = params.c();
    let gamma = params.gamma;
    for t in (0..t_len).rev() {
        let row = t * batch;
        for i in 0..batch {
            let not_done = 1.0 - b.done_mask[row + i];
            let v = b.values[row + i];
            let delta = b.rewards[row + i] + gamma * v_next[i] * not_done - v;
            let a = delta + c * not_done * carry[i];
            carry[i] = a;
            v_next[i] = v; // register the original value for row t-1
            b.rewards[row + i] = a; // advantage overwrites reward
            b.values[row + i] = a + v; // RTG overwrites value
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::Trajectory;
    use crate::testing::{check, Gen};

    fn random_batch(g: &mut Gen, t_len: usize, batch: usize) -> Vec<Trajectory> {
        (0..batch)
            .map(|_| {
                let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
                let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
                let dones = (0..t_len).map(|_| g.bool_p(0.05)).collect();
                Trajectory::new(rewards, values, dones)
            })
            .collect()
    }

    #[test]
    fn matches_reference_per_trajectory() {
        check("batched == scalar reference", 30, |g| {
            let t_len = g.usize_in(1, 48);
            let batch = g.usize_in(1, 16);
            let trajs = random_batch(g, t_len, batch);
            let b = GaeBatch::from_trajectories(&trajs);
            let out = gae_batched(&GaeParams::default(), &b);
            for (i, traj) in trajs.iter().enumerate() {
                let want = gae_trajectory(&GaeParams::default(), traj);
                for t in 0..t_len {
                    let got = out.advantages[b.idx(t, i)];
                    assert!(
                        (got - want.advantages[t]).abs() < 1e-4,
                        "traj {i} t {t}: {got} vs {}",
                        want.advantages[t]
                    );
                    let got_rtg = out.rewards_to_go[b.idx(t, i)];
                    assert!((got_rtg - want.rewards_to_go[t]).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn in_place_matches_out_of_place() {
        check("in-place == out-of-place", 30, |g| {
            let t_len = g.usize_in(1, 40);
            let batch = g.usize_in(1, 8);
            let trajs = random_batch(g, t_len, batch);
            let b = GaeBatch::from_trajectories(&trajs);
            let out = gae_batched(&GaeParams::default(), &b);
            let mut b2 = b.clone();
            gae_batched_in_place(&GaeParams::default(), &mut b2);
            for t in 0..t_len {
                for i in 0..batch {
                    let k = b.idx(t, i);
                    assert!((b2.rewards[k] - out.advantages[k]).abs() < 1e-5);
                    assert!((b2.values[k] - out.rewards_to_go[k]).abs() < 1e-5);
                }
            }
        });
    }

    #[test]
    fn blocked_kernel_is_bit_identical_to_the_scalar_reference() {
        // The lane-blocked kernel shares the reference's float
        // expressions, so every width — below, at, and across the
        // LANE_BLOCK boundary — must match the gathered scalar loop
        // *bitwise*, not just within tolerance.
        check("blocked batched == scalar (bitwise)", 20, |g| {
            let t_len = g.usize_in(1, 33);
            let batch = *g.choose(&[1usize, 7, 8, 9, 15, 16, 17, 23]);
            let trajs = random_batch(g, t_len, batch);
            let b = GaeBatch::from_trajectories(&trajs);
            let out = gae_batched(&GaeParams::default(), &b);
            for (i, traj) in trajs.iter().enumerate() {
                let want = gae_trajectory(&GaeParams::default(), traj);
                for t in 0..t_len {
                    assert_eq!(
                        out.advantages[b.idx(t, i)].to_bits(),
                        want.advantages[t].to_bits(),
                        "lane {i} t {t}"
                    );
                    assert_eq!(
                        out.rewards_to_go[b.idx(t, i)].to_bits(),
                        want.rewards_to_go[t].to_bits(),
                        "rtg lane {i} t {t}"
                    );
                }
            }
        });
    }

    #[test]
    fn strided_window_matches_the_packed_subset_bitwise() {
        // A column window [col0, col0+width) of a wide [T, B] plane,
        // computed in place with stride B, must equal packing those
        // columns into a dense tile and computing that — bit for bit.
        check("strided window == packed subset", 20, |g| {
            let t_len = g.usize_in(1, 24);
            let batch = g.usize_in(2, 20);
            let trajs = random_batch(g, t_len, batch);
            let wide = GaeBatch::from_trajectories(&trajs);
            let col0 = g.usize_in(0, batch - 1);
            let width = g.usize_in(1, batch - col0);
            let mut adv = Vec::new();
            let mut rtg = Vec::new();
            gae_batched_strided_into(
                &GaeParams::default(),
                t_len,
                width,
                batch,
                &wide.rewards[col0..],
                &wide.values[col0..],
                &wide.done_mask[col0..],
                &mut adv,
                &mut rtg,
            );
            let dense = GaeBatch::from_trajectories(&trajs[col0..col0 + width]);
            let want = gae_batched(&GaeParams::default(), &dense);
            assert_eq!(adv.len(), t_len * width);
            for k in 0..t_len * width {
                assert_eq!(adv[k].to_bits(), want.advantages[k].to_bits(), "adv {k}");
                assert_eq!(rtg[k].to_bits(), want.rewards_to_go[k].to_bits(), "rtg {k}");
            }
        });
    }

    #[test]
    fn into_form_reuses_capacity_across_shrinking_reruns() {
        let mut g = Gen::new(11);
        let big = GaeBatch::from_trajectories(&random_batch(&mut g, 32, 9));
        let small = GaeBatch::from_trajectories(&random_batch(&mut g, 4, 3));
        let mut adv = Vec::new();
        let mut rtg = Vec::new();
        gae_batched_into(&GaeParams::default(), &big, &mut adv, &mut rtg);
        let cap = adv.capacity();
        gae_batched_into(&GaeParams::default(), &small, &mut adv, &mut rtg);
        assert_eq!(adv.len(), 4 * 3);
        assert_eq!(adv.capacity(), cap, "shrinking rerun must not reallocate");
        let want = gae_batched(&GaeParams::default(), &small);
        assert_eq!(adv, want.advantages);
        assert_eq!(rtg, want.rewards_to_go);
    }

    #[test]
    fn layout_is_timestep_major() {
        let trajs = vec![
            Trajectory::without_dones(vec![1.0, 2.0], vec![0.0, 0.0, 0.0]),
            Trajectory::without_dones(vec![3.0, 4.0], vec![0.0, 0.0, 0.0]),
        ];
        let b = GaeBatch::from_trajectories(&trajs);
        // Row t=0 holds element 0 of both trajectories (Fig. 6 layout).
        assert_eq!(&b.rewards[0..2], &[1.0, 3.0]);
        assert_eq!(&b.rewards[2..4], &[2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn unequal_lengths_rejected() {
        let trajs = vec![
            Trajectory::without_dones(vec![1.0], vec![0.0, 0.0]),
            Trajectory::without_dones(vec![1.0, 2.0], vec![0.0, 0.0, 0.0]),
        ];
        GaeBatch::from_trajectories(&trajs);
    }
}

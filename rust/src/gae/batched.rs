//! Timestep-major batched GAE — the software analogue of the 64-PE row
//! array.
//!
//! Data layout matches the paper's memory-block layout (§IV): a
//! `[T, B]` matrix where row `t` holds element `t` of all `B`
//! trajectories ("groups data from different trajectories with the same
//! timestep into memory blocks, enabling simultaneous retrieval and
//! processing"). The backward loop then runs once over `T` with a
//! `B`-wide vectorizable inner loop — exactly the work distribution the
//! systolic rows perform in hardware.

use super::{GaeOutput, GaeParams};

/// A batch of equal-length trajectories in timestep-major layout.
#[derive(Debug, Clone)]
pub struct GaeBatch {
    /// Number of timesteps `T`.
    pub t_len: usize,
    /// Number of trajectories `B`.
    pub batch: usize,
    /// Rewards, `[T, B]` row-major (`rewards[t*batch + i]`).
    pub rewards: Vec<f32>,
    /// Values, `[T+1, B]` row-major; the final row bootstraps.
    pub values: Vec<f32>,
    /// Terminal flags, `[T, B]` row-major, 1.0 = done (f32 mask form so
    /// the inner loop is branch-free, as in the hardware datapath).
    pub done_mask: Vec<f32>,
}

impl GaeBatch {
    pub fn new(t_len: usize, batch: usize) -> Self {
        GaeBatch {
            t_len,
            batch,
            rewards: vec![0.0; t_len * batch],
            values: vec![0.0; (t_len + 1) * batch],
            done_mask: vec![0.0; t_len * batch],
        }
    }

    /// Assemble from per-trajectory vectors (all must share the length).
    pub fn from_trajectories(trajs: &[super::Trajectory]) -> Self {
        assert!(!trajs.is_empty(), "empty batch");
        let t_len = trajs[0].len();
        assert!(
            trajs.iter().all(|t| t.len() == t_len),
            "all trajectories must have equal length in batched layout"
        );
        let batch = trajs.len();
        let mut b = GaeBatch::new(t_len, batch);
        for (i, traj) in trajs.iter().enumerate() {
            for t in 0..t_len {
                b.rewards[t * batch + i] = traj.rewards[t];
                b.done_mask[t * batch + i] = if traj.dones[t] { 1.0 } else { 0.0 };
            }
            for t in 0..=t_len {
                b.values[t * batch + i] = traj.values[t];
            }
        }
        b
    }

    #[inline]
    pub fn idx(&self, t: usize, i: usize) -> usize {
        t * self.batch + i
    }
}

/// Batched GAE: one backward pass over `T`, vector work over `B`.
pub fn gae_batched(params: &GaeParams, b: &GaeBatch) -> GaeOutput {
    let (t_len, batch) = (b.t_len, b.batch);
    let mut advantages = vec![0.0f32; t_len * batch];
    let mut rewards_to_go = vec![0.0f32; t_len * batch];
    let mut carry = vec![0.0f32; batch]; // A_{t+1} per trajectory
    let c = params.c();
    let gamma = params.gamma;
    for t in (0..t_len).rev() {
        let row = t * batch;
        let vrow = &b.values[row..row + batch];
        let vnext = &b.values[row + batch..row + 2 * batch];
        let r = &b.rewards[row..row + batch];
        let dm = &b.done_mask[row..row + batch];
        let adv = &mut advantages[row..row + batch];
        let rtg = &mut rewards_to_go[row..row + batch];
        // Branch-free, dependency-free across the batch lane ⇒ the
        // compiler vectorizes this to the lane width (§Perf log).
        for (((((ci, ai), gi), &ri), &vi), (&vni, &di)) in carry
            .iter_mut()
            .zip(adv.iter_mut())
            .zip(rtg.iter_mut())
            .zip(r)
            .zip(vrow)
            .zip(vnext.iter().zip(dm))
        {
            let not_done = 1.0 - di;
            let delta = ri + gamma * vni * not_done - vi;
            let a = delta + c * not_done * *ci;
            *ci = a;
            *ai = a;
            *gi = a + vi;
        }
    }
    GaeOutput { advantages, rewards_to_go }
}

/// In-place variant modelling the paper's dual-port overwrite (§IV-3):
/// advantages overwrite the rewards array and rewards-to-go overwrite
/// values rows `0..T`, halving working memory.
///
/// Note the hazard Algorithm 2 sidesteps by writing to row `t+1`: by the
/// time row `t` is processed, row `t+1` of the value plane has already
/// been overwritten with RTGs. Like the hardware PE, we keep the
/// original `V(s_{t+1})` row in registers (`v_next`) across iterations.
pub fn gae_batched_in_place(params: &GaeParams, b: &mut GaeBatch) {
    let (t_len, batch) = (b.t_len, b.batch);
    let mut carry = vec![0.0f32; batch];
    // Original values of row t+1 (starts as the bootstrap row, which is
    // never overwritten).
    let mut v_next: Vec<f32> = b.values[t_len * batch..(t_len + 1) * batch].to_vec();
    let c = params.c();
    let gamma = params.gamma;
    for t in (0..t_len).rev() {
        let row = t * batch;
        for i in 0..batch {
            let not_done = 1.0 - b.done_mask[row + i];
            let v = b.values[row + i];
            let delta = b.rewards[row + i] + gamma * v_next[i] * not_done - v;
            let a = delta + c * not_done * carry[i];
            carry[i] = a;
            v_next[i] = v; // register the original value for row t-1
            b.rewards[row + i] = a; // advantage overwrites reward
            b.values[row + i] = a + v; // RTG overwrites value
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::Trajectory;
    use crate::testing::{check, Gen};

    fn random_batch(g: &mut Gen, t_len: usize, batch: usize) -> Vec<Trajectory> {
        (0..batch)
            .map(|_| {
                let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
                let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
                let dones = (0..t_len).map(|_| g.bool_p(0.05)).collect();
                Trajectory::new(rewards, values, dones)
            })
            .collect()
    }

    #[test]
    fn matches_reference_per_trajectory() {
        check("batched == scalar reference", 30, |g| {
            let t_len = g.usize_in(1, 48);
            let batch = g.usize_in(1, 16);
            let trajs = random_batch(g, t_len, batch);
            let b = GaeBatch::from_trajectories(&trajs);
            let out = gae_batched(&GaeParams::default(), &b);
            for (i, traj) in trajs.iter().enumerate() {
                let want = gae_trajectory(&GaeParams::default(), traj);
                for t in 0..t_len {
                    let got = out.advantages[b.idx(t, i)];
                    assert!(
                        (got - want.advantages[t]).abs() < 1e-4,
                        "traj {i} t {t}: {got} vs {}",
                        want.advantages[t]
                    );
                    let got_rtg = out.rewards_to_go[b.idx(t, i)];
                    assert!((got_rtg - want.rewards_to_go[t]).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn in_place_matches_out_of_place() {
        check("in-place == out-of-place", 30, |g| {
            let t_len = g.usize_in(1, 40);
            let batch = g.usize_in(1, 8);
            let trajs = random_batch(g, t_len, batch);
            let b = GaeBatch::from_trajectories(&trajs);
            let out = gae_batched(&GaeParams::default(), &b);
            let mut b2 = b.clone();
            gae_batched_in_place(&GaeParams::default(), &mut b2);
            for t in 0..t_len {
                for i in 0..batch {
                    let k = b.idx(t, i);
                    assert!((b2.rewards[k] - out.advantages[k]).abs() < 1e-5);
                    assert!((b2.values[k] - out.rewards_to_go[k]).abs() < 1e-5);
                }
            }
        });
    }

    #[test]
    fn layout_is_timestep_major() {
        let trajs = vec![
            Trajectory::without_dones(vec![1.0, 2.0], vec![0.0, 0.0, 0.0]),
            Trajectory::without_dones(vec![3.0, 4.0], vec![0.0, 0.0, 0.0]),
        ];
        let b = GaeBatch::from_trajectories(&trajs);
        // Row t=0 holds element 0 of both trajectories (Fig. 6 layout).
        assert_eq!(&b.rewards[0..2], &[1.0, 3.0]);
        assert_eq!(&b.rewards[2..4], &[2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "equal length")]
    fn unequal_lengths_rejected() {
        let trajs = vec![
            Trajectory::without_dones(vec![1.0], vec![0.0, 0.0]),
            Trajectory::without_dones(vec![1.0, 2.0], vec![0.0, 0.0, 0.0]),
        ];
        GaeBatch::from_trajectories(&trajs);
    }
}

//! k-step lookahead GAE — the paper's key pipelining transformation
//! (§III-B, Table II, Eq. 10–12).
//!
//! The recurrence `A_t = δ_t + C·A_{t+1}` has a single-cycle feedback
//! loop: the multiplier output is needed one step later, so the
//! multiplier cannot be pipelined without stalling. Unrolling k steps,
//!
//! ```text
//! A_t = C^k · A_{t+k} + Σ_{i=0}^{k-1} C^{(k-1)-i} · δ_{t+i}     (Eq. 12)
//! ```
//!
//! puts k registers in the loop: the `C^k` multiplier may now have k
//! pipeline stages and still produce each result in time. In software /
//! Pallas terms the same identity turns a length-T sequential chain into
//! ⌈T/k⌉ chain steps of vectorizable work — the schedule used by the L1
//! kernel (`python/compile/kernels/gae.py`) and the cycle simulator
//! ([`crate::hwsim::pe`]).
//!
//! Lookahead applies *within* an episode segment; terminal (`done`)
//! boundaries reset the carry exactly as the sequential recurrence does.

use super::{GaeOutput, GaeParams, Trajectory};

/// Compute advantages via the k-step lookahead identity on a trajectory
/// with **no mid-vector terminals** (the hardware case — each systolic
/// row receives exactly one episode's vectors).
///
/// Bit-for-bit this differs from the sequential recurrence only by
/// floating-point reassociation; tests bound the drift.
pub fn gae_lookahead_no_dones(
    params: &GaeParams,
    rewards: &[f32],
    values: &[f32],
    k: usize,
) -> GaeOutput {
    assert!(k >= 1, "lookahead k must be >= 1");
    assert_eq!(values.len(), rewards.len() + 1);
    let t_len = rewards.len();
    let c = params.c();
    // Precompute C^i up to k (the hardware bakes these into the PE).
    let c_pows: Vec<f32> = (0..=k).map(|i| c.powi(i as i32)).collect();

    // δ_t for all t — in hardware this is the feed-forward (non-loop)
    // part of the PE datapath, fully pipelined.
    let deltas: Vec<f32> = (0..t_len)
        .map(|t| rewards[t] + params.gamma * values[t + 1] - values[t])
        .collect();

    let mut advantages = vec![0.0f32; t_len];
    // Process chunks of k from the tail. Within a chunk, each element
    // needs its own partial sum of deltas (the feed-forward terms) plus
    // C^j times the carry from the next chunk.
    let mut carry = 0.0f32; // A at the first index of the previous (later) chunk
    let mut chunk_start = t_len;
    while chunk_start > 0 {
        let lo = chunk_start.saturating_sub(k);
        let len = chunk_start - lo;
        // For t in [lo, chunk_start): A_t = C^{chunk_start - t} * carry
        //   + Σ_{u=t}^{chunk_start-1} C^{u-t} δ_u
        // Computed with a running suffix so the chunk costs O(k) — this
        // mirrors the PE, whose adder tree accumulates the k δ-terms.
        let mut suffix = 0.0f32;
        for t in (lo..chunk_start).rev() {
            let dist = chunk_start - t;
            suffix = deltas[t] + c * suffix;
            advantages[t] = suffix + c_pows[dist] * carry;
        }
        carry = advantages[lo];
        chunk_start = lo;
        let _ = len;
    }

    let rewards_to_go = advantages
        .iter()
        .zip(values.iter())
        .map(|(a, v)| a + v)
        .collect();
    GaeOutput { advantages, rewards_to_go }
}

/// k-step lookahead over a trajectory that may contain terminals: the
/// vector is split at `done` boundaries and each segment is processed
/// independently (the coordinator performs this split before dispatching
/// rows to the accelerator).
pub fn gae_lookahead(params: &GaeParams, traj: &Trajectory, k: usize) -> GaeOutput {
    let t_len = traj.len();
    let mut advantages = vec![0.0f32; t_len];
    let mut rewards_to_go = vec![0.0f32; t_len];
    // Split into maximal segments [start, end) where every done lies at a
    // segment's last step.
    let mut start = 0;
    for t in 0..t_len {
        if traj.dones[t] || t == t_len - 1 {
            process_segment(params, traj, start, t + 1, k, &mut advantages, &mut rewards_to_go);
            start = t + 1;
        }
    }
    GaeOutput { advantages, rewards_to_go }
}

/// Process `[start, end)` as a closed segment: the value bootstrap at
/// `end` applies only when the segment is *not* terminated by a done.
fn process_segment(
    params: &GaeParams,
    traj: &Trajectory,
    start: usize,
    end: usize,
    k: usize,
    advantages: &mut [f32],
    rewards_to_go: &mut [f32],
) {
    let seg_len = end - start;
    let rewards = &traj.rewards[start..end];
    // Values slice is seg_len + 1; zero the bootstrap if the segment ends
    // in a terminal.
    let mut values: Vec<f32> = traj.values[start..=end].to_vec();
    if traj.dones[end - 1] {
        values[seg_len] = 0.0;
    }
    let out = gae_lookahead_no_dones(params, rewards, &values, k);
    advantages[start..end].copy_from_slice(&out.advantages);
    rewards_to_go[start..end].copy_from_slice(&out.rewards_to_go);
}

/// Verify the Table II decomposition identities for a given δ sequence:
/// returns the max absolute error between `A_t` computed sequentially and
/// via `A_t = C^k A_{t+k} + Σ_{i=0}^{k-1} C^i δ_{t+i}` for every valid
/// `t`. Used by tests and the Fig. 4/Table II bench.
///
/// **Paper erratum:** the paper's general k-step equation writes the
/// summand as `C^{(k-1)-i} δ_{t+i}`, which contradicts its own Eq. 10
/// (`Â_t = C²Â_{t+2} + Cδ_{t+1} + δ_t`, i.e. coefficient `C^i` on
/// `δ_{t+i}`) and Table II. Expanding the recurrence confirms `C^i` is
/// correct: `A_t = δ_t + C·A_{t+1} = δ_t + Cδ_{t+1} + C²A_{t+2} = …`.
pub fn decomposition_max_error(c: f32, deltas: &[f32], k: usize) -> f32 {
    let t_len = deltas.len();
    // Sequential A.
    let mut a = vec![0.0f32; t_len + k]; // pad zeros past the end
    for t in (0..t_len).rev() {
        a[t] = deltas[t] + c * a[t + 1];
    }
    let mut max_err = 0.0f32;
    for t in 0..t_len {
        let mut rhs = c.powi(k as i32) * a[t + k];
        for i in 0..k {
            if t + i < t_len {
                rhs += c.powi(i as i32) * deltas[t + i];
            }
        }
        max_err = max_err.max((a[t] - rhs).abs());
    }
    max_err
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gae::reference::gae_trajectory;
    use crate::testing::check;

    #[test]
    fn table2_identities_hold() {
        // Table II: Â_{T-1} = CÂ_T + δ_{T-1}; Â_{T-2} = C²Â_T + Cδ_{T-1}
        // + δ_{T-2}; Â_{T-3} = C²Â_{T-1} + Cδ_{T-2} + δ_{T-3}; etc.
        check("table II decomposition", 50, |g| {
            let t_len = g.usize_in(4, 128);
            let deltas = g.vec_normal_f32(t_len, 0.0, 2.0);
            let c = g.f32_in(0.5, 1.0);
            for k in 1..=4 {
                let err = decomposition_max_error(c, &deltas, k);
                assert!(err < 2e-3, "k={k} err={err}");
            }
        });
    }

    #[test]
    fn lookahead_matches_reference_no_dones() {
        check("lookahead == sequential (no dones)", 40, |g| {
            let t_len = g.usize_in(1, 200);
            let k = g.usize_in(1, 8);
            let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
            let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
            let params = GaeParams::default();
            let traj = Trajectory::without_dones(rewards.clone(), values.clone());
            let want = gae_trajectory(&params, &traj);
            let got = gae_lookahead_no_dones(&params, &rewards, &values, k);
            for t in 0..t_len {
                assert!(
                    (got.advantages[t] - want.advantages[t]).abs() < 1e-3,
                    "t={t} k={k}: {} vs {}",
                    got.advantages[t],
                    want.advantages[t]
                );
            }
        });
    }

    #[test]
    fn lookahead_matches_reference_with_dones() {
        check("lookahead == sequential (dones)", 40, |g| {
            let t_len = g.usize_in(1, 96);
            let k = g.usize_in(1, 5);
            let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
            let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
            let dones: Vec<bool> = (0..t_len).map(|_| g.bool_p(0.15)).collect();
            let params = GaeParams::default();
            let traj = Trajectory::new(rewards, values, dones);
            let want = gae_trajectory(&params, &traj);
            let got = gae_lookahead(&params, &traj, k);
            for t in 0..t_len {
                assert!(
                    (got.advantages[t] - want.advantages[t]).abs() < 1e-3,
                    "t={t} k={k}"
                );
                assert!(
                    (got.rewards_to_go[t] - want.rewards_to_go[t]).abs() < 1e-3
                );
            }
        });
    }

    #[test]
    fn k1_is_plain_recurrence() {
        let params = GaeParams::default();
        let rewards = vec![1.0, -0.5, 2.0, 0.25];
        let values = vec![0.1, 0.2, 0.3, 0.4, 0.5];
        let traj = Trajectory::without_dones(rewards.clone(), values.clone());
        let want = gae_trajectory(&params, &traj);
        let got = gae_lookahead_no_dones(&params, &rewards, &values, 1);
        for t in 0..4 {
            assert!((got.advantages[t] - want.advantages[t]).abs() < 1e-6);
        }
    }

    #[test]
    fn k_larger_than_t() {
        let params = GaeParams::default();
        let rewards = vec![1.0, 2.0];
        let values = vec![0.0, 0.0, 0.0];
        let traj = Trajectory::without_dones(rewards.clone(), values.clone());
        let want = gae_trajectory(&params, &traj);
        let got = gae_lookahead_no_dones(&params, &rewards, &values, 16);
        for t in 0..2 {
            assert!((got.advantages[t] - want.advantages[t]).abs() < 1e-6);
        }
    }

    #[test]
    fn eq10_two_step_explicit() {
        // Eq. 10: Â_t = C²Â_{t+2} + Cδ_{t+1} + δ_t.
        let c = 0.9405f32; // γλ for defaults
        let deltas = [0.3f32, -1.2, 0.8, 2.0, -0.4];
        let err = decomposition_max_error(c, &deltas, 2);
        assert!(err < 1e-5);
    }
}

//! Scalar per-trajectory GAE — the baseline the paper measures against.
//!
//! This is the textbook backward loop (one trajectory at a time, element
//! by element, in reverse), structurally identical to the "standard GAE
//! implementation [17]" the paper profiles at ≈9000 elements/s on a
//! 32-core Xeon + V100 machine (§V-D-3). It is also the correctness
//! oracle for every other implementation (batched CPU, lookahead, the
//! Pallas kernel, and the cycle simulator).

use super::{GaeOutput, GaeParams, Trajectory};

/// The sequential recurrence (paper Eq. 4–5) over *indexed* accessors:
/// `reward(t)` for `t in 0..t_len`, `value(t)` for `t in 0..=t_len`
/// (`value(t_len)` bootstraps the tail), `done(t)` for `t in 0..t_len`.
///
/// This is the single scalar kernel behind both [`gae_trajectory`]
/// (contiguous per-trajectory buffers) and the serving subsystem's
/// borrowed plane columns (strided `[T, B]` views) — the accessor
/// indirection keeps the float expressions, and therefore the bits of
/// the result, identical across both layouts.
pub fn gae_indexed(
    params: &GaeParams,
    t_len: usize,
    reward: impl Fn(usize) -> f32,
    value: impl Fn(usize) -> f32,
    done: impl Fn(usize) -> bool,
) -> GaeOutput {
    let mut out = GaeOutput { advantages: Vec::new(), rewards_to_go: Vec::new() };
    gae_indexed_into(
        params,
        t_len,
        reward,
        value,
        done,
        &mut out.advantages,
        &mut out.rewards_to_go,
    );
    out
}

/// Scratch-reusing form of [`gae_indexed`]: outputs land in
/// caller-provided vectors (cleared + resized, capacity reused), so a
/// warmed caller performs zero allocations per pass.
pub fn gae_indexed_into(
    params: &GaeParams,
    t_len: usize,
    reward: impl Fn(usize) -> f32,
    value: impl Fn(usize) -> f32,
    done: impl Fn(usize) -> bool,
    advantages: &mut Vec<f32>,
    rewards_to_go: &mut Vec<f32>,
) {
    advantages.clear();
    advantages.resize(t_len, 0.0);
    rewards_to_go.clear();
    rewards_to_go.resize(t_len, 0.0);
    let mut carry = 0.0f32; // A_{t+1}
    for t in (0..t_len).rev() {
        let not_done = if done(t) { 0.0 } else { 1.0 };
        let v_t = value(t);
        let delta = reward(t) + params.gamma * value(t + 1) * not_done - v_t;
        carry = delta + params.c() * not_done * carry;
        advantages[t] = carry;
        rewards_to_go[t] = carry + v_t; // Eq. 5
    }
}

/// Compute advantages and rewards-to-go for one trajectory with the
/// sequential recurrence (paper Eq. 4–5).
pub fn gae_trajectory(params: &GaeParams, traj: &Trajectory) -> GaeOutput {
    gae_indexed(
        params,
        traj.len(),
        |t| traj.rewards[t],
        |t| traj.values[t],
        |t| traj.dones[t],
    )
}

/// Compute GAE for a list of trajectories sequentially — the exact shape
/// of the CPU baseline ("iterating over one trajectory at a time, not in
/// batch form", §V-D-3).
pub fn gae_sequential(params: &GaeParams, trajs: &[Trajectory]) -> Vec<GaeOutput> {
    trajs.iter().map(|t| gae_trajectory(params, t)).collect()
}

/// Direct evaluation of the infinite-sum definition (paper Eq. 3),
/// truncated at the trajectory end — O(T²), used only as a cross-check
/// oracle in tests.
pub fn gae_definition_oracle(params: &GaeParams, traj: &Trajectory) -> Vec<f32> {
    let t_len = traj.len();
    let mut deltas = vec![0.0f32; t_len];
    for t in 0..t_len {
        let not_done = if traj.dones[t] { 0.0 } else { 1.0 };
        deltas[t] = traj.rewards[t] + params.gamma * traj.values[t + 1] * not_done
            - traj.values[t];
    }
    let mut adv = vec![0.0f32; t_len];
    for t in 0..t_len {
        let mut acc = 0.0f64;
        let mut w = 1.0f64;
        for l in t..t_len {
            acc += w * deltas[l] as f64;
            if traj.dones[l] {
                break; // the episode ends; later deltas belong to the next episode
            }
            w *= params.c() as f64;
        }
        adv[t] = acc as f32;
    }
    adv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    fn random_trajectory(g: &mut Gen, t_len: usize, with_dones: bool) -> Trajectory {
        let rewards = g.vec_normal_f32(t_len, 0.0, 1.0);
        let values = g.vec_normal_f32(t_len + 1, 0.0, 1.0);
        let dones = (0..t_len)
            .map(|_| with_dones && g.bool_p(0.1))
            .collect();
        Trajectory::new(rewards, values, dones)
    }

    #[test]
    fn matches_definition_oracle_no_dones() {
        check("recurrence == truncated sum (no dones)", 50, |g| {
            let t_len = g.usize_in(1, 64);
            let traj = random_trajectory(g, t_len, false);
            let params = GaeParams::new(g.f32_in(0.8, 1.0), g.f32_in(0.8, 1.0));
            let out = gae_trajectory(&params, &traj);
            let oracle = gae_definition_oracle(&params, &traj);
            for t in 0..t_len {
                assert!(
                    (out.advantages[t] - oracle[t]).abs() < 1e-3,
                    "t={t} got={} want={}",
                    out.advantages[t],
                    oracle[t]
                );
            }
        });
    }

    #[test]
    fn matches_definition_oracle_with_dones() {
        check("recurrence == truncated sum (dones)", 50, |g| {
            let t_len = g.usize_in(1, 64);
            let traj = random_trajectory(g, t_len, true);
            let params = GaeParams::default();
            let out = gae_trajectory(&params, &traj);
            let oracle = gae_definition_oracle(&params, &traj);
            for t in 0..t_len {
                assert!((out.advantages[t] - oracle[t]).abs() < 1e-3, "t={t}");
            }
        });
    }

    #[test]
    fn known_small_case() {
        // T=2, gamma=1, lambda=1: delta_1 = r1 + v2 - v1, A_1 = delta_1;
        // A_0 = delta_0 + A_1.
        let params = GaeParams::new(1.0, 1.0);
        let traj = Trajectory::without_dones(vec![1.0, 2.0], vec![0.5, 1.5, 2.5]);
        let out = gae_trajectory(&params, &traj);
        let d1 = 2.0 + 2.5 - 1.5;
        let d0 = 1.0 + 1.5 - 0.5;
        assert!((out.advantages[1] - d1).abs() < 1e-6);
        assert!((out.advantages[0] - (d0 + d1)).abs() < 1e-6);
        // RTG = A + V (Eq. 5)
        assert!((out.rewards_to_go[0] - (d0 + d1 + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn terminal_blocks_bootstrap() {
        // done at t=0 must ignore values[1] entirely.
        let params = GaeParams::default();
        let traj = Trajectory::new(vec![3.0], vec![1.0, 100.0], vec![true]);
        let out = gae_trajectory(&params, &traj);
        assert!((out.advantages[0] - (3.0 - 1.0)).abs() < 1e-6);
    }

    #[test]
    fn terminal_splits_credit() {
        // With a done in the middle, advantage before the done must not
        // see rewards after it.
        let params = GaeParams::new(0.99, 0.95);
        let mut rewards = vec![0.0f32; 10];
        rewards[7] = 100.0; // big reward after the terminal at t=4
        let values = vec![0.0f32; 11];
        let mut dones = vec![false; 10];
        dones[4] = true;
        let traj = Trajectory::new(rewards, values, dones);
        let out = gae_trajectory(&params, &traj);
        for t in 0..=4 {
            assert!(
                out.advantages[t].abs() < 1e-6,
                "t={t} leaked credit {}",
                out.advantages[t]
            );
        }
        assert!(out.advantages[5] > 1.0);
    }

    #[test]
    fn empty_trajectory() {
        let params = GaeParams::default();
        let traj = Trajectory::without_dones(vec![], vec![0.0]);
        let out = gae_trajectory(&params, &traj);
        assert!(out.advantages.is_empty());
        assert!(out.rewards_to_go.is_empty());
    }

    #[test]
    fn lambda_zero_is_one_step_td() {
        // λ=0 ⇒ A_t = δ_t exactly.
        check("lambda=0 is TD(0)", 30, |g| {
            let t_len = g.usize_in(1, 32);
            let traj = random_trajectory(g, t_len, true);
            let params = GaeParams::new(0.99, 0.0);
            let out = gae_trajectory(&params, &traj);
            for t in 0..t_len {
                let nd = if traj.dones[t] { 0.0 } else { 1.0 };
                let delta = traj.rewards[t] + 0.99 * traj.values[t + 1] * nd
                    - traj.values[t];
                assert!((out.advantages[t] - delta).abs() < 1e-5);
            }
        });
    }
}

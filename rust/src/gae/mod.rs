//! Generalized Advantage Estimation (GAE) — the computation HEPPO-GAE
//! accelerates.
//!
//! The recurrence (paper Eq. 2–5), for discount γ and GAE parameter λ:
//!
//! ```text
//! δ_t  = r_t + γ·V(s_{t+1})·(1 - done_t) - V(s_t)       (TD residual)
//! A_t  = δ_t + γλ·(1 - done_t)·A_{t+1}                  (GAE, Eq. 4)
//! RTG_t = V(s_t) + A_t                                  (rewards-to-go, Eq. 5)
//! ```
//!
//! Three implementations, mirroring the paper's evaluation axis:
//!
//! - [`reference`] — the *scalar, per-trajectory* backward loop: the shape
//!   of the standard CPU implementation the paper benchmarks at ≈9000
//!   elements/s (their ref. [17]).
//! - [`batched`] — timestep-major batched processing of all trajectories
//!   at once: the software analogue of the 64-PE systolic row array.
//! - [`lookahead`] — the k-step lookahead decomposition (paper Table II
//!   and Eq. 10–12) that breaks the feedback loop for pipelining; also
//!   used by the Pallas kernel (L1) as its chunked-scan schedule.

pub mod batched;
pub mod lookahead;
pub mod reference;

/// GAE hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaeParams {
    /// Discount factor γ.
    pub gamma: f32,
    /// GAE exponential weight λ.
    pub lambda: f32,
}

impl GaeParams {
    pub fn new(gamma: f32, lambda: f32) -> Self {
        assert!((0.0..=1.0).contains(&gamma), "gamma out of range");
        assert!((0.0..=1.0).contains(&lambda), "lambda out of range");
        GaeParams { gamma, lambda }
    }

    /// The paper's constant `C = γ·λ` (Table II).
    #[inline]
    pub fn c(&self) -> f32 {
        self.gamma * self.lambda
    }
}

impl Default for GaeParams {
    /// The standard PPO setting (γ=0.99, λ=0.95).
    fn default() -> Self {
        GaeParams { gamma: 0.99, lambda: 0.95 }
    }
}

/// Output of a GAE pass.
#[derive(Debug, Clone, PartialEq)]
pub struct GaeOutput {
    /// Advantage estimates Â_t, same layout as the input rewards.
    pub advantages: Vec<f32>,
    /// Rewards-to-go (returns targets), same layout.
    pub rewards_to_go: Vec<f32>,
}

/// A single-trajectory GAE problem: `T` rewards, `T+1` values (the last
/// is the bootstrap value of the final state), and per-step terminal
/// flags.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub rewards: Vec<f32>,
    /// `len = rewards.len() + 1`; `values[T]` bootstraps the tail.
    pub values: Vec<f32>,
    /// `dones[t]` = episode terminated *at* step t (no bootstrap across).
    pub dones: Vec<bool>,
}

impl Trajectory {
    pub fn new(rewards: Vec<f32>, values: Vec<f32>, dones: Vec<bool>) -> Self {
        assert_eq!(values.len(), rewards.len() + 1, "values must have T+1 entries");
        assert_eq!(dones.len(), rewards.len(), "dones must have T entries");
        Trajectory { rewards, values, dones }
    }

    /// A trajectory with no mid-vector terminals (the hardware case: each
    /// systolic row receives one episode's vectors).
    pub fn without_dones(rewards: Vec<f32>, values: Vec<f32>) -> Self {
        let t = rewards.len();
        Trajectory::new(rewards, values, vec![false; t])
    }

    pub fn len(&self) -> usize {
        self.rewards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rewards.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_is_gamma_lambda() {
        let p = GaeParams::new(0.99, 0.95);
        assert!((p.c() - 0.9405).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "gamma out of range")]
    fn rejects_bad_gamma() {
        GaeParams::new(1.5, 0.9);
    }

    #[test]
    #[should_panic(expected = "values must have T+1")]
    fn trajectory_shape_checked() {
        Trajectory::new(vec![1.0; 4], vec![0.0; 4], vec![false; 4]);
    }
}

//! # HEPPO-GAE
//!
//! A reproduction of *HEPPO-GAE: Hardware-Efficient Proximal Policy
//! Optimization with Generalized Advantage Estimation* (Taha & Abdelhadi,
//! CS.AR 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the coordinator: PPO trainer, vectorized
//!   environment engine, SoC phase machine, quantized FILO trajectory
//!   memory, and a cycle-level simulator of the paper's FPGA
//!   microarchitecture ([`hwsim`]).
//! - **L2 (JAX, build-time)** — actor-critic forward + PPO-clip train
//!   step, AOT-lowered to HLO text artifacts loaded by [`runtime`].
//! - **L1 (Pallas, build-time)** — the GAE hot-spot as a k-step-lookahead
//!   blocked-scan kernel, lowered inside the same artifacts.
//!
//! Python never runs on the training path: `make artifacts` runs once,
//! after which the `heppo` binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | self-contained substrates: RNG, JSON, CSV, CLI, thread pool |
//! | [`stats`] | Welford running statistics, rolling windows, histograms |
//! | [`gae`] | GAE math: scalar reference, batched, k-step lookahead |
//! | [`quant`] | dynamic/block standardization + n-bit uniform quantization |
//! | [`memory`] | FILO BRAM stack layout, dual-port BRAM + DDR4 models |
//! | [`hwsim`] | cycle-level HEPPO-GAE simulator + resource/fmax model |
//! | [`envs`] | Rust-native RL environments + thread-pooled vector env |
//! | [`runtime`] | PJRT client wrapper: load + execute HLO artifacts |
//! | [`coordinator`] | the PPO training system (rollout, GAE stage, update) |
//! | [`service`] | GAE serving: dynamic batching, sharded workers, admission control |
//! | [`net`] | network front-end: quantized wire protocol, TCP server, pipelined client |
//! | [`obs`] | request-scoped tracing: span rings, trace-id propagation, Chrome-trace export |
//! | [`fabric`] | sharded service fleet: consistent-hash router, client pool, fleet metrics |
//! | [`bench`] | micro-benchmark harness used by `cargo bench` targets |
//! | [`testing`] | mini property-test harness used across the test suite |

pub mod bench;
pub mod coordinator;
pub mod envs;
pub mod fabric;
pub mod gae;
pub mod hwsim;
pub mod memory;
pub mod net;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod service;
pub mod stats;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! The fleet view: per-shard [`MetricsSnapshot`]s aggregated into one
//! picture of the whole fabric, with the per-tenant breakdown merged
//! across shards.
//!
//! In-process shards contribute their full service snapshot (queue,
//! batcher, tile, latency, per-tenant counters) directly; remote shards
//! answer the wire metrics RPC
//! ([`FRAME_TYPE_METRICS_REQUEST`](crate::net::wire)), so they
//! contribute the same full snapshot when reachable. A remote shard
//! that cannot answer — dead connection, pre-v3 peer — degrades to the
//! router's own counters (submitted, completed, failed-over) with its
//! service column reading `None`, which the aggregation treats as
//! "unknown", not zero.

use crate::obs::slo::SloHealth;
use crate::service::{MetricsSnapshot, TenantSnapshot};
use std::collections::HashMap;
use std::fmt;

/// One shard's slice of the fleet view.
#[derive(Debug, Clone)]
pub struct ShardStatus {
    pub label: String,
    /// Raw health flag (an unhealthy shard may still be probed once its
    /// cooldown elapses).
    pub healthy: bool,
    /// Router-side submit attempts against this shard.
    pub submitted: u64,
    /// Requests this shard completed.
    pub completed: u64,
    /// Requests this shard failed that another shard absorbed.
    pub failed_over: u64,
    /// Full service metrics: snapshotted directly for in-process
    /// shards, fetched over the wire metrics RPC for remote shards.
    /// `None` when a remote shard could not answer the RPC.
    pub service: Option<MetricsSnapshot>,
}

impl ShardStatus {
    /// The shard's SLO verdict as the fleet sees it: the snapshot's
    /// multi-window burn-rate health, overridden to `Critical` while
    /// the router has the shard marked unhealthy (a shard that cannot
    /// take traffic is failing its objective by definition), and
    /// degraded to `Warn` for a nominally-healthy remote shard that
    /// did not answer the metrics RPC (its burn rates are unknowable,
    /// which is not the same as fine).
    pub fn slo_health(&self) -> SloHealth {
        if !self.healthy {
            return SloHealth::Critical;
        }
        match &self.service {
            // A saturating quantizer is an objective violation the
            // latency burn rates cannot see — the numerics verdict
            // folds into the same chain, worst wins, so one tenant's
            // clipping planes page fleet-wide within a window.
            Some(m) => m.slo.health.max(m.numerics.health.to_slo()),
            None => SloHealth::Warn,
        }
    }
}

/// Aggregated point-in-time view of a [`GaeFabric`](crate::fabric::GaeFabric).
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    pub shards: Vec<ShardStatus>,
    /// Router-side submit attempts summed over shards.
    pub submitted: u64,
    /// Requests completed, summed over shards.
    pub completed: u64,
    /// Failover events, summed over shards.
    pub failed_over: u64,
    /// Shards currently marked healthy.
    pub healthy_shards: usize,
    /// GAE elements computed by in-process shards (their snapshots).
    pub elements: u64,
    /// Per-tenant breakdown merged across in-process shard snapshots,
    /// heaviest (by elements) first.
    pub tenants: Vec<TenantSnapshot>,
    /// Worst per-shard SLO verdict across the fleet (an operator pages
    /// on the worst shard, not the average one); `Ok` for an empty
    /// fleet.
    pub health: SloHealth,
}

impl FleetSnapshot {
    /// Fold per-shard statuses into fleet totals and the merged
    /// per-tenant breakdown.
    pub fn aggregate(shards: Vec<ShardStatus>) -> FleetSnapshot {
        let submitted = shards.iter().map(|s| s.submitted).sum();
        let completed = shards.iter().map(|s| s.completed).sum();
        let failed_over = shards.iter().map(|s| s.failed_over).sum();
        let healthy_shards = shards.iter().filter(|s| s.healthy).count();
        let elements = shards
            .iter()
            .filter_map(|s| s.service.as_ref())
            .map(|m| m.elements)
            .sum();
        let tenants = merge_tenants(
            shards
                .iter()
                .filter_map(|s| s.service.as_ref())
                .flat_map(|m| m.tenants.iter()),
        );
        let health = shards
            .iter()
            .map(|s| s.slo_health())
            .max()
            .unwrap_or(SloHealth::Ok);
        FleetSnapshot {
            shards,
            submitted,
            completed,
            failed_over,
            healthy_shards,
            elements,
            tenants,
            health,
        }
    }
}

/// Merge tenant slices from many shard snapshots: counters sum per
/// tenant id; the result sorts heaviest (by elements) first with the
/// name as a deterministic tie-break.
pub fn merge_tenants<'a>(
    slices: impl Iterator<Item = &'a TenantSnapshot>,
) -> Vec<TenantSnapshot> {
    let mut merged: HashMap<String, TenantSnapshot> = HashMap::new();
    for t in slices {
        match merged.get_mut(&t.tenant) {
            Some(m) => {
                m.requests += t.requests;
                m.elements += t.elements;
                m.shed += t.shed;
                m.quota_shed += t.quota_shed;
                m.auth_rejected += t.auth_rejected;
                m.quant_planes += t.quant_planes;
                m.quant_elements += t.quant_elements;
                m.quant_clipped += t.quant_clipped;
                // Rates and verdicts don't sum: an operator pages on
                // the tenant's worst shard.
                m.quant_saturation_1s = m.quant_saturation_1s.max(t.quant_saturation_1s);
                m.numerics_health = m.numerics_health.max(t.numerics_health);
                m.wire_payload_bytes += t.wire_payload_bytes;
                m.wire_f32_bytes += t.wire_f32_bytes;
            }
            None => {
                merged.insert(t.tenant.clone(), t.clone());
            }
        }
    }
    let mut out: Vec<TenantSnapshot> = merged.into_values().collect();
    out.sort_by(|a, b| {
        b.elements.cmp(&a.elements).then_with(|| a.tenant.cmp(&b.tenant))
    });
    out
}

impl fmt::Display for FleetSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "fleet:    {} shards ({} healthy) | slo {} | {} submitted, {} completed, {} failed over | {} elements (in-process)",
            self.shards.len(),
            self.healthy_shards,
            self.health.as_str(),
            self.submitted,
            self.completed,
            self.failed_over,
            self.elements,
        )?;
        for s in &self.shards {
            writeln!(
                f,
                "  {:<12} {} slo:{} | {} submitted / {} completed / {} failed over{}",
                s.label,
                if s.healthy { "healthy" } else { "UNHEALTHY" },
                s.slo_health().as_str(),
                s.submitted,
                s.completed,
                s.failed_over,
                match &s.service {
                    Some(m) => {
                        let w = m.window(10);
                        let numerics = if m.numerics.planes > 0 {
                            format!(
                                " | num:{} sat(1s) {:.2}%",
                                m.numerics.health.as_str(),
                                m.numerics.window(1).saturation_rate * 100.0,
                            )
                        } else {
                            String::new()
                        };
                        format!(
                            " | {} elem, queue {}, shed {} | {:.1} rps / p99 {:.0}µs (10s){}",
                            m.elements,
                            m.queue_depth,
                            m.shed,
                            w.rate_rps,
                            w.total_us.p99,
                            numerics,
                        )
                    }
                    None => " | remote".to_string(),
                },
            )?;
        }
        if self.tenants.is_empty() {
            write!(f, "  tenants: none attributed")?;
        } else {
            write!(f, "  tenants:")?;
            for t in self.tenants.iter().take(6) {
                write!(
                    f,
                    " {}: {} req / {} elem ({} shed, {} quota)",
                    t.tenant, t.requests, t.elements, t.shed, t.quota_shed
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tenant(name: &str, requests: u64, elements: u64) -> TenantSnapshot {
        TenantSnapshot {
            tenant: name.to_string(),
            requests,
            elements,
            shed: 0,
            quota_shed: 0,
            auth_rejected: 0,
            quant_planes: 0,
            quant_elements: 0,
            quant_clipped: 0,
            quant_saturation_1s: 0.0,
            numerics_health: crate::obs::numerics::NumericsHealth::Ok,
            wire_payload_bytes: 0,
            wire_f32_bytes: 0,
        }
    }

    fn status(label: &str, completed: u64, tenants: Vec<TenantSnapshot>) -> ShardStatus {
        // A service snapshot solely to carry tenants/elements: build it
        // from a live recorder so the struct stays construction-honest.
        let m = crate::service::ServiceMetrics::new();
        for t in &tenants {
            for _ in 0..t.requests {
                m.record_tenant_request(&t.tenant, t.elements / t.requests.max(1));
            }
        }
        let snap = m.snapshot(crate::service::SnapshotInputs::default());
        ShardStatus {
            label: label.to_string(),
            healthy: true,
            submitted: completed,
            completed,
            failed_over: 0,
            service: Some(snap),
        }
    }

    #[test]
    fn aggregate_sums_shards_and_merges_tenants() {
        let fleet = FleetSnapshot::aggregate(vec![
            status("s0", 3, vec![tenant("a", 2, 20), tenant("b", 1, 5)]),
            status("s1", 2, vec![tenant("a", 1, 10)]),
        ]);
        assert_eq!(fleet.completed, 5);
        assert_eq!(fleet.healthy_shards, 2);
        assert_eq!(fleet.tenants.len(), 2);
        assert_eq!(fleet.tenants[0].tenant, "a", "heaviest tenant first");
        assert_eq!(fleet.tenants[0].requests, 3);
        assert_eq!(fleet.tenants[0].elements, 30);
        let text = fleet.to_string();
        assert!(text.contains("2 shards") && text.contains("tenants:"), "{text}");
    }

    #[test]
    fn merge_is_deterministic_under_ties() {
        let a = vec![tenant("x", 1, 10), tenant("y", 1, 10)];
        let merged = merge_tenants(a.iter());
        assert_eq!(merged[0].tenant, "x");
        assert_eq!(merged[1].tenant, "y");
    }

    #[test]
    fn unreachable_remote_shards_contribute_router_counters_only() {
        // A remote shard whose metrics RPC failed reports `service:
        // None`; its router counters still land in the totals.
        let fleet = FleetSnapshot::aggregate(vec![ShardStatus {
            label: "remote-0".to_string(),
            healthy: false,
            submitted: 7,
            completed: 6,
            failed_over: 1,
            service: None,
        }]);
        assert_eq!(fleet.submitted, 7);
        assert_eq!(fleet.elements, 0);
        assert_eq!(fleet.healthy_shards, 0);
        assert!(fleet.tenants.is_empty());
        assert!(fleet.to_string().contains("UNHEALTHY"));
        // An unhealthy shard is Critical regardless of its last snapshot.
        assert_eq!(fleet.health, SloHealth::Critical);
    }

    #[test]
    fn fleet_health_is_the_worst_shard_verdict() {
        let ok = status("s0", 3, vec![]);
        assert_eq!(ok.slo_health(), SloHealth::Ok);

        // Healthy but silent remote: burn rates unknowable → Warn.
        let silent = ShardStatus {
            label: "remote-0".to_string(),
            healthy: true,
            submitted: 1,
            completed: 1,
            failed_over: 0,
            service: None,
        };
        assert_eq!(silent.slo_health(), SloHealth::Warn);

        let fleet = FleetSnapshot::aggregate(vec![ok.clone(), silent]);
        assert_eq!(fleet.health, SloHealth::Warn, "worst shard wins");
        assert!(fleet.to_string().contains("slo warn"), "{fleet}");

        let empty = FleetSnapshot::aggregate(vec![]);
        assert_eq!(empty.health, SloHealth::Ok);

        let down = ShardStatus { healthy: false, ..ok.clone() };
        let fleet = FleetSnapshot::aggregate(vec![ok, down]);
        assert_eq!(fleet.health, SloHealth::Critical);
        assert!(fleet.to_string().contains("slo:critical"), "{fleet}");
    }

    #[test]
    fn numerics_verdict_folds_into_fleet_health() {
        use crate::obs::numerics::{NumericsHealth, PlaneNumerics};
        // One shard whose quantizer is saturating: its SLO burn rates
        // are clean, but the numerics verdict must page the fleet.
        let m = crate::service::ServiceMetrics::new();
        let mut pn = PlaneNumerics::default();
        pn.set_block(0.0, 1.0);
        for i in 0..512u16 {
            // Every 8th element on an end code → 12.5% saturation.
            pn.note_code(if i % 8 == 0 { 255 } else { 100 + i % 16 }, 8);
        }
        m.record_plane_numerics("hot", &pn, 0);
        let snap = m.snapshot(crate::service::SnapshotInputs::default());
        assert_eq!(snap.numerics.health, NumericsHealth::Critical);

        let saturating = ShardStatus {
            label: "s-sat".to_string(),
            healthy: true,
            submitted: 1,
            completed: 1,
            failed_over: 0,
            service: Some(snap),
        };
        assert_eq!(saturating.slo_health(), SloHealth::Critical);
        let fleet = FleetSnapshot::aggregate(vec![status("s-ok", 2, vec![]), saturating]);
        assert_eq!(fleet.health, SloHealth::Critical);
        assert!(fleet.to_string().contains("num:critical"), "{fleet}");

        // The saturating tenant's row survives the fleet merge with its
        // verdict and counters intact.
        let t = fleet.tenants.iter().find(|t| t.tenant == "hot").unwrap();
        assert_eq!(t.quant_planes, 1);
        assert_eq!(t.quant_elements, 512);
        assert_eq!(t.numerics_health, NumericsHealth::Critical);
    }
}

//! The shard router: rendezvous hashing over `(tenant, trajectory
//! key)` across N GAE shards, with health-tracked failover.
//!
//! ## Routing
//!
//! Every request carries a routing key; the router scores each shard
//! with an FNV-1a rendezvous hash over `(tenant, key, shard index)` and
//! prefers shards in descending score order ([`GaeFabric::rank`]).
//! Rendezvous (highest-random-weight) hashing gives the two properties
//! a shard fleet needs and a modulo hash lacks:
//!
//! - **Stability** — adding or removing one shard remaps only the keys
//!   that scored highest on it (~1/N of traffic), not everything.
//! - **A total failover order** — the rank vector *is* the spill
//!   chain: when a shard sheds or its connection drops, the request
//!   moves to the next-ranked shard, so one dead shard's key range
//!   spreads evenly over the survivors instead of dogpiling one.
//!
//! ## Health and failover
//!
//! A shard that sheds (`Overloaded`), refuses (`ShuttingDown`), or
//! drops its connection is marked unhealthy and skipped by routing
//! until a cooldown elapses; after the cooldown one request whose rank
//! prefers it probes it again (half-open), re-marking it on failure and
//! fully restoring it on success. Failures *after* admission — a shard
//! dying with the request in flight — are retried through the same
//! rank order by [`FabricPending::wait`], bounded by
//! [`FabricConfig::max_attempts`], so "every submitted request
//! completes" holds as long as any shard survives. Replication is
//! deliberately absent (see ROADMAP): a request lives on exactly one
//! shard at a time, and failover re-computes rather than re-reads.
//!
//! Results are **bit-identical** to the in-process scalar path for f32
//! transport regardless of which shard served them — every shard runs
//! the same service compute ([`crate::service`]), and the integration
//! suite (`tests/fabric_integration.rs`) pins that down across forced
//! mid-load failovers.

use crate::fabric::fleet::{FleetSnapshot, ShardStatus};
use crate::fabric::pool::{ClientPool, PoolClient, PoolConfig, PoolPending};
use crate::net::client::NetError;
use crate::service::{GaeService, PlaneSet, PlanesPending, ServiceError};
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Fabric deployment knobs.
#[derive(Debug, Clone, Copy)]
pub struct FabricConfig {
    /// How long an unhealthy shard sits out before one request probes
    /// it again (half-open recovery).
    pub cooldown: Duration,
    /// Submit attempts per request across the whole fleet before
    /// [`FabricError::Exhausted`]; `0` = twice the shard count.
    pub max_attempts: usize,
    /// Per-attempt deadline for remote shards: a shard that holds a
    /// request longer than this is treated exactly like a dropped
    /// connection — marked unhealthy and failed over — rather than
    /// stalling the caller behind one wedged peer. `None` (the default)
    /// waits indefinitely, preserving the pre-deadline behavior.
    /// In-process shards are not subject to the deadline: their worker
    /// pool cannot silently lose a request the way a network peer can.
    pub request_timeout: Option<Duration>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            cooldown: Duration::from_millis(500),
            max_attempts: 0,
            request_timeout: None,
        }
    }
}

/// Where one shard's compute lives.
pub enum ShardBackend {
    /// A service in this process (the sharded-trainer shape).
    InProcess(Arc<GaeService>),
    /// A remote TCP endpoint behind a connection-multiplexing pool.
    Remote {
        pool: ClientPool,
        /// One pooled submitter per tenant, created on demand,
        /// LRU-bounded like the quota and tenant-metrics maps.
        submitters: Mutex<SubmitterCache>,
    },
}

/// Most per-tenant submitters cached per remote shard. At the cap the
/// longest-untouched tenant's submitter is evicted (O(n), only on a new
/// tenant at the cap) — an *active* tenant is by definition recently
/// touched, so eviction lands on idle submitters; a dropped submitter
/// deregisters its seq space, and any frame somehow still in flight
/// fails over through the router rather than hanging.
const MAX_CACHED_SUBMITTERS: usize = 4096;

/// Tenant → (submitter, last-touch tick), bounded at
/// [`MAX_CACHED_SUBMITTERS`].
#[derive(Default)]
pub struct SubmitterCache {
    map: HashMap<String, (Arc<PoolClient>, u64)>,
    tick: u64,
}

impl SubmitterCache {
    fn get_or_insert(
        &mut self,
        tenant: &str,
        make: impl FnOnce() -> PoolClient,
    ) -> Arc<PoolClient> {
        self.tick += 1;
        let tick = self.tick;
        if let Some((s, last)) = self.map.get_mut(tenant) {
            *last = tick;
            return Arc::clone(s);
        }
        if self.map.len() >= MAX_CACHED_SUBMITTERS {
            if let Some(stalest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, last))| *last)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&stalest);
            }
        }
        let s = Arc::new(make());
        self.map.insert(tenant.to_string(), (Arc::clone(&s), tick));
        s
    }
}

impl ShardBackend {
    /// An in-process shard over an `Arc`-shared service.
    pub fn in_process(service: Arc<GaeService>) -> ShardBackend {
        ShardBackend::InProcess(service)
    }

    /// Dial a remote shard endpoint.
    pub fn remote(addr: &str, pool: PoolConfig) -> anyhow::Result<ShardBackend> {
        Ok(ShardBackend::Remote {
            pool: ClientPool::connect(addr, pool)?,
            submitters: Mutex::new(SubmitterCache::default()),
        })
    }
}

/// How long one half-open probe may hold the probe slot before it is
/// presumed lost and another request may probe. Longer than the pool's
/// dial timeout so a hung probe cannot wedge recovery, short enough
/// that an abandoned claim (the probing request succeeded elsewhere
/// first) delays the next probe by seconds, not forever.
const PROBE_GRACE: Duration = Duration::from_secs(5);

/// Health timestamps of one shard, behind one short mutex.
#[derive(Debug, Default)]
struct HealthTimes {
    /// Last failure (re-armed by every failed probe).
    failed_at: Option<Instant>,
    /// A half-open probe currently holds the slot (set when routing
    /// lets one request through to an unhealthy shard).
    probe_started: Option<Instant>,
}

/// One shard slot: backend + health + counters.
pub(crate) struct Shard {
    pub(crate) label: String,
    pub(crate) backend: ShardBackend,
    healthy: AtomicBool,
    times: Mutex<HealthTimes>,
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) failed_over: AtomicU64,
}

impl Shard {
    fn new(label: String, backend: ShardBackend) -> Shard {
        Shard {
            label,
            backend,
            healthy: AtomicBool::new(true),
            times: Mutex::new(HealthTimes::default()),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed_over: AtomicU64::new(0),
        }
    }

    pub(crate) fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    /// Routable now: healthy, or unhealthy with the cooldown elapsed
    /// AND the half-open probe slot free — in which case the caller
    /// *claims* the slot, so exactly one request probes a recovering
    /// shard instead of a thundering herd piling onto a possibly-dead
    /// connection. The claim self-expires after [`PROBE_GRACE`] in case
    /// the claiming request never actually reaches the shard.
    fn routable(&self, cooldown: Duration) -> bool {
        if self.is_healthy() {
            return true;
        }
        let mut t = self.times.lock().unwrap();
        let cooled = match t.failed_at {
            Some(at) => at.elapsed() >= cooldown,
            None => true,
        };
        if !cooled {
            return false;
        }
        match t.probe_started {
            Some(since) if since.elapsed() < PROBE_GRACE => false,
            _ => {
                t.probe_started = Some(Instant::now());
                true
            }
        }
    }

    fn mark_unhealthy(&self) {
        self.healthy.store(false, Ordering::Release);
        let mut t = self.times.lock().unwrap();
        t.failed_at = Some(Instant::now());
        t.probe_started = None;
    }

    fn mark_healthy(&self) {
        self.healthy.store(true, Ordering::Release);
        let mut t = self.times.lock().unwrap();
        t.failed_at = None;
        t.probe_started = None;
    }

    fn submitter_for(&self, tenant: &str) -> Option<Arc<PoolClient>> {
        match &self.backend {
            ShardBackend::InProcess(_) => None,
            ShardBackend::Remote { pool, submitters } => Some(
                submitters
                    .lock()
                    .unwrap()
                    .get_or_insert(tenant, || pool.submitter(tenant)),
            ),
        }
    }
}

/// Why a fabric request failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FabricError {
    /// The request is invalid everywhere (bad geometry, non-binary
    /// mask, tenant over quota): retrying it on another shard can never
    /// succeed.
    Rejected(String),
    /// Every submit attempt across the fleet failed.
    Exhausted { attempts: usize, last: String },
}

impl fmt::Display for FabricError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FabricError::Rejected(e) => write!(f, "request rejected (not retryable): {e}"),
            FabricError::Exhausted { attempts, last } => write!(
                f,
                "all shards refused after {attempts} attempts (last: {last})"
            ),
        }
    }
}

impl std::error::Error for FabricError {}

/// One request's planes, shared so a failover can resubmit without the
/// submitter keeping its own copy alive.
#[derive(Debug)]
struct FabricPayload {
    t_len: usize,
    batch: usize,
    rewards: Vec<f32>,
    values: Vec<f32>,
    done_mask: Vec<f32>,
    /// Trace id minted once at [`GaeFabric::submit`]; every submit
    /// attempt — including failover resubmits to other shards — carries
    /// the same id, so a request that crosses shards still renders as
    /// one causal timeline (`0` = untraced).
    trace: u64,
}

impl FabricPayload {
    /// Mirror of [`PlaneSet::new`]'s checks, run once at the fabric
    /// boundary so an invalid request is a [`FabricError::Rejected`]
    /// before any shard (or clone) is touched.
    fn validate(&self) -> Result<(), FabricError> {
        let reject = |e: ServiceError| Err(FabricError::Rejected(e.to_string()));
        if self.t_len == 0 || self.batch == 0 {
            return reject(ServiceError::EmptyRequest);
        }
        let n = self.t_len * self.batch;
        if self.rewards.len() != n {
            return reject(ServiceError::ShapeMismatch {
                plane: "rewards",
                got: self.rewards.len(),
                want: n,
            });
        }
        if self.values.len() != (self.t_len + 1) * self.batch {
            return reject(ServiceError::ShapeMismatch {
                plane: "values",
                got: self.values.len(),
                want: (self.t_len + 1) * self.batch,
            });
        }
        if self.done_mask.len() != n {
            return reject(ServiceError::ShapeMismatch {
                plane: "done_mask",
                got: self.done_mask.len(),
                want: n,
            });
        }
        if let Some(index) =
            self.done_mask.iter().position(|&d| d != 0.0 && d != 1.0)
        {
            return reject(ServiceError::NonBinaryDoneMask { index });
        }
        Ok(())
    }

    fn elements(&self) -> u64 {
        (self.t_len * self.batch) as u64
    }
}

/// An admitted request sitting on one shard.
enum Attempt {
    InProcess(PlanesPending),
    Remote(PoolPending),
}

enum TryFail {
    /// Shard-local failure: mark unhealthy, spill to the next shard.
    Retryable(String),
    /// Request-level failure: no shard will accept it.
    Fatal(String),
}

pub(crate) struct FabricInner {
    pub(crate) shards: Vec<Shard>,
    config: FabricConfig,
}

impl FabricInner {
    fn max_attempts(&self) -> usize {
        if self.config.max_attempts > 0 {
            self.config.max_attempts
        } else {
            (self.shards.len() * 2).max(2)
        }
    }

    /// Rendezvous score of `shard` for `(tenant, key)`.
    fn score(tenant: &str, key: u64, shard: usize) -> u64 {
        let mut h = crate::net::wire::Fnv1a::new();
        h.write(tenant.as_bytes());
        h.write_u8(0xFE); // domain separator: tenant bytes never alias the key
        h.write_u64(key);
        h.write_u64(shard as u64);
        h.finish()
    }

    /// Shard preference order for `(tenant, key)`: descending rendezvous
    /// score, index as the deterministic tie-break.
    fn rank(&self, tenant: &str, key: u64) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.shards.len()).collect();
        order.sort_by_key(|&s| (std::cmp::Reverse(Self::score(tenant, key, s)), s));
        order
    }

    /// One submit attempt against one shard.
    fn try_shard(
        &self,
        idx: usize,
        tenant: &str,
        payload: &FabricPayload,
    ) -> Result<Attempt, TryFail> {
        let shard = &self.shards[idx];
        shard.submitted.fetch_add(1, Ordering::Relaxed);
        // One instant per attempt: a failover shows up as two (or more)
        // `fabric.attempt` events under the same trace id.
        crate::obs::instant("fabric.attempt", payload.trace);
        match &shard.backend {
            ShardBackend::InProcess(svc) => {
                // Validated at the fabric boundary, so this cannot fail.
                let planes = PlaneSet::new(
                    payload.t_len,
                    payload.batch,
                    payload.rewards.clone(),
                    payload.values.clone(),
                    payload.done_mask.clone(),
                )
                .map_err(|e| TryFail::Fatal(e.to_string()))?;
                // Fail-fast admission: a shedding shard spills instead
                // of stalling the submitter.
                match svc.try_submit_plane_set_traced(planes, payload.trace) {
                    // Per-tenant accounting happens at *completion*
                    // (the wait path), so a request that fails over
                    // mid-flight is never double-counted.
                    Ok(pending) => Ok(Attempt::InProcess(pending)),
                    Err(e @ ServiceError::Overloaded { .. }) => {
                        svc.metrics_handle().record_tenant_shed(tenant);
                        Err(TryFail::Retryable(e.to_string()))
                    }
                    Err(e @ ServiceError::ShuttingDown) => {
                        Err(TryFail::Retryable(e.to_string()))
                    }
                    Err(e) => Err(TryFail::Fatal(e.to_string())),
                }
            }
            ShardBackend::Remote { .. } => {
                let submitter = shard
                    .submitter_for(tenant)
                    .expect("remote backend always yields a submitter");
                match submitter.submit_planes_traced(
                    payload.t_len,
                    payload.batch,
                    &payload.rewards,
                    &payload.values,
                    &payload.done_mask,
                    payload.trace,
                ) {
                    Ok(pending) => Ok(Attempt::Remote(pending)),
                    Err(NetError::InvalidRequest(e)) => Err(TryFail::Fatal(e)),
                    Err(e) => Err(TryFail::Retryable(e.to_string())),
                }
            }
        }
    }

    /// Walk the rank order — available shards first, desperation probes
    /// of cooling-down shards after — until one admits the request or
    /// the attempt budget runs out. `exclude` skips the shard a retry
    /// just watched fail.
    fn submit_with_budget(
        &self,
        tenant: &str,
        key: u64,
        payload: &FabricPayload,
        attempts_used: &mut usize,
        exclude: Option<usize>,
    ) -> Result<(usize, Attempt), FabricError> {
        let budget = self.max_attempts();
        let order = self.rank(tenant, key);
        // Routability is evaluated exactly once per shard: `routable`
        // claims the half-open probe slot as a side effect, so calling
        // it twice would burn a second claim.
        let mut routable = Vec::new();
        let mut desperate = Vec::new();
        for &s in &order {
            if Some(s) == exclude {
                continue;
            }
            if self.shards[s].routable(self.config.cooldown) {
                routable.push(s);
            } else {
                // Last resort only: tried when every routable shard
                // refused, rather than skipped outright.
                desperate.push(s);
            }
        }
        let candidates: Vec<usize> = routable.into_iter().chain(desperate).collect();
        let mut last = "no routable shard".to_string();
        for s in candidates {
            if *attempts_used >= budget {
                break;
            }
            *attempts_used += 1;
            match self.try_shard(s, tenant, payload) {
                Ok(attempt) => return Ok((s, attempt)),
                Err(TryFail::Retryable(e)) => {
                    self.shards[s].mark_unhealthy();
                    self.shards[s].failed_over.fetch_add(1, Ordering::Relaxed);
                    last = format!("{} ({e})", self.shards[s].label);
                }
                Err(TryFail::Fatal(e)) => return Err(FabricError::Rejected(e)),
            }
        }
        Err(FabricError::Exhausted { attempts: *attempts_used, last })
    }
}

/// A horizontally sharded GAE fleet behind one submit API: requests
/// route by rendezvous hash over `(tenant, key)`, spill to the
/// next-ranked shard on failure, and return results bit-identical to
/// the single-service path. Cheap to clone (`Arc` inside).
#[derive(Clone)]
pub struct GaeFabric {
    inner: Arc<FabricInner>,
}

impl GaeFabric {
    /// Build a fabric over `(label, backend)` shard slots.
    pub fn new(
        shards: Vec<(String, ShardBackend)>,
        config: FabricConfig,
    ) -> anyhow::Result<GaeFabric> {
        anyhow::ensure!(!shards.is_empty(), "fabric needs at least one shard");
        let shards = shards
            .into_iter()
            .map(|(label, backend)| Shard::new(label, backend))
            .collect();
        Ok(GaeFabric { inner: Arc::new(FabricInner { shards, config }) })
    }

    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    pub fn shard_label(&self, idx: usize) -> &str {
        &self.inner.shards[idx].label
    }

    /// The shard's raw health flag (not probe eligibility).
    pub fn is_healthy(&self, idx: usize) -> bool {
        self.inner.shards[idx].is_healthy()
    }

    /// Shard preference order for `(tenant, key)` — index 0 is the
    /// primary, the rest is the spill chain.
    pub fn rank(&self, tenant: &str, key: u64) -> Vec<usize> {
        self.inner.rank(tenant, key)
    }

    /// Route one plane-shaped request into the fleet. Returns once a
    /// shard admits it; [`FabricPending::wait`] completes it, retrying
    /// through the spill chain if the serving shard dies mid-flight.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &self,
        tenant: &str,
        key: u64,
        t_len: usize,
        batch: usize,
        rewards: Vec<f32>,
        values: Vec<f32>,
        done_mask: Vec<f32>,
    ) -> Result<FabricPending, FabricError> {
        // Minted once here; failover resubmits reuse it so the whole
        // request — across any number of shard attempts — is one trace.
        let trace =
            if crate::obs::enabled() { crate::obs::mint_trace_id() } else { 0 };
        let payload = Arc::new(FabricPayload {
            t_len,
            batch,
            rewards,
            values,
            done_mask,
            trace,
        });
        payload.validate()?;
        let mut attempts_used = 0;
        let (shard, attempt) = self.inner.submit_with_budget(
            tenant,
            key,
            &payload,
            &mut attempts_used,
            None,
        )?;
        Ok(FabricPending {
            inner: Arc::clone(&self.inner),
            tenant: tenant.to_string(),
            key,
            payload,
            shard,
            attempt,
            attempts_used,
            failovers: attempts_used.saturating_sub(1) as u32,
        })
    }

    /// Synchronous convenience: submit and wait.
    #[allow(clippy::too_many_arguments)]
    pub fn call(
        &self,
        tenant: &str,
        key: u64,
        t_len: usize,
        batch: usize,
        rewards: Vec<f32>,
        values: Vec<f32>,
        done_mask: Vec<f32>,
    ) -> Result<FabricGae, FabricError> {
        self.submit(tenant, key, t_len, batch, rewards, values, done_mask)?.wait()
    }

    /// Point-in-time fleet view: per-shard status plus aggregated
    /// totals and the merged per-tenant breakdown. Each shard carries
    /// its windowed rates and SLO burn-rate verdict (unhealthy shards
    /// read `Critical` regardless of their last snapshot), and the
    /// snapshot's `health` is the worst verdict in the fleet.
    pub fn fleet(&self) -> FleetSnapshot {
        let shards: Vec<ShardStatus> = self
            .inner
            .shards
            .iter()
            .map(|s| ShardStatus {
                label: s.label.clone(),
                healthy: s.is_healthy(),
                submitted: s.submitted.load(Ordering::Relaxed),
                completed: s.completed.load(Ordering::Relaxed),
                failed_over: s.failed_over.load(Ordering::Relaxed),
                service: match &s.backend {
                    ShardBackend::InProcess(svc) => Some(svc.metrics()),
                    // Full snapshot over the metrics RPC; a shard that
                    // cannot answer (dead, pre-v3 peer) reports `None`
                    // and still contributes its router-side counters.
                    ShardBackend::Remote { pool, .. } => pool.fetch_metrics().ok(),
                },
            })
            .collect();
        FleetSnapshot::aggregate(shards)
    }
}

/// A completed fabric request.
#[derive(Debug, Clone)]
pub struct FabricGae {
    /// `[T * B]` advantages, timestep-major.
    pub advantages: Vec<f32>,
    /// `[T * B]` rewards-to-go, timestep-major.
    pub rewards_to_go: Vec<f32>,
    pub hw_cycles: Option<u64>,
    /// A remote shard answered from its response cache (always `false`
    /// for in-process shards, which sit below the network cache).
    pub cache_hit: bool,
    /// Shard that ultimately served the request.
    pub shard: usize,
    /// Shards this request had to leave before completing (0 = the
    /// primary served it).
    pub failovers: u32,
}

enum Outcome {
    Done {
        advantages: Vec<f32>,
        rewards_to_go: Vec<f32>,
        hw_cycles: Option<u64>,
        cache_hit: bool,
    },
    Retry(String),
    Fatal(String),
}

/// One in-flight fabric request. Dropping it abandons the result
/// (computed and discarded, like a dropped service handle).
pub struct FabricPending {
    inner: Arc<FabricInner>,
    tenant: String,
    key: u64,
    payload: Arc<FabricPayload>,
    shard: usize,
    attempt: Attempt,
    attempts_used: usize,
    failovers: u32,
}

impl FabricPending {
    /// The shard currently holding the request.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until a shard completes the request, spilling to the next
    /// ranked shard if the serving one dies mid-flight. Fails only when
    /// the request is invalid ([`FabricError::Rejected`]) or every
    /// shard refused within the attempt budget
    /// ([`FabricError::Exhausted`]).
    pub fn wait(self) -> Result<FabricGae, FabricError> {
        let FabricPending {
            inner,
            tenant,
            key,
            payload,
            mut shard,
            mut attempt,
            mut attempts_used,
            mut failovers,
        } = self;
        loop {
            let outcome = match attempt {
                Attempt::InProcess(pending) => match pending.wait() {
                    Ok(gae) => Outcome::Done {
                        advantages: gae.advantages,
                        rewards_to_go: gae.rewards_to_go,
                        hw_cycles: gae.hw_cycles,
                        cache_hit: false,
                    },
                    // The service died with the request in flight; the
                    // computation is lost, not the request.
                    Err(e @ ServiceError::ShuttingDown) => {
                        Outcome::Retry(e.to_string())
                    }
                    Err(e) => Outcome::Fatal(e.to_string()),
                },
                Attempt::Remote(pending) => {
                    let waited = match inner.config.request_timeout {
                        Some(deadline) => pending.wait_timeout(deadline),
                        None => pending.wait(),
                    };
                    match waited {
                        Ok(gae) => Outcome::Done {
                            advantages: gae.advantages,
                            rewards_to_go: gae.rewards_to_go,
                            hw_cycles: gae.hw_cycles,
                            cache_hit: gae.cache_hit,
                        },
                        Err(e) => match &e {
                            // Request-level refusals follow the request.
                            NetError::InvalidRequest(_) => {
                                Outcome::Fatal(e.to_string())
                            }
                            NetError::Remote { kind, .. } => match kind {
                                // An auth refusal is a deployment-wide
                                // misconfiguration (wrong or missing
                                // token): every shard shares the key, so
                                // retrying elsewhere only spends this
                                // connection's strike budget on the
                                // whole fleet.
                                crate::net::ErrorKind::Quota
                                | crate::net::ErrorKind::Malformed
                                | crate::net::ErrorKind::Auth => {
                                    Outcome::Fatal(e.to_string())
                                }
                                // Shed/shutdown/internal: shard-local.
                                _ => Outcome::Retry(e.to_string()),
                            },
                            // Dead socket, undecodable frame, or an
                            // elapsed deadline ([`NetError::Timeout`]):
                            // shard-local — the request fails over as if
                            // the connection had dropped.
                            _ => Outcome::Retry(e.to_string()),
                        },
                    }
                }
            };
            match outcome {
                Outcome::Done { advantages, rewards_to_go, hw_cycles, cache_hit } => {
                    let served = &inner.shards[shard];
                    served.completed.fetch_add(1, Ordering::Relaxed);
                    served.mark_healthy();
                    // Tenant accounting lands on the shard that actually
                    // answered — "requests answered with a result", once
                    // per request even across failovers. (Remote shards
                    // record on their own server side.)
                    if let ShardBackend::InProcess(svc) = &served.backend {
                        svc.metrics_handle()
                            .record_tenant_request(&tenant, payload.elements());
                    }
                    return Ok(FabricGae {
                        advantages,
                        rewards_to_go,
                        hw_cycles,
                        cache_hit,
                        shard,
                        failovers,
                    });
                }
                Outcome::Retry(_reason) => {
                    inner.shards[shard].mark_unhealthy();
                    inner.shards[shard].failed_over.fetch_add(1, Ordering::Relaxed);
                    failovers += 1;
                    let (next_shard, next_attempt) = inner.submit_with_budget(
                        &tenant,
                        key,
                        &payload,
                        &mut attempts_used,
                        Some(shard),
                    )?;
                    shard = next_shard;
                    attempt = next_attempt;
                }
                Outcome::Fatal(reason) => return Err(FabricError::Rejected(reason)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GaeBackend;
    use crate::gae::reference::gae_trajectory;
    use crate::gae::{GaeParams, Trajectory};
    use crate::testing::Gen;

    fn in_process_fabric(shards: usize, config: FabricConfig) -> GaeFabric {
        let slots = (0..shards)
            .map(|i| {
                let svc = Arc::new(
                    GaeService::with_workers(1, GaeBackend::Scalar).unwrap(),
                );
                (format!("shard-{i}"), ShardBackend::in_process(svc))
            })
            .collect();
        GaeFabric::new(slots, config).unwrap()
    }

    fn planes(g: &mut Gen, t_len: usize, batch: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let rewards = g.vec_normal_f32(t_len * batch, 0.0, 1.0);
        let values = g.vec_normal_f32((t_len + 1) * batch, 0.0, 1.0);
        let done_mask = (0..t_len * batch)
            .map(|_| if g.bool_p(0.06) { 1.0 } else { 0.0 })
            .collect();
        (rewards, values, done_mask)
    }

    #[test]
    fn rank_is_deterministic_total_and_key_sensitive() {
        let fabric = in_process_fabric(4, FabricConfig::default());
        let mut moved = 0;
        for key in 0..256u64 {
            let order = fabric.rank("tenant", key);
            assert_eq!(order.len(), 4);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "rank must be a permutation");
            assert_eq!(order, fabric.rank("tenant", key), "rank must be stable");
            if order != fabric.rank("other-tenant", key) {
                moved += 1;
            }
        }
        // Tenant participates in the hash: most keys route differently
        // under a different tenant.
        assert!(moved > 128, "only {moved}/256 keys moved across tenants");
    }

    #[test]
    fn rendezvous_spreads_keys_over_all_shards() {
        let fabric = in_process_fabric(4, FabricConfig::default());
        let mut counts = [0usize; 4];
        for key in 0..4096u64 {
            counts[fabric.rank("t", key)[0]] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // Expect ~1024 per shard; even a loose bound catches a
            // broken hash (all-on-one or dead shards).
            assert!(c > 512 && c < 1536, "shard {i} got {c}/4096 keys");
        }
    }

    #[test]
    fn routed_results_are_bit_identical_to_the_scalar_reference() {
        let fabric = in_process_fabric(3, FabricConfig::default());
        let mut g = Gen::new(17);
        for key in 0..8u64 {
            let (t_len, batch) = (g.usize_in(3, 24), g.usize_in(1, 5));
            let (rewards, values, done_mask) = planes(&mut g, t_len, batch);
            let got = fabric
                .call(
                    "tenant",
                    key,
                    t_len,
                    batch,
                    rewards.clone(),
                    values.clone(),
                    done_mask.clone(),
                )
                .unwrap();
            assert_eq!(got.failovers, 0);
            for col in 0..batch {
                let traj = Trajectory::new(
                    (0..t_len).map(|t| rewards[t * batch + col]).collect(),
                    (0..=t_len).map(|t| values[t * batch + col]).collect(),
                    (0..t_len).map(|t| done_mask[t * batch + col] == 1.0).collect(),
                );
                let want = gae_trajectory(&GaeParams::default(), &traj);
                for t in 0..t_len {
                    assert_eq!(
                        got.advantages[t * batch + col].to_bits(),
                        want.advantages[t].to_bits(),
                        "key {key} col {col} t {t}"
                    );
                }
            }
        }
    }

    #[test]
    fn dead_primary_spills_to_next_ranked_shard() {
        let fabric = in_process_fabric(
            2,
            FabricConfig { cooldown: Duration::from_secs(3600), ..Default::default() },
        );
        let mut g = Gen::new(5);
        // Find a key whose primary is shard 0, then kill shard 0.
        let key = (0..64u64)
            .find(|&k| fabric.rank("t", k)[0] == 0)
            .expect("some key must rank shard 0 first");
        match &fabric.inner.shards[0].backend {
            ShardBackend::InProcess(svc) => svc.begin_shutdown(),
            _ => unreachable!(),
        }
        let (rewards, values, done_mask) = planes(&mut g, 8, 2);
        let got = fabric.call("t", key, 8, 2, rewards, values, done_mask).unwrap();
        assert_eq!(got.shard, 1, "must spill to the surviving shard");
        assert!(got.failovers >= 1);
        assert!(!fabric.is_healthy(0), "failed shard must be marked");
        assert!(fabric.is_healthy(1));
        let fleet = fabric.fleet();
        assert_eq!(fleet.completed, 1);
        assert!(fleet.failed_over >= 1);
        // With the long cooldown, the dead shard is no longer probed
        // first: the same key now routes straight to shard 1.
        let (rewards, values, done_mask) = planes(&mut g, 8, 2);
        let got = fabric.call("t", key, 8, 2, rewards, values, done_mask).unwrap();
        assert_eq!(got.shard, 1);
        assert_eq!(got.failovers, 0, "unavailable shards are skipped, not probed");
    }

    #[test]
    fn all_shards_down_reports_exhausted() {
        let fabric = in_process_fabric(2, FabricConfig::default());
        for shard in &fabric.inner.shards {
            match &shard.backend {
                ShardBackend::InProcess(svc) => svc.begin_shutdown(),
                _ => unreachable!(),
            }
        }
        let mut g = Gen::new(9);
        let (rewards, values, done_mask) = planes(&mut g, 4, 1);
        let err = fabric
            .call("t", 1, 4, 1, rewards, values, done_mask)
            .unwrap_err();
        match err {
            FabricError::Exhausted { attempts, .. } => assert!(attempts >= 2),
            other => panic!("expected Exhausted, got {other:?}"),
        }
    }

    #[test]
    fn invalid_requests_are_rejected_without_touching_shards() {
        let fabric = in_process_fabric(2, FabricConfig::default());
        // Shape mismatch.
        let err = fabric
            .call("t", 1, 4, 2, vec![0.0; 7], vec![0.0; 10], vec![0.0; 8])
            .unwrap_err();
        assert!(matches!(err, FabricError::Rejected(_)), "{err:?}");
        // Non-binary done mask.
        let err = fabric
            .call("t", 1, 2, 1, vec![0.0; 2], vec![0.0; 3], vec![0.5, 0.0])
            .unwrap_err();
        assert!(matches!(err, FabricError::Rejected(_)), "{err:?}");
        let fleet = fabric.fleet();
        assert_eq!(fleet.submitted, 0, "rejections must not count as submissions");
        assert!(fabric.is_healthy(0) && fabric.is_healthy(1));
    }

    #[test]
    fn per_tenant_breakdown_reaches_the_fleet_view() {
        let fabric = in_process_fabric(2, FabricConfig::default());
        let mut g = Gen::new(3);
        for (tenant, n) in [("alpha", 4u64), ("beta", 2)] {
            for key in 0..n {
                let (rewards, values, done_mask) = planes(&mut g, 6, 2);
                fabric
                    .call(tenant, key, 6, 2, rewards, values, done_mask)
                    .unwrap();
            }
        }
        let fleet = fabric.fleet();
        assert_eq!(fleet.completed, 6);
        let alpha = fleet.tenants.iter().find(|t| t.tenant == "alpha").unwrap();
        assert_eq!(alpha.requests, 4);
        assert_eq!(alpha.elements, 4 * 12);
        let beta = fleet.tenants.iter().find(|t| t.tenant == "beta").unwrap();
        assert_eq!(beta.requests, 2);
    }
}

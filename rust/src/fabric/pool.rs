//! The connection-multiplexing client pool: many logical submitters
//! sharing a few pipelined sockets per endpoint.
//!
//! The PR-3 [`NetClient`](crate::net::NetClient) answered *latency*
//! (pipeline N frames over one socket); a many-client load generator —
//! or a fabric front-end speaking for hundreds of trainer replicas —
//! still paid one socket, one reader thread, and one globally-locked
//! pending map per client. This module folds that fan-in:
//!
//! - **Few sockets, many submitters.** A [`ClientPool`] opens
//!   [`PoolConfig::sockets`] pipelined connections; every
//!   [`PoolClient`] (a cheap logical submitter) is pinned to one of
//!   them round-robin. A thousand submitters cost a thousand small
//!   structs, not a thousand fds and threads.
//! - **Seq-space partitioning.** Frame sequence numbers are
//!   `(submitter_space << 32) | frame`, so every submitter owns a
//!   disjoint 2³²-frame space ([`seq_for`]) and the response's target
//!   is derivable from its seq alone. Completions route through the
//!   submitter's **private** slot map: the reader takes the
//!   connection-global registry only as a *read* lock (written once per
//!   submitter registration), so no frame ever serializes unrelated
//!   submitters on a shared mutex — the per-frame locks are between one
//!   submitter and its reader only.
//! - **Self-healing sockets.** A dead connection fails its in-flight
//!   frames (each submitter sees [`NetError::Disconnected`]) and is
//!   re-dialed transparently on the next submit ([`PoolConn::live`]);
//!   the old reader is joined *before* the replacement registers, so a
//!   late failure broadcast can never kill fresh frames.
//!
//! The fabric's [`ShardRouter`](crate::fabric::GaeFabric) uses one pool
//! per remote shard; `serve_gae --connect --clients M --pool-sockets S`
//! drives M closed-loop submitters over S sockets.

use crate::net::auth::AuthToken;
use crate::net::client::{NetError, NetGae, WireStats};
use crate::net::wire::{self, Frame, PlaneCodec};
use crate::service::metrics::MetricsSnapshot;
use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Bound on one dial attempt. Re-dials happen under the connection's
/// write lock (so submitters pinned to that socket wait), and the
/// router leans on pool submits failing *fast* to spill a dead shard —
/// the OS default connect timeout (minutes on a blackholed host) would
/// turn fail-fast failover into a fleet-wide stall.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Dial with [`CONNECT_TIMEOUT`] per resolved address.
fn dial(addr: &str) -> std::io::Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for sock_addr in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sock_addr, CONNECT_TIMEOUT) {
            Ok(stream) => return Ok(stream),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            "address resolved to no socket addresses",
        )
    }))
}

/// Pool deployment knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    /// Pipelined TCP connections to the endpoint; submitters are pinned
    /// round-robin across them.
    pub sockets: usize,
    /// Request-plane transport codec.
    pub codec: PlaneCodec,
    /// Reply-plane transport codec ([`PlaneCodec::F32`] = bit-exact).
    pub resp: PlaneCodec,
    /// Tenant token carried in every request frame's header when set.
    /// The pool signs for one tenant identity — the token is
    /// HMAC(deployment key, tenant id), so it only verifies for the
    /// tenant string the submitters actually send.
    pub auth: Option<AuthToken>,
}

impl Default for PoolConfig {
    /// Two sockets, the paper's 8-bit request transport, exact replies.
    fn default() -> Self {
        PoolConfig {
            sockets: 2,
            codec: PlaneCodec::Q8,
            resp: PlaneCodec::F32,
            auth: None,
        }
    }
}

/// The high 32 bits of every seq a submitter emits — its id plus one,
/// so seq 0 (reserved) and the plain-`NetClient` low space (high bits
/// zero) are never produced.
pub fn seq_space(submitter: u32) -> u32 {
    submitter
        .checked_add(1)
        .expect("submitter id space exhausted (u32::MAX submitters)")
}

/// The wire sequence number of frame `frame` from `submitter`: the two
/// spaces of distinct submitters are disjoint by construction, so a
/// completion's target falls out of its seq with no shared state.
pub fn seq_for(submitter: u32, frame: u32) -> u64 {
    ((seq_space(submitter) as u64) << 32) | frame as u64
}

/// Recover the submitter id a pool seq belongs to (`None` for seqs
/// outside any pool space, e.g. a plain `NetClient`'s counter).
pub fn submitter_of(seq: u64) -> Option<u32> {
    ((seq >> 32) as u32).checked_sub(1)
}

type Reply = Result<wire::ResponseFrame, NetError>;
/// One submitter's private in-flight slots, keyed by the low 32 seq
/// bits. Locked only by that submitter and the connection reader.
type SlotMap = Arc<Mutex<HashMap<u32, mpsc::Sender<Reply>>>>;
/// Seq-space (high 32 bits) → the owning submitter's slot map. Written
/// once per submitter registration; the frame path only read-locks it.
type Registry = Arc<RwLock<HashMap<u32, SlotMap>>>;
type MetricsReply = Result<MetricsSnapshot, NetError>;
/// In-flight metrics RPCs on one connection, keyed by full seq. Metrics
/// seqs live in the reserved space 0 (high 32 bits zero — no submitter
/// ever produces them), so they can never shadow a plane frame.
type MetricsSlotMap = Arc<Mutex<HashMap<u64, mpsc::Sender<MetricsReply>>>>;

/// Route one reply to its owner entirely from the seq: space → private
/// slot map → slot. Unknown spaces/slots are dropped (abandoned
/// handles), exactly like `NetClient`.
fn route(registry: &Registry, seq: u64, reply: Reply) {
    let space = (seq >> 32) as u32;
    let slot = seq as u32;
    let map = registry.read().unwrap().get(&space).cloned();
    if let Some(map) = map {
        if let Some(tx) = map.lock().unwrap().remove(&slot) {
            let _ = tx.send(reply);
        }
    }
}

/// Fail every in-flight frame of every submitter on this connection,
/// plus pending metrics RPCs. Sets `closed` *before* draining, so a
/// slot registered after the drain is caught by the submitter's own
/// post-write check.
fn fail_all(
    registry: &Registry,
    metrics: &MetricsSlotMap,
    closed: &AtomicBool,
    error: NetError,
) {
    closed.store(true, Ordering::SeqCst);
    let maps: Vec<SlotMap> = registry.read().unwrap().values().cloned().collect();
    for map in maps {
        let slots: Vec<mpsc::Sender<Reply>> =
            map.lock().unwrap().drain().map(|(_, tx)| tx).collect();
        for tx in slots {
            let _ = tx.send(Err(error.clone()));
        }
    }
    let slots: Vec<mpsc::Sender<MetricsReply>> =
        metrics.lock().unwrap().drain().map(|(_, tx)| tx).collect();
    for tx in slots {
        let _ = tx.send(Err(error.clone()));
    }
}

fn reader_loop(
    stream: TcpStream,
    registry: Registry,
    metrics: MetricsSlotMap,
    closed: Arc<AtomicBool>,
) {
    let mut reader = std::io::BufReader::new(stream);
    loop {
        let frame = match wire::read_frame(&mut reader) {
            Ok(Some(frame)) => frame,
            Ok(None) | Err(_) => {
                fail_all(&registry, &metrics, &closed, NetError::Disconnected);
                return;
            }
        };
        match wire::decode_frame(&frame) {
            Ok(Frame::Response(resp)) => route(&registry, resp.seq, Ok(resp)),
            Ok(Frame::MetricsResponse(m)) => {
                if let Some(tx) = metrics.lock().unwrap().remove(&m.seq) {
                    let _ = tx.send(Ok(m.snapshot));
                }
            }
            Ok(Frame::Error(err)) => {
                let remote =
                    NetError::Remote { kind: err.kind, message: err.message };
                if err.seq == 0 {
                    fail_all(&registry, &metrics, &closed, remote);
                    return;
                }
                // A per-frame error may answer a metrics RPC too.
                if let Some(tx) = metrics.lock().unwrap().remove(&err.seq) {
                    let _ = tx.send(Err(remote));
                } else {
                    route(&registry, err.seq, Err(remote));
                }
            }
            Ok(Frame::Request(_)) | Ok(Frame::MetricsRequest(_))
            | Ok(Frame::TraceRequest(_)) => {
                fail_all(
                    &registry,
                    &metrics,
                    &closed,
                    NetError::Decode("server sent a request frame".to_string()),
                );
                return;
            }
            // The pool never issues trace RPCs; an unsolicited reply is
            // droppable, not fatal.
            Ok(Frame::TraceResponse(_)) => {}
            Err(e) => {
                fail_all(&registry, &metrics, &closed, NetError::Decode(e.to_string()));
                return;
            }
        }
    }
}

/// One live socket generation: write half + reader thread.
struct ConnInner {
    writer: Mutex<std::io::BufWriter<TcpStream>>,
    /// Clone of the socket, for interrupting a blocked reader.
    stream: TcpStream,
    closed: Arc<AtomicBool>,
    reader: Mutex<Option<JoinHandle<()>>>,
}

impl ConnInner {
    fn connect(
        addr: &str,
        registry: Registry,
        metrics: MetricsSlotMap,
    ) -> std::io::Result<Arc<ConnInner>> {
        let stream = dial(addr)?;
        stream.set_nodelay(true)?;
        let read_half = stream.try_clone()?;
        let write_half = stream.try_clone()?;
        let closed = Arc::new(AtomicBool::new(false));
        let reader_closed = Arc::clone(&closed);
        let reader = std::thread::spawn(move || {
            reader_loop(read_half, registry, metrics, reader_closed)
        });
        Ok(Arc::new(ConnInner {
            writer: Mutex::new(std::io::BufWriter::new(write_half)),
            stream,
            closed,
            reader: Mutex::new(Some(reader)),
        }))
    }

    /// Interrupt the reader and join it — its failure broadcast (if
    /// any) completes before this returns, so a replacement connection
    /// can safely register fresh slots.
    fn abort(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let _ = self.stream.shutdown(Shutdown::Both);
        let handle = self.reader.lock().unwrap().take();
        if let Some(handle) = handle {
            let _ = handle.join();
        }
    }
}

impl Drop for ConnInner {
    fn drop(&mut self) {
        self.abort();
    }
}

/// One pooled endpoint connection across socket generations: the
/// registry of submitter slot maps survives re-dials.
struct PoolConn {
    addr: String,
    registry: Registry,
    /// Pending metrics RPCs; like the registry it survives re-dials.
    metrics: MetricsSlotMap,
    inner: RwLock<Arc<ConnInner>>,
}

impl PoolConn {
    fn open(addr: &str) -> std::io::Result<PoolConn> {
        let registry: Registry = Arc::new(RwLock::new(HashMap::new()));
        let metrics: MetricsSlotMap = Arc::new(Mutex::new(HashMap::new()));
        let inner =
            ConnInner::connect(addr, Arc::clone(&registry), Arc::clone(&metrics))?;
        Ok(PoolConn {
            addr: addr.to_string(),
            registry,
            metrics,
            inner: RwLock::new(inner),
        })
    }

    /// The current socket generation, transparently re-dialing a dead
    /// one. The old reader is joined under the write lock *before* the
    /// replacement exists, so its failure broadcast cannot touch frames
    /// submitted on the fresh socket.
    fn live(&self) -> Result<Arc<ConnInner>, NetError> {
        let conn = self.inner.read().unwrap().clone();
        if !conn.closed.load(Ordering::SeqCst) {
            return Ok(conn);
        }
        let mut guard = self.inner.write().unwrap();
        if !guard.closed.load(Ordering::SeqCst) {
            return Ok(Arc::clone(&guard)); // someone else re-dialed first
        }
        guard.abort();
        match ConnInner::connect(
            &self.addr,
            Arc::clone(&self.registry),
            Arc::clone(&self.metrics),
        ) {
            Ok(fresh) => {
                *guard = fresh;
                Ok(Arc::clone(&guard))
            }
            Err(e) => Err(NetError::Io(e.to_string())),
        }
    }
}

#[derive(Default)]
struct PoolStats {
    frames: AtomicU64,
    payload_bytes: AtomicU64,
    f32_payload_bytes: AtomicU64,
    wire_bytes: AtomicU64,
    traced_frames: AtomicU64,
}

struct PoolShared {
    config: PoolConfig,
    conns: Vec<PoolConn>,
    next_submitter: AtomicU32,
    /// Metrics-RPC seqs live in the reserved space 0 (high bits zero),
    /// which no submitter can produce; start at 1 (seq 0 is reserved).
    next_metrics_seq: AtomicU64,
    stats: PoolStats,
}

/// A pool of pipelined connections to one GAE endpoint. Create once,
/// then mint cheap [`PoolClient`] submitters from any thread.
pub struct ClientPool {
    shared: Arc<PoolShared>,
}

impl ClientPool {
    /// Dial `config.sockets` connections to a
    /// [`NetServer`](crate::net::NetServer).
    pub fn connect(addr: &str, config: PoolConfig) -> anyhow::Result<ClientPool> {
        anyhow::ensure!(config.sockets >= 1, "pool needs at least one socket");
        let mut conns = Vec::with_capacity(config.sockets);
        for _ in 0..config.sockets {
            conns.push(PoolConn::open(addr)?);
        }
        Ok(ClientPool {
            shared: Arc::new(PoolShared {
                config,
                conns,
                next_submitter: AtomicU32::new(0),
                next_metrics_seq: AtomicU64::new(1),
                stats: PoolStats::default(),
            }),
        })
    }

    pub fn config(&self) -> PoolConfig {
        self.shared.config
    }

    pub fn sockets(&self) -> usize {
        self.shared.conns.len()
    }

    /// Mint a logical submitter for `tenant`: a disjoint seq space, a
    /// private slot map, and a round-robin-pinned socket.
    pub fn submitter(&self, tenant: &str) -> PoolClient {
        let id = self.shared.next_submitter.fetch_add(1, Ordering::Relaxed);
        assert!(id < u32::MAX, "submitter id space exhausted");
        let conn_index = id as usize % self.shared.conns.len();
        let slots: SlotMap = Arc::new(Mutex::new(HashMap::new()));
        self.shared.conns[conn_index]
            .registry
            .write()
            .unwrap()
            .insert(seq_space(id), Arc::clone(&slots));
        PoolClient {
            shared: Arc::clone(&self.shared),
            conn_index,
            id,
            tenant: tenant.to_string(),
            slots,
            next_frame: AtomicU64::new(0),
        }
    }

    /// Fetch the endpoint's full
    /// [`MetricsSnapshot`](crate::service::MetricsSnapshot) over the
    /// wire (the fleet-metrics RPC), on the first pooled socket. The
    /// RPC's seq lives in the reserved space 0, so it can never shadow
    /// a submitter's plane frame.
    pub fn fetch_metrics(&self) -> Result<MetricsSnapshot, NetError> {
        let pool_conn = &self.shared.conns[0];
        let conn = pool_conn.live()?;
        let seq = self.shared.next_metrics_seq.fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(submitter_of(seq), None, "metrics seqs stay in space 0");
        let bytes = wire::encode_metrics_request(seq);
        let (tx, rx) = mpsc::channel();
        pool_conn.metrics.lock().unwrap().insert(seq, tx);
        let write_result = {
            let mut writer = conn.writer.lock().unwrap();
            writer.write_all(&bytes).and_then(|_| writer.flush())
        };
        if let Err(e) = write_result {
            pool_conn.metrics.lock().unwrap().remove(&seq);
            conn.closed.store(true, Ordering::SeqCst);
            return Err(NetError::Io(e.to_string()));
        }
        self.shared
            .stats
            .wire_bytes
            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        if conn.closed.load(Ordering::SeqCst) {
            pool_conn.metrics.lock().unwrap().remove(&seq);
            return Err(NetError::Disconnected);
        }
        rx.recv().map_err(|_| NetError::Disconnected)?
    }

    /// Transport accounting summed over every socket and submitter.
    /// Round-trip timing is a per-`NetClient` measure; pooled slots
    /// don't carry submit timestamps, so the RTT fields stay zero here.
    pub fn wire_stats(&self) -> WireStats {
        let s = &self.shared.stats;
        WireStats {
            frames: s.frames.load(Ordering::Relaxed),
            payload_bytes: s.payload_bytes.load(Ordering::Relaxed),
            f32_payload_bytes: s.f32_payload_bytes.load(Ordering::Relaxed),
            wire_bytes: s.wire_bytes.load(Ordering::Relaxed),
            rtt_count: 0,
            rtt_total_us: 0,
            rtt_max_us: 0,
            traced_frames: s.traced_frames.load(Ordering::Relaxed),
        }
    }
}

/// One logical submitter of a [`ClientPool`]: owns seq space
/// `seq_space(id)`, shares its pinned socket with every other submitter
/// pinned there. `&self` methods are thread-safe.
pub struct PoolClient {
    shared: Arc<PoolShared>,
    conn_index: usize,
    id: u32,
    tenant: String,
    slots: SlotMap,
    next_frame: AtomicU64,
}

impl PoolClient {
    pub fn id(&self) -> u32 {
        self.id
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Frames of this submitter currently awaiting a response.
    pub fn in_flight(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// Encode and write one plane-shaped request on the pinned socket;
    /// returns immediately with a handle (the pipelined shape). Mints a
    /// fresh trace id while tracing is on.
    pub fn submit_planes(
        &self,
        t_len: usize,
        batch: usize,
        rewards: &[f32],
        values: &[f32],
        done_mask: &[f32],
    ) -> Result<PoolPending, NetError> {
        let trace = if crate::obs::enabled() {
            crate::obs::mint_trace_id()
        } else {
            0
        };
        self.submit_planes_traced(t_len, batch, rewards, values, done_mask, trace)
    }

    /// [`PoolClient::submit_planes`] under a caller-supplied trace id
    /// (`0` = untraced). The fabric router uses this so one id spans
    /// every failover attempt of a single logical request.
    pub fn submit_planes_traced(
        &self,
        t_len: usize,
        batch: usize,
        rewards: &[f32],
        values: &[f32],
        done_mask: &[f32],
        trace: u64,
    ) -> Result<PoolPending, NetError> {
        let _submit_span = crate::obs::span("client.submit", trace);
        let slot = self.next_frame.fetch_add(1, Ordering::Relaxed) as u32;
        let seq = seq_for(self.id, slot);
        let encoded = wire::encode_request_signed(
            seq,
            &self.tenant,
            self.shared.config.codec,
            self.shared.config.resp,
            trace,
            self.shared.config.auth.as_ref().map(|t| t.as_bytes()),
            t_len,
            batch,
            rewards,
            values,
            done_mask,
        )
        .map_err(|e| NetError::InvalidRequest(e.to_string()))?;

        let conn = self.shared.conns[self.conn_index].live()?;
        let (tx, rx) = mpsc::channel();
        // Register before writing so a lightning-fast response cannot
        // race past an unregistered slot.
        self.slots.lock().unwrap().insert(slot, tx);
        let write_result = {
            let mut writer = conn.writer.lock().unwrap();
            writer.write_all(&encoded.bytes).and_then(|_| writer.flush())
        };
        if let Err(e) = write_result {
            self.slots.lock().unwrap().remove(&slot);
            // Mark the generation dead; the next submit re-dials.
            conn.closed.store(true, Ordering::SeqCst);
            return Err(NetError::Io(e.to_string()));
        }
        let s = &self.shared.stats;
        s.frames.fetch_add(1, Ordering::Relaxed);
        s.payload_bytes
            .fetch_add(encoded.payload_bytes as u64, Ordering::Relaxed);
        s.f32_payload_bytes
            .fetch_add(encoded.f32_payload_bytes as u64, Ordering::Relaxed);
        s.wire_bytes
            .fetch_add(encoded.bytes.len() as u64, Ordering::Relaxed);
        if trace != 0 {
            s.traced_frames.fetch_add(1, Ordering::Relaxed);
        }
        // The reader sets `closed` *before* draining the slot maps, so a
        // slot registered after the drain is caught here and never leaks.
        if conn.closed.load(Ordering::SeqCst) {
            self.slots.lock().unwrap().remove(&slot);
            return Err(NetError::Disconnected);
        }
        Ok(PoolPending { seq, rx })
    }

    /// Synchronous convenience: submit one frame and wait for it.
    pub fn call_planes(
        &self,
        t_len: usize,
        batch: usize,
        rewards: &[f32],
        values: &[f32],
        done_mask: &[f32],
    ) -> Result<NetGae, NetError> {
        self.submit_planes(t_len, batch, rewards, values, done_mask)?.wait()
    }
}

impl Drop for PoolClient {
    /// Deregister the seq space so a long-lived pool doesn't accumulate
    /// dead submitters. Frames still in flight are abandoned: their
    /// [`PoolPending::wait`] fails with [`NetError::Disconnected`]
    /// (the slot map dies with the submitter), never hangs.
    fn drop(&mut self) {
        self.shared.conns[self.conn_index]
            .registry
            .write()
            .unwrap()
            .remove(&seq_space(self.id));
    }
}

/// Handle to one in-flight pooled frame.
#[derive(Debug)]
pub struct PoolPending {
    seq: u64,
    rx: mpsc::Receiver<Reply>,
}

impl PoolPending {
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Block until the endpoint answers this frame (out-of-order safe).
    pub fn wait(self) -> Result<NetGae, NetError> {
        Self::reply_to_gae(self.rx.recv().map_err(|_| NetError::Disconnected))
    }

    /// Like [`wait`](PoolPending::wait), but give up after `deadline`
    /// with [`NetError::Timeout`]. The frame stays in flight; a reply
    /// landing after the handle is dropped is discarded by the reader.
    pub fn wait_timeout(self, deadline: Duration) -> Result<NetGae, NetError> {
        Self::reply_to_gae(self.rx.recv_timeout(deadline).map_err(|e| match e {
            mpsc::RecvTimeoutError::Timeout => NetError::Timeout,
            mpsc::RecvTimeoutError::Disconnected => NetError::Disconnected,
        }))
    }

    fn reply_to_gae(reply: Result<Reply, NetError>) -> Result<NetGae, NetError> {
        match reply {
            Ok(Ok(resp)) => Ok(NetGae {
                advantages: resp.advantages,
                rewards_to_go: resp.rewards_to_go,
                hw_cycles: resp.hw_cycles,
                cache_hit: resp.cache_hit,
                quantized: resp.quantized,
            }),
            Ok(Err(e)) => Err(e),
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn seq_spaces_are_disjoint_and_recoverable() {
        check("pool seq partition", 200, |g| {
            let a = g.usize_in(0, 1 << 20) as u32;
            let b = g.usize_in(0, 1 << 20) as u32;
            let x = g.usize_in(0, u32::MAX as usize) as u32;
            let y = g.usize_in(0, u32::MAX as usize) as u32;
            let sa = seq_for(a, x);
            let sb = seq_for(b, y);
            assert_ne!(sa, 0, "seq 0 is reserved");
            assert_eq!(submitter_of(sa), Some(a));
            assert_eq!(submitter_of(sb), Some(b));
            if a != b {
                // Different submitters can never collide, whatever
                // their frame counters are — the partition property.
                assert_ne!(sa, sb);
            } else if x != y {
                assert_ne!(sa, sb);
            }
        });
    }

    #[test]
    fn plain_client_seqs_fall_outside_every_space() {
        // NetClient seqs are small counters: high bits zero.
        assert_eq!(submitter_of(1), None);
        assert_eq!(submitter_of(u32::MAX as u64), None);
        // The first pool space starts just above.
        assert_eq!(submitter_of(1 << 32), Some(0));
    }

    #[test]
    fn default_config_is_quantized_requests_exact_replies() {
        let c = PoolConfig::default();
        assert!(c.sockets >= 1);
        assert!(c.codec.is_quantized());
        assert!(!c.resp.is_quantized());
    }
}

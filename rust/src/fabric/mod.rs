//! The GAE service fabric: a horizontally sharded fleet behind one
//! submit API.
//!
//! PR 1–4 built a single `GaeService` and taught it to serve a socket;
//! this module is the layer above — the point where "a service" becomes
//! "a fleet", which is where RL serving throughput actually scales
//! (Stooke & Abbeel: many coordinated actors feeding shared compute):
//!
//! ```text
//!   trainer replicas / load generators / serve_gae --connect a,b,c
//!        │                 │                  │
//!        ▼                 ▼                  ▼
//!   GaeFabric::submit(tenant, key, planes)          (router.rs)
//!        │  rendezvous hash over (tenant, key) → shard rank
//!        │  unhealthy shards skipped; spill chain = rank order
//!        ├────────────┬──────────────────┐
//!        ▼            ▼                  ▼
//!   InProcess      InProcess          Remote (TCP)
//!   GaeService     GaeService         ClientPool    (pool.rs)
//!        │            │                  │  few pipelined sockets,
//!        │            │                  │  many submitters, seq-space
//!        │            │                  │  partitioned completions
//!        ▼            ▼                  ▼
//!   FabricPending::wait — retries through the rank order if the
//!   serving shard dies mid-flight; results bit-identical to the
//!   single-service path (f32 transport).
//!
//!   GaeFabric::fleet() → FleetSnapshot                (fleet.rs)
//!   per-shard status + aggregated totals + merged per-tenant view
//! ```
//!
//! Layer boundaries:
//!
//! - [`router`] owns placement and failure policy: rendezvous ranking,
//!   health/cooldown state, the attempt budget, retry-on-wait.
//! - [`pool`] owns remote transport: the connection-multiplexing
//!   [`ClientPool`] that replaces one-socket-per-client fan-out.
//! - [`fleet`] owns observability: per-shard
//!   [`MetricsSnapshot`](crate::service::MetricsSnapshot)s folded into
//!   one [`FleetSnapshot`] with the per-tenant breakdown merged.
//! - Compute stays in [`crate::service`] — the fabric never computes
//!   GAE, which is what keeps routed results bit-identical to the
//!   in-process path no matter which shard (or how many failovers) a
//!   request crossed.
//!
//! The multi-replica trainer mode
//! ([`crate::coordinator::pipeline::run_stage_fleet`]) drives several
//! PR-2 stage-driver replicas into one fabric; `benches/fabric_scaling.rs`
//! sweeps shards × replicas × pool sockets, and
//! `tests/fabric_integration.rs` kills shards mid-load and checks every
//! request still completes bit-identically.

pub mod fleet;
pub mod pool;
pub mod router;

pub use fleet::{merge_tenants, FleetSnapshot, ShardStatus};
pub use pool::{
    seq_for, seq_space, submitter_of, ClientPool, PoolClient, PoolConfig, PoolPending,
};
pub use router::{
    FabricConfig, FabricError, FabricGae, FabricPending, GaeFabric, ShardBackend,
};

//! Fixed-size worker thread pool with a scoped parallel-for.
//!
//! This is the execution substrate for the vectorized environment engine
//! (`envs::vec_env`) — the same role EnvPool's C++ thread-pool executor
//! plays in the paper's related work. tokio is unavailable in the offline
//! crate set; a purpose-built pool is smaller and has no runtime on the
//! hot path anyway.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A pool of `n` OS threads consuming jobs from a shared channel.
pub struct ThreadPool {
    tx: mpsc::Sender<Message>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("heppo-worker-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Message::Run(job)) => job(),
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    /// Pool sized to the machine (logical cores, capped).
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.min(32))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx.send(Message::Run(Box::new(job))).expect("pool alive");
    }

    /// Run `f(i)` for every `i in 0..n` across the pool and wait for all
    /// of them. `f` must be `Sync` since workers share it.
    ///
    /// Work is distributed by an atomic cursor so fast workers steal the
    /// remaining indices (important: env episodes have skewed lengths —
    /// the same load imbalance the paper's round-robin row scheduler
    /// addresses in hardware).
    pub fn scoped_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let cursor = AtomicUsize::new(0);
        // SAFETY ALTERNATIVE: use std scoped threads through the pool's
        // channel is not possible (jobs are 'static), so we run the
        // parallel-for on scoped threads directly; the pool size only
        // bounds the worker count. This keeps the API safe without
        // unsafe lifetime laundering.
        let workers = self.size.min(n);
        thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map `f` over `0..n` in parallel, collecting results in order.
    pub fn map<T: Send, F>(&self, n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<Mutex<&mut Option<T>>> =
                out.iter_mut().map(Mutex::new).collect();
            self.scoped_for(n, |i| {
                let v = f(i);
                **slots[i].lock().unwrap() = Some(v);
            });
        }
        out.into_iter().map(|v| v.expect("slot filled")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Message::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.scoped_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(257, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_with_skewed_work() {
        let pool = ThreadPool::new(4);
        let out = pool.map(32, |i| {
            // Skewed busy-work emulating unequal episode lengths.
            let mut acc = 0u64;
            for k in 0..(i as u64 * 1000) {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        for (i, (j, _)) in out.iter().enumerate() {
            assert_eq!(i, *j);
        }
    }

    #[test]
    fn zero_len_is_noop() {
        let pool = ThreadPool::new(2);
        pool.scoped_for(0, |_| panic!("should not run"));
    }
}

//! Leveled stderr logger.
//!
//! Controlled by `HEPPO_LOG` (error|warn|info|debug|trace, default info)
//! or programmatically via [`set_level`].

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

/// Set once the first unrecognized `HEPPO_LOG` value has been reported,
/// so a typo warns exactly once instead of on every lazy init race.
static WARNED_BAD_LEVEL: AtomicBool = AtomicBool::new(false);

fn env_level() -> Level {
    match std::env::var("HEPPO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok(other) => {
            // A typo'd HEPPO_LOG used to silently mean "info"; say so
            // once so a missing debug stream is diagnosable.
            if !WARNED_BAD_LEVEL.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[heppo WARN ] unrecognized HEPPO_LOG={other:?} \
                     (valid: error|warn|info|debug|trace); defaulting to info"
                );
            }
            Level::Info
        }
        Err(_) => Level::Info,
    }
}

/// Current level (lazily initialized from the environment).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = env_level();
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Emit a record if `lvl` is enabled.
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[heppo {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_and_get() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Info);
        assert_eq!(level(), Level::Info);
    }

    #[test]
    fn trace_macro_gates_on_level() {
        // Compiles and routes through the same gate as the other
        // macros; suppressed below Trace, emitted at Trace.
        set_level(Level::Error);
        crate::log_trace!("suppressed: {}", 42);
        set_level(Level::Trace);
        crate::log_trace!("emitted at trace");
        set_level(Level::Info);
    }
}

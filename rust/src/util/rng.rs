//! Deterministic pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) — small, fast, statistically solid for
//! simulation workloads, and fully deterministic across platforms, which
//! the reproduction experiments (seeded reward curves) rely on.

/// A PCG-XSH-RR 64/32 generator.
///
/// Two independent 32-bit draws are combined for [`Rng::next_u64`].
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (seed << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (used to give each environment
    /// worker / trajectory its own stream).
    pub fn split(&mut self) -> Rng {
        let seed = self.next_u64();
        let stream = self.next_u64();
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.uniform_in(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; modulo bias is negligible for n << 2^64 but we reject to
    /// be exact).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean/std.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.normal() as f32;
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)` (f32).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for x in out.iter_mut() {
            *x = self.uniform_f32(lo, hi);
        }
    }

    /// Sample an index from unnormalized log-probabilities (Gumbel-max).
    pub fn categorical_from_logits(&mut self, logits: &[f32]) -> usize {
        let mut best = f64::NEG_INFINITY;
        let mut best_i = 0;
        for (i, &l) in logits.iter().enumerate() {
            let u = loop {
                let u = self.uniform();
                if u > 1e-300 {
                    break u;
                }
            };
            let g = l as f64 - (-u.ln()).ln();
            if g > best {
                best = g;
                best_i = i;
            }
        }
        best_i
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_prefers_large_logit() {
        let mut rng = Rng::new(5);
        let logits = [0.0f32, 5.0, 0.0];
        let hits = (0..1000)
            .filter(|_| rng.categorical_from_logits(&logits) == 1)
            .count();
        assert!(hits > 950, "hits={hits}");
    }

    #[test]
    fn categorical_matches_softmax_frequencies() {
        let mut rng = Rng::new(9);
        let logits = [1.0f32, 2.0, 3.0];
        let exps: Vec<f64> = logits.iter().map(|&l| (l as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[rng.categorical_from_logits(&logits)] += 1;
        }
        for i in 0..3 {
            let p = exps[i] / z;
            let f = counts[i] as f64 / n as f64;
            assert!((f - p).abs() < 0.02, "i={i} p={p} f={f}");
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Rng::new(13);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

//! Flag-style command-line argument parser for the `heppo` binary and the
//! bench/example drivers (clap is unavailable in the offline crate set).
//!
//! Grammar: `heppo <subcommand> [--key value]... [--flag]...`
//! `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, key/value options, and bare flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token, if any.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs.
    opts: BTreeMap<String, String>,
    /// `--flag` tokens without values.
    flags: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — tokens exclude argv[0].
    pub fn parse_tokens<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.opts.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Self::parse_tokens(std::env::args().skip(1))
    }

    /// Raw option lookup.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; exits the process on a malformed value
    /// (CLI surface, not library surface).
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.opt(key) {
            None => default,
            Some(raw) => raw.parse().unwrap_or_else(|_| {
                eprintln!("error: --{key} expects a {}, got {raw:?}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }

    /// Was `--flag` passed (with no value)?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// All unknown keys, for strict validation.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse_tokens(tokens.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--env", "cartpole", "--iters=50", "--quiet"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("env"), Some("cartpole"));
        assert_eq!(a.get_or("iters", 0usize), 50);
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_or("iters", 7usize), 7);
        assert_eq!(a.str_or("env", "pendulum"), "pendulum");
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "alpha", "beta"]);
        assert_eq!(a.positional, vec!["alpha", "beta"]);
    }

    #[test]
    fn negative_number_values() {
        let a = parse(&["x", "--lo=-3.5"]);
        assert_eq!(a.get_or("lo", 0.0f64), -3.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }
}

//! Wall-clock timing helpers shared by the phase profiler and the bench
//! harness.

use std::time::{Duration, Instant};

/// Measure the wall-clock duration of `f`, returning `(result, elapsed)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed())
}

/// A named accumulator of durations — a phase is entered many times per
/// run; we keep total + count for means.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    count: u64,
}

impl Stopwatch {
    pub fn add(&mut self, d: Duration) {
        self.total += d;
        self.count += 1;
    }

    /// Time a closure and accumulate.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let (out, d) = timed(f);
        self.add(d);
        out
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

/// Format a duration compactly (µs/ms/s) for table output.
pub fn fmt_duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::default();
        sw.add(Duration::from_millis(10));
        sw.add(Duration::from_millis(30));
        assert_eq!(sw.count(), 2);
        assert_eq!(sw.total(), Duration::from_millis(40));
        assert_eq!(sw.mean(), Duration::from_millis(20));
    }

    #[test]
    fn empty_mean_is_zero() {
        assert_eq!(Stopwatch::default().mean(), Duration::ZERO);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}

//! CSV writer for benchmark and figure outputs.
//!
//! Every bench target writes its table/series as CSV under `results/` so
//! the paper figures can be re-plotted from the raw data.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// Create a table with the given column names.
    pub fn new(columns: &[&str]) -> Self {
        CsvTable {
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of already-formatted cells; must match the header len.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    /// Append a row of displayable values.
    pub fn row_display<D: std::fmt::Display>(&mut self, cells: &[D]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to CSV text (RFC-4180-style quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        Self::write_row(&mut out, &self.header);
        for row in &self.rows {
            Self::write_row(&mut out, row);
        }
        out
    }

    fn write_row(out: &mut String, cells: &[String]) {
        for (i, cell) in cells.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
                let escaped = cell.replace('"', "\"\"");
                let _ = write!(out, "\"{escaped}\"");
            } else {
                out.push_str(cell);
            }
        }
        out.push('\n');
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Render as a GitHub-markdown table (used in bench stdout and
    /// EXPERIMENTS.md snippets).
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(out, " {c:<w$} |");
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row_display(&[1, 2]);
        t.row(&["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,2\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.row_display(&[1]);
    }

    #[test]
    fn markdown_alignment() {
        let mut t = CsvTable::new(&["name", "v"]);
        t.row_display(&["long-name", "1"]);
        let md = t.to_markdown();
        assert!(md.contains("| name      | v |"));
        assert!(md.lines().count() == 3);
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("heppo_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = CsvTable::new(&["x"]);
        t.row_display(&[42]);
        let path = dir.join("sub/out.csv");
        t.save(&path).unwrap();
        assert!(std::fs::read_to_string(path).unwrap().contains("42"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Minimal JSON parser + emitter.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`) and for run configuration files. Supports
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with context.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers → `Vec<usize>` (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Num(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"shapes":{"obs":[64,4],"act":[64]},"n":128,"name":"cartpole","f":0.5,"flag":false,"null":null}"#;
        let v = Json::parse(doc).unwrap();
        let emitted = v.to_string();
        let v2 = Json::parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_special_strings() {
        let v = Json::Str("quote\" slash\\ tab\t nl\n".into());
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn usize_vec_helper() {
        let v = Json::parse("[64, 4, 2]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![64, 4, 2]);
        assert!(Json::parse(r#"[1, "x"]"#).unwrap().as_usize_vec().is_none());
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(64.0).to_string(), "64");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}

//! Self-contained utility substrates.
//!
//! The offline crate set for this build contains no `serde`, `clap`,
//! `tokio`, `rand` or `criterion`; the equivalents needed by the system
//! are implemented here from scratch (per the build-every-substrate rule):
//!
//! - [`rng`] — PCG64-based RNG with uniform/normal/categorical sampling.
//! - [`json`] — minimal JSON parser + emitter (artifact manifests, configs).
//! - [`csv`] — CSV writer for benchmark/figure outputs.
//! - [`cli`] — flag-style argument parser for the `heppo` binary.
//! - [`threadpool`] — fixed worker pool with scoped parallel-for
//!   (the EnvPool-style executor substrate).
//! - [`timer`] — wall-clock phase timing.
//! - [`logging`] — leveled stderr logger.

pub mod cli;
pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;

//! `heppo` — the HEPPO-GAE coordinator CLI.
//!
//! Subcommands:
//!   train       run PPO training (see --env/--iters/--codec/--backend/…)
//!   eval        greedy evaluation of trained parameters
//!   gae-sim     cycle-simulate the accelerator on a synthetic workload
//!   profile     per-phase time profile of a short training run (Table I)
//!   resources   resource/fmax report for n-step lookahead PEs (Table IV)
//!   info        manifest + platform summary

use heppo::bench::format_si;
use heppo::coordinator::{Trainer, TrainerConfig};
use heppo::gae::Trajectory;
use heppo::hwsim::{GaeHwSim, ResourceModel, SimConfig};
use heppo::runtime::Runtime;
use heppo::util::cli::Args;
use heppo::util::csv::CsvTable;
use heppo::util::Rng;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("gae-sim") => cmd_gae_sim(&args),
        Some("profile") => cmd_profile(&args),
        Some("resources") => cmd_resources(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: heppo <train|eval|gae-sim|profile|resources|info> [--key value]...\n\
                 examples:\n\
                 \x20 heppo train --env cartpole --iters 100 --codec exp5 --backend hlo\n\
                 \x20 heppo gae-sim --trajectories 64 --timesteps 1024 --rows 64 --lookahead 2\n\
                 \x20 heppo profile --env humanoid_lite --iters 3\n\
                 \x20 heppo resources --pes 64"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let config = TrainerConfig::from_args(args)?;
    println!(
        "training {} for {} iters (codec exp{}, {}-bit, backend {}, pipeline {}, seed {})",
        config.env,
        config.iters,
        config.codec.index(),
        config.quant_bits,
        config.backend.label(),
        config.pipeline.label(),
        config.seed
    );
    let mut trainer = Trainer::new(config)?;
    if let Some(path) = args.opt("load") {
        trainer.load_checkpoint(path)?;
        println!("resumed from {path}");
    }
    let stats = trainer.run()?;
    if let Some(last) = stats.last() {
        println!(
            "done: {} steps, {} episodes, rolling return {:.2}",
            last.steps, last.episodes, last.mean_return
        );
    }
    if let Some(path) = args.opt("save") {
        trainer.save_checkpoint(path)?;
        println!("checkpoint saved to {path}");
    }
    if let Some(out) = args.opt("out") {
        let mut t = CsvTable::new(&["iter", "steps", "mean_return", "pi_loss", "v_loss", "entropy"]);
        for s in &stats {
            t.row(&[
                s.iter.to_string(),
                s.steps.to_string(),
                format!("{:.4}", s.mean_return),
                format!("{:.6}", s.losses.pi_loss),
                format!("{:.6}", s.losses.v_loss),
                format!("{:.6}", s.losses.entropy),
            ]);
        }
        t.save(out)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let mut config = TrainerConfig::from_args(args)?;
    let episodes = args.get_or("episodes", 10usize);
    let mut trainer;
    if let Some(path) = args.opt("load") {
        config.iters = 0;
        trainer = Trainer::new(config)?;
        trainer.load_checkpoint(path)?;
        println!("loaded checkpoint {path}");
    } else {
        config.iters = args.get_or("iters", 20usize);
        trainer = Trainer::new(config)?;
        trainer.run()?;
    }
    let ret = trainer.evaluate(episodes)?;
    println!("greedy eval over {episodes} episodes: mean return {ret:.2}");
    Ok(())
}

fn cmd_gae_sim(args: &Args) -> anyhow::Result<()> {
    let n_traj = args.get_or("trajectories", 64usize);
    let t_len = args.get_or("timesteps", 1024usize);
    let rows = args.get_or("rows", 64usize);
    let lookahead = args.get_or("lookahead", 2usize);
    let mut cfg = SimConfig::paper_default();
    cfg.rows = rows;
    cfg.pe.lookahead = lookahead;
    let sim = GaeHwSim::new(cfg);

    let mut rng = Rng::new(args.get_or("seed", 0u64));
    let trajs: Vec<Trajectory> = (0..n_traj)
        .map(|_| {
            let mut r = vec![0.0f32; t_len];
            let mut v = vec![0.0f32; t_len + 1];
            rng.fill_normal_f32(&mut r);
            rng.fill_normal_f32(&mut v);
            Trajectory::without_dones(r, v)
        })
        .collect();
    let rep = sim.simulate(&trajs);
    println!(
        "workload: {n_traj} trajectories x {t_len} steps = {} elements",
        rep.elements
    );
    println!(
        "rows {rows}, lookahead {lookahead} -> {} cycles @ {} MHz (bubbles {}, xbar {:.2}, util {:.1}%)",
        rep.cycles,
        rep.clock_hz / 1e6,
        rep.bubbles,
        rep.crossbar_factor,
        rep.row_utilization * 100.0
    );
    println!(
        "projected: {} elements/s, wall {:.2} us",
        format_si(rep.elements_per_sec()),
        rep.wall_time().as_secs_f64() * 1e6
    );
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let mut config = TrainerConfig::from_args(args)?;
    config.iters = args.get_or("iters", 3usize);
    let label = format!("{} ({})", config.env, config.backend.label());
    let mut trainer = Trainer::new(config)?;
    trainer.run()?;
    println!("{}", trainer.profiler.to_table(&label).to_markdown());
    println!(
        "GAE share of iteration time: {:.1}%  (paper Table I: ~30% CPU-GPU / ~15% CPU-only)",
        trainer.profiler.gae_fraction() * 100.0
    );
    println!(
        "PS<->PL handshakes: {} (total overhead {:?})",
        trainer.phases.handshakes(),
        trainer.phases.overhead()
    );
    Ok(())
}

fn cmd_resources(args: &Args) -> anyhow::Result<()> {
    let pes = args.get_or("pes", 64usize);
    let model = ResourceModel::default();
    let mut t = CsvTable::new(&[
        "lookahead", "LUTs/PE", "FFs/PE", "DSPs/PE", "total LUTs", "total FFs",
        "total DSPs", "LUT %", "FF %", "DSP %", "fmax MHz",
    ]);
    for k in 1..=4 {
        let p = model.per_pe(k);
        let tot = model.total(k, pes);
        let (ul, uf, ud) = model.utilization(k, pes);
        t.row(&[
            k.to_string(),
            p.luts.to_string(),
            p.ffs.to_string(),
            p.dsps.to_string(),
            tot.luts.to_string(),
            tot.ffs.to_string(),
            tot.dsps.to_string(),
            format!("{:.2}", ul * 100.0),
            format!("{:.2}", uf * 100.0),
            format!("{:.2}", ud * 100.0),
            format!("{:.0}", model.fmax_hz(k) / 1e6),
        ]);
    }
    println!("{}", t.to_markdown());
    println!("(paper Table IV at k=2, 64 PEs: 12864 LUTs / 54336 FFs / 768 DSPs)");
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let rt = Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    let geo = rt.manifest.geometry;
    println!(
        "geometry: {} envs x {} steps, minibatch {}, gamma {}, lambda {}, {}-bit quant",
        geo.num_envs, geo.rollout_t, geo.minibatch, geo.gamma, geo.lambda, geo.quant_bits
    );
    println!("artifacts:");
    for (name, a) in &rt.manifest.artifacts {
        println!(
            "  {name:<28} {} in -> {} out{}",
            a.inputs.len(),
            a.outputs.len(),
            if a.is_blob { "  (blob)" } else { "" }
        );
    }
    Ok(())
}

//! Block standardization of values (paper §II-B).
//!
//! Values come from a trainable critic whose output distribution drifts
//! over training (paper Fig. 2), so a single running standardizer fails
//! ("dynamic standardization of values was unsuccessful as it affected
//! the loss calculations"). Instead each collected block is standardized
//! by its own (μ_v, σ_v):
//!
//! 1. collect a block of values from multiple trajectories;
//! 2. compute μ_v, σ_v of the block;
//! 3. standardize: `(v - μ_v) / σ_v`;
//! 4. uniformly quantize, storing the codewords **with** (μ_v, σ_v);
//! 5. on reconstruction, de-quantize and de-standardize:
//!    `v ≈ q·σ_v + μ_v`.

use super::dynamic_std::STD_FLOOR;

/// Per-block statistics stored alongside the quantized codewords.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockStats {
    pub mean: f32,
    pub std: f32,
}

impl BlockStats {
    /// Compute μ/σ of a block (population σ, matching the paper's reward
    /// path; σ is floored to keep standardization finite for constant
    /// blocks).
    pub fn of(block: &[f32]) -> BlockStats {
        if block.is_empty() {
            return BlockStats { mean: 0.0, std: STD_FLOOR as f32 };
        }
        let n = block.len() as f64;
        let mean = block.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = block
            .iter()
            .map(|&v| (v as f64 - mean).powi(2))
            .sum::<f64>()
            / n;
        BlockStats {
            mean: mean as f32,
            std: (var.sqrt().max(STD_FLOOR)) as f32,
        }
    }

    /// Step 3 — standardize in place.
    pub fn standardize(&self, block: &mut [f32]) {
        for v in block.iter_mut() {
            *v = (*v - self.mean) / self.std;
        }
    }

    /// Step 5 — de-standardize in place ("multiplying the elements back
    /// by the stored standard deviation σ_v and then adding the mean μ_v").
    pub fn destandardize(&self, block: &mut [f32]) {
        for v in block.iter_mut() {
            *v = *v * self.std + self.mean;
        }
    }
}

/// Standardize a block, returning the stats needed for reconstruction.
pub fn block_standardize(block: &mut [f32]) -> BlockStats {
    let stats = BlockStats::of(block);
    stats.standardize(block);
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn standardized_block_has_unit_moments() {
        check("block std moments", 30, |g| {
            let n = g.usize_in(2, 500);
            let mean = g.f64_in(-5.0, 5.0);
            let std = g.f64_in(0.1, 10.0);
            let mut block = g.vec_normal_f32(n, mean, std);
            // Skip degenerate constant blocks (handled by their own test).
            let stats = block_standardize(&mut block);
            assert!(stats.std > 0.0);
            let m = block.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            let s2 = block.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / n as f64;
            assert!(m.abs() < 1e-3, "mean={m}");
            assert!((s2 - 1.0).abs() < 1e-2, "var={s2}");
        });
    }

    #[test]
    fn roundtrip_is_identity() {
        check("std→destd roundtrip", 30, |g| {
            let n = g.usize_in(1, 300);
            let orig = g.vec_normal_f32(n, 3.0, 7.0);
            let mut block = orig.clone();
            let stats = block_standardize(&mut block);
            stats.destandardize(&mut block);
            for (a, b) in block.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn constant_block_is_safe() {
        let mut block = vec![4.2f32; 16];
        let stats = block_standardize(&mut block);
        assert!(block.iter().all(|v| v.is_finite()));
        assert!(block.iter().all(|&v| v.abs() < 1e-3));
        stats.destandardize(&mut block);
        assert!(block.iter().all(|&v| (v - 4.2).abs() < 1e-4));
    }

    #[test]
    fn empty_block() {
        let stats = BlockStats::of(&[]);
        assert_eq!(stats.mean, 0.0);
        assert!(stats.std > 0.0);
    }

    #[test]
    fn distinct_blocks_get_distinct_stats() {
        // The point of *block* standardization (vs global): a late-
        // training block with shifted value distribution gets its own μ/σ.
        let mut g = Gen::new(7);
        let early = g.vec_normal_f32(256, 0.0, 1.0);
        let late = g.vec_normal_f32(256, 50.0, 10.0);
        let s_early = BlockStats::of(&early);
        let s_late = BlockStats::of(&late);
        assert!((s_early.mean - 0.0).abs() < 0.5);
        assert!((s_late.mean - 50.0).abs() < 2.0);
        assert!(s_late.std > 5.0 * s_early.std);
    }
}

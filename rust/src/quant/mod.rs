//! Standardization + quantization — the paper's algorithmic contribution
//! (§II), which makes 8-bit on-chip storage of rewards/values viable.
//!
//! - [`dynamic_std`] — *dynamic standardization* of rewards (§II-A):
//!   a Welford running mean/std over **all rewards ever seen**, so the
//!   relative scale between epochs is preserved (per-epoch standardizing
//!   was found to diverge). Rewards stay standardized afterwards — the
//!   paper reports ≈1.5× cumulative reward from exactly this choice.
//! - [`block_std`] — *block standardization* of values (§II-B): values
//!   come from an evolving critic, so each collected block is
//!   standardized by its own (μ_v, σ_v), quantized, and de-standardized
//!   on reconstruction.
//! - [`uniform`] — n-bit uniform quantization (§II-C) on the standardized
//!   distributions, with sub-byte bit-packing for memory accounting.
//! - [`codec`] — the five end-to-end configurations of Table III
//!   (Experiments 1–5) behind one trait, so the trainer and the Fig. 10
//!   bench can swap them freely.
//!
//! # Numerics observability
//!
//! Quantization here is *observed*, not assumed: wherever an f32 plane
//! and its coded image are both in hand — the wire plane encoder and
//! decoder, and [`RewardValueCodec::transform_observed`] — the stack
//! fills a [`crate::obs::numerics::PlaneNumerics`] (reconstruction
//! error, end-code saturation, code utilization, and the block (μ,σ)
//! that sat between the representations) and feeds it to the windowed
//! accumulators in [`crate::obs::numerics`]. Saturation past the
//! Chebyshev-derived thresholds or upward σ-drift pages through the
//! fleet health chain; the per-tenant/per-window rows ride
//! `GET /metrics` and the wire metrics RPC. See the module docs on
//! [`crate::obs`] for the full plane.

pub mod block_std;
pub mod codec;
pub mod dynamic_std;
pub mod uniform;

pub use block_std::BlockStats;
pub use codec::{CodecKind, RewardValueCodec};
pub use dynamic_std::DynamicStandardizer;
pub use uniform::UniformQuantizer;

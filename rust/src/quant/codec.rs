//! End-to-end reward/value storage codecs — the five configurations of
//! paper Table III ("Overview of Experiment Attributes"), used by the
//! trainer and the Fig. 10 bench.
//!
//! | Exp | Rewards | Values | Quantized |
//! |-----|---------|--------|-----------|
//! | 1 | — (baseline PPO) | — | no |
//! | 2 | dynamic std. | — | no |
//! | 3 | block std. **with** de-std. | block std. with de-std. | both, 8-bit |
//! | 4 | block std. **no** de-std. | block std. with de-std. | both, 8-bit |
//! | 5 | dynamic std. (kept standardized) | block std. with de-std. | both, 8-bit |
//!
//! The paper's findings: Exp 4 performs poorly (keeping *block*-
//! standardized rewards loses cross-epoch scale), while Exp 5 — dynamic
//! standardization for rewards + block quantization for values — is best
//! and is what the HEPPO-GAE hardware implements.

use super::block_std::{block_standardize, BlockStats};
use super::dynamic_std::DynamicStandardizer;
use super::uniform::UniformQuantizer;
use crate::obs::numerics::PlaneNumerics;

/// Which Table III experiment configuration to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// Experiment 1: baseline PPO, rewards/values stored as f32.
    Exp1Baseline,
    /// Experiment 2: dynamic standardization of rewards, no quantization.
    Exp2DynamicStd,
    /// Experiment 3: block std + n-bit quant for rewards (de-standardized
    /// after load) and values.
    Exp3BlockDestd,
    /// Experiment 4: like 3 but rewards stay in block-standardized form.
    Exp4BlockKeepStd,
    /// Experiment 5 (the paper's pick): dynamic std for rewards (kept
    /// standardized) + block std for values; both n-bit quantized.
    Exp5DynamicBlock,
}

impl CodecKind {
    pub fn all() -> [CodecKind; 5] {
        [
            CodecKind::Exp1Baseline,
            CodecKind::Exp2DynamicStd,
            CodecKind::Exp3BlockDestd,
            CodecKind::Exp4BlockKeepStd,
            CodecKind::Exp5DynamicBlock,
        ]
    }

    /// Paper experiment index (1-based).
    pub fn index(&self) -> usize {
        match self {
            CodecKind::Exp1Baseline => 1,
            CodecKind::Exp2DynamicStd => 2,
            CodecKind::Exp3BlockDestd => 3,
            CodecKind::Exp4BlockKeepStd => 4,
            CodecKind::Exp5DynamicBlock => 5,
        }
    }

    pub fn parse(s: &str) -> Option<CodecKind> {
        match s {
            "exp1" | "baseline" => Some(CodecKind::Exp1Baseline),
            "exp2" | "dynamic" => Some(CodecKind::Exp2DynamicStd),
            "exp3" => Some(CodecKind::Exp3BlockDestd),
            "exp4" => Some(CodecKind::Exp4BlockKeepStd),
            "exp5" | "heppo" => Some(CodecKind::Exp5DynamicBlock),
            _ => None,
        }
    }
}

/// Memory accounting for one encoded block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecReport {
    /// Bits per stored reward element.
    pub reward_bits_per_elem: usize,
    /// Bits per stored value element.
    pub value_bits_per_elem: usize,
    /// Fixed per-block side information (μ/σ pairs), bits.
    pub block_overhead_bits: usize,
}

impl CodecReport {
    /// Total stored bits for a block of `n` rewards + `n` values.
    pub fn total_bits(&self, n: usize) -> usize {
        n * (self.reward_bits_per_elem + self.value_bits_per_elem) + self.block_overhead_bits
    }

    /// Reduction factor vs f32/f32 storage.
    pub fn reduction_vs_f32(&self, n: usize) -> f64 {
        (n * 64) as f64 / self.total_bits(n) as f64
    }
}

/// Stateful reward/value codec implementing all five experiments.
///
/// `transform` applies the full store→load round trip **in place**: after
/// it returns, `rewards`/`values` hold exactly what the GAE stage would
/// read back from BRAM under that experiment's configuration.
#[derive(Debug, Clone)]
pub struct RewardValueCodec {
    pub kind: CodecKind,
    /// Quantizer bit width (paper sweeps 3–10; 8 is the operating point).
    pub bits: u8,
    dynamic: DynamicStandardizer,
}

impl RewardValueCodec {
    pub fn new(kind: CodecKind, bits: u8) -> Self {
        RewardValueCodec { kind, bits, dynamic: DynamicStandardizer::new() }
    }

    /// The paper's operating point for a kind (8-bit).
    pub fn paper(kind: CodecKind) -> Self {
        Self::new(kind, 8)
    }

    /// Shared running-reward statistics (Exp 2/5) for inspection.
    pub fn dynamic_stats(&self) -> &DynamicStandardizer {
        &self.dynamic
    }

    /// Apply the store→load round trip in place and return the memory
    /// accounting for this block.
    pub fn transform(&mut self, rewards: &mut [f32], values: &mut [f32]) -> CodecReport {
        let q = UniformQuantizer::new(self.bits);
        match self.kind {
            CodecKind::Exp1Baseline => CodecReport {
                reward_bits_per_elem: 32,
                value_bits_per_elem: 32,
                block_overhead_bits: 0,
            },
            CodecKind::Exp2DynamicStd => {
                self.dynamic.absorb_and_standardize(rewards);
                CodecReport {
                    reward_bits_per_elem: 32,
                    value_bits_per_elem: 32,
                    block_overhead_bits: 0,
                }
            }
            CodecKind::Exp3BlockDestd => {
                let rs = block_standardize(rewards);
                q.roundtrip_all(rewards);
                rs.destandardize(rewards);
                let vs = block_standardize(values);
                q.roundtrip_all(values);
                vs.destandardize(values);
                CodecReport {
                    reward_bits_per_elem: self.bits as usize,
                    value_bits_per_elem: self.bits as usize,
                    block_overhead_bits: 2 * 64, // two (μ,σ) f32 pairs
                }
            }
            CodecKind::Exp4BlockKeepStd => {
                let _rs = block_standardize(rewards);
                q.roundtrip_all(rewards); // no de-standardization
                let vs = block_standardize(values);
                q.roundtrip_all(values);
                vs.destandardize(values);
                CodecReport {
                    reward_bits_per_elem: self.bits as usize,
                    value_bits_per_elem: self.bits as usize,
                    block_overhead_bits: 64, // only the value (μ,σ) must be kept
                }
            }
            CodecKind::Exp5DynamicBlock => {
                self.dynamic.absorb_and_standardize(rewards);
                q.roundtrip_all(rewards); // stays in dynamically standardized form
                let vs = block_standardize(values);
                q.roundtrip_all(values);
                vs.destandardize(values);
                CodecReport {
                    reward_bits_per_elem: self.bits as usize,
                    value_bits_per_elem: self.bits as usize,
                    block_overhead_bits: 64,
                }
            }
        }
    }

    /// [`Self::transform`] plus post-hoc quantization-health
    /// measurement: the originals are copied before the in-place round
    /// trip, then each quantized plane's codes are re-derived against
    /// the standardization stats that sat between the representations
    /// and folded into a [`PlaneNumerics`]. Unquantized planes (Exp 1
    /// and 2, which store f32) measure as `None`.
    ///
    /// Reconstruction error lands in the units the trainer reads back:
    /// de-standardized planes (values everywhere, Exp 3 rewards) scale
    /// the per-element error by the block σ; planes kept in
    /// standardized form (Exp 4/5 rewards) report it unscaled.
    pub fn transform_observed(
        &mut self,
        rewards: &mut [f32],
        values: &mut [f32],
    ) -> (CodecReport, Option<PlaneNumerics>, Option<PlaneNumerics>) {
        match self.kind {
            CodecKind::Exp1Baseline | CodecKind::Exp2DynamicStd => {
                (self.transform(rewards, values), None, None)
            }
            CodecKind::Exp3BlockDestd
            | CodecKind::Exp4BlockKeepStd
            | CodecKind::Exp5DynamicBlock => {
                let q = UniformQuantizer::new(self.bits);
                let r0 = rewards.to_vec();
                let v0 = values.to_vec();
                let report = self.transform(rewards, values);
                let (r_mean, r_std, r_destd) = match self.kind {
                    CodecKind::Exp3BlockDestd => {
                        let s = BlockStats::of(&r0);
                        (s.mean, s.std, true)
                    }
                    CodecKind::Exp4BlockKeepStd => {
                        let s = BlockStats::of(&r0);
                        (s.mean, s.std, false)
                    }
                    // Dynamic standardization absorbed the block before
                    // standardizing it, so the post-transform running
                    // stats are exactly what the plane was divided by.
                    _ => (self.dynamic.mean() as f32, self.dynamic.std() as f32, false),
                };
                let r_pn = PlaneNumerics::measure(&r0, rewards, &q, r_mean, r_std, r_destd);
                let vs = BlockStats::of(&v0);
                let v_pn = PlaneNumerics::measure(&v0, values, &q, vs.mean, vs.std, true);
                (report, Some(r_pn), Some(v_pn))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn exp1_is_identity() {
        let mut codec = RewardValueCodec::paper(CodecKind::Exp1Baseline);
        let mut r = vec![1.0f32, -2.0, 3.0];
        let mut v = vec![0.5f32, 0.6, 0.7];
        let (r0, v0) = (r.clone(), v.clone());
        let rep = codec.transform(&mut r, &mut v);
        assert_eq!(r, r0);
        assert_eq!(v, v0);
        assert!((rep.reduction_vs_f32(3) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn exp5_reaches_4x_reduction() {
        let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
        let mut g = Gen::new(1);
        let mut r = g.vec_normal_f32(64 * 1024, 0.0, 2.0);
        let mut v = g.vec_normal_f32(64 * 1024, 1.0, 3.0);
        let rep = codec.transform(&mut r, &mut v);
        let red = rep.reduction_vs_f32(64 * 1024);
        assert!(red > 3.99 && red <= 4.0, "reduction={red}");
    }

    #[test]
    fn exp3_values_return_near_original_scale() {
        check("exp3 value reconstruction", 20, |g| {
            let n = g.usize_in(16, 512);
            let mut codec = RewardValueCodec::paper(CodecKind::Exp3BlockDestd);
            let mean = g.f64_in(-20.0, 20.0);
            let std = g.f64_in(0.5, 10.0);
            let orig_v = g.vec_normal_f32(n, mean, std);
            let mut v = orig_v.clone();
            let mut r = g.vec_normal_f32(n, 0.0, 1.0);
            codec.transform(&mut r, &mut v);
            // 8-bit in standardized space: error <= step/2 * sigma_block
            let tol = UniformQuantizer::new(8).max_in_range_error() * (std * 1.6) as f32 + 1e-3;
            for (a, b) in v.iter().zip(&orig_v) {
                assert!((a - b).abs() <= tol, "{a} vs {b} tol={tol}");
            }
        });
    }

    #[test]
    fn exp5_rewards_stay_standardized() {
        let mut codec = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
        let mut g = Gen::new(2);
        let mut r = g.vec_normal_f32(5000, 100.0, 10.0); // far from zero
        let mut v = g.vec_normal_f32(5000, 0.0, 1.0);
        codec.transform(&mut r, &mut v);
        let m = r.iter().map(|&x| x as f64).sum::<f64>() / r.len() as f64;
        assert!(m.abs() < 0.2, "rewards should be ~zero-mean, got {m}");
    }

    #[test]
    fn exp4_rewards_lose_scale_across_epochs() {
        // The failure the paper observed: with *block* standardization and
        // no de-std, an epoch of bigger rewards looks identical to a small
        // one after the codec.
        let mut codec = RewardValueCodec::paper(CodecKind::Exp4BlockKeepStd);
        let mut g = Gen::new(3);
        let mut small = g.vec_normal_f32(2000, 1.0, 0.5);
        let mut big = g.vec_normal_f32(2000, 50.0, 0.5);
        let mut v1 = g.vec_normal_f32(2000, 0.0, 1.0);
        let mut v2 = g.vec_normal_f32(2000, 0.0, 1.0);
        codec.transform(&mut small, &mut v1);
        codec.transform(&mut big, &mut v2);
        let m_small = small.iter().map(|&x| x as f64).sum::<f64>() / 2000.0;
        let m_big = big.iter().map(|&x| x as f64).sum::<f64>() / 2000.0;
        assert!((m_small - m_big).abs() < 0.1, "block-std erased the scale difference");

        // Contrast: exp5's dynamic standardizer preserves the ordering.
        let mut codec5 = RewardValueCodec::paper(CodecKind::Exp5DynamicBlock);
        let mut small = g.vec_normal_f32(2000, 1.0, 0.5);
        let mut big = g.vec_normal_f32(2000, 50.0, 0.5);
        codec5.transform(&mut small, &mut v1);
        codec5.transform(&mut big, &mut v2);
        let m_small = small.iter().map(|&x| x as f64).sum::<f64>() / 2000.0;
        let m_big = big.iter().map(|&x| x as f64).sum::<f64>() / 2000.0;
        assert!(m_big > m_small + 0.5, "dynamic std must preserve epoch ordering");
    }

    #[test]
    fn bit_width_controls_error() {
        // Error shrinks monotonically (roughly 2x per bit) across the
        // Fig. 8/9 sweep range.
        let mut g = Gen::new(4);
        let orig = g.vec_normal_f32(4096, 0.0, 1.0);
        let mut errs = Vec::new();
        for bits in [3u8, 4, 6, 8, 10] {
            let mut codec = RewardValueCodec::new(CodecKind::Exp5DynamicBlock, bits);
            let mut r = orig.clone();
            let mut v = orig.clone();
            codec.transform(&mut r, &mut v);
            // Compare values (round-tripped to original scale).
            let err: f64 = v
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / orig.len() as f64;
            errs.push(err);
        }
        for w in errs.windows(2) {
            assert!(w[1] < w[0], "error must shrink with more bits: {errs:?}");
        }
    }

    #[test]
    fn transform_observed_measures_quantized_planes() {
        let mut g = Gen::new(7);
        let r0 = g.vec_normal_f32(4096, 0.0, 2.0);
        let v0 = g.vec_normal_f32(4096, 5.0, 3.0);

        // Unquantized kinds measure nothing but transform identically.
        let mut plain = RewardValueCodec::paper(CodecKind::Exp2DynamicStd);
        let mut observed = RewardValueCodec::paper(CodecKind::Exp2DynamicStd);
        let (mut r_a, mut v_a) = (r0.clone(), v0.clone());
        let (mut r_b, mut v_b) = (r0.clone(), v0.clone());
        let rep_a = plain.transform(&mut r_a, &mut v_a);
        let (rep_b, r_pn, v_pn) = observed.transform_observed(&mut r_b, &mut v_b);
        assert_eq!(rep_a, rep_b);
        assert_eq!(r_a, r_b);
        assert!(r_pn.is_none() && v_pn.is_none());

        // Quantized kinds: identical planes out, sane measurements.
        for kind in [
            CodecKind::Exp3BlockDestd,
            CodecKind::Exp4BlockKeepStd,
            CodecKind::Exp5DynamicBlock,
        ] {
            let mut plain = RewardValueCodec::paper(kind);
            let mut observed = RewardValueCodec::paper(kind);
            let (mut r_a, mut v_a) = (r0.clone(), v0.clone());
            let (mut r_b, mut v_b) = (r0.clone(), v0.clone());
            plain.transform(&mut r_a, &mut v_a);
            let (_, r_pn, v_pn) = observed.transform_observed(&mut r_b, &mut v_b);
            assert_eq!(r_a, r_b, "{kind:?} rewards must match plain transform");
            assert_eq!(v_a, v_b, "{kind:?} values must match plain transform");
            let (r_pn, v_pn) = (r_pn.unwrap(), v_pn.unwrap());
            assert_eq!(r_pn.elements, 4096);
            assert_eq!(v_pn.elements, 4096);
            assert!(r_pn.err_measured && v_pn.err_measured);
            // Gaussian data inside ±5σ: low saturation, real error.
            assert!(r_pn.saturation_rate() < 0.01, "{kind:?}");
            assert!(v_pn.sum_sq_err > 0.0 && v_pn.max_abs_err > 0.0);
            assert!(v_pn.codes_used() > 64, "{kind:?} should use many codes");
            // Value error is in de-standardized units — bounded by
            // step/2 · σ_block for every in-range element.
            if v_pn.clipped == 0 {
                let tol =
                    UniformQuantizer::new(8).max_in_range_error() * v_pn.std.abs() + 1e-4;
                assert!(v_pn.max_abs_err <= tol, "{} vs {tol}", v_pn.max_abs_err);
            }
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(CodecKind::parse("exp5"), Some(CodecKind::Exp5DynamicBlock));
        assert_eq!(CodecKind::parse("heppo"), Some(CodecKind::Exp5DynamicBlock));
        assert_eq!(CodecKind::parse("baseline"), Some(CodecKind::Exp1Baseline));
        assert_eq!(CodecKind::parse("nope"), None);
        for k in CodecKind::all() {
            assert_eq!(k.index() >= 1 && k.index() <= 5, true);
        }
    }
}

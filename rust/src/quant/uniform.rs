//! n-bit uniform quantization (paper §II-C) with sub-byte bit-packing.
//!
//! Standardized data (≈ N(0,1)) is mapped to `2^n` evenly spaced levels
//! over a clip range `[-R, R]`. The paper sweeps n = 3..10 (Figs. 8–9)
//! and concludes n ≥ 8 is the stable threshold; n = 8 with in-place
//! storage yields the headline 4× memory reduction (32-bit → 8-bit).
//!
//! Codewords are held in `u16` (n ≤ 16) for processing and bit-packed
//! tightly for storage accounting; the BRAM model consumes
//! [`UniformQuantizer::bits_for`] when sizing memory.

/// Uniform quantizer over `[-range, range]` with `2^bits` levels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UniformQuantizer {
    pub bits: u8,
    /// Half-width of the representable range (in σ units for
    /// standardized data). The paper does not publish its value; ±5σ
    /// clips < 0.0001% of a standard normal while keeping step size
    /// small, and is our default.
    pub range: f32,
}

/// Default clip range (σ units).
pub const DEFAULT_RANGE: f32 = 5.0;

impl UniformQuantizer {
    pub fn new(bits: u8) -> Self {
        Self::with_range(bits, DEFAULT_RANGE)
    }

    pub fn with_range(bits: u8, range: f32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        assert!(range > 0.0);
        UniformQuantizer { bits, range }
    }

    /// Number of levels `2^bits`.
    #[inline]
    pub fn levels(&self) -> u32 {
        1u32 << self.bits
    }

    /// Quantization step Δ.
    #[inline]
    pub fn step(&self) -> f32 {
        2.0 * self.range / (self.levels() - 1) as f32
    }

    /// Quantize one value to a codeword (clamped at the range ends).
    #[inline]
    pub fn quantize(&self, x: f32) -> u16 {
        let clamped = x.clamp(-self.range, self.range);
        let code = ((clamped + self.range) / self.step()).round();
        code as u16
    }

    /// De-quantize one codeword.
    #[inline]
    pub fn dequantize(&self, code: u16) -> f32 {
        -self.range + code as f32 * self.step()
    }

    /// Quantize a slice.
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<u16> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// De-quantize a slice.
    pub fn dequantize_all(&self, codes: &[u16]) -> Vec<f32> {
        codes.iter().map(|&c| self.dequantize(c)).collect()
    }

    /// Quantize-then-dequantize (the value the training loop actually
    /// sees after a BRAM round trip).
    pub fn roundtrip(&self, x: f32) -> f32 {
        self.dequantize(self.quantize(x))
    }

    pub fn roundtrip_all(&self, xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = self.roundtrip(*x);
        }
    }

    /// Worst-case round-trip error for in-range inputs: Δ/2.
    pub fn max_in_range_error(&self) -> f32 {
        self.step() / 2.0
    }

    /// Storage cost of `n` codewords, in bits (tight packing).
    pub fn bits_for(&self, n: usize) -> usize {
        n * self.bits as usize
    }

    /// Pack codewords tightly, LSB-first.
    ///
    /// Perf (§Perf log): the byte-aligned widths take dedicated paths —
    /// 8-bit (the paper's operating point) is a straight cast, 16-bit a
    /// byte split; odd widths stream through a 64-bit shift register
    /// rather than per-bit RMW.
    pub fn pack(&self, codes: &[u16]) -> Vec<u8> {
        let bits = self.bits as usize;
        if bits == 8 {
            return codes.iter().map(|&c| c as u8).collect();
        }
        if bits == 16 {
            return codes.iter().flat_map(|c| c.to_le_bytes()).collect();
        }
        let total = codes.len() * bits;
        let mut out = Vec::with_capacity(total.div_ceil(8));
        let mut acc: u64 = 0;
        let mut filled = 0usize;
        for &c in codes {
            debug_assert!((c as u32) < self.levels());
            acc |= (c as u64) << filled;
            filled += bits;
            while filled >= 8 {
                out.push(acc as u8);
                acc >>= 8;
                filled -= 8;
            }
        }
        if filled > 0 {
            out.push(acc as u8);
        }
        out
    }

    /// Unpack `n` codewords from a tight bitstream.
    pub fn unpack(&self, bytes: &[u8], n: usize) -> Vec<u16> {
        let bits = self.bits as usize;
        assert!(bytes.len() * 8 >= n * bits, "bitstream too short");
        if bits == 8 {
            return bytes[..n].iter().map(|&b| b as u16).collect();
        }
        if bits == 16 {
            return bytes[..2 * n]
                .chunks_exact(2)
                .map(|c| u16::from_le_bytes([c[0], c[1]]))
                .collect();
        }
        let mask: u64 = (1u64 << bits) - 1;
        let mut out = Vec::with_capacity(n);
        let mut acc: u64 = 0;
        let mut filled = 0usize;
        let mut next = 0usize;
        for _ in 0..n {
            while filled < bits {
                acc |= (bytes[next] as u64) << filled;
                next += 1;
                filled += 8;
            }
            out.push((acc & mask) as u16);
            acc >>= bits;
            filled -= bits;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        check("roundtrip error <= step/2", 50, |g| {
            let bits = g.usize_in(3, 10) as u8;
            let q = UniformQuantizer::new(bits);
            let x = g.f32_in(-q.range, q.range);
            let err = (q.roundtrip(x) - x).abs();
            assert!(
                err <= q.max_in_range_error() + 1e-6,
                "bits={bits} x={x} err={err} max={}",
                q.max_in_range_error()
            );
        });
    }

    #[test]
    fn codes_in_level_range() {
        check("codes < 2^bits", 50, |g| {
            let bits = g.usize_in(1, 10) as u8;
            let q = UniformQuantizer::new(bits);
            let x = g.f32_in(-100.0, 100.0); // includes out-of-range
            let c = q.quantize(x);
            assert!((c as u32) < q.levels());
        });
    }

    #[test]
    fn out_of_range_clamps_to_ends() {
        let q = UniformQuantizer::new(8);
        assert_eq!(q.quantize(-100.0), 0);
        assert_eq!(q.quantize(100.0), (q.levels() - 1) as u16);
        assert!((q.dequantize(0) + q.range).abs() < 1e-6);
        assert!((q.dequantize((q.levels() - 1) as u16) - q.range).abs() < 1e-6);
    }

    #[test]
    fn eight_bit_error_is_small_for_standardized_data() {
        // The paper's operating point: standardized (≈N(0,1)) data at 8
        // bits must round-trip with tiny relative error.
        let q = UniformQuantizer::new(8);
        let mut g = Gen::new(3);
        let xs = g.vec_normal_f32(10_000, 0.0, 1.0);
        let mut max_err = 0.0f32;
        for &x in &xs {
            max_err = max_err.max((q.roundtrip(x) - x).abs());
        }
        // step = 10/255 ≈ 0.0392 ⇒ max error ≈ 0.0196
        assert!(max_err < 0.02, "max_err={max_err}");
    }

    #[test]
    fn three_bit_error_is_coarse() {
        // The other end of the Fig. 8 sweep.
        let q = UniformQuantizer::new(3);
        assert!(q.step() > 1.0); // 10/7 ≈ 1.43
    }

    #[test]
    fn pack_unpack_roundtrip() {
        check("pack/unpack roundtrip", 40, |g| {
            let bits = g.usize_in(1, 10) as u8;
            let q = UniformQuantizer::new(bits);
            let n = g.usize_in(0, 200);
            let codes: Vec<u16> = (0..n)
                .map(|_| g.usize_in(0, (q.levels() - 1) as usize) as u16)
                .collect();
            let packed = q.pack(&codes);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8));
            let unpacked = q.unpack(&packed, n);
            assert_eq!(unpacked, codes);
        });
    }

    #[test]
    fn memory_reduction_vs_f32_is_4x_at_8_bits() {
        // The headline claim: 32-bit float → 8-bit codeword = 4×.
        let q = UniformQuantizer::new(8);
        let n = 64 * 1024;
        let f32_bits = n * 32;
        assert_eq!(f32_bits / q.bits_for(n), 4);
    }

    #[test]
    fn quantizer_is_monotonic() {
        check("quantize monotonic", 30, |g| {
            let q = UniformQuantizer::new(g.usize_in(2, 10) as u8);
            let a = g.f32_in(-6.0, 6.0);
            let b = g.f32_in(-6.0, 6.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(q.quantize(lo) <= q.quantize(hi));
        });
    }
}

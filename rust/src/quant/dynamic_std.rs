//! Dynamic standardization of rewards (paper §II-A, Eq. 6–9).
//!
//! At every training epoch the incoming rewards are standardized using a
//! running mean and running std maintained over **all rewards processed
//! so far** (not just the current epoch): per-epoch standardization
//! "disrupt[s] the relative differences in reward distributions between
//! epochs", which the paper observed to diverge. The stream statistics
//! are updated by Welford's algorithm (shared with [`crate::stats`]).
//!
//! Rewards standardized this way are *kept* in standardized form — the
//! paper's Experiment 5 finding (Table III / Fig. 10) — so this type has
//! no de-standardize path; contrast [`super::block_std`].

use crate::stats::Welford;

/// Floor on σ to avoid division blow-ups before statistics accumulate.
pub const STD_FLOOR: f64 = 1e-6;

/// Running reward standardizer.
#[derive(Debug, Clone, Default)]
pub struct DynamicStandardizer {
    stats: Welford,
}

impl DynamicStandardizer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Update the running statistics with one reward — Eq. (6)–(8).
    #[inline]
    pub fn update(&mut self, r: f64) {
        self.stats.push(r);
    }

    /// Standardize one reward with the *current* statistics.
    #[inline]
    pub fn standardize(&self, r: f64) -> f64 {
        (r - self.stats.mean()) / self.stats.std_population().max(STD_FLOOR)
    }

    /// Update-then-standardize, the per-element streaming operation the
    /// hardware performs as rewards arrive.
    #[inline]
    pub fn push(&mut self, r: f64) -> f64 {
        self.update(r);
        self.standardize(r)
    }

    /// Standardize a batch in place after absorbing it into the stream
    /// (epoch-granularity operation used by the trainer).
    pub fn absorb_and_standardize(&mut self, rewards: &mut [f32]) {
        self.stats.push_all(rewards);
        let mean = self.stats.mean() as f32;
        let inv = (1.0 / self.std()) as f32;
        for r in rewards.iter_mut() {
            *r = (*r - mean) * inv;
        }
    }

    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    /// Running std, Eq. (9) (population form, exactly as the paper).
    pub fn std(&self) -> f64 {
        self.stats.std_population().max(STD_FLOOR)
    }

    /// Merge a worker's local stream statistics (parallel collection).
    pub fn merge(&mut self, other: &DynamicStandardizer) {
        self.stats.merge(&other.stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Gen};

    #[test]
    fn stationary_stream_converges_to_unit_scale() {
        let mut ds = DynamicStandardizer::new();
        let mut g = Gen::new(1);
        // Burn in the statistics.
        for _ in 0..20_000 {
            ds.update(g.rng().normal_with(10.0, 3.0));
        }
        // Freshly standardized samples should be ≈ N(0, 1).
        let mut w = crate::stats::Welford::new();
        for _ in 0..20_000 {
            let r = g.rng().normal_with(10.0, 3.0);
            w.push(ds.push(r));
        }
        assert!(w.mean().abs() < 0.05, "mean={}", w.mean());
        assert!((w.std_population() - 1.0).abs() < 0.05, "std={}", w.std_population());
    }

    #[test]
    fn history_is_preserved_across_epochs() {
        // The defining property vs per-epoch standardization: an epoch of
        // uniformly larger rewards must stay larger after standardization.
        let mut ds = DynamicStandardizer::new();
        let mut g = Gen::new(2);
        let epoch1: Vec<f64> = (0..2000).map(|_| g.rng().normal_with(1.0, 0.5)).collect();
        let epoch2: Vec<f64> = (0..2000).map(|_| g.rng().normal_with(5.0, 0.5)).collect();
        let s1: Vec<f64> = epoch1.iter().map(|&r| ds.push(r)).collect();
        let s2: Vec<f64> = epoch2.iter().map(|&r| ds.push(r)).collect();
        let m1 = s1.iter().sum::<f64>() / s1.len() as f64;
        let m2 = s2.iter().sum::<f64>() / s2.len() as f64;
        assert!(
            m2 > m1 + 1.0,
            "epoch-2 rewards must remain clearly larger: {m1} vs {m2}"
        );
    }

    #[test]
    fn per_epoch_standardization_erases_history() {
        // Control for the test above: independent per-epoch z-scoring
        // maps both epochs to ≈0 mean — the failure mode the paper avoids.
        let mut g = Gen::new(3);
        let zscore = |xs: &[f64]| {
            let n = xs.len() as f64;
            let m = xs.iter().sum::<f64>() / n;
            let s = (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n).sqrt();
            xs.iter().map(|x| (x - m) / s).collect::<Vec<_>>()
        };
        let epoch1: Vec<f64> = (0..2000).map(|_| g.rng().normal_with(1.0, 0.5)).collect();
        let epoch2: Vec<f64> = (0..2000).map(|_| g.rng().normal_with(5.0, 0.5)).collect();
        let m1 = zscore(&epoch1).iter().sum::<f64>() / 2000.0;
        let m2 = zscore(&epoch2).iter().sum::<f64>() / 2000.0;
        assert!(m1.abs() < 1e-9 && m2.abs() < 1e-9);
    }

    #[test]
    fn early_stream_is_finite() {
        let mut ds = DynamicStandardizer::new();
        let s = ds.push(0.0);
        assert!(s.is_finite());
        let s = ds.push(0.0); // zero variance
        assert!(s.is_finite());
    }

    #[test]
    fn absorb_matches_streaming() {
        check("absorb == stream", 20, |g| {
            let n = g.usize_in(1, 200);
            let raw: Vec<f32> = g.vec_normal_f32(n, 2.0, 4.0);
            let mut a = DynamicStandardizer::new();
            let mut batch = raw.clone();
            a.absorb_and_standardize(&mut batch);
            // Streaming variant updates all then standardizes all with the
            // final stats — equivalent by construction; verify against a
            // manual implementation.
            let mut b = DynamicStandardizer::new();
            for &r in &raw {
                b.update(r as f64);
            }
            for (i, &r) in raw.iter().enumerate() {
                let want = b.standardize(r as f64) as f32;
                assert!((batch[i] - want).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn merge_workers_equals_global_stream() {
        let mut g = Gen::new(5);
        let xs: Vec<f64> = (0..3000).map(|_| g.rng().normal_with(0.5, 2.0)).collect();
        let mut global = DynamicStandardizer::new();
        for &x in &xs {
            global.update(x);
        }
        let mut w1 = DynamicStandardizer::new();
        let mut w2 = DynamicStandardizer::new();
        for &x in &xs[..1000] {
            w1.update(x);
        }
        for &x in &xs[1000..] {
            w2.update(x);
        }
        w1.merge(&w2);
        assert!((w1.mean() - global.mean()).abs() < 1e-9);
        assert!((w1.std() - global.std()).abs() < 1e-9);
    }
}
